"""PR 2 API surface: the `Aligner` facade lifecycle, the versioned
mmap-backed index store, the IndexBuilder/SearchIndex split, and the
deprecation shims that keep the pre-split entry points alive."""

import json

import numpy as np
import pytest

from repro.api import Aligner, AlignerConfig
from repro.core import (IndexBuilder, SearchIndex, batch_query, load_index,
                        make_scheme, query, save_index, scheme_from_spec,
                        scheme_spec)
from repro.core.sharded_index import ShardedAlignmentIndex
from repro.core.weights import WeightFn


def _corpus(rng, n_docs=8, vocab=40, n=60):
    docs = [rng.integers(0, vocab, size=n).astype(np.int64)
            for _ in range(n_docs)]
    if n_docs > 5:
        docs[5] = docs[2].copy()                  # planted duplicate
    return docs


def _blocks(results):
    return [(a.text_id, a.blocks) for a in results]


def _batch_blocks(res):
    return [_blocks(r) for r in res]


SIMS = ["multiset", "weighted", "tfidf"]


# --------------------------------------------------------------------------
# Aligner end-to-end
# --------------------------------------------------------------------------

@pytest.mark.parametrize("similarity", SIMS)
def test_aligner_end_to_end(similarity):
    rng = np.random.default_rng(0)
    docs = _corpus(rng)
    a = Aligner.build(docs, similarity=similarity, k=8, seed=3)
    hits = a.find(docs[2][5:50], 0.5)
    assert {h.text_id for h in hits} >= {2, 5}
    batch = a.find_batch([docs[2][5:50], docs[0][:30]], 0.5)
    assert _blocks(batch[0]) == _blocks(hits)
    # freeze and serve: identical results from the CSR layout
    a.freeze()
    assert a.is_frozen
    assert _batch_blocks(a.find_batch([docs[2][5:50], docs[0][:30]], 0.5)) \
        == _batch_blocks(batch)
    with pytest.raises(RuntimeError):
        a.add(docs[0])


def test_aligner_add_then_find():
    rng = np.random.default_rng(1)
    docs = _corpus(rng, n_docs=4)
    a = Aligner.build(docs[:3], similarity="multiset", k=8)
    assert a.add(docs[3]) == 3
    assert a.num_docs == 4
    assert any(h.text_id == 3 for h in a.find(docs[3][5:50], 0.5))


def test_aligner_on_strings_with_default_tokenizer():
    corpus = ["the quick brown fox jumps over the lazy dog",
              "completely unrelated words about pallas kernels",
              "the quick brown fox jumps over a sleepy dog"]
    a = Aligner.build(corpus, similarity="tfidf", k=16)
    hits = a.find("the quick brown fox jumps", 0.5)
    assert {h.text_id for h in hits} >= {0, 2}


def test_aligner_config_object():
    rng = np.random.default_rng(2)
    docs = _corpus(rng, n_docs=4)
    cfg = AlignerConfig(similarity="weighted", k=4, tf="log")
    a = Aligner.build(docs, config=cfg)
    assert a.config.k == 4 and a.scheme.k == 4
    assert a.scheme.weight.tf == "log"


# --------------------------------------------------------------------------
# versioned mmap-backed store
# --------------------------------------------------------------------------

@pytest.mark.parametrize("similarity", ["multiset", "tfidf"])
def test_mmap_roundtrip_block_identical(tmp_path, similarity):
    rng = np.random.default_rng(3)
    docs = _corpus(rng)
    qs = [docs[2][5:50], docs[0][:30],
          rng.integers(1000, 1040, 20).astype(np.int64)]       # + a miss
    a = Aligner.build(docs, similarity=similarity, k=8, seed=7)
    in_memory = _batch_blocks(a.find_batch(qs, 0.5))
    a.save(tmp_path / "idx")

    served = Aligner.load(tmp_path / "idx", mmap=True)
    assert _batch_blocks(served.find_batch(qs, 0.5)) == in_memory
    # the table arrays are memory-mapped, not materialized copies
    assert served._index.is_mmap()
    for t in served._index.tables:
        for arr in (t.keys, t.offsets, t.windows):
            if arr.size:
                assert isinstance(arr, np.memmap)

    # and the non-mmap load agrees too
    ram = Aligner.load(tmp_path / "idx", mmap=False)
    assert _batch_blocks(ram.find_batch(qs, 0.5)) == in_memory
    assert not ram._index.is_mmap()


def test_sharded_aligner_mmap_roundtrip(tmp_path):
    rng = np.random.default_rng(4)
    docs = _corpus(rng, n_docs=9)
    qs = [docs[2][5:50], docs[7][:30]]
    a = Aligner.build(docs, similarity="multiset", k=8, shards=3, seed=9)
    expected = _batch_blocks(a.find_batch(qs, 0.5))
    a.save(tmp_path / "idx")
    served = Aligner.load(tmp_path / "idx", mmap=True)
    assert served.config.shards == 3
    assert _batch_blocks(served.find_batch(qs, 0.5)) == expected
    for shard in served._index.shards:
        assert shard.is_mmap()


def test_unknown_manifest_version_rejected(tmp_path):
    rng = np.random.default_rng(5)
    a = Aligner.build(_corpus(rng, n_docs=3), similarity="multiset", k=4)
    a.save(tmp_path / "idx")
    mpath = tmp_path / "idx" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["format_version"] = 99
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="format version"):
        Aligner.load(tmp_path / "idx")


def test_store_save_load_functions_direct(tmp_path):
    rng = np.random.default_rng(6)
    docs = _corpus(rng, n_docs=4)
    scheme = make_scheme("weighted", seed=1, k=8, tf="raw")
    search = IndexBuilder(scheme=scheme).build(docs).freeze()
    save_index(search, tmp_path / "s", doc_map=[10, 11, 12, 13])
    loaded = load_index(tmp_path / "s", mmap=True)
    assert loaded.num_texts == 4 and loaded.method == search.method
    q = docs[1][5:40]
    assert _blocks(query(loaded, q, 0.5)) == _blocks(query(search, q, 0.5))
    manifest = json.loads((tmp_path / "s" / "manifest.json").read_text())
    assert manifest["doc_map"] == [10, 11, 12, 13]
    assert manifest["text_lengths"] == [len(d) for d in docs]


def test_scheme_spec_roundtrip():
    for scheme in (make_scheme("multiset", seed=3, k=8, family="mix"),
                   make_scheme("weighted", seed=5, k=4, tf="log"),
                   make_scheme("tfidf", seed=7, k=4,
                               corpus=[[1, 2, 2], [2, 3]])):
        clone = scheme_from_spec(json.loads(json.dumps(scheme_spec(scheme))))
        toks = np.array([1, 2, 2, 3, 1], np.int64)
        assert clone.sketch(toks) == scheme.sketch(toks)


# --------------------------------------------------------------------------
# builder / search split
# --------------------------------------------------------------------------

def test_builder_stays_usable_after_freeze():
    rng = np.random.default_rng(7)
    docs = _corpus(rng, n_docs=4)
    builder = IndexBuilder(scheme=make_scheme("multiset", seed=2, k=8))
    builder.build(docs[:3])
    search = builder.freeze()
    assert isinstance(search, SearchIndex) and search.is_frozen
    assert not builder.is_frozen
    builder.add_text(docs[3])                    # no personality switch
    assert builder.num_texts == 4 and search.num_texts == 3
    assert not hasattr(search, "add_text")       # immutability by omission
    assert search.freeze() is search


def test_weightfn_fit_counts_doc_frequencies():
    docs = [np.array([1, 1, 2], np.int64), np.array([2, 3], np.int64)]
    w = WeightFn.fit(docs, tf="raw", idf="smooth")
    assert w.n_docs == 2
    assert w.doc_freq == {1: 1, 2: 2, 3: 1}
    assert w(np.array([1]), np.array([1]))[0] > 0


def test_make_scheme_rejects_unknown_similarity():
    with pytest.raises(ValueError, match="unknown similarity"):
        make_scheme("cosine")
    with pytest.raises(ValueError, match="tfidf"):
        make_scheme("tfidf")                     # needs corpus or weight


# --------------------------------------------------------------------------
# sharded persistence migration + satellites
# --------------------------------------------------------------------------

def test_sharded_restore_shard_count_mismatch_raises_value_error(tmp_path):
    rng = np.random.default_rng(8)
    docs = _corpus(rng, n_docs=6)
    scheme = make_scheme("multiset", seed=1, k=4)
    ShardedAlignmentIndex(scheme=scheme, n_shards=3).build(docs) \
        .save(tmp_path)
    other = ShardedAlignmentIndex(scheme=scheme, n_shards=4)
    with pytest.raises(ValueError, match="shard-count mismatch"):
        other.restore(tmp_path)


def test_sharded_frozen_save_uses_versioned_store(tmp_path):
    rng = np.random.default_rng(9)
    docs = _corpus(rng, n_docs=6)
    scheme = make_scheme("multiset", seed=1, k=4)
    idx = ShardedAlignmentIndex(scheme=scheme, n_shards=2).build(docs)
    idx.freeze()
    idx.save(tmp_path)
    assert (tmp_path / "shard_0" / "manifest.json").exists()
    assert not (tmp_path / "shard_0.pkl").exists()
    restored = ShardedAlignmentIndex(scheme=scheme, n_shards=2)
    assert restored.restore(tmp_path, mmap=True) == []
    assert all(s.is_mmap() for s in restored.shards)
    q = docs[2][5:50]
    assert _batch_blocks(restored.batch_query([q], 0.5)) == \
        _batch_blocks(idx.batch_query([q], 0.5))


def test_sharded_store_writes_scheme_once_at_root(tmp_path):
    rng = np.random.default_rng(12)
    docs = _corpus(rng, n_docs=6)
    a = Aligner.build(docs, similarity="tfidf", k=4, shards=2)
    a.save(tmp_path / "idx")
    meta = json.loads((tmp_path / "idx" / "meta.json").read_text())
    assert meta["scheme"]["kind"] == "weighted"
    assert meta["scheme"]["weight"]["doc_freq"]          # fitted stats
    for s in range(2):
        shard = json.loads(
            (tmp_path / "idx" / f"shard_{s}" / "manifest.json").read_text())
        assert shard["scheme"] is None                   # not duplicated
    served = Aligner.load(tmp_path / "idx", mmap=True)
    q = docs[2][5:50]
    assert _batch_blocks(served.find_batch([q], 0.5)) == \
        _batch_blocks(a.find_batch([q], 0.5))


def test_sharded_add_after_freeze_raises_without_corrupting_doc_map():
    rng = np.random.default_rng(13)
    docs = _corpus(rng, n_docs=6)
    idx = ShardedAlignmentIndex(scheme=make_scheme("multiset", seed=1, k=4),
                                n_shards=2).build(docs)
    idx.freeze()
    with pytest.raises(RuntimeError, match="frozen"):
        idx.add_text(docs[0])
    assert len(idx.doc_map) == len(docs)                 # no partial append


def test_resave_over_existing_store_is_clean(tmp_path):
    rng = np.random.default_rng(14)
    docs = _corpus(rng, n_docs=4)
    a = Aligner.build(docs, similarity="multiset", k=4)
    a.save(tmp_path / "idx")
    a.save(tmp_path / "idx")                             # overwrite in place
    served = Aligner.load(tmp_path / "idx")
    q = docs[1][5:40]
    assert _batch_blocks(served.find_batch([q], 0.5)) == \
        _batch_blocks(a.find_batch([q], 0.5))


def test_loaded_config_round_trips_scheme_knobs(tmp_path):
    rng = np.random.default_rng(15)
    docs = _corpus(rng, n_docs=3)
    Aligner.build(docs, similarity="multiset", k=4, family="mix") \
        .save(tmp_path / "m")
    assert Aligner.load(tmp_path / "m").config.family == "mix"
    Aligner.build(docs, similarity="weighted", k=4, tf="log") \
        .save(tmp_path / "w")
    cfg = Aligner.load(tmp_path / "w").config
    assert cfg.tf == "log" and cfg.idf == "unary"


def test_string_query_without_tokenizer_raises():
    rng = np.random.default_rng(16)
    a = Aligner.build(_corpus(rng, n_docs=3), similarity="multiset", k=4)
    with pytest.raises(ValueError, match="tokenizer"):
        a.find("a string query", 0.5)


def test_sharded_inverse_doc_map_cached_and_invalidated():
    rng = np.random.default_rng(10)
    docs = _corpus(rng, n_docs=6)
    idx = ShardedAlignmentIndex(scheme=make_scheme("multiset", seed=1, k=4),
                                n_shards=2).build(docs)
    inv1 = idx._inverse_doc_map()
    assert idx._inverse_doc_map() is inv1        # cached between queries
    idx.add_text(docs[0])
    inv2 = idx._inverse_doc_map()
    assert inv2 is not inv1 and len(inv2) == len(docs) + 1


# --------------------------------------------------------------------------
# deprecation shims
# --------------------------------------------------------------------------

def test_legacy_entry_points_importable_and_working():
    # the pre-split surface keeps importing from repro.core — except the
    # AlignmentIndex shim, whose package re-export is now gone (the shim
    # itself stays importable from its home module one release longer)
    from repro.core import (FrozenTable, MultisetScheme,
                            ShardedAlignmentIndex, WeightedScheme, WeightFn)
    from repro.core.index import AlignmentIndex   # repro: allow[RPR403]
    from repro.data import default_scheme
    import repro.core
    assert not hasattr(repro.core, "AlignmentIndex")
    assert isinstance(default_scheme("weighted", k=4).weight, WeightFn)
    assert isinstance(default_scheme("multiset", k=4), MultisetScheme)
    assert isinstance(make_scheme("weighted", k=4), WeightedScheme)
    assert FrozenTable is not None and ShardedAlignmentIndex is not None

    rng = np.random.default_rng(11)
    docs = _corpus(rng, n_docs=4)
    with pytest.warns(DeprecationWarning):
        idx = AlignmentIndex(scheme=MultisetScheme(seed=1, k=8))  # repro: allow[RPR403]
    idx.build(docs)
    looped = _blocks(query(idx, docs[2][5:50], 0.5))
    idx.freeze()
    assert idx.is_frozen and idx.tables == [] and idx.frozen is not None
    assert _batch_blocks(batch_query(idx, [docs[2][5:50]], 0.5)) == [looped]
    with pytest.raises(RuntimeError):
        idx.add_text(docs[0])
