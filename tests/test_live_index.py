"""PR 5 live incremental serve: `LiveIndex` (frozen mmap store + mutable
delta), columnar merge-compaction into promoted store generations, the
sharded per-shard deltas with process fan-out, and crash-safety of
promotion (an interrupted compaction must never corrupt serving)."""

import shutil
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro import fault
from repro.api import Aligner
from repro.core import (IndexBuilder, QueryOptions, ShardedAlignmentIndex,
                        batch_query, make_scheme, query, save_index)
from repro.core import store as index_store
from repro.core.live import LiveIndex
from repro.core.store import (CURRENT_POINTER, current_generation,
                              promote_generation, resolve_store)

SIMS = ["multiset", "tfidf"]


def _corpus(rng, n_docs=8, vocab=40, n=60):
    docs = [rng.integers(0, vocab, size=n).astype(np.int64)
            for _ in range(n_docs)]
    if n_docs > 5:
        docs[5] = docs[2].copy()                  # planted duplicate
    return docs


def _scheme(similarity, docs):
    kw = {"corpus": docs} if similarity == "tfidf" else {}
    return make_scheme(similarity, seed=5, k=8, **kw)


def _blocks(results):
    return [(a.text_id, a.blocks) for a in results]


def _batch_blocks(res):
    return [_blocks(r) for r in res]


def _save_flat(scheme, docs, path):
    save_index(IndexBuilder(scheme=scheme).build(docs).freeze(), path)


def _delta_docs(rng, base, n=3):
    docs = [rng.integers(0, 40, size=60).astype(np.int64) for _ in range(n)]
    docs[-1] = base[2].copy()                     # near-dup into the delta
    return docs


def _queries(rng, base, delta):
    return [base[2][5:50], delta[-1][:30],
            rng.integers(1000, 1040, 20).astype(np.int64)]     # + a miss


# --------------------------------------------------------------------------
# LiveIndex == from-scratch build of the union corpus (the core contract)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("similarity", SIMS)
@pytest.mark.parametrize("mmap", [True, False])
def test_live_matches_scratch_build(tmp_path, similarity, mmap):
    rng = np.random.default_rng(0)
    base = _corpus(rng)
    scheme = _scheme(similarity, base)
    _save_flat(scheme, base, tmp_path / "idx")

    live = LiveIndex.open(tmp_path / "idx", mmap=mmap)
    delta = _delta_docs(rng, base)
    for t in delta:
        live.add_text(t)
    assert live.num_texts == len(base) + len(delta)
    assert live.delta_fraction == pytest.approx(3 / 11)

    oracle = IndexBuilder(scheme=scheme).build(base + delta)
    qs = _queries(rng, base, delta)
    expected = _batch_blocks(batch_query(oracle, qs, 0.5))
    # frozen + delta merge, before compaction
    assert _batch_blocks(live.batch_query(qs, 0.5)) == expected
    # the single-query path agrees too
    assert _blocks(live.query(qs[0], 0.5)) == \
        _blocks(query(oracle, qs[0], 0.5))

    gen = live.compact()
    assert gen == 1 and live.generation == 1
    assert live.delta.num_texts == 0 and live.frozen.num_texts == 11
    assert _batch_blocks(live.batch_query(qs, 0.5)) == expected

    # a fresh reader resolves the promoted generation
    again = LiveIndex.open(tmp_path / "idx", mmap=mmap)
    assert again.generation == 1
    assert again.frozen.is_mmap() == mmap
    assert _batch_blocks(again.batch_query(qs, 0.5)) == expected

    # second round over the compacted base: add more, still block-identical
    more = _delta_docs(rng, base, n=2)
    for t in more:
        again.add_text(t)
    oracle2 = IndexBuilder(scheme=scheme).build(base + delta + more)
    expected2 = _batch_blocks(batch_query(oracle2, qs, 0.5))
    assert _batch_blocks(again.batch_query(qs, 0.5)) == expected2
    again.compact()
    assert again.generation == 2
    assert _batch_blocks(again.batch_query(qs, 0.5)) == expected2


@pytest.mark.parametrize("probe_backend", ["numpy", "percoord"])
def test_live_probe_backends_agree(tmp_path, probe_backend):
    rng = np.random.default_rng(2)
    base = _corpus(rng)
    scheme = _scheme("multiset", base)
    _save_flat(scheme, base, tmp_path / "idx")
    live = LiveIndex.open(tmp_path / "idx")
    delta = _delta_docs(rng, base)
    for t in delta:
        live.add_text(t)
    qs = _queries(rng, base, delta)
    oracle = IndexBuilder(scheme=scheme).build(base + delta)
    assert _batch_blocks(
        live.batch_query(qs, 0.5,
                         options=QueryOptions(probe_backend=probe_backend))) == \
        _batch_blocks(batch_query(oracle, qs, 0.5))


def test_live_compacted_store_identical_to_scratch_store(tmp_path):
    """The compacted generation's arrays are bit-identical to freezing a
    from-scratch build of the union corpus (not just result-identical)."""
    rng = np.random.default_rng(3)
    base = _corpus(rng)
    scheme = _scheme("multiset", base)
    _save_flat(scheme, base, tmp_path / "idx")
    live = LiveIndex.open(tmp_path / "idx")
    delta = _delta_docs(rng, base)
    for t in delta:
        live.add_text(t)
    live.compact()

    scratch = IndexBuilder(scheme=scheme).build(base + delta).freeze()
    for ta, tb in zip(live.frozen.tables, scratch.tables):
        assert ta.kind == tb.kind and ta.kint_min == tb.kint_min
        assert np.array_equal(ta.keys, tb.keys)
        assert np.array_equal(ta.offsets, tb.offsets)
        assert np.array_equal(ta.windows, tb.windows)
    aa, ab = live.frozen.arena(), scratch.arena()
    assert aa.mode == ab.mode
    assert np.array_equal(aa.keys, ab.keys)
    assert np.array_equal(aa.offsets, ab.offsets)
    assert np.array_equal(aa.windows, ab.windows)


def test_live_freeze_merges_in_memory(tmp_path):
    rng = np.random.default_rng(4)
    base = _corpus(rng)
    scheme = _scheme("multiset", base)
    _save_flat(scheme, base, tmp_path / "idx")
    live = LiveIndex.open(tmp_path / "idx")
    delta = _delta_docs(rng, base)
    for t in delta:
        live.add_text(t)
    merged = live.freeze()
    assert merged.is_frozen and merged.num_texts == 11
    # the on-disk store is untouched (no generation written)
    assert current_generation(tmp_path / "idx") == 0
    qs = _queries(rng, base, delta)
    assert _batch_blocks(batch_query(merged, qs, 0.5)) == \
        _batch_blocks(live.batch_query(qs, 0.5))


# --------------------------------------------------------------------------
# sharded live serving (per-shard deltas, process-pool compaction)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("similarity", SIMS)
@pytest.mark.parametrize("fanout,mmap", [("process", True),
                                         ("serial", False)])
def test_sharded_live_matches_scratch(tmp_path, similarity, fanout, mmap):
    rng = np.random.default_rng(5)
    base = _corpus(rng, n_docs=9)
    a = Aligner.build(base, similarity=similarity, k=8, seed=5, shards=3)
    a.save(tmp_path / "sh")

    live = Aligner.load(tmp_path / "sh", live=True, mmap=mmap)
    delta = _delta_docs(rng, base, n=4)
    assert [live.add(t) for t in delta] == [9, 10, 11, 12]

    oracle = ShardedAlignmentIndex(scheme=live.scheme, n_shards=3)
    for t in base + delta:
        oracle.add_text(t)
    qs = _queries(rng, base, delta)
    expected = _batch_blocks(oracle.batch_query(qs, 0.5))
    assert _batch_blocks(live.find_batch(qs, 0.5)) == expected

    live.compact(fanout=fanout)
    assert all(s.generation == 1 and s.delta.num_texts == 0
               for s in live._index.shards)
    assert _batch_blocks(live.find_batch(qs, 0.5)) == expected

    # both reader modes see the promoted generations
    for live_reload in (True, False):
        again = Aligner.load(tmp_path / "sh", live=live_reload, mmap=mmap)
        assert again.num_docs == 13
        assert _batch_blocks(again.find_batch(qs, 0.5)) == expected


def test_sharded_restore_remaps_doc_ids_via_store_manifests(tmp_path):
    """The per-shard store manifests are authoritative for global doc ids:
    a shard compacted (with new docs) after meta.json was written still
    restores correctly — no contiguity assumption on shard-local ids."""
    rng = np.random.default_rng(6)
    base = _corpus(rng, n_docs=9)
    a = Aligner.build(base, similarity="multiset", k=8, seed=6, shards=3)
    a.save(tmp_path / "sh")
    stale_meta = (tmp_path / "sh" / "meta.json").read_bytes()

    live = Aligner.load(tmp_path / "sh", live=True)
    delta = _delta_docs(rng, base, n=4)
    for t in delta:
        live.add(t)
    live.compact()
    qs = _queries(rng, base, delta)
    expected = _batch_blocks(live.find_batch(qs, 0.5))

    # simulate the crash window between shard promotion and the root
    # meta.json rewrite: the stale meta knows nothing of the delta docs
    (tmp_path / "sh" / "meta.json").write_bytes(stale_meta)  # repro: allow[RPR203]
    again = Aligner.load(tmp_path / "sh", live=True)
    assert again.num_docs == 13          # rebuilt from the shard manifests
    assert _batch_blocks(again.find_batch(qs, 0.5)) == expected


def test_sharded_live_save_snapshots_merged_store(tmp_path):
    rng = np.random.default_rng(7)
    base = _corpus(rng, n_docs=9)
    a = Aligner.build(base, similarity="multiset", k=8, seed=7, shards=3)
    a.save(tmp_path / "sh")
    live = Aligner.load(tmp_path / "sh", live=True)
    delta = _delta_docs(rng, base, n=4)
    for t in delta:
        live.add(t)
    qs = _queries(rng, base, delta)
    expected = _batch_blocks(live.find_batch(qs, 0.5))
    live.save(tmp_path / "snap")                  # frozen+delta, one pass
    served = Aligner.load(tmp_path / "snap")
    assert served.num_docs == 13
    assert _batch_blocks(served.find_batch(qs, 0.5)) == expected
    # the snapshot did not disturb the serving aligner: still live, delta
    # intact, still taking writes
    assert all(getattr(s, "is_live", False) for s in live._index.shards)
    assert live.add(_delta_docs(rng, base, n=1)[0]) == 13
    assert _batch_blocks(live.find_batch(qs, 0.5)) != []


# --------------------------------------------------------------------------
# promotion crash-safety & rollback
# --------------------------------------------------------------------------

def _live_with_delta(tmp_path, rng):
    base = _corpus(rng)
    scheme = _scheme("multiset", base)
    _save_flat(scheme, base, tmp_path / "idx")
    live = LiveIndex.open(tmp_path / "idx")
    delta = _delta_docs(rng, base)
    for t in delta:
        live.add_text(t)
    return base, delta, live


def _compaction_site_schedule():
    """Enumerate every fsio fault checkpoint one compaction of the
    reference corpus hits, as ``(site, occurrence)`` pairs — recorded
    once at collection time so the sweep below parametrizes over ALL of
    them (new fsio call sites in the compaction path are swept
    automatically; hand-picked kill sites can't rot)."""
    tmp = Path(tempfile.mkdtemp())
    try:
        rng = np.random.default_rng(8)
        _base, _delta, live = _live_with_delta(tmp, rng)
        with fault.record_sites() as sites:
            assert live.compact() == 1
        return sorted(set(sites))
    finally:
        shutil.rmtree(tmp)


_COMPACTION_SITES = _compaction_site_schedule()


@pytest.mark.parametrize(
    "site,hit", _COMPACTION_SITES,
    ids=[f"{s}@{h}" for s, h in _COMPACTION_SITES])
def test_interrupted_compaction_preserves_serving(tmp_path, site, hit):
    """Fail compaction at EVERY fsio checkpoint it crosses — array
    writes, manifest tmp/rename, pointer tmp/rename: the serving
    generation must be untouched, a fresh reader must load it
    identically, and retrying the compaction must succeed."""
    rng = np.random.default_rng(8)
    base, delta, live = _live_with_delta(tmp_path, rng)
    qs = _queries(rng, base, delta)
    expected_live = _batch_blocks(live.batch_query(qs, 0.5))
    frozen_before = _batch_blocks(
        batch_query(live.frozen, qs, 0.5))

    plan = fault.FaultPlan(triggers=[fault.Trigger(site=site, hit=hit)])
    with fault.armed(plan):
        with pytest.raises(fault.FaultInjected):
            live.compact()

    # the pointer never flipped; pre-promote failures leave no manifest
    root = tmp_path / "idx"
    assert current_generation(root) == 0
    assert resolve_store(root) == root
    if not site.startswith("store.promote"):
        assert not (root / "v000001" / "manifest.json").exists()
    # the live index kept the docs (delta restored, or still sealed when
    # the failure hit after the merge) and still serves the union
    if site.startswith("store.promote"):
        assert live.sealed is not None
        assert live.sealed.num_texts == len(delta)
    else:
        assert live.sealed is None
        assert live.delta.num_texts == len(delta)
    assert _batch_blocks(live.batch_query(qs, 0.5)) == expected_live
    # a fresh (non-live) reader serves the old generation, bit-for-bit
    reader = Aligner.load(root)
    assert _batch_blocks(reader.find_batch(qs, 0.5)) == frozen_before

    # retry converges: a clean commit over (or past) the aborted dir
    gen = live.compact()
    assert gen >= 1
    assert current_generation(root) == gen
    assert _batch_blocks(live.batch_query(qs, 0.5)) == expected_live


def test_promote_refuses_manifestless_generation(tmp_path):
    rng = np.random.default_rng(9)
    _base, _delta, live = _live_with_delta(tmp_path, rng)
    root = tmp_path / "idx"
    (root / "v000001").mkdir()                 # aborted write: arrays only
    with pytest.raises(ValueError, match="no manifest"):
        promote_generation(root, 1)
    with pytest.raises(ValueError, match="generation 0"):
        promote_generation(root, 0)
    # a hand-corrupted pointer is rejected loudly, not served stale
    (root / CURRENT_POINTER).write_text("v000042")  # repro: allow[RPR202,RPR203]
    with pytest.raises(ValueError, match="v000042"):
        resolve_store(root)
    (root / CURRENT_POINTER).unlink()  # repro: allow[RPR203] (fixture reset)
    assert live.compact() == 1                 # still compacts cleanly


def test_rollback_to_retained_generation(tmp_path):
    rng = np.random.default_rng(10)
    base, delta, live = _live_with_delta(tmp_path, rng)
    root = tmp_path / "idx"
    qs = _queries(rng, base, delta)
    live.compact()                             # gen 1 = base + delta
    gen1 = _batch_blocks(Aligner.load(root).find_batch(qs, 0.5))
    for t in _delta_docs(rng, base, n=2):
        live.add_text(t)
    live.compact()                             # gen 2 = gen1 + 2 docs
    assert current_generation(root) == 2

    promote_generation(root, 1)                # operator rollback
    assert current_generation(root) == 1
    assert _batch_blocks(Aligner.load(root).find_batch(qs, 0.5)) == gen1
    rolled = LiveIndex.open(root)
    assert rolled.frozen.num_texts == len(base) + len(delta)


def test_compact_with_empty_delta_is_noop(tmp_path):
    """Nothing to fold in -> no new generation (a timer-driven compactor
    must not duplicate the whole corpus on every tick)."""
    rng = np.random.default_rng(16)
    base = _corpus(rng)
    scheme = _scheme("multiset", base)
    _save_flat(scheme, base, tmp_path / "idx")
    live = LiveIndex.open(tmp_path / "idx")
    assert live.compact() == 0
    assert not (tmp_path / "idx" / "v000001").exists()
    live.add_text(base[2].copy())
    assert live.compact() == 1
    assert live.compact() == 1                 # empty again: still a no-op
    assert not (tmp_path / "idx" / "v000002").exists()

    # sharded: only the shard that actually took a write is compacted
    a = Aligner.build(base, similarity="multiset", k=8, seed=16, shards=3)
    a.save(tmp_path / "sh")
    sh = Aligner.load(tmp_path / "sh", live=True)
    sh.compact()                               # all deltas empty: no-op
    assert all(s.generation == 0 for s in sh._index.shards)
    gid = sh.add(base[2].copy())               # lands in shard gid % 3
    sh.compact()
    gens = [s.generation for s in sh._index.shards]
    assert gens[gid % 3] == 1
    assert sum(gens) == 1                      # untouched shards stayed put
    hits = sh.find(base[2][5:50], 0.5)
    assert {h.text_id for h in hits} >= {2, 5, gid}


def test_compact_after_rollback_never_renumbers_retained_gen(tmp_path):
    """A promoted generation is immutable: after a rollback, the next
    compaction takes a FRESH number instead of rewriting the rolled-off
    version (whose arrays may still be mmap'd by running readers)."""
    rng = np.random.default_rng(12)
    base, _delta, live = _live_with_delta(tmp_path, rng)
    root = tmp_path / "idx"
    live.compact()                             # v000001
    for t in _delta_docs(rng, base, n=2):
        live.add_text(t)
    live.compact()                             # v000002
    v2_manifest = (root / "v000002" / "manifest.json").read_bytes()

    promote_generation(root, 1)                # rollback
    rolled = LiveIndex.open(root)
    for t in _delta_docs(rng, base, n=1):
        rolled.add_text(t)
    assert rolled.compact() == 3               # not 2!
    assert (root / "v000002" / "manifest.json").read_bytes() == v2_manifest
    assert current_generation(root) == 3


def test_live_save_refuses_overwriting_served_store(tmp_path):
    rng = np.random.default_rng(13)
    base, _delta, live_idx = _live_with_delta(tmp_path, rng)
    root = tmp_path / "idx"
    live = Aligner.load(root, live=True)
    live.add(base[2].copy())
    with pytest.raises(RuntimeError, match="serving from"):
        live.save(root)
    # sharded: same refusal on the shared store root
    a = Aligner.build(base, similarity="multiset", k=8, seed=13, shards=2)
    a.save(tmp_path / "sh")
    sh = Aligner.load(tmp_path / "sh", live=True)
    sh.add(base[2].copy())
    with pytest.raises(RuntimeError, match="serving from"):
        sh.save(tmp_path / "sh")
    del live_idx


def test_live_save_retires_stale_pointer_at_target(tmp_path):
    """Snapshotting onto a directory that used to be a versioned live
    store must retire its CURRENT pointer — otherwise the old generation
    silently shadows the fresh flat snapshot on reload."""
    rng = np.random.default_rng(14)
    base, _delta, old_live = _live_with_delta(tmp_path, rng)
    target = tmp_path / "idx"
    old_live.compact()                         # target now has CURRENT
    assert current_generation(target) == 1

    fresh_docs = _corpus(np.random.default_rng(15), n_docs=6)
    b = Aligner.build(fresh_docs, similarity="multiset", k=8, seed=14)
    b.save(tmp_path / "b")
    live_b = Aligner.load(tmp_path / "b", live=True)
    live_b.add(fresh_docs[1].copy())
    qs = [fresh_docs[1][:40]]
    expected = _batch_blocks(live_b.find_batch(qs, 0.5))
    live_b.save(target)                        # different store: allowed
    assert not (target / CURRENT_POINTER).exists()
    served = Aligner.load(target)
    assert served.num_docs == 7
    assert _batch_blocks(served.find_batch(qs, 0.5)) == expected


def test_store_helpers_and_is_index_store(tmp_path):
    rng = np.random.default_rng(11)
    _base, _delta, live = _live_with_delta(tmp_path, rng)
    root = tmp_path / "idx"
    assert index_store.is_index_store(root)
    live.compact()
    assert index_store.is_index_store(root)
    assert resolve_store(root) == root / "v000001"
    # read_manifest follows the pointer: the serving manifest has 11 texts
    assert index_store.read_manifest(root)["num_texts"] == 11
    assert not index_store.is_index_store(tmp_path / "nowhere")
