"""Exact reproduction of the paper's running example (Fig. 1, Examples 1-9).

T = ABABAABBCC with the toy hash function from the Fig. 1 caption.
"""

import numpy as np
import pytest

from repro.core import (allalign_partition, generate_keys_multiset,
                        jaccard_multiset, minhash_gid_grid_multiset,
                        monotonic_partition, validate_partition)

HMAP = {(0, 1): 2, (0, 2): 5, (0, 3): 8, (0, 4): 12,
        (1, 1): 9, (1, 2): 4, (1, 3): 16, (1, 4): 1,
        (2, 1): 3, (2, 2): 6}


class ToyHash:
    def __call__(self, t, x):
        t = np.atleast_1d(np.asarray(t))
        x = np.atleast_1d(np.asarray(x))
        return np.array([HMAP[(int(a), int(b))] for a, b in zip(t, x)],
                        dtype=np.uint64)


@pytest.fixture
def example():
    tok = {"A": 0, "B": 1, "C": 2}
    tokens = np.array([tok[ch] for ch in "ABABAABBCC"], dtype=np.int64)
    return tokens, ToyHash()


def test_example_1_multiset_jaccard():
    # T = ABBC, S = BCD -> J = 2/5
    t = np.array([0, 1, 1, 2])
    s = np.array([1, 2, 3])
    assert jaccard_multiset(t, s) == pytest.approx(2 / 5)


def test_example_2_minhash_of_T(example):
    tokens, h = example
    grid, table = minhash_gid_grid_multiset(tokens, h)
    assert table[grid[0, 9]] == 1          # h(T) = h(B,4) = 1
    assert table[grid[2, 5]] == 2          # h(T[3,6]) = 2 (Example 6)


def test_example_7_key_counts(example):
    tokens, h = example
    keys_all = generate_keys_multiset(tokens, h, active=False)
    keys_act = generate_keys_multiset(tokens, h, active=True)
    assert len(keys_all) == 23             # Example 7: 23 keys in K(T)
    assert len(keys_act) == 14             # Fig 1(e): 14 active keys


def test_example_7_key_1_3_hash(example):
    tokens, h = example
    keys = generate_keys_multiset(tokens, h, active=False)
    # key (1,3) 1-indexed -> (0,2): hash h(A, f(A, T[1,3])) = h(A,2) = 5
    mask = (keys.p == 0) & (keys.q == 2)
    assert mask.sum() == 1
    gid = int(keys.gid[np.flatnonzero(mask)[0]])
    assert keys.gid_key[gid] == 5


def test_example_9_monotonic_partitioning(example):
    tokens, h = example
    keys = generate_keys_multiset(tokens, h, active=False)
    # first visited key is (2,8) (1-indexed) with hash value 1
    assert (int(keys.p[0]) + 1, int(keys.q[0]) + 1) == (2, 8)
    part = monotonic_partition(keys)
    assert len(part) == 13                 # Fig 1(b): 13 compact windows
    # first window is <T, h, 1, 1, 2, 8, 10> (1-indexed)
    first = (int(part.a[0]) + 1, int(part.b[0]) + 1,
             int(part.c[0]) + 1, int(part.d[0]) + 1)
    assert first == (1, 2, 8, 10)
    assert part.gid_key[int(part.gid[0])] == 1


def test_example_4_compact_window_covers_hash(example):
    tokens, h = example
    grid, table = minhash_gid_grid_multiset(tokens, h)
    # <T,h,1,1,2,8,10>: all (i,j) in [1,2]x[8,10] (1-indexed) have minhash 1
    assert all(table[grid[i, j]] == 1 for i in range(0, 2) for j in range(7, 10))
    # <T,h,2,4,5,5,10>: minhash 2
    assert all(table[grid[i, j]] == 2 for i in range(3, 5) for j in range(4, 10))


def test_partitions_validate_and_agree(example):
    tokens, h = example
    grid, table = minhash_gid_grid_multiset(tokens, h)
    k_all = generate_keys_multiset(tokens, h, active=False)
    k_act = generate_keys_multiset(tokens, h, active=True)
    p_all = monotonic_partition(k_all)
    p_act = monotonic_partition(k_act)
    validate_partition(p_all, grid, table)
    validate_partition(p_act, grid, table)
    # §6.1: active optimization does not change generated windows
    for f in ("a", "b", "c", "d", "gid"):
        va, vb = getattr(p_all, f), getattr(p_act, f)
        if f == "gid":
            va = [p_all.gid_key[int(g)] for g in va]
            vb = [p_act.gid_key[int(g)] for g in vb]
            assert va == vb
        else:
            assert np.array_equal(va, vb)
    p_alla = allalign_partition(k_all)
    validate_partition(p_alla, grid, table)


def test_total_coverage_count(example):
    tokens, h = example
    part = monotonic_partition(generate_keys_multiset(tokens, h, active=True))
    n = len(tokens)
    assert part.covered_cells() == n * (n + 1) // 2
