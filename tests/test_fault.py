"""The seeded fault-injection harness (:mod:`repro.fault`): trigger
matching and modes, the fsio indirection's failure semantics (torn
writes, error ordering), env-var arming, and degraded-mode shard fan-out
(the end of the blast radius: one failing shard must cost its docs, not
the query)."""

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro import fault
from repro.core import ShardedAlignmentIndex, make_scheme
from repro.fault import FaultInjected, FaultPlan, Trigger, fsio

# --------------------------------------------------------------------------
# checkpoints, triggers, modes
# --------------------------------------------------------------------------


def test_checkpoint_is_a_noop_when_disarmed():
    assert fault.active_plan() is None
    assert fault.checkpoint("any.site") is None
    assert fault.stats()["armed"] is False


def test_trigger_fires_on_its_occurrence_only():
    plan = FaultPlan(triggers=[Trigger(site="w.x", hit=2)])
    with fault.armed(plan):
        fault.checkpoint("w.x")                      # hit 1: passes
        with pytest.raises(FaultInjected) as ei:
            fault.checkpoint("w.x")                  # hit 2: fires
        assert ei.value.site == "w.x" and ei.value.hit == 2
        fault.checkpoint("w.x")                      # hit 3: passes again
    assert fault.active_plan() is None               # context disarms


def test_sticky_trigger_keeps_firing():
    plan = FaultPlan(triggers=[Trigger(site="w.x", hit=2, sticky=True)])
    with fault.armed(plan):
        fault.checkpoint("w.x")
        for _ in range(3):
            with pytest.raises(FaultInjected):
                fault.checkpoint("w.x")


def test_glob_site_patterns_match():
    plan = FaultPlan(triggers=[Trigger(site="store.writer.*")])
    with fault.armed(plan):
        fault.checkpoint("store.promote.rename")     # no match
        with pytest.raises(FaultInjected):
            fault.checkpoint("store.writer.manifest.tmp_write")


def test_slow_mode_delays_but_succeeds():
    fault.reset_stats()                  # counters are process-global
    plan = FaultPlan(triggers=[Trigger(site="s", mode="slow",
                                       delay_s=0.05)])
    with fault.armed(plan):
        t0 = time.perf_counter()
        assert fault.checkpoint("s") is None
        assert time.perf_counter() - t0 >= 0.04
    st = fault.stats()
    assert st["injected"] == 1 and st["by_mode"].get("slow") == 1


def test_plan_json_roundtrip_and_validation():
    plan = FaultPlan(triggers=[Trigger(site="a", hit=3, mode="torn",
                                       sticky=True)], seed=7)
    back = FaultPlan.from_json(plan.to_json())
    assert back == plan
    with pytest.raises(ValueError):
        Trigger(site="a", mode="nope")
    with pytest.raises(ValueError):
        Trigger(site="a", hit=0)


def test_record_sites_reports_ordered_occurrences():
    with fault.record_sites() as sites:
        fault.checkpoint("a")
        fault.checkpoint("b")
        fault.checkpoint("a")
    assert sites == [("a", 1), ("b", 1), ("a", 2)]
    assert fault.checkpoint("a") is None             # recorder detached


def test_env_var_arms_a_child_process():
    plan = FaultPlan(triggers=[Trigger(site="child.site")])
    code = ("from repro import fault\n"
            "assert fault.active_plan() is not None\n"
            "try:\n"
            "    fault.checkpoint('child.site')\n"
            "except fault.FaultInjected:\n"
            "    print('FIRED')\n")
    out = subprocess.run(
        [sys.executable, "-c", code],
        env={**os.environ, "REPRO_FAULT_PLAN": plan.to_json(),
             "PYTHONPATH": "src"},
        capture_output=True, text=True, cwd=Path(__file__).parent.parent)
    assert out.returncode == 0, out.stderr
    assert "FIRED" in out.stdout


# --------------------------------------------------------------------------
# fsio failure semantics
# --------------------------------------------------------------------------


def test_fsio_error_fires_before_the_write(tmp_path):
    p = tmp_path / "f.txt"
    plan = FaultPlan(triggers=[Trigger(site="t.w")])
    with fault.armed(plan):
        with pytest.raises(FaultInjected):
            fsio.write_text(p, "hello", site="t.w")
    assert not p.exists()                            # nothing landed
    fsio.write_text(p, "hello", site="t.w")          # disarmed: clean
    assert p.read_text() == "hello"


def test_fsio_torn_write_leaves_a_truncated_file(tmp_path):
    p = tmp_path / "f.bin"
    data = bytes(range(200))
    plan = FaultPlan(triggers=[Trigger(site="t.w", mode="torn")])
    with fault.armed(plan):
        with pytest.raises(FaultInjected):
            fsio.write_bytes(p, data, site="t.w")
    assert p.exists()
    assert 0 < p.stat().st_size < len(data)          # literally torn


def test_fsio_torn_np_save_is_unloadable(tmp_path):
    p = tmp_path / "a.npy"
    arr = np.arange(4096, dtype=np.int64)
    plan = FaultPlan(triggers=[Trigger(site="t.a", mode="torn")])
    with fault.armed(plan):
        with pytest.raises(FaultInjected):
            fsio.np_save(p, arr, site="t.a")
    with pytest.raises(Exception):
        np.load(p)                                   # torn: fails loudly


def test_fsio_commit_emits_tmp_then_rename_checkpoints(tmp_path):
    p = tmp_path / "manifest.json"
    with fault.record_sites() as sites:
        fsio.commit_text(p, "{}", site="x.manifest")
    assert sites == [("x.manifest.tmp_write", 1), ("x.manifest.rename", 1)]
    assert p.read_text() == "{}"
    assert not p.with_name("manifest.json.tmp").exists()
    # failing the rename leaves the target absent but the tmp staged
    plan = FaultPlan(triggers=[Trigger(site="x.m2.rename")])
    with fault.armed(plan):
        with pytest.raises(FaultInjected):
            fsio.commit_text(tmp_path / "m2", "{}", site="x.m2")
    assert not (tmp_path / "m2").exists()


# --------------------------------------------------------------------------
# degraded-mode shard fan-out
# --------------------------------------------------------------------------


def _sharded_with_dup():
    scheme = make_scheme("multiset", seed=5, k=8)
    rng = np.random.default_rng(7)
    docs = [rng.integers(0, 400, 60).astype(np.int64) for _ in range(9)]
    docs[4] = docs[1].copy()                         # dup across shards
    idx = ShardedAlignmentIndex(scheme=scheme, n_shards=3).build(docs)
    return idx, docs


def test_failing_shard_is_skipped_and_reported():
    idx, docs = _sharded_with_dup()
    q = docs[1][5:50]
    full = {a.text_id for a in idx.batch_query([q], 0.5)[0]}
    assert {1, 4} <= full
    bad = 4 % idx.n_shards                           # the shard holding doc 4
    plan = FaultPlan(triggers=[Trigger(site=f"sharded.probe.s{bad}",
                                       sticky=True)])
    with fault.armed(plan):
        failures: list[int] = []
        res = idx.batch_query([q], 0.5, failures=failures)
        assert failures == [bad]
        got = {a.text_id for a in res[0]}
        # partial: exactly the failed shard's docs are missing
        assert got == {d for d in full if d % idx.n_shards != bad}


def test_strict_mode_still_raises():
    idx, docs = _sharded_with_dup()
    plan = FaultPlan(triggers=[Trigger(site="sharded.probe.s1",
                                       sticky=True)])
    with fault.armed(plan):
        with pytest.raises(FaultInjected):
            idx.batch_query([docs[1][5:50]], 0.5)    # failures=None


def test_transient_shard_failure_is_retried_away():
    idx, docs = _sharded_with_dup()
    q = docs[1][5:50]
    full = idx.batch_query([q], 0.5)
    plan = FaultPlan(triggers=[Trigger(site="sharded.probe.s1", hit=1)])
    with fault.armed(plan):
        failures: list[int] = []
        res = idx.batch_query([q], 0.5, failures=failures,
                              shard_retries=2)
        assert failures == []                        # retry absorbed it
    assert [{a.text_id for a in r} for r in res] == \
        [{a.text_id for a in r} for r in full]


def test_aligner_stamps_degraded_results():
    from repro.api import Aligner
    idx, docs = _sharded_with_dup()
    al = Aligner(idx)
    plan = FaultPlan(triggers=[Trigger(site="sharded.probe.s1",
                                       sticky=True)])
    with fault.armed(plan):
        res = al.find_batch([docs[1][5:50]], 0.5)
    assert all(r.degraded for r in res)
    assert all(r.failed_shards == (1,) for r in res)
    d = res[0].to_dict()
    assert d["degraded"] is True and d["failed_shards"] == [1]
    clean = al.find_batch([docs[1][5:50]], 0.5)
    assert not clean[0].degraded and clean[0].failed_shards == ()


# --------------------------------------------------------------------------
# the kill-loop itself (a 3-iteration smoke of examples/churn.py --chaos;
# CI's tier1-chaos job runs the full 100-iteration soak)
# --------------------------------------------------------------------------


def test_chaos_kill_loop_smoke(tmp_path):
    repo = Path(__file__).resolve().parent.parent
    out = tmp_path / "chaos.json"
    env = {**os.environ}
    env["PYTHONPATH"] = str(repo / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, str(repo / "examples" / "churn.py"),
         "--chaos", "3", "--chaos-store", str(tmp_path / "store"),
         "--chaos-out", str(out)],
        env=env, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rec = json.loads(out.read_text())
    assert rec["ok"] and rec["killed"] + rec["survived"] == 3
    assert rec["schedule"], "recorded kill schedule must not be empty"
    # the soak's store survives for post-hoc fsck, like CI does it
    from repro.fsck import check_store
    rep = check_store(tmp_path / "store")
    assert rep["ok"] and not rep["quarantined"]
