"""Optimizer, checkpointing (fault tolerance + elasticity), trainer loop,
dedup data plane, and the sharded index."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import (ContaminationChecker, DedupFilter,
                        HashWordTokenizer, default_scheme)
from repro.train import OptConfig, adamw_update, init_opt_state, lr_at
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.loop import Trainer, TrainerConfig

pytestmark = pytest.mark.slow          # tier-2: full trainer-loop runs


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    oc = OptConfig(lr=0.1, warmup_steps=1, decay_steps=200,
                   weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, g, opt, oc)
    assert float(loss(params)) < 1e-3


def test_lr_schedule_warmup_and_decay():
    oc = OptConfig(lr=1e-3, warmup_steps=10, decay_steps=100,
                   min_lr_ratio=0.1)
    assert float(lr_at(oc, jnp.int32(0))) < 2e-4
    assert abs(float(lr_at(oc, jnp.int32(10))) - 1e-3) < 1e-4
    assert float(lr_at(oc, jnp.int32(100))) <= 1.01e-4 + 1e-6


def test_grad_clipping_bounds_update():
    from repro.train import clip_by_global_norm
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5


def test_grad_compression_error_feedback():
    from repro.train import compress_grads
    g = {"w": jnp.array([1.0 + 1e-4, -2.0])}
    comp, err = compress_grads(g, "bf16")
    # bf16 quantization error is captured in the feedback buffer
    back = jax.tree.map(lambda c, e: c.astype(jnp.float32) + e, comp, err)
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(g["w"]),
                               rtol=0, atol=1e-7)


# --------------------------------------------------------------------------
# checkpointing: atomic commit, resume, elasticity
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "opt": {"step": jnp.int32(7)}}
    for s in (1, 2, 3, 4):
        save_checkpoint(tmp_path, s, tree, keep=2)
    assert latest_step(tmp_path) == 4
    got, step = restore_checkpoint(tmp_path, 4)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.arange(12.0).reshape(3, 4))
    # keep=2 garbage-collects older steps
    import pathlib
    steps = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert len(steps) == 2


def test_checkpoint_skips_uncommitted(tmp_path):
    tree = {"w": jnp.zeros(3)}
    save_checkpoint(tmp_path, 1, tree)
    save_checkpoint(tmp_path, 2, tree)
    # simulate a crash mid-write of step 3: no COMMITTED marker
    d = tmp_path / "step_00000003"
    d.mkdir()
    (d / "manifest.json").write_text("{}")  # repro: allow[RPR202,RPR203] (deliberately torn)
    assert latest_step(tmp_path) == 2


def test_checkpoint_elastic_reshard(tmp_path):
    """Save sharded on a (2,) mesh slice, restore replicated (new mesh)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    devs = jax.devices()
    mesh = Mesh(np.array(devs[:1]).reshape(1), ("data",))
    x = jax.device_put(jnp.arange(8.0),
                       NamedSharding(mesh, P("data")))
    save_checkpoint(tmp_path, 5, {"x": x})
    got, _ = restore_checkpoint(
        tmp_path, 5, shardings={"x": NamedSharding(mesh, P())})
    np.testing.assert_array_equal(np.asarray(got["x"]), np.arange(8.0))


# --------------------------------------------------------------------------
# trainer end-to-end (CPU, tiny config)
# --------------------------------------------------------------------------

def test_trainer_loss_decreases_and_resumes(tmp_path):
    cfg = get_config("qwen1.5-4b").reduced(vocab=512)
    tc = TrainerConfig(steps=30, batch_size=4, seq_len=32, log_every=0,
                       ckpt_every=20, ckpt_dir=str(tmp_path), n_docs=300)
    oc = OptConfig(lr=5e-3, warmup_steps=5, decay_steps=500)
    out = Trainer(cfg, tc, ocfg=oc).run()
    first = float(np.mean(out["losses"][:3]))
    last = float(np.mean(out["losses"][-3:]))
    assert last < first, (first, last)
    assert latest_step(tmp_path) == 20
    # resume from step 20 and continue to 40
    tc2 = dataclasses.replace(tc, steps=40)
    out2 = Trainer(cfg, tc2, ocfg=oc).run(resume=True)
    assert out2["steps"] == 20      # only 20 more steps
    assert float(np.mean(out2["losses"][-3:])) < first


def test_trainer_with_dedup_drops_planted_duplicates():
    cfg = get_config("qwen1.5-4b").reduced(vocab=512)
    tc = TrainerConfig(steps=2, batch_size=2, seq_len=32, log_every=0,
                       n_docs=120, dedup_theta=0.55)
    out = Trainer(cfg, tc).run()
    assert out["dedup"]["dropped"] > 5          # planted dup_fraction=0.25
    assert out["dedup"]["admitted"] > 50


# --------------------------------------------------------------------------
# data plane: dedup + contamination via the paper's index
# --------------------------------------------------------------------------

def test_dedup_filter_exact_and_near_duplicates():
    tok = HashWordTokenizer(vocab=4096)
    f = DedupFilter(theta=0.6)
    base = tok.encode("the quick brown fox jumps over the lazy dog " * 8)
    assert f.admit(base)
    assert not f.admit(base)                       # exact dup dropped
    near = base.copy()
    near[::17] = (near[::17] + 7) % 4096           # ~6% token edits
    assert not f.admit(near)                       # near dup dropped
    other = tok.encode("completely different words about lattice "
                       "entropy quantum manifold " * 10)
    assert f.admit(other)


def test_contamination_checker_finds_leak():
    rng = np.random.default_rng(3)
    train = [rng.integers(4, 4000, 150).astype(np.int64) for _ in range(12)]
    test = [rng.integers(4, 4000, 80).astype(np.int64) for _ in range(6)]
    # plant: test doc 2 contains train doc 5's span
    test[2] = np.concatenate([test[2][:10], train[5][20:100]])
    cc = ContaminationChecker(theta=0.5).fit(train)
    hits = cc.check(test)
    assert any(h["test_doc"] == 2 and h["train_doc"] == 5 for h in hits)
    assert all(h["test_doc"] == 2 for h in hits)   # no false positives


def test_sharded_index_matches_flat_index():
    from repro.core import IndexBuilder, query
    from repro.core.sharded_index import ShardedAlignmentIndex
    scheme = default_scheme("weighted", seed=5, k=16)
    scheme_flat = default_scheme("weighted", seed=5, k=16)
    rng = np.random.default_rng(7)
    docs = [rng.integers(0, 500, 60).astype(np.int64) for _ in range(9)]
    docs[4] = docs[1].copy()                        # a planted duplicate
    sharded = ShardedAlignmentIndex(scheme=scheme, n_shards=3).build(docs)
    flat = IndexBuilder(scheme=scheme_flat).build(docs)
    q = docs[1][5:50]
    r1 = sharded.query(q, 0.5)
    r2 = query(flat, q, 0.5)
    assert {a.text_id for a in r1} == {a.text_id for a in r2}
    assert sharded.num_windows == flat.num_windows


def test_sharded_index_recovers_lost_shard(tmp_path):
    scheme = default_scheme("weighted", seed=5, k=8)
    rng = np.random.default_rng(7)
    docs = [rng.integers(0, 500, 40).astype(np.int64) for _ in range(6)]
    idx = ShardedOrNone = None
    from repro.core.sharded_index import ShardedAlignmentIndex
    idx = ShardedAlignmentIndex(scheme=scheme, n_shards=3).build(docs)
    idx.save(tmp_path)
    # simulate losing shard 1 on disk
    (tmp_path / "shard_1.pkl").unlink()  # repro: allow[RPR203] (simulated loss)
    idx2 = ShardedAlignmentIndex(scheme=scheme, n_shards=3)
    lost = idx2.restore(tmp_path)
    assert lost == [1]
    for gid in idx2.docs_of_shard(1):               # rebuild only shard 1
        idx2.shards[1].add_text(docs[gid])
    r1 = {a.text_id for a in idx.query(docs[2], 0.5)}
    r2 = {a.text_id for a in idx2.query(docs[2], 0.5)}
    assert r1 == r2


def test_tokenizer_deterministic_and_in_range():
    tok = HashWordTokenizer(vocab=1000)
    a = tok.encode("Hello World hello")
    b = tok.encode("hello world hello")
    np.testing.assert_array_equal(a, b)             # lowercasing
    assert a[0] == a[2]
    assert (a >= 4).all() and (a < 1000).all()
