"""Execution plans + the device-resident query pipeline (PR 10).

The contract under test: ``plan="device"`` is *block-for-block identical*
to ``plan="cpu"`` on every scheme (the kernels run in interpret mode on
CPU CI), the ProbeArena goes device-resident at most once per store
generation (and re-uploads exactly once when compaction/promotion swaps
the generation), the mutable live delta level transparently keeps the
host probe, ``plan="auto"`` downgrades silently when no accelerator backs
jax, and the legacy per-stage kwargs still work one release behind a
``DeprecationWarning`` that names the removal release.
"""

import warnings

import numpy as np
import pytest

from repro.api import Aligner
from repro.core import (IndexBuilder, LiveIndex, MultisetScheme,
                        QueryOptions, WeightedScheme, WeightFn, batch_query,
                        make_scheme, resolve_plan, save_index)
from repro.core import device_plan as dp
from repro.core.device_plan import (device_arena, reset_transfer_stats,
                                    resident_probe, transfer_stats)

SCHEMES = {
    "multiset": lambda docs: MultisetScheme(seed=13, k=8),
    "weighted": lambda docs: WeightedScheme(weight=WeightFn(tf="raw"),
                                            seed=21, k=8),
    "tfidf": lambda docs: make_scheme("tfidf", seed=5, k=8, corpus=docs),
}


def _corpus(rng, n_docs=6, vocab=30, n=50):
    docs = [rng.integers(0, vocab, size=n).astype(np.int64)
            for _ in range(n_docs)]
    docs[-1] = docs[1].copy()                     # planted duplicate
    return docs


def _queries(rng, docs, n=5):
    qs = [docs[i % len(docs)][5:30].copy() for i in range(n)]
    qs.append(rng.integers(1000, 1030, size=12).astype(np.int64))  # miss
    return qs


def _blocks(results):
    return [(a.text_id, a.blocks) for a in results]


def _batch_blocks(res):
    return [_blocks(r) for r in res]


def _frozen(kind, docs):
    return IndexBuilder(scheme=SCHEMES[kind](docs)).build(docs).freeze()


# --------------------------------------------------------------------------
# bit parity: plan="device" == plan="cpu", block for block
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", list(SCHEMES))
@pytest.mark.parametrize("theta", [0.3, 0.6, 1.0])
def test_device_plan_matches_cpu_plan(kind, theta):
    rng = np.random.default_rng(0)
    docs = _corpus(rng)
    frozen = _frozen(kind, docs)
    qs = _queries(rng, docs)
    cpu = batch_query(frozen, qs, theta, options=QueryOptions(plan="cpu"))
    dev = batch_query(frozen, qs, theta, options=QueryOptions(plan="device"))
    assert _batch_blocks(dev) == _batch_blocks(cpu)
    # ncoords (the similarity numerator) survives the fused path too
    assert [[a.ncoords for a in r] for r in dev] == \
        [[a.ncoords for a in r] for r in cpu]


@pytest.mark.parametrize("kind", ["multiset", "weighted"])
def test_resident_probe_matches_host_probe(kind):
    # both arena key layouts: weighted packs (coord << 56) | key, multiset's
    # wide hashes carry the coordinate as a separate tag word
    rng = np.random.default_rng(1)
    docs = _corpus(rng)
    frozen = _frozen(kind, docs)
    arena = frozen.arena()
    sketches = frozen.scheme.sketch_batch(_queries(rng, docs))
    pk, co, va = arena.encode_batch(sketches)
    host_s, host_e = arena.probe(pk, co, va, backend="numpy")
    dev_s, dev_e = resident_probe(frozen, pk, co, va)
    assert np.array_equal(dev_s, host_s)
    assert np.array_equal(dev_e, host_e)


def test_device_plan_on_mutable_builder_falls_back_to_host_probe():
    # fused pipeline needs a frozen index; a dict-table builder under
    # plan="device" still answers (host per-coordinate probe, device sweep)
    rng = np.random.default_rng(2)
    docs = _corpus(rng)
    builder = IndexBuilder(scheme=MultisetScheme(seed=13, k=8)).build(docs)
    qs = _queries(rng, docs)
    cpu = batch_query(builder, qs, 0.5, options=QueryOptions(plan="cpu"))
    dev = batch_query(builder, qs, 0.5, options=QueryOptions(plan="device"))
    assert _batch_blocks(dev) == _batch_blocks(cpu)


# --------------------------------------------------------------------------
# residency: one upload per store generation
# --------------------------------------------------------------------------

def test_arena_uploads_once_across_batches():
    rng = np.random.default_rng(3)
    docs = _corpus(rng)
    frozen = _frozen("multiset", docs)
    qs = _queries(rng, docs)
    opts = QueryOptions(plan="device")
    reset_transfer_stats()
    for _ in range(3):
        batch_query(frozen, qs, 0.5, options=opts)
    st = transfer_stats()
    assert st["batches"] == 3
    assert st["arena_uploads"] == 1               # resident, not re-sent
    assert st["arena_bytes"] > 0
    # steady-state per-batch traffic excludes the arena: strictly smaller
    # than re-uploading it every batch would be
    assert st["h2d_bytes"] < 3 * st["arena_bytes"] + st["arena_bytes"]
    # the cache is keyed by arena identity on the index instance
    assert frozen._device_arena[0] is frozen.arena()
    assert device_arena(frozen) is frozen._device_arena[1]


def test_residency_invalidated_by_compaction(tmp_path):
    rng = np.random.default_rng(4)
    base = _corpus(rng, n_docs=8)
    scheme = MultisetScheme(seed=13, k=8)
    save_index(IndexBuilder(scheme=scheme).build(base).freeze(),
               tmp_path / "idx")
    live = LiveIndex.open(tmp_path / "idx")
    qs = _queries(rng, base)
    opts = QueryOptions(plan="device")

    reset_transfer_stats()
    first = live.batch_query(qs, 0.5, options=opts)
    live.batch_query(qs, 0.5, options=opts)
    assert transfer_stats()["arena_uploads"] == 1

    # promotion swaps in a new SearchIndex generation: exactly one more
    # upload, and the old residency can never serve the new generation
    extra = [base[2].copy(), rng.integers(0, 30, 50).astype(np.int64)]
    for t in extra:
        live.add_text(t)
    live.compact()
    assert live.generation == 1
    live.batch_query(qs, 0.5, options=opts)
    live.batch_query(qs, 0.5, options=opts)
    assert transfer_stats()["arena_uploads"] == 2

    oracle = IndexBuilder(scheme=scheme).build(base + extra)
    assert _batch_blocks(live.batch_query(qs, 0.5, options=opts)) == \
        _batch_blocks(batch_query(oracle, qs, 0.5))
    assert _batch_blocks(first) == \
        _batch_blocks(batch_query(IndexBuilder(scheme=scheme).build(base),
                                  qs, 0.5))


def test_oversized_arena_caches_host_fallback(monkeypatch):
    rng = np.random.default_rng(5)
    docs = _corpus(rng)
    frozen = _frozen("multiset", docs)
    qs = _queries(rng, docs)
    cpu = _batch_blocks(batch_query(frozen, qs, 0.5,
                                    options=QueryOptions(plan="cpu")))
    # pretend the CSR extent overflows the kernel's int32 offsets
    monkeypatch.setattr(dp, "_I32_MAX", -1)
    reset_transfer_stats()
    opts = QueryOptions(plan="device")
    for _ in range(2):
        got = _batch_blocks(batch_query(frozen, qs, 0.5, options=opts))
        assert got == cpu                         # host fallback, same blocks
    st = transfer_stats()
    assert st["arena_uploads"] == 0
    assert st["h2d_bytes"] == 0 and st["d2h_bytes"] == 0
    # the None outcome is cached: no rebuild attempt per batch
    assert frozen._device_arena == (frozen.arena(), None)


# --------------------------------------------------------------------------
# live delta level: host probe fallback under writes
# --------------------------------------------------------------------------

def test_live_delta_serves_device_plan_via_host_fallback(tmp_path):
    rng = np.random.default_rng(6)
    base = _corpus(rng, n_docs=8)
    scheme = MultisetScheme(seed=13, k=8)
    save_index(IndexBuilder(scheme=scheme).build(base).freeze(),
               tmp_path / "idx")
    live = LiveIndex.open(tmp_path / "idx")
    delta = [rng.integers(0, 30, 50).astype(np.int64) for _ in range(2)]
    delta.append(base[2].copy())                  # near-dup lands in delta
    for t in delta:
        live.add_text(t)
    assert live.delta.num_texts == len(delta)     # genuinely pre-compaction

    qs = _queries(rng, base) + [delta[-1][:30]]
    oracle = IndexBuilder(scheme=scheme).build(base + delta)
    expected = _batch_blocks(batch_query(oracle, qs, 0.5))
    got = _batch_blocks(live.batch_query(
        qs, 0.5, options=QueryOptions(plan="device")))
    assert got == expected
    # results include hits resolved from the mutable delta level (high
    # text ids), proving the host-probed delta merged into the device scan
    assert any(tid >= len(base) for r in got for tid, _ in r)


# --------------------------------------------------------------------------
# plan resolution: auto downgrade + pin validation
# --------------------------------------------------------------------------

def test_auto_plan_downgrades_without_accelerator():
    xp = resolve_plan(QueryOptions(plan="auto"),
                      capabilities={"device": False})
    assert xp.name == "cpu" and not xp.fused
    xp = resolve_plan(QueryOptions(plan="auto"),
                      capabilities={"device": True})
    assert xp.name == "device" and xp.fused
    # no capability override: follows the real backend probe, silently
    assert resolve_plan(QueryOptions(plan="auto")).name in ("cpu", "device")


def test_resolved_device_plan_keeps_exact_sketching():
    xp = resolve_plan(QueryOptions(plan="device"))
    assert xp.sketch_backend == "exact"           # bit parity by default
    assert xp.probe_backend == "device" and xp.sweep == "device"


def test_stage_pins_override_plan_defaults():
    xp = resolve_plan(QueryOptions(plan="device", sweep="grouped"))
    assert xp.sweep == "grouped" and not xp.fused
    assert xp.probe_backend == "device"


def test_unknown_plan_and_invalid_pin_are_errors():
    with pytest.raises(ValueError, match="unknown execution plan"):
        resolve_plan(QueryOptions(plan="gpu"))
    with pytest.raises(TypeError, match="cannot execute"):
        resolve_plan(QueryOptions(plan="cpu", probe_backend="device"))


# --------------------------------------------------------------------------
# deprecation shims: one release of grace, loudly
# --------------------------------------------------------------------------

def test_legacy_kwargs_warn_name_release_and_round_trip():
    rng = np.random.default_rng(7)
    docs = _corpus(rng)
    frozen = _frozen("multiset", docs)
    qs = _queries(rng, docs)
    new = batch_query(frozen, qs, 0.5,
                      options=QueryOptions(probe_backend="percoord",
                                           sweep="loop"))
    with pytest.warns(DeprecationWarning, match=r"removed in release 0\.3"):
        old = batch_query(frozen, qs, 0.5,                      # repro: allow[RPR404]
                          probe_backend="percoord", sweep="loop")
    assert _batch_blocks(old) == _batch_blocks(new)


def test_aligner_legacy_backend_kwarg_warns_and_matches():
    rng = np.random.default_rng(8)
    docs = _corpus(rng)
    a = Aligner.build(docs, similarity="multiset", k=8)
    qs = _queries(rng, docs)
    new = a.find_batch(qs, 0.5, options=QueryOptions(sketch_backend="exact"))
    with pytest.warns(DeprecationWarning, match=r"options=QueryOptions"):
        old = a.find_batch(qs, 0.5, backend="exact")            # repro: allow[RPR401]
    assert _batch_blocks(old) == _batch_blocks(new)


def test_mixing_options_and_legacy_kwargs_is_an_error():
    rng = np.random.default_rng(9)
    docs = _corpus(rng)
    frozen = _frozen("multiset", docs)
    with pytest.raises(TypeError, match="both"):
        batch_query(frozen, [docs[0][:20]], 0.5,    # repro: allow[RPR404]
                    options=QueryOptions(plan="cpu"), sweep="loop")
