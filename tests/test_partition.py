"""Oracle-equivalence tests (Theorem 1): every partitioning method produces a
disjoint, covering, value-correct partition, for multiset and ICWS hashing,
across text shapes / alphabet sizes / weight functions."""

import numpy as np
import pytest

from repro.core import (ICWS, UniversalHash, WeightFn, allalign_partition,
                        generate_keys_icws, generate_keys_multiset,
                        minhash_gid_grid_icws, minhash_gid_grid_multiset,
                        monotonic_partition, validate_partition)

METHODS = ["mono_all", "mono_active", "allalign"]


def _build(keys, method):
    if method == "allalign":
        return allalign_partition(keys)
    return monotonic_partition(keys)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("alpha", [1, 2, 5, 50])
@pytest.mark.parametrize("n", [1, 2, 7, 40])
@pytest.mark.parametrize("method", METHODS)
def test_multiset_oracle(seed, alpha, n, method):
    rng = np.random.default_rng(seed * 1000 + alpha * 7 + n)
    tokens = rng.integers(0, alpha, size=n).astype(np.int64)
    h = UniversalHash.from_seed(seed + 99, 1)[0]
    active = method == "mono_active"
    keys = generate_keys_multiset(tokens, h, active=active)
    part = _build(keys, method)
    grid, table = minhash_gid_grid_multiset(tokens, h)
    validate_partition(part, grid, table)


@pytest.mark.parametrize("tf", ["binary", "raw", "log", "squared"])
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("seed", [0, 3])
def test_icws_oracle(tf, method, seed):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, 6, size=50).astype(np.int64)
    icws = ICWS.from_seed(seed + 5, 1)[0]
    w = WeightFn(tf=tf)
    active = method == "mono_active"
    keys = generate_keys_icws(tokens, icws, w, active=active)
    part = _build(keys, method)
    grid, table = minhash_gid_grid_icws(tokens, icws, w)
    validate_partition(part, grid, table)


@pytest.mark.parametrize("tf", ["binary", "raw", "log", "squared"])
def test_mono_all_equals_mono_active_icws(tf):
    """§6.1: the active optimization does not change the output windows."""
    rng = np.random.default_rng(42)
    tokens = rng.integers(0, 5, size=70).astype(np.int64)
    icws = ICWS.from_seed(1, 1)[0]
    w = WeightFn(tf=tf)
    pa = monotonic_partition(generate_keys_icws(tokens, icws, w, active=False))
    px = monotonic_partition(generate_keys_icws(tokens, icws, w, active=True))
    assert len(pa) == len(px)
    for f in ("a", "b", "c", "d"):
        assert np.array_equal(getattr(pa, f), getattr(px, f))
    assert [pa.gid_key[int(g)] for g in pa.gid] == \
           [px.gid_key[int(g)] for g in px.gid]


def test_mono_all_equals_mono_active_multiset():
    rng = np.random.default_rng(7)
    tokens = rng.integers(0, 3, size=90).astype(np.int64)
    h = UniversalHash.from_seed(11, 1)[0]
    pa = monotonic_partition(generate_keys_multiset(tokens, h, active=False))
    px = monotonic_partition(generate_keys_multiset(tokens, h, active=True))
    assert len(pa) == len(px)
    for f in ("a", "b", "c", "d"):
        assert np.array_equal(getattr(pa, f), getattr(px, f))


def test_idf_weighted_partition_oracle():
    """TF-IDF (standard idf) with corpus stats still satisfies AoW."""
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 10, size=40).astype(np.int64)
    doc_freq = {t: int(rng.integers(1, 50)) for t in range(10)}
    w = WeightFn(tf="raw", idf="smooth", n_docs=100, doc_freq=doc_freq)
    icws = ICWS.from_seed(2, 1)[0]
    keys = generate_keys_icws(tokens, icws, w, active=True)
    part = monotonic_partition(keys)
    grid, table = minhash_gid_grid_icws(tokens, icws, w)
    validate_partition(part, grid, table)


def test_worst_case_all_same_token():
    """Appendix B's hard instance: every token identical."""
    n = 64
    tokens = np.zeros(n, dtype=np.int64)
    h = UniversalHash.from_seed(17, 1)[0]
    keys = generate_keys_multiset(tokens, h, active=True)
    part = monotonic_partition(keys)
    grid, table = minhash_gid_grid_multiset(tokens, h)
    validate_partition(part, grid, table)


def test_single_token_text():
    tokens = np.array([5], dtype=np.int64)
    h = UniversalHash.from_seed(0, 1)[0]
    part = monotonic_partition(generate_keys_multiset(tokens, h, active=True))
    assert len(part) == 1
    assert (part.a[0], part.b[0], part.c[0], part.d[0]) == (0, 0, 0, 0)
