"""Empirical validation of the complexity results (Theorem 2, Lemma 11,
Lemma 13, Appendix B lower bound)."""

import numpy as np
import pytest

from repro.core import (ICWS, UniversalHash, WeightFn, count_active_hashes,
                        generate_keys_multiset, monotonic_partition)


def harmonic(n: int) -> float:
    return float(np.sum(1.0 / np.arange(1, n + 1)))


def test_active_hash_count_harmonic():
    """E[#active hash values of a token with freq f] = H(f) (Lemma 11).

    Uses MixHash: Lemma 11 assumes the h(t, 1..f) sequence is i.i.d.
    uniform, which splitmix64 satisfies.  (The paper's concrete linear
    family violates it — see test_linear_family_inflates_active_count.)
    """
    from repro.core import MixHash
    f = 256
    tokens = np.zeros(f, dtype=np.int64)
    counts = [count_active_hashes(tokens, None, None,
                                  hashfn=MixHash.from_seed(s, 1)[0])
              for s in range(200)]
    mean = np.mean(counts)
    # E = H(256) ~ 6.12; sd of mean over 200 trials ~ sqrt(var)/14 small
    assert abs(mean - harmonic(f)) < 0.6, (mean, harmonic(f))


def test_linear_family_inflates_active_count():
    """Empirical erratum: h=(a1·t+a2·x+b) mod p is an arithmetic progression
    in x, so its running-minima count exceeds the i.i.d. H(f) of Lemma 11
    (≈1.5-1.8x at f=256).  Documented in EXPERIMENTS.md §Beyond-paper."""
    f = 256
    tokens = np.zeros(f, dtype=np.int64)
    counts = [count_active_hashes(tokens, None, None,
                                  hashfn=UniversalHash.from_seed(s, 1)[0])
              for s in range(200)]
    mean = np.mean(counts)
    assert mean > harmonic(f) * 1.25, (mean, harmonic(f))


def test_active_keys_scale_n_log_f():
    """|X(T)| = O(n + n log f) with matching growth (Theorem 2/Lemma 11)."""
    rng = np.random.default_rng(0)
    n = 4096
    sizes = []
    for alpha, f_expect in [(n // 4, 4), (n // 64, 64), (n // 512, 512)]:
        tokens = rng.integers(0, alpha, size=n).astype(np.int64)
        h = UniversalHash.from_seed(1, 1)[0]
        keys = generate_keys_multiset(tokens, h, active=True)
        sizes.append(len(keys))
    # ratios should grow like (1 + H(f)) not like f
    r1 = sizes[1] / sizes[0]
    r2 = sizes[2] / sizes[1]
    assert r1 < 4.0 and r2 < 4.0, sizes  # raw-f scaling would give ~16x
    assert sizes[2] > sizes[0]           # but it does grow with f


@pytest.mark.parametrize("tf,bound", [
    ("binary", "n"), ("log", "nloglogf"), ("raw", "nlogf"), ("squared", "nlogf"),
])
def test_lemma13_weight_function_scaling(tf, bound):
    """Active-key counts ordered binary <= log <= raw <= squared (Lemma 13)."""
    rng = np.random.default_rng(3)
    n, alpha = 2000, 25
    tokens = rng.integers(0, alpha, size=n).astype(np.int64)
    w = WeightFn(tf=tf)
    icws = ICWS.from_seed(9, 1)[0]
    from repro.core import generate_keys_icws
    cnt = len(generate_keys_icws(tokens, icws, w, active=True))
    if not hasattr(test_lemma13_weight_function_scaling, "_seen"):
        test_lemma13_weight_function_scaling._seen = {}
    test_lemma13_weight_function_scaling._seen[tf] = cnt
    seen = test_lemma13_weight_function_scaling._seen
    if len(seen) == 4:
        assert seen["binary"] <= seen["log"] <= seen["raw"] <= seen["squared"]
        # binary generates exactly one active value per distinct token:
        # key count = sum of freqs = n
        assert seen["binary"] == n


def test_binary_tf_active_keys_exactly_n():
    rng = np.random.default_rng(5)
    tokens = rng.integers(0, 11, size=500).astype(np.int64)
    from repro.core import generate_keys_icws
    keys = generate_keys_icws(tokens, ICWS.from_seed(0, 1)[0],
                              WeightFn(tf="binary"), active=True)
    assert len(keys) == 500


def test_lower_bound_worst_case():
    """Appendix B: all-duplicate text needs Ω(n log n) windows; our
    partitioner should produce Θ(n log n) (within constant of harmonic sum)."""
    n = 512
    tokens = np.zeros(n, dtype=np.int64)
    sizes = []
    for s in range(20):
        h = UniversalHash.from_seed(s, 1)[0]
        part = monotonic_partition(generate_keys_multiset(tokens, h, active=True))
        sizes.append(len(part))
    mean = np.mean(sizes)
    # E[|S|] = (n+1)H(n) - n  ~ lower bound set size (Eq. 7)
    lb = (n + 1) * harmonic(n) - n
    assert mean >= lb * 0.9, (mean, lb)           # matches the Ω bound
    assert mean <= 2.2 * lb, (mean, lb)           # and is within ~2x optimal


def test_mono_vs_vanilla_key_counts():
    """Active optimization reduces generated keys by ~f/log f on dup-heavy
    text (the Fig. 5 effect)."""
    rng = np.random.default_rng(1)
    n, alpha = 3000, 10         # f ~ 300
    tokens = rng.integers(0, alpha, size=n).astype(np.int64)
    h = UniversalHash.from_seed(2, 1)[0]
    k_all = generate_keys_multiset(tokens, h, active=False)
    k_act = generate_keys_multiset(tokens, h, active=True)
    assert len(k_act) < len(k_all) / 10
