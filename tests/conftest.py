"""Shared test configuration.

The property suites require ``hypothesis`` (declared in
requirements-dev.txt).  When it is absent — minimal local environments —
skip collecting those modules instead of erroring the whole run.
"""

import importlib.util

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += ["test_property.py", "test_property_system.py"]
