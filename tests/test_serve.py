"""Serving front-end integration tests: dynamic batching, deadlines,
backpressure, mid-flight compaction, and the wire protocol.

No pytest-asyncio: every async test drives its own loop via
``asyncio.run``.  The server binds port 0 (ephemeral) on 127.0.0.1.
"""

from __future__ import annotations

import asyncio
import math
import threading
import time

import numpy as np
import pytest

from repro.api import Aligner, Match, QueryOptions, QueryResult
from repro.serve import AlignServer, DynamicBatcher, QueueFull
from repro.serve.batcher import DeadlineExceeded
from repro.serve.client import (AlignClient, AsyncAlignClient, AsyncWSClient,
                                ServerError)


def _mk_aligner(n_docs: int = 30, doc_len: int = 120, live: bool = False,
                tmp_path=None):
    rng = np.random.default_rng(5)
    docs = [rng.integers(0, 1 << 40, size=doc_len) for _ in range(n_docs)]
    if live:
        store = str(tmp_path / "idx")
        Aligner.build(docs, similarity="multiset", seed=3, k=8,
                      pipeline="columnar", store=store)
        return Aligner.load(store, live=True), docs
    return Aligner.build(docs, similarity="multiset", seed=3, k=8), docs


class _ThreadServer:
    """Run an AlignServer on a background event loop so blocking clients
    (http.client) can talk to it from the test thread."""

    def __init__(self, aligner, **kw):
        self.aligner = aligner
        self.kw = kw
        self.server = None
        self.loop = None

    def __enter__(self):
        started = threading.Event()

        def run():
            self.loop = asyncio.new_event_loop()
            asyncio.set_event_loop(self.loop)
            self.server = self.loop.run_until_complete(
                AlignServer(self.aligner, **self.kw).start())
            started.set()
            self.loop.run_forever()

        self.thread = threading.Thread(target=run, daemon=True)
        self.thread.start()
        assert started.wait(10)
        return self.server

    def __exit__(self, *exc):
        asyncio.run_coroutine_threadsafe(
            self.server.close(), self.loop).result(10)
        self.loop.call_soon_threadsafe(self.loop.stop)
        self.thread.join(10)


def test_http_query_roundtrip_typed_results(tmp_path):
    aligner, docs = _mk_aligner()
    with _ThreadServer(aligner) as srv:
        with AlignClient(port=srv.port) as client:
            # a snippet of doc 7 must come back as a typed match on doc 7
            snippet = [int(t) for t in docs[7][10:90]]
            result = QueryResult.from_dict(client.query(snippet, 0.5))
            assert result, "planted snippet found nothing"
            assert any(m.doc_id == 7 for m in result)
            for m in result.matches:
                assert isinstance(m, Match)
                assert m.estimated_similarity >= 0.5
                assert m.span[0] <= m.span[1]
            # novel text: clean empty result, not an error
            novel = [int(t) for t in
                     np.random.default_rng(9).integers(0, 1 << 40, 80)]
            assert QueryResult.from_dict(client.query(novel, 0.5)).matches \
                == []
            health = client.healthz()
            assert health["docs"] == len(docs)
            snap = client.metrics()
            assert snap["counters"]["requests_total"] == 2
            assert snap["counters"]["responses_total"] == 2
            assert snap["counters"]["errors_total"] == 0


def test_http_error_statuses(tmp_path):
    aligner, _ = _mk_aligner(n_docs=4)
    aligner.freeze()            # frozen, not live: /add must 409
    with _ThreadServer(aligner) as srv:
        with AlignClient(port=srv.port) as client:
            with pytest.raises(ServerError) as ei:
                client.query([1, 2, 3], theta=7.5)      # theta out of range
            assert ei.value.status == 400
            status, _ = client._request("POST", "/nope", {})
            assert status == 404
            status, _ = client._request("GET", "/query")
            assert status == 405
            # /add against a non-live (fully frozen) aligner is a 409
            with pytest.raises(ServerError) as ei:
                client.add([1, 2, 3])
            assert ei.value.status == 409


def test_batcher_coalesces_concurrent_requests():
    """N concurrent same-key queries must cost <= ceil(N/max_batch)
    find_batch probes — the tentpole's coalescing contract."""
    aligner, docs = _mk_aligner()
    probes = []
    orig = aligner.find_batch

    def counting(texts, theta, **kw):
        probes.append(len(texts))
        return orig(texts, theta, **kw)

    aligner.find_batch = counting
    N, max_batch = 24, 8

    async def main():
        batcher = DynamicBatcher(aligner, max_batch=max_batch,
                                 max_linger_us=50_000.0)
        # all N submitted before the drain task first runs -> the queue
        # already holds every request when batching starts
        futs = [batcher.submit_query([int(t) for t in docs[i % 5][:60]], 0.5)
                for i in range(N)]
        results = await asyncio.gather(*futs)
        await batcher.close()
        return results, batcher.metrics.snapshot()

    results, snap = asyncio.run(main())
    assert len(results) == N
    assert all(isinstance(r, QueryResult) for r in results)
    assert len(probes) <= math.ceil(N / max_batch)
    assert all(p <= max_batch for p in probes)
    assert snap["counters"]["batches_total"] == len(probes)
    assert snap["batch_size"]["count"] == len(probes)


def test_batcher_splits_incompatible_options():
    """Different (theta, options) keys may not share a find_batch call."""
    aligner, docs = _mk_aligner()
    seen = []
    orig = aligner.find_batch

    def spy(texts, theta, *, options=None, **kw):
        seen.append((theta, options.batch_key()))
        return orig(texts, theta, options=options, **kw)

    aligner.find_batch = spy

    async def main():
        batcher = DynamicBatcher(aligner, max_batch=32,
                                 max_linger_us=50_000.0)
        q = [int(t) for t in docs[0][:60]]
        futs = [batcher.submit_query(q, 0.5),
                batcher.submit_query(q, 0.8),
                batcher.submit_query(q, 0.5,
                                     options=QueryOptions(sweep="loop"))]
        await asyncio.gather(*futs)
        await batcher.close()

    asyncio.run(main())
    assert len(seen) == 3
    assert len(set(seen)) == 3


def test_execution_plan_rides_the_wire_and_keys_batches():
    """``options.plan`` survives the request envelope and partitions the
    batcher's coalescing key, so mixed-plan traffic never shares a
    ``find_batch`` call (a cpu request must not ride a device batch)."""
    from repro.serve.protocol import ProtocolError, parse_query_request
    req = parse_query_request(
        {"text": [1, 2, 3], "theta": 0.6, "options": {"plan": "device"}})
    assert req.options.plan == "device"
    assert req.options.batch_key() != QueryOptions().batch_key()
    # same plan, same pins -> same key: coalescable
    assert req.options.batch_key() == \
        QueryOptions(plan="device").batch_key()
    # server-side sketching means client-supplied sketches stay rejected
    with pytest.raises(ProtocolError, match="sketches"):
        parse_query_request({"text": [1], "options": {"sketches": []}})

    aligner, docs = _mk_aligner()
    seen = []
    orig = aligner.find_batch

    def spy(texts, theta, *, options=None, **kw):
        seen.append(options.batch_key())
        return orig(texts, theta, options=options, **kw)

    aligner.find_batch = spy

    async def main():
        batcher = DynamicBatcher(aligner, max_batch=32,
                                 max_linger_us=50_000.0)
        q = [int(t) for t in docs[0][:60]]
        futs = [batcher.submit_query(q, 0.5),
                batcher.submit_query(q, 0.5),
                batcher.submit_query(q, 0.5,
                                     options=QueryOptions(plan="device"))]
        res = await asyncio.gather(*futs)
        await batcher.close()
        return res

    res = asyncio.run(main())
    assert len(seen) == 2                 # 2 cpu coalesced + 1 device
    assert len(set(seen)) == 2
    # and the device-plan result matches the coalesced cpu results
    assert res[2].to_dict() == res[0].to_dict()


def test_deadline_expired_skips_probe():
    """A request whose deadline passes while queued is failed with
    DeadlineExceeded and must never reach the engine."""
    aligner, docs = _mk_aligner()
    probes = []
    orig = aligner.find_batch

    def counting(texts, theta, **kw):
        probes.append(len(texts))
        return orig(texts, theta, **kw)

    aligner.find_batch = counting

    async def main():
        batcher = DynamicBatcher(aligner, max_batch=4, max_linger_us=100.0)
        # park the engine so the query's 20 ms deadline expires in-queue
        batcher.submit_control(lambda: time.sleep(0.2), label="stall")
        fut = batcher.submit_query([int(t) for t in docs[0][:60]], 0.5,
                                   deadline_s=0.02)
        with pytest.raises(DeadlineExceeded):
            await fut
        snap = batcher.metrics.snapshot()
        await batcher.close()
        return snap

    snap = asyncio.run(main())
    assert probes == []
    assert snap["counters"]["expired_total"] == 1
    assert snap["counters"]["batches_total"] == 0


def test_deadline_maps_to_504():
    aligner, docs = _mk_aligner()

    async def main():
        async with AlignServer(aligner, max_linger_us=100.0) as srv:
            # engine parked -> the 10 ms deadline cannot be met
            srv.batcher.submit_control(lambda: time.sleep(0.2),
                                       label="stall")
            client = await AsyncAlignClient.connect("127.0.0.1", srv.port)
            status, payload = await client.query(
                [int(t) for t in docs[0][:60]], 0.5, deadline_ms=10)
            await client.close()
            return status, payload

    status, payload = asyncio.run(main())
    assert status == 504
    assert payload["ok"] is False


def test_backpressure_503_at_queue_cap():
    aligner, docs = _mk_aligner()

    async def main():
        async with AlignServer(aligner, queue_cap=3,
                               max_linger_us=100.0) as srv:
            srv.batcher.submit_control(lambda: time.sleep(0.3),
                                       label="stall")
            ws = await AsyncWSClient.connect("127.0.0.1", srv.port)
            q = [int(t) for t in docs[0][:60]]
            futs = [ws.submit(q, 0.5) for _ in range(5)]
            msgs = await asyncio.gather(*futs)
            snap = srv.metrics.snapshot()
            await ws.close()
            return msgs, snap

    msgs, snap = asyncio.run(main())
    rejected = [m for m in msgs if not m.get("ok", False)]
    served = [m for m in msgs if m.get("ok", False)]
    assert len(served) == 3 and len(rejected) == 2
    assert all(m["status"] == 503 for m in rejected)
    assert snap["counters"]["rejected_total"] == 2
    # admission frees as requests complete: the server is not wedged
    aligner2_check = served[0]["result"]
    assert "matches" in aligner2_check


def test_ws_pipelining_correlates_by_id():
    aligner, docs = _mk_aligner()

    async def main():
        async with AlignServer(aligner, max_linger_us=20_000.0) as srv:
            ws = await AsyncWSClient.connect("127.0.0.1", srv.port)
            futs = {i: ws.submit([int(t) for t in docs[i][:60]], 0.5)
                    for i in range(8)}
            msgs = {i: await f for i, f in futs.items()}
            await ws.close()
            return msgs

    msgs = asyncio.run(main())
    for i, msg in msgs.items():
        assert msg["ok"], msg
        res = QueryResult.from_dict(msg["result"])
        # each doc's own prefix must find that doc (self-hit)
        assert any(m.doc_id == i for m in res), (i, res.matches)


def test_add_is_read_your_writes(tmp_path):
    aligner, docs = _mk_aligner(live=True, tmp_path=tmp_path)
    new_doc = [int(t) for t in
               np.random.default_rng(11).integers(0, 1 << 40, 120)]

    async def main():
        async with AlignServer(aligner) as srv:
            client = await AsyncAlignClient.connect("127.0.0.1", srv.port)
            doc_id = await client.add(new_doc)
            # enqueued after the add -> FIFO guarantees visibility
            status, payload = await client.query(new_doc[20:100], 0.5)
            await client.close()
            return doc_id, status, payload

    doc_id, status, payload = asyncio.run(main())
    assert doc_id == len(docs)
    assert status == 200
    res = QueryResult.from_dict(payload["result"])
    assert any(m.doc_id == doc_id for m in res)


def test_midflight_compaction_bit_identical(tmp_path):
    """Queries racing a /compact (seal -> off-thread merge -> promote)
    must answer bit-identically to the quiesced server, with the
    generation bumped and zero errors."""
    aligner, docs = _mk_aligner(n_docs=40, live=True, tmp_path=tmp_path)
    rng = np.random.default_rng(6)
    delta = [rng.integers(0, 1 << 40, size=120) for _ in range(8)]
    queries = [[int(t) for t in d[10:90]] for d in docs[:6] + delta[:4]]

    async def main():
        async with AlignServer(aligner, max_linger_us=500.0) as srv:
            ctl = await AsyncAlignClient.connect("127.0.0.1", srv.port)
            for d in delta:
                await ctl.add([int(t) for t in d])
            ws = await AsyncWSClient.connect("127.0.0.1", srv.port)
            gen0 = (await ctl.request("GET", "/healthz"))[1]["generation"]

            answers = []

            async def traffic():
                for round_ in range(12):
                    futs = [ws.submit(q, 0.5) for q in queries]
                    answers.extend(await asyncio.gather(*futs))
                    await asyncio.sleep(0)

            compact_task = asyncio.ensure_future(ctl.compact())
            await traffic()
            gen1 = await compact_task
            # quiesced reference: same server, after the promotion
            ref = []
            for q in queries:
                status, payload = await ctl.query(q, 0.5)
                assert status == 200
                ref.append(payload["result"])
            snap = srv.metrics.snapshot()
            await ws.close()
            await ctl.close()
            return gen0, gen1, answers, ref, snap

    gen0, gen1, answers, ref, snap = asyncio.run(main())
    assert gen1 == gen0 + 1
    assert snap["counters"]["errors_total"] == 0
    assert snap["counters"]["compactions_total"] == 1
    assert len(answers) == 12 * len(ref)
    for i, msg in enumerate(answers):
        assert msg["ok"], msg
        assert msg["result"] == ref[i % len(ref)], \
            f"response {i} diverged across promotion"


def test_compaction_concurrent_request_conflict(tmp_path):
    aligner, _ = _mk_aligner(live=True, tmp_path=tmp_path)

    async def main():
        async with AlignServer(aligner) as srv:
            client = await AsyncAlignClient.connect("127.0.0.1", srv.port)
            await client.add(list(range(100)))
            first = asyncio.ensure_future(client.request(
                "POST", "/compact", {}))
            # second connection so the requests truly overlap
            other = await AsyncAlignClient.connect("127.0.0.1", srv.port)
            second = await other.request("POST", "/compact", {})
            status1, payload1 = await first
            await client.close()
            await other.close()
            return (status1, payload1), second

    (s1, p1), (s2, p2) = asyncio.run(main())
    statuses = sorted([s1, s2])
    assert statuses == [200, 409], (s1, p1, s2, p2)


def test_queue_full_on_closed_batcher():
    aligner, docs = _mk_aligner(n_docs=4)

    async def main():
        batcher = DynamicBatcher(aligner)
        await batcher.close()
        with pytest.raises(QueueFull):
            batcher.submit_query([1, 2, 3], 0.5)

    asyncio.run(main())


# --------------------------------------------------------------------------
# degraded mode, retries, and the compaction supervisor (fault harness)
# --------------------------------------------------------------------------

from repro import fault  # noqa: E402
from repro.fault import FaultPlan, Trigger  # noqa: E402
from repro.serve import CompactionSupervisor  # noqa: E402


def _mk_sharded_aligner(n_docs: int = 12, doc_len: int = 120):
    rng = np.random.default_rng(5)
    docs = [rng.integers(0, 1 << 40, size=doc_len) for _ in range(n_docs)]
    return Aligner.build(docs, similarity="multiset", seed=3, k=8,
                         shards=2), docs


def _wait_for(predicate, timeout_s: float = 15.0, interval_s: float = 0.05):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval_s)
    return False


def test_shard_failure_degrades_instead_of_500():
    aligner, docs = _mk_sharded_aligner()
    with _ThreadServer(aligner) as srv:
        with AlignClient(port=srv.port) as client:
            snippet = [int(t) for t in docs[6][10:90]]   # doc 6 -> shard 0
            plan = FaultPlan(triggers=[Trigger(site="sharded.probe.s1",
                                               sticky=True)])
            try:
                fault.arm(plan)
                result = client.query(snippet, 0.5)      # 200, not 500
                assert result["degraded"] is True
                assert result["failed_shards"] == [1]
                # the healthy shard's docs still come back
                assert any(m["doc_id"] == 6 for m in result["matches"])
                health = client.healthz()
                assert health["status"] == "degraded"
                assert health["failed_shards"] == [1]
                snap = client.metrics()
                assert snap["counters"]["degraded_total"] >= 1
                assert snap["counters"]["errors_total"] == 0
                assert snap["fault"]["armed"] is True
                assert "store" in snap
            finally:
                fault.disarm()
            # fault cleared: the next query restores full health
            result = client.query(snippet, 0.5)
            assert result["degraded"] is False
            assert client.healthz()["status"] == "healthy"


def test_batcher_probe_fault_hook_maps_to_500_then_recovers():
    aligner, docs = _mk_sharded_aligner(n_docs=6)
    with _ThreadServer(aligner) as srv:
        with AlignClient(port=srv.port) as client:
            q = [int(t) for t in docs[0][10:90]]
            plan = FaultPlan(triggers=[Trigger(site="serve.batcher.probe",
                                               sticky=True)])
            try:
                fault.arm(plan)
                with pytest.raises(ServerError) as ei:
                    client.query(q, 0.5)
                assert ei.value.status == 500
            finally:
                fault.disarm()
            assert client.metrics()["counters"]["errors_total"] >= 1
            assert client.query(q, 0.5)["matches"]        # healthy again


def test_503_carries_retry_after_and_client_retries_queries():
    aligner, docs = _mk_aligner(n_docs=6)
    with _ThreadServer(aligner, retry_after_s=0.25) as srv:
        q = [int(t) for t in docs[0][10:90]]
        orig = srv.batcher.submit_query
        calls = {"n": 0, "fail_first": 0}

        def flaky(*a, **kw):
            calls["n"] += 1
            if calls["n"] <= calls["fail_first"]:
                raise QueueFull("induced shed")
            return orig(*a, **kw)

        srv.batcher.submit_query = flaky

        # a bare client surfaces the 503, and the Retry-After hint rides it
        with AlignClient(port=srv.port) as client:
            calls.update(n=0, fail_first=1)
            status, payload, headers = client._request_full(
                "POST", "/query", {"text": q, "theta": 0.5})
            assert status == 503
            assert float(headers["retry-after"]) == 0.25
            # non-idempotent endpoints never carry the retry hint
            status, _, headers = client._request_full("POST", "/nope", {})
            assert "retry-after" not in headers

        # retries=2 absorbs the shed and answers the query
        with AlignClient(port=srv.port, retries=2,
                         backoff_s=0.01, backoff_max_s=0.05) as client:
            calls.update(n=0, fail_first=1)
            result = client.query(q, 0.5)
            assert calls["n"] == 2
            assert any(m["doc_id"] == 0 for m in result["matches"])
            # more 503s than retries: the failure still surfaces
            calls.update(n=0, fail_first=10)
            with pytest.raises(ServerError) as ei:
                client.query(q, 0.5)
            assert ei.value.status == 503


def test_client_retries_reconnect_after_dropped_connection():
    import socket

    aligner, docs = _mk_aligner(n_docs=6)
    with _ThreadServer(aligner) as srv:
        q = [int(t) for t in docs[0][10:90]]
        with AlignClient(port=srv.port, retries=2, backoff_s=0.01) as client:
            assert client.query(q, 0.5)["matches"]
            # kill the keep-alive socket under the client: the retry
            # must reconnect instead of surfacing the connection error
            client._conn.sock.shutdown(socket.SHUT_RDWR)
            assert client.query(q, 0.5)["matches"]
        # without retries the same drop surfaces as a connection error
        with AlignClient(port=srv.port) as client:
            assert client.query(q, 0.5)["matches"]
            client._conn.sock.shutdown(socket.SHUT_RDWR)
            with pytest.raises(ConnectionError):
                client.query(q, 0.5)


def test_supervisor_auto_compacts_and_prunes(tmp_path):
    aligner, docs = _mk_aligner(live=True, tmp_path=tmp_path)
    sup = CompactionSupervisor(max_delta_fraction=0.01, interval_s=0.05,
                               prune_keep=1)
    rng = np.random.default_rng(11)
    with _ThreadServer(aligner, supervisor=sup) as srv:
        with AlignClient(port=srv.port) as client:
            assert client.healthz()["generation"] == 0
            new_doc = [int(t) for t in rng.integers(0, 1 << 40, 120)]
            client.add(new_doc)
            assert _wait_for(
                lambda: client.healthz()["generation"] >= 1), \
                "supervisor never compacted"
            snap = client.metrics()
            assert snap["counters"]["supervisor_compactions_total"] >= 1
            assert snap["counters"]["supervisor_failures_total"] == 0
            # the folded doc still serves from the new generation
            result = client.query(new_doc[20:100], 0.5)
            assert any(m["doc_id"] == len(docs)
                       for m in result["matches"])
    # generations beyond prune_keep were reclaimed on the way
    assert (tmp_path / "idx" / "v000001").exists()


def test_supervisor_rolls_back_after_exhausted_retries(tmp_path):
    aligner, docs = _mk_aligner(live=True, tmp_path=tmp_path)
    sup = CompactionSupervisor(max_delta_fraction=0.01, interval_s=0.05,
                               max_retries=1, backoff_base_s=0.02,
                               backoff_max_s=0.1)
    rng = np.random.default_rng(12)
    new_doc = [int(t) for t in rng.integers(0, 1 << 40, 120)]
    plan = FaultPlan(triggers=[Trigger(site="store.writer.*",
                                       sticky=True)])
    with _ThreadServer(aligner, supervisor=sup) as srv:
        with AlignClient(port=srv.port) as client:
            try:
                fault.arm(plan)
                client.add(new_doc)
                # attempts burn down: past max_retries the seal is rolled
                # back and /healthz reports degraded
                assert _wait_for(
                    lambda: client.healthz()["status"] == "degraded"), \
                    "supervisor never reported failure"
                snap = client.metrics()
                assert snap["counters"]["supervisor_retries_total"] >= 2
                assert snap["counters"]["supervisor_failures_total"] >= 1
                assert client.healthz()["generation"] == 0
                # the delta (or sealed level) kept serving the new doc
                result = client.query(new_doc[20:100], 0.5)
                assert any(m["doc_id"] == len(docs)
                           for m in result["matches"])
            finally:
                fault.disarm()
            # faults cleared: the supervisor converges and health returns
            assert _wait_for(
                lambda: client.healthz()["generation"] >= 1), \
                "supervisor never recovered"
            assert _wait_for(
                lambda: client.healthz()["status"] == "healthy")
            result = client.query(new_doc[20:100], 0.5)
            assert any(m["doc_id"] == len(docs)
                       for m in result["matches"])
