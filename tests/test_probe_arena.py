"""Fused probe arena: parity with the per-table probes on both re-keying
schemes, Pallas-vs-NumPy backend equality, the grouped small-sweep
dispatcher, threaded-vs-serial sharded fan-out, and arena persistence."""

import json

import numpy as np
import pytest

from repro.core import (FrozenTable, IndexBuilder, MultisetScheme,
                        ProbeArena, SearchIndex, ShardedAlignmentIndex,
                        WeightedScheme, WeightFn, batch_query,
                        estimate_similarity, query)
from repro.core.frozen import KIND_EMPTY, MODE_COORD, MODE_PACKED
from repro.core.results import QueryOptions
from repro.core.query import _sweep_small_batch, _sweep_text

SCHEMES = {
    "multiset": lambda: MultisetScheme(seed=13, k=8),
    "mix": lambda: MultisetScheme(seed=13, k=8, family="mix"),
    "weighted": lambda: WeightedScheme(weight=WeightFn(tf="raw"), seed=21,
                                       k=8),
}


def _corpus(rng, n_docs=6, vocab=30, n=50):
    return [rng.integers(0, vocab, size=n).astype(np.int64)
            for _ in range(n_docs)]


def _queries(rng, docs, n=5):
    qs = [docs[i % len(docs)][5:30].copy() for i in range(n)]
    qs.append(rng.integers(1000, 1030, size=12).astype(np.int64))  # miss
    return qs


def _blocks(results):
    return [(a.text_id, a.blocks) for a in results]


def _frozen(kind, docs):
    return IndexBuilder(scheme=SCHEMES[kind]()).build(docs).freeze()


# --------------------------------------------------------------------------
# arena layout + probe parity with the per-table path
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", list(SCHEMES))
def test_arena_mode_selection_and_layout(kind):
    rng = np.random.default_rng(0)
    frozen = _frozen(kind, _corpus(rng))
    arena = frozen.arena()
    # 61/64-bit multiset hashes overflow (coord << 56); ICWS pair keys with
    # a small vocabulary pack
    assert arena.mode == (MODE_PACKED if kind == "weighted" else MODE_COORD)
    assert arena.keys.dtype == np.uint64
    assert len(arena.keys) == sum(len(t) for t in frozen.tables)
    assert arena.offsets[0] == 0
    assert arena.offsets[-1] == len(arena.windows)
    assert len(arena.windows) == sum(len(t.windows) for t in frozen.tables)
    if arena.mode == MODE_PACKED:
        assert np.all(arena.keys[:-1] < arena.keys[1:])   # globally sorted
        assert len(arena.coords) == 0
    else:
        assert np.all(arena.keys[:-1] <= arena.keys[1:])
        tie = arena.keys[:-1] == arena.keys[1:]
        assert np.all(arena.coords[:-1][tie] < arena.coords[1:][tie])


@pytest.mark.parametrize("kind", list(SCHEMES))
@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_arena_probe_matches_per_table_probe(kind, backend):
    rng = np.random.default_rng(1)
    docs = _corpus(rng)
    frozen = _frozen(kind, docs)
    arena = frozen.arena()
    k = arena.k
    sketches = frozen.scheme.sketch_batch(_queries(rng, docs))
    pkeys, coords, valid = arena.encode_batch(sketches)
    starts, ends = arena.probe(pkeys, coords, valid, backend=backend)
    for b, sk in enumerate(sketches):
        for i in range(k):
            table = frozen.tables[i]
            ts, te = table.probe(table.encode([sk[i]]))
            rows_table = table.windows[ts[0]:te[0]]
            p = b * k + i
            rows_arena = arena.windows[starts[p]:ends[p]]
            assert np.array_equal(np.asarray(rows_arena),
                                  np.asarray(rows_table)), (b, i)


def test_arena_coord_mode_on_packable_keys_agrees():
    """Force the coord layout onto pair tables (packable) — both schemes
    must resolve every probe to the same posting rows."""
    rng = np.random.default_rng(2)
    docs = _corpus(rng)
    frozen = _frozen("weighted", docs)
    packed = ProbeArena.from_tables(frozen.tables, mode=MODE_PACKED)
    coord = ProbeArena.from_tables(frozen.tables, mode=MODE_COORD)
    sketches = frozen.scheme.sketch_batch(_queries(rng, docs))
    for arena in (packed, coord):
        pk, co, va = arena.encode_batch(sketches)
        s, e = arena.probe(pk, co, va)
        arena_rows = [np.asarray(arena.windows[s[p]:e[p]])
                      for p in range(len(pk))]
        if arena is packed:
            want = arena_rows
        else:
            assert all(np.array_equal(a, b)
                       for a, b in zip(arena_rows, want))


def test_arena_unpackable_probe_keys_miss():
    rng = np.random.default_rng(3)
    frozen = _frozen("weighted", _corpus(rng))
    arena = frozen.arena()
    k = arena.k
    # out-of-range tokens / k_int spans cannot equal any stored key
    bad = [[(1 << 40, 0)] * k, [(-5, 0)] * k, [(3, 1 << 40)] * k,
           [(3, -(1 << 40))] * k]
    pk, co, valid = arena.encode_batch(bad)
    assert not valid.any()
    s, e = arena.probe(pk, co, valid)
    assert not (e > s).any()


def test_arena_with_empty_tables():
    t_real = FrozenTable.from_dict({7: [(0, 0, 1, 0, 1)],
                                    9: [(1, 2, 3, 2, 3)]})
    t_empty = FrozenTable.from_dict({})
    assert t_empty.kind == KIND_EMPTY
    arena = ProbeArena.from_tables([t_real, t_empty])
    assert len(arena.keys) == 2
    pk, co, valid = arena.encode_batch([[7, 7], [9, 9], [8, 8]])
    # probes against the empty coordinate are invalid, hence misses
    assert list(valid) == [True, False, True, False, True, False]
    s, e = arena.probe(pk, co, valid)
    assert list((e - s)) == [1, 0, 1, 0, 0, 0]


def test_arena_probe_is_one_searchsorted(monkeypatch):
    rng = np.random.default_rng(4)
    docs = _corpus(rng)
    frozen = _frozen("multiset", docs)
    arena = frozen.arena()
    assert arena.max_run == 1     # independent hash functions rarely collide
    sketches = frozen.scheme.sketch_batch(_queries(rng, docs))
    pk, co, va = arena.encode_batch(sketches)
    calls = []
    real = np.searchsorted
    monkeypatch.setattr(np, "searchsorted",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    arena.probe(pk, co, va)
    assert len(calls) == 1


# --------------------------------------------------------------------------
# batched query engine over the arena
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", list(SCHEMES))
@pytest.mark.parametrize("theta", [0.3, 0.6, 1.0])
def test_batch_query_backends_equal_looped_query(kind, theta):
    rng = np.random.default_rng(5)
    docs = _corpus(rng)
    qs = _queries(rng, docs)
    builder = IndexBuilder(scheme=SCHEMES[kind]()).build(docs)
    frozen = builder.freeze()
    looped = [_blocks(query(builder, q, theta)) for q in qs]
    for probe_backend in ("numpy", "pallas", "percoord"):
        for sweep in ("grouped", "loop"):
            got = [_blocks(r) for r in batch_query(
                frozen, qs, theta,
                options=QueryOptions(probe_backend=probe_backend,
                                     sweep=sweep))]
            assert got == looped, (probe_backend, sweep)


def test_batch_query_empty_batch_and_all_miss():
    rng = np.random.default_rng(6)
    frozen = _frozen("multiset", _corpus(rng, n_docs=2))
    assert batch_query(frozen, [], 0.5) == []
    miss = [rng.integers(500, 520, 10).astype(np.int64)]
    assert batch_query(frozen, miss, 0.5) == [[]]


def test_sweep_small_batch_matches_sweep_text_randomized():
    rng = np.random.default_rng(7)
    for _ in range(40):
        m = int(rng.integers(1, 5))
        groups = []
        for _g in range(int(rng.integers(1, 10))):
            s = int(rng.integers(max(1, m), 17))
            lim = int(rng.integers(2, 10))    # tiny space -> duplicate and
            a = rng.integers(0, lim, s)       # zero-width boundaries
            b = a + rng.integers(0, lim, s)
            c = rng.integers(0, lim, s)
            d = c + rng.integers(0, lim, s)
            groups.append(np.stack([a, b, c, d], 1).astype(np.int64))
        sizes = np.array([len(g) for g in groups])
        arr = np.zeros((len(groups), int(sizes.max()), 4), np.int64)
        for g, wins in enumerate(groups):
            arr[g, :len(wins)] = wins
        assert _sweep_small_batch(arr, sizes, m) == \
            [_sweep_text(g, m) for g in groups]


# --------------------------------------------------------------------------
# sharded fan-out
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["multiset", "weighted"])
def test_sharded_threaded_equals_serial(kind):
    rng = np.random.default_rng(8)
    docs = _corpus(rng, n_docs=9)
    qs = _queries(rng, docs, n=4)
    sharded = ShardedAlignmentIndex(scheme=SCHEMES[kind](),
                                    n_shards=3).build(docs)
    looped = [_blocks(sharded.query(q, 0.5)) for q in qs]
    sharded.freeze()
    serial = [_blocks(r) for r in sharded.batch_query(
        qs, 0.5, options=QueryOptions(fanout="serial"))]
    threaded = [_blocks(r) for r in sharded.batch_query(
        qs, 0.5, options=QueryOptions(fanout="threaded"))]
    assert serial == threaded == looped


# --------------------------------------------------------------------------
# persistence
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["multiset", "weighted"])
def test_store_roundtrip_persists_mmap_arena(tmp_path, kind):
    rng = np.random.default_rng(9)
    docs = _corpus(rng)
    qs = _queries(rng, docs, n=3)
    frozen = _frozen(kind, docs)
    want = [_blocks(r) for r in batch_query(frozen, qs, 0.5)]
    frozen.save(tmp_path)
    assert (tmp_path / "arena.keys.npy").exists()
    loaded = SearchIndex.load(tmp_path, mmap=True)
    assert loaded._arena is not None          # restored, not rebuilt
    assert isinstance(loaded._arena.keys, np.memmap)
    assert isinstance(loaded._arena.windows, np.memmap)
    assert loaded._arena.mode == frozen.arena().mode
    assert [_blocks(r) for r in batch_query(loaded, qs, 0.5)] == want


def test_pre_arena_store_rebuilds_lazily(tmp_path):
    rng = np.random.default_rng(10)
    docs = _corpus(rng)
    qs = _queries(rng, docs, n=3)
    frozen = _frozen("multiset", docs)
    want = [_blocks(r) for r in batch_query(frozen, qs, 0.5)]
    frozen.save(tmp_path)
    # simulate a pre-arena store: no arena files, and a manifest that
    # never knew about them (no arena entry, no arena checksums)
    for p in tmp_path.glob("arena.*.npy"):
        p.unlink()  # repro: allow[RPR203] (pre-arena fixture)
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    manifest.pop("arena", None)
    manifest["checksums"] = {f: rec for f, rec in
                             manifest.get("checksums", {}).items()
                             if not f.startswith("arena.")}
    (tmp_path / "manifest.json").write_text(json.dumps(manifest))  # repro: allow[RPR202,RPR203]
    loaded = SearchIndex.load(tmp_path, mmap=True)
    assert loaded._arena is None
    assert [_blocks(r) for r in batch_query(loaded, qs, 0.5)] == want
    assert loaded._arena is not None          # built on first batch


def test_sharded_restore_keeps_per_shard_arenas(tmp_path):
    rng = np.random.default_rng(11)
    docs = _corpus(rng, n_docs=9)
    qs = _queries(rng, docs, n=3)
    sharded = ShardedAlignmentIndex(scheme=SCHEMES["multiset"](),
                                    n_shards=3).build(docs).freeze()
    want = [_blocks(r) for r in sharded.batch_query(qs, 0.5)]
    sharded.save(tmp_path)
    restored = ShardedAlignmentIndex(scheme=SCHEMES["multiset"](),
                                     n_shards=3)
    assert restored.restore(tmp_path, mmap=True) == []
    assert all(s._arena is not None for s in restored.shards)
    assert [_blocks(r) for r in restored.batch_query(qs, 0.5)] == want


# --------------------------------------------------------------------------
# estimate_similarity vectorization
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", list(SCHEMES))
def test_estimate_similarity_matches_scalar_reference(kind):
    rng = np.random.default_rng(12)
    docs = _corpus(rng, n_docs=2, n=40)
    idx = IndexBuilder(scheme=SCHEMES[kind]()).build(docs)
    for other in (docs[1], docs[0][5:30], docs[0]):
        got = estimate_similarity(idx, docs[0], other)
        sq = idx.scheme.sketch(docs[0])
        sd = idx.scheme.sketch(other)
        want = float(np.mean([1.0 if x == y else 0.0
                              for x, y in zip(sq, sd)]))
        assert got == want
    assert estimate_similarity(idx, docs[0], docs[0]) == 1.0
