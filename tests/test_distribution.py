"""Distribution correctness: sharded == unsharded, sharding-rule resolution,
and the dry-run cell builder on a small in-process mesh (subprocess with 8
placeholder devices, since jax locks the device count at first init)."""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def _run(script: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    res = _run(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import RunFlags, init_params
        from repro.models.params import abstract_params
        from repro.sharding import tree_specs
        from repro.train import OptConfig, init_opt_state, make_train_step

        cfg = get_config("mixtral-8x7b").reduced(vocab=512)
        oc = OptConfig(warmup_steps=1, decay_steps=10)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        rng = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(rng, (8, 16), 0, 512),
                 "labels": jax.random.randint(rng, (8, 16), 0, 512)}
        flags = RunFlags(q_chunk=0, scan_chunk=8, moe_mode="dense",
                         remat_policy="none")

        # single device reference
        ref_fn = jax.jit(make_train_step(cfg, oc, None, flags))
        p_ref, o_ref, m_ref = ref_fn(params, opt, batch)

        # 4x2 mesh (data x model)
        devs = np.array(jax.devices()[:8]).reshape(4, 2)
        mesh = Mesh(devs, ("data", "model"))
        specs = tree_specs(abstract_params(cfg), mesh)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
        osh = {"step": NamedSharding(mesh, P()), "m": psh, "v": psh}
        bsh = jax.tree.map(lambda _: NamedSharding(mesh, P("data")), batch)
        params2 = jax.device_put(params, psh)
        opt2 = jax.device_put(opt, osh)
        batch2 = jax.device_put(batch, bsh)
        with mesh:
            sh_fn = jax.jit(make_train_step(cfg, oc, mesh, flags),
                            in_shardings=(psh, osh, bsh),
                            out_shardings=(psh, osh, None))
            p_sh, o_sh, m_sh = sh_fn(params2, opt2, batch2)

        diffs = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                               - b.astype(jnp.float32)))),
            p_ref, jax.device_get(p_sh))
        print(json.dumps({
            "loss_ref": float(m_ref["loss"]),
            "loss_sh": float(m_sh["loss"]),
            "max_param_diff": max(jax.tree.leaves(diffs)),
        }))
    """))
    # CPU all-reduce ordering differs from single-device accumulation; the
    # fp32 loss agrees to ~4e-3 on host backends (exact on TPU meshes).
    assert abs(res["loss_ref"] - res["loss_sh"]) < 1e-2, res
    assert res["max_param_diff"] < 2e-3, res


def test_dryrun_cell_builder_small_mesh():
    """cell_arguments + build_step lower/compile on an 8-device mesh for one
    representative arch per family (the real grid runs at 256/512)."""
    res = _run(textwrap.dedent("""
        import json
        import jax, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get_config, SHAPES
        import dataclasses
        from repro.launch.dryrun import build_step, flags_for
        from repro.models.config import ShapeConfig

        devs = np.array(jax.devices()[:8]).reshape(4, 2)
        mesh = Mesh(devs, ("data", "model"))
        shape = ShapeConfig("t", 64, 8, "train")
        out = {}
        for arch in ["qwen1.5-4b", "mixtral-8x7b", "falcon-mamba-7b",
                     "zamba2-1.2b"]:
            cfg = get_config(arch).reduced(vocab=512)
            flags = flags_for(cfg, "train_4k", {"q_chunk": 0,
                                                "scan_chunk": 16,
                                                "seq_shard_carry": False})
            with mesh:
                jfn, sds = build_step(cfg, shape, mesh, flags, 2)
                c = jfn.lower(*sds).compile()
            ca = c.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # jax<=0.4.x
            out[arch] = int(ca.get("flops", 0) > 0)
        print(json.dumps(out))
    """))
    assert all(v == 1 for v in res.values()), res


def test_moe_shardmap_matches_dense_on_mesh():
    """Explicit-collective EP dispatch == dense reference (fwd + grad)."""
    res = _run(textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.models.layers import moe_dense, moe_shardmap
        devs = np.array(jax.devices()[:8]).reshape(4, 2)
        mesh = Mesh(devs, ("data", "model"))
        rng = np.random.default_rng(0)
        B, S, d, E, f, k = 4, 8, 16, 4, 32, 2
        x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
        wr = jnp.asarray(rng.normal(size=(d, E)), jnp.float32)
        w1 = jnp.asarray(rng.normal(size=(E, d, f))*0.2, jnp.float32)
        w3 = jnp.asarray(rng.normal(size=(E, d, f))*0.2, jnp.float32)
        w2 = jnp.asarray(rng.normal(size=(E, f, d))*0.2, jnp.float32)
        dense = moe_dense(x, wr, w1, w3, w2, k)
        with mesh:
            sm = jax.jit(lambda *a: moe_shardmap(*a, k, 16.0, mesh))(
                x, wr, w1, w3, w2)
            g1 = jax.grad(lambda x: jnp.sum(
                moe_dense(x, wr, w1, w3, w2, k) ** 2))(x)
            g2 = jax.grad(lambda x: jnp.sum(
                moe_shardmap(x, wr, w1, w3, w2, k, 16.0, mesh) ** 2))(x)
        print(json.dumps({
            "fwd_err": float(jnp.max(jnp.abs(dense - sm))),
            "grad_err": float(jnp.max(jnp.abs(g1 - g2)))}))
    """))
    assert res["fwd_err"] < 1e-4, res
    assert res["grad_err"] < 1e-3, res


@pytest.mark.parametrize("shape,logical,expected", [
    ((128256, 16384), ("vocab", "embed"), ("model", "data")),
    ((16384, 16384), ("embed", "q_feat"), ("data", "model")),
    ((8, 4096, 1536), ("experts", "embed", "moe_ff"), (None, "data", "model")),
    ((128, 4096, 1536), ("experts", "embed", "moe_ff"),
     ("model", "data", None)),
    ((20, 128), ("heads", "head_dim"), (None, "model")),
    ((1, 32768, 8, 128), ("batch", "seq_kv", "kv_heads", "head_dim"),
     (None, "model", None, None)),
])
def test_resolve_spec_rules(shape, logical, expected):
    """Divisibility fallbacks on a fake 16x16 mesh (no devices needed)."""
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    got = _resolve(shape, logical, FakeMesh())
    assert got == expected, (got, expected)


def _resolve(shape, logical, mesh):
    from repro.sharding import resolve_spec
    spec = resolve_spec(shape, logical, mesh)
    out = []
    for e in spec:
        if e is None or e == ():
            out.append(None)
        elif isinstance(e, tuple) and len(e) == 1:
            out.append(e[0])
        else:
            out.append(e)
    return tuple(out)
