"""Runtime engine-affinity guard (REPRO_THREAD_GUARD=1): ownership +
thread-name enforcement, the zero-overhead off path, and end-to-end
subprocess runs with the env var set and unset.

The env var is read once at ``repro.core.guard`` import, so the two
end-to-end cases run in subprocesses with a controlled environment; the
in-process tests flip ``guard.GUARD_ENABLED`` via monkeypatch and
decorate *fresh* functions (decoration-time check)."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.core import IndexBuilder, guard
from repro.core.live import LiveIndex

REPO = Path(__file__).resolve().parents[1]


def _sub_env(**extra):
    env = {k: v for k, v in os.environ.items()
           if k not in ("REPRO_THREAD_GUARD",)}
    env.update({"PYTHONPATH": str(REPO / "src"), "JAX_PLATFORMS": "cpu"})
    env.update(extra)
    return env


# -- static markers (always on, guard on or off) ----------------------------


def test_engine_only_markers_are_always_attached():
    for fn in (IndexBuilder.add_text, LiveIndex.add_text,
               LiveIndex.seal_delta, LiveIndex.promote_sealed,
               LiveIndex.compact):
        assert getattr(fn, "__engine_only__", False)
        assert not fn.__engine_reads_immutable__
    assert LiveIndex.merge_sealed.__engine_only__
    assert LiveIndex.merge_sealed.__engine_reads_immutable__


@pytest.mark.skipif(guard.GUARD_ENABLED,
                    reason="suite launched with REPRO_THREAD_GUARD=1")
def test_guard_off_returns_the_original_function():
    # zero overhead off: no wrapper, not even an if
    assert not hasattr(LiveIndex.add_text, "__wrapped__")
    assert not hasattr(IndexBuilder.add_text, "__wrapped__")


# -- enforcement semantics (fresh decorations with the flag flipped) --------


def _fresh_guarded(monkeypatch, **kw):
    monkeypatch.setattr(guard, "GUARD_ENABLED", True)

    class Idx:
        def __init__(self):
            self.calls = 0

        @guard.engine_only(**kw) if kw else guard.engine_only
        def mutate(self):
            self.calls += 1
            return self.calls

    return Idx()


def test_guarded_call_raises_off_engine_when_owned(monkeypatch):
    idx = _fresh_guarded(monkeypatch)
    idx.mutate()                       # unowned: any thread may mutate
    guard.adopt(idx)
    with pytest.raises(guard.EngineAffinityError, match="engine-only"):
        idx.mutate()
    assert idx.calls == 1              # the guarded call never ran
    guard.disown(idx)
    idx.mutate()                       # released: unguarded again
    assert idx.calls == 2


def test_guarded_call_succeeds_on_engine_named_thread(monkeypatch):
    idx = _fresh_guarded(monkeypatch)
    guard.adopt(idx)
    out = []
    t = threading.Thread(target=lambda: out.append(idx.mutate()),
                         name=guard.ENGINE_THREAD_PREFIX + "_test_0")
    t.start()
    t.join(10)
    assert out == [1]


def test_reads_immutable_never_wraps(monkeypatch):
    idx = _fresh_guarded(monkeypatch, reads_immutable=True)
    guard.adopt(idx)
    assert idx.mutate() == 1           # off-band merge path stays callable
    assert type(idx).mutate.__engine_only__
    assert type(idx).mutate.__engine_reads_immutable__
    assert not hasattr(type(idx).mutate, "__wrapped__")


def test_adopt_tolerates_none_and_slots():
    class Slotted:
        __slots__ = ()

    guard.adopt(None, Slotted())       # must not raise
    guard.disown(None, Slotted())


# -- end-to-end subprocess runs ---------------------------------------------


_E2E_SCRIPT = r"""
import asyncio, json
import numpy as np
from repro.api import Aligner
from repro.serve import AlignServer

rng = np.random.default_rng(0)
docs = [rng.integers(0, 1 << 30, size=60) for _ in range(4)]
store = "idx_store"
Aligner.build(docs, similarity="multiset", seed=3, k=4,
              pipeline="columnar", store=store)
aligner = Aligner.load(store, live=True)

async def main():
    srv = await AlignServer(aligner).start()
    try:
        body = json.dumps(
            {"text": [int(t) for t in docs[0][:30]]}).encode()
        status, _ = await srv.handle_add(body)
        assert status == 200, f"engine-path add failed: {status}"
        print("ENGINE-OK")
        try:
            aligner.add([9, 9, 9])          # main thread, engine-owned
        except Exception as e:
            print("DIRECT:" + type(e).__name__)
        else:
            print("DIRECT:no-error")
    finally:
        await srv.close()
    aligner.add([7, 7, 7])                  # disowned on close: allowed
    print("POST-CLOSE-OK")

asyncio.run(main())
"""


def _run_e2e(tmp_path, env):
    return subprocess.run(
        [sys.executable, "-c", _E2E_SCRIPT], cwd=tmp_path, env=env,
        capture_output=True, text=True, timeout=300)


def test_guard_on_blocks_direct_add_but_not_engine_path(tmp_path):
    proc = _run_e2e(tmp_path, _sub_env(REPRO_THREAD_GUARD="1"))
    assert proc.returncode == 0, proc.stderr
    out = proc.stdout.split()
    assert "ENGINE-OK" in out
    assert "DIRECT:EngineAffinityError" in out
    assert "POST-CLOSE-OK" in out


def test_guard_off_direct_add_is_unrestricted(tmp_path):
    proc = _run_e2e(tmp_path, _sub_env())
    assert proc.returncode == 0, proc.stderr
    out = proc.stdout.split()
    assert "ENGINE-OK" in out
    assert "DIRECT:no-error" in out
