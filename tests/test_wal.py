"""Durable live ingest (`repro.wal`): frame/segment format round-trips,
group-commit fsync policy, torn-tail repair under torn/crash/crash_after
faults, idempotent crash replay (twice == once), watermark truncation at
compaction, request-id dedup across replay, fsck's WAL verification, and
a recorded-schedule kill sweep over every ``wal.*`` fsio site (mirroring
the compaction sweep in tests/test_live_index.py)."""

import os
import shutil
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro import fault
from repro.api import Aligner
from repro.core import IndexBuilder, batch_query, make_scheme, save_index
from repro.core.live import LiveIndex
from repro.core.store import read_manifest, resolve_store, verify_store
from repro.fsck import check_store
from repro.wal import (WalConfig, WalError, WriteAheadLog, iter_records,
                       segment_paths, verify_wal, wal_dir)

SEED_DOCS = 4


def _doc(i, n=60, vocab=40):
    return np.random.default_rng(500 + i).integers(0, vocab, n).astype(
        np.int64)


def _seed_store(root, n=SEED_DOCS):
    scheme = make_scheme("multiset", seed=5, k=8)
    docs = [_doc(i) for i in range(n)]
    save_index(IndexBuilder(scheme=scheme).build(docs).freeze(), root)
    return scheme, docs


def _blocks(res):
    return [[(a.text_id, a.blocks) for a in r] for r in res]


def _expected(scheme, corpus, qs):
    oracle = IndexBuilder(scheme=scheme).build(corpus)
    return _blocks(batch_query(oracle, qs, 0.5))


# --------------------------------------------------------------------------
# frame + segment format
# --------------------------------------------------------------------------

def test_append_reopen_roundtrip(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal")
    toks = np.array([3, 1, 4, 1, 5], np.int64)
    assert wal.append(7, "rid-a", toks) == 0
    assert wal.append(8, None, toks * 2) == 1
    wal.sync()
    wal.close()

    recs = list(iter_records(tmp_path / "wal"))
    assert [(r.lsn, r.gid, r.request_id) for r in recs] == \
        [(0, 7, "rid-a"), (1, 8, None)]
    assert np.array_equal(recs[0].tokens, toks)
    assert np.array_equal(recs[1].tokens, toks * 2)

    # a reopened writer resumes numbering after the durable end
    again = WriteAheadLog(tmp_path / "wal")
    assert again.next_lsn == 2 and again.durable_lsn == 2
    assert again.append(9, None, toks) == 2


def test_rotation_names_segments_by_base_lsn(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal",
                        config=WalConfig(segment_bytes=200))
    for i in range(5):
        wal.append(i, None, np.arange(12, dtype=np.int64))
    wal.sync()
    segs = segment_paths(tmp_path / "wal")
    assert len(segs) > 1
    assert [int(p.stem) for p in segs][0] == 0
    # base names must equal the running record count (self-describing)
    recs = list(iter_records(tmp_path / "wal"))
    assert [r.lsn for r in recs] == list(range(5))
    assert wal.counters["rotations"] == len(segs)


def test_group_commit_fsync_policy(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal",
                        config=WalConfig(fsync_every_n=3))
    for i in range(7):
        wal.append(i, None, np.arange(4, dtype=np.int64))
        wal.maybe_sync()
    assert wal.counters["fsyncs"] == 2           # at appends 3 and 6
    assert wal.pending_records == 1
    assert wal.sync() == 7                       # explicit barrier
    assert wal.pending_records == 0
    assert wal.counters["fsyncs"] == 3

    async_wal = WriteAheadLog(tmp_path / "w2",
                              config=WalConfig(fsync_every_n=0))
    for i in range(4):
        async_wal.append(i, None, np.arange(4, dtype=np.int64))
        async_wal.maybe_sync()
    assert async_wal.counters["fsyncs"] == 0     # async: explicit-only
    assert async_wal.pending_records == 4


def test_segment_gap_refuses_to_open(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal",
                        config=WalConfig(segment_bytes=200))
    for i in range(5):
        wal.append(i, None, np.arange(12, dtype=np.int64))
    wal.close()
    segs = segment_paths(tmp_path / "wal")
    assert len(segs) >= 3
    segs[1].unlink()                             # mid-chain segment gone
    with pytest.raises(WalError, match="gap"):
        WriteAheadLog(tmp_path / "wal")
    # the read-only observer scan tolerates it (stops are per-segment)
    assert list(iter_records(tmp_path / "wal"))


# --------------------------------------------------------------------------
# torn tails: in-process torn write + subprocess kills
# --------------------------------------------------------------------------

def test_torn_append_repaired_in_process(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal")
    wal.append(0, None, np.arange(8, dtype=np.int64))
    plan = fault.FaultPlan(
        triggers=[fault.Trigger(site="wal.append", hit=1, mode="torn")])
    with fault.armed(plan):
        with pytest.raises(fault.FaultInjected):
            wal.append(1, None, np.arange(8, dtype=np.int64))
    # the partial frame was truncated back off: the log is still clean
    assert wal.counters["tail_repairs"] == 1
    assert wal.append(1, None, np.arange(8, dtype=np.int64)) == 1
    wal.sync()
    assert [r.lsn for r in iter_records(tmp_path / "wal")] == [0, 1]


_CHILD = r"""
import sys
import numpy as np
from repro.wal import WalConfig, WriteAheadLog

wal = WriteAheadLog(sys.argv[1],
                    config=WalConfig(fsync_every_n=2, segment_bytes=512))
for i in range(3):
    rng = np.random.default_rng(500 + i)
    wal.append(100 + i, f"doc-{i}", rng.integers(0, 40, 60).astype(np.int64))
    wal.maybe_sync()
wal.sync()
print("CHILD_DONE")
"""


@pytest.mark.parametrize("mode", ["crash", "crash_after"])
@pytest.mark.parametrize("site", ["wal.append", "wal.fsync", "wal.rotate"])
def test_kill_mid_write_then_reopen_repairs(tmp_path, site, mode):
    """Kill a writer subprocess at each WAL site (before and after the
    durable op): reopening must repair any torn tail, keep every
    complete frame, and resume appending cleanly."""
    plan = fault.FaultPlan(
        triggers=[fault.Trigger(site=site, hit=2, mode=mode)])
    repo = Path(__file__).resolve().parent.parent
    env = {**os.environ, "REPRO_FAULT_PLAN": plan.to_json(),
           "PYTHONPATH": str(repo / "src")}
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD, str(tmp_path / "wal")],
        env=env, capture_output=True, text=True)
    assert proc.returncode in (0, fault.FAULT_EXIT), proc.stderr
    killed = proc.returncode == fault.FAULT_EXIT
    assert killed == ("CHILD_DONE" not in proc.stdout)

    wal = WriteAheadLog(tmp_path / "wal",
                        config=WalConfig(fsync_every_n=2, segment_bytes=512))
    n = wal.next_lsn
    assert 0 <= n <= 3
    recs = list(wal.records())
    assert [r.lsn for r in recs] == list(range(n))
    # every surviving record is complete and CRC-clean with its payload
    for i, r in enumerate(recs):
        assert r.gid == 100 + i and r.request_id == f"doc-{i}"
        assert np.array_equal(
            r.tokens,
            np.random.default_rng(500 + i).integers(0, 40, 60))
    # ...and the repaired log accepts new appends exactly at next_lsn
    assert wal.append(100 + n, f"doc-{n}",
                      np.arange(6, dtype=np.int64)) == n
    wal.sync()


def test_torn_tail_bytes_truncated_on_open(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal")
    wal.append(0, None, np.arange(8, dtype=np.int64))
    wal.sync()
    wal.close()
    seg = segment_paths(tmp_path / "wal")[0]
    good = seg.read_bytes()
    seg.write_bytes(good + b"\x99\x07garbage")    # repro: allow[RPR203]
    rep = verify_wal(tmp_path)
    assert rep["ok"] and rep["torn_tail"]         # tail debris: tolerated
    again = WriteAheadLog(tmp_path / "wal")
    assert again.counters["tail_repairs"] == 1
    assert seg.read_bytes() == good               # byte-exact repair
    assert again.next_lsn == 1


# --------------------------------------------------------------------------
# LiveIndex replay, dedup, and watermark truncation
# --------------------------------------------------------------------------

WAL_CFG = WalConfig(fsync_every_n=2, segment_bytes=1024)


def test_replay_is_idempotent_and_matches_oracle(tmp_path):
    root = tmp_path / "idx"
    scheme, docs = _seed_store(root)
    live = LiveIndex.open(root, wal=WAL_CFG)
    fresh = [_doc(SEED_DOCS + i) for i in range(3)]
    for i, t in enumerate(fresh):
        live.add_text(t, request_id=f"doc-{SEED_DOCS + i}")
    live.wal_commit()

    corpus = docs + fresh
    qs = [corpus[2][5:50], fresh[-1][:30]]
    want = _expected(scheme, corpus, qs)
    assert _blocks(live.batch_query(qs, 0.5)) == want

    # reopening replays the un-compacted records; twice == once
    for _ in range(2):
        re = LiveIndex.open(root, wal=WAL_CFG)
        assert re.wal_replayed == 3
        assert re.num_texts == len(corpus)
        assert _blocks(re.batch_query(qs, 0.5)) == want
        # the dedup window survives replay: a replayed id is answered
        # from the window, indexing nothing
        n = re.num_texts
        lid = re.add_text(np.arange(9, dtype=np.int64),
                          request_id=f"doc-{SEED_DOCS}")
        assert re.num_texts == n and re.doc_map[lid] == SEED_DOCS

    # the plain (non-WAL) open still serves only the committed prefix
    assert LiveIndex.open(root).num_texts == SEED_DOCS


def test_compaction_truncates_covered_segments(tmp_path):
    root = tmp_path / "idx"
    scheme, docs = _seed_store(root)
    live = LiveIndex.open(root, wal=WalConfig(fsync_every_n=1,
                                              segment_bytes=600))
    fresh = [_doc(SEED_DOCS + i) for i in range(3)]
    for i, t in enumerate(fresh):
        live.add_text(t, request_id=f"doc-{SEED_DOCS + i}")
    assert len(segment_paths(wal_dir(root))) >= 3    # rotation happened

    gen = live.compact()
    assert gen == 1
    manifest = read_manifest(resolve_store(root))
    assert manifest["wal_watermark"] == 3
    # covered segments removed; the active tail (debris) survives
    assert len(segment_paths(wal_dir(root))) == 1
    assert live.wal_status()["lag_records"] == 0

    # post-compact reopen replays nothing but keeps the LSN chain
    re = LiveIndex.open(root, wal=WAL_CFG)
    assert re.wal_replayed == 0 and re.num_texts == len(docs) + 3
    # ...and the dedup window CLOSED at compaction: the same id now
    # indexes anew (the documented un-compacted-window bound)
    n = re.num_texts
    re.add_text(np.arange(9, dtype=np.int64),
                request_id=f"doc-{SEED_DOCS}")
    assert re.num_texts == n + 1

    # append after full truncation: LSNs continue past the watermark
    assert re.wal.next_lsn == 4
    qs = [docs[2][5:50]]
    want = _expected(scheme, docs + fresh +
                     [np.arange(9, dtype=np.int64)], qs)
    assert _blocks(re.batch_query(qs, 0.5)) == want


def test_rollback_keeps_wal_segments(tmp_path):
    root = tmp_path / "idx"
    _scheme, _docs = _seed_store(root)
    live = LiveIndex.open(root, wal=WAL_CFG)
    live.add_text(_doc(SEED_DOCS), request_id="r0")
    live.wal_commit()
    n_segs = len(segment_paths(wal_dir(root)))
    live.seal_delta()
    live.unseal_delta()
    assert len(segment_paths(wal_dir(root))) == n_segs
    # after rollback a compaction still truncates correctly
    live.compact()
    manifest = read_manifest(resolve_store(root))
    assert manifest["wal_watermark"] == 1


def test_aligner_load_wires_the_wal(tmp_path):
    root = tmp_path / "idx"
    docs = ["alpha beta gamma delta " * 6, "epsilon zeta eta " * 8]
    Aligner.build(docs, k=4, store=str(root), pipeline="columnar")
    a = Aligner.load(root, live=True, wal=True)
    d1 = a.add("alpha beta gamma " * 7, request_id="rid-x")
    d2 = a.add("totally different words " * 7, request_id="rid-x")
    assert d1 == d2 == 2                      # deduped
    a2 = Aligner.load(root, live=True, wal=True)
    assert a2.num_docs == 3                   # replayed
    with pytest.raises(ValueError, match="live"):
        Aligner.load(root, wal=True)


# --------------------------------------------------------------------------
# fsck / verify_wal
# --------------------------------------------------------------------------

def _live_with_wal(tmp_path):
    root = tmp_path / "idx"
    _seed_store(root)
    live = LiveIndex.open(root, wal=WalConfig(fsync_every_n=1,
                                              segment_bytes=600))
    for i in range(3):
        live.add_text(_doc(SEED_DOCS + i))
    return root, live


def test_fsck_passes_healthy_wal(tmp_path):
    root, _live = _live_with_wal(tmp_path)
    rep = verify_store(root)
    assert rep["ok"] and rep["wal"]["ok"]
    assert rep["wal"]["records"] == 3
    assert check_store(root)["ok"]


def test_fsck_fails_mid_chain_corruption(tmp_path):
    root, live = _live_with_wal(tmp_path)
    live.wal.close()
    first = segment_paths(wal_dir(root))[0]
    data = bytearray(first.read_bytes())
    data[len(data) // 2] ^= 0xFF
    first.write_bytes(bytes(data))            # repro: allow[RPR203]
    rep = verify_store(root)
    assert not rep["ok"]
    assert any("mid-chain" in p for p in rep["wal"]["problems"])
    with pytest.raises(WalError):
        WriteAheadLog(wal_dir(root))


def test_fsck_fails_watermark_past_chain_end(tmp_path):
    root, live = _live_with_wal(tmp_path)
    live.compact()                            # watermark = 3, chain end = 3
    live.wal.close()
    for seg in segment_paths(wal_dir(root)):
        seg.unlink()                          # repro: allow[RPR203]
    rep = verify_wal(root, serving_watermark=3)
    assert rep["ok"]                          # empty chain: nothing to say
    # rebuild a chain that ENDS before the watermark
    w = WriteAheadLog(wal_dir(root), start_lsn=0)
    w.append(99, None, np.arange(4, dtype=np.int64))
    w.close()
    rep = verify_wal(root, serving_watermark=3)
    assert not rep["ok"]
    assert any("never durable" in p for p in rep["problems"])


def test_fsck_fails_replay_window_gap(tmp_path):
    root, live = _live_with_wal(tmp_path)
    live.wal.close()
    # chain starts at 0 but pretend the manifest covers only up to -?:
    # simulate lost replay-window records by a watermark below first_lsn
    for seg in segment_paths(wal_dir(root))[:1]:
        seg.unlink()                          # repro: allow[RPR203]
    rep = verify_wal(root, serving_watermark=0)
    assert not rep["ok"]
    assert any("replay window" in p for p in rep["problems"])


# --------------------------------------------------------------------------
# recorded-site kill sweep (mirrors the compaction sweep)
# --------------------------------------------------------------------------

def _wal_site_schedule():
    """Record every ``wal.*`` fsio site one ingest round hits — new WAL
    call sites join the sweep automatically."""
    tmp = Path(tempfile.mkdtemp())
    try:
        root = tmp / "idx"
        _seed_store(root)
        live = LiveIndex.open(root, wal=WalConfig(fsync_every_n=2,
                                                  segment_bytes=600))
        with fault.record_sites() as sites:
            for i in range(3):
                live.add_text(_doc(SEED_DOCS + i), request_id=f"d{i}")
            live.wal_commit()
            live.compact()
        return sorted({(s, h) for s, h in sites if s.startswith("wal.")})
    finally:
        shutil.rmtree(tmp)


_WAL_SITES = _wal_site_schedule()


def test_schedule_covers_every_wal_site_family():
    fams = {s.rsplit(".", 1)[0] if s.startswith("wal.truncate") else s
            for s, _ in _WAL_SITES}
    assert fams == {"wal.append", "wal.fsync", "wal.rotate", "wal.truncate"}


_SWEEP_CHILD = r"""
import sys
import numpy as np
from repro.core.live import LiveIndex
from repro.wal import WalConfig


def doc(i):
    return np.random.default_rng(500 + i).integers(0, 40, 60).astype(
        np.int64)


live = LiveIndex.open(sys.argv[1],
                      wal=WalConfig(fsync_every_n=2, segment_bytes=600))
n = live.num_texts
for i in range(n, n + 3):
    live.add_text(doc(i), request_id=f"doc-{i}")
live.wal_commit()
live.compact()
print("CHILD_DONE")
"""


@pytest.mark.parametrize("mode", ["crash", "crash_after"])
@pytest.mark.parametrize(
    "site,hit", _WAL_SITES, ids=[f"{s}@{h}" for s, h in _WAL_SITES])
def test_ingest_kill_sweep_recovers_acknowledged_state(tmp_path, site, hit,
                                                       mode):
    """os._exit the ingest workload at every recorded ``wal.*`` site:
    recovery must serve a clean prefix of the deterministic corpus,
    bit-match a from-scratch oracle, and fsck clean."""
    root = tmp_path / "idx"
    scheme, docs = _seed_store(root)
    plan = fault.FaultPlan(
        triggers=[fault.Trigger(site=site, hit=hit, mode=mode)])
    repo = Path(__file__).resolve().parent.parent
    env = {**os.environ, "REPRO_FAULT_PLAN": plan.to_json(),
           "PYTHONPATH": str(repo / "src")}
    proc = subprocess.run(
        [sys.executable, "-c", _SWEEP_CHILD, str(root)],
        env=env, capture_output=True, text=True)
    assert proc.returncode in (0, fault.FAULT_EXIT), \
        proc.stdout + proc.stderr

    rep = check_store(root)
    assert rep["ok"], rep
    assert not rep["quarantined"]

    re = LiveIndex.open(root, wal=WalConfig(fsync_every_n=2,
                                            segment_bytes=600))
    n = re.num_texts
    assert SEED_DOCS <= n <= SEED_DOCS + 3
    corpus = [_doc(i) for i in range(n)]
    qs = [corpus[2][5:50], corpus[-1][:30]]
    assert _blocks(re.batch_query(qs, 0.5)) == _expected(scheme, corpus, qs)
