"""Per-architecture smoke tests (deliverable f): every assigned arch, reduced
config, forward + one train step on CPU; decode == teacher-forced forward."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, arch_cells, get_config
from repro.models import (RunFlags, decode_step, forward, init_params,
                          prefill)
from repro.train import OptConfig, init_opt_state, make_train_step

FLAGS = RunFlags(q_chunk=4, scan_chunk=4, moe_mode="dense",
                 remat_policy="full")


def _batch(cfg, B=2, S=16, seed=0):
    rng = jax.random.PRNGKey(seed)
    if cfg.frontend == "none":
        return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
                "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    return {"embeds": 0.02 * jax.random.normal(rng, (B, S, cfg.d_model),
                                               jnp.bfloat16),
            "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    b = _batch(cfg)
    logits = forward(params, cfg, tokens=b.get("tokens"),
                     embeds=b.get("embeds"), flags=FLAGS)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_runs_and_updates(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, OptConfig(warmup_steps=1,
                                                  decay_steps=10),
                                   flags=FLAGS, microbatches=2))
    b = _batch(cfg)
    p2, o2, m = step(params, opt, b)
    assert bool(jnp.isfinite(m["loss"]))
    assert int(o2["step"]) == 1
    # at least one parameter moved
    moved = any(bool(jnp.any(a != b_)) for a, b_ in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_teacher_forcing(arch):
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              compute_dtype="float32")
    flags = dataclasses.replace(FLAGS, remat_policy="none")
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 2), 0, cfg.vocab)
    ref = forward(params, cfg, tokens=toks, flags=flags)
    lg, cache = prefill(params, cfg, tokens=toks[:, :S], max_seq=S + 2,
                        flags=flags)
    scale = float(jnp.max(jnp.abs(ref)))
    errs = [float(jnp.max(jnp.abs(lg[:, 0] - ref[:, S - 1])))]
    for t in range(2):
        lg, cache = decode_step(params, cache, toks[:, S + t:S + t + 1],
                                jnp.int32(S + t), cfg, flags=flags)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - ref[:, S + t]))))
    assert max(errs) / scale < 2e-4, errs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_descriptors(arch):
    from repro.models import param_count_tree
    cfg = get_config(arch)
    analytic = cfg.param_count()
    tree = param_count_tree(cfg)
    assert abs(analytic - tree) / tree < 0.02, (analytic, tree)


def test_assigned_cells_cover_40():
    cells = [(a, s) for a in ARCH_IDS for s in arch_cells(a)]
    assert len(cells) == 40
    runnable = [c for c in cells if not c[1].endswith(":skip")]
    skipped = [c for c in cells if c[1].endswith(":skip")]
    assert len(skipped) == 7     # pure full-attention archs x long_500k
    assert len(runnable) == 33


def test_moe_scatter_matches_dense():
    from repro.models.layers import moe_dense, moe_scatter
    rng = np.random.default_rng(0)
    B, S, d, E, f, k = 2, 8, 16, 4, 32, 2
    x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    wr = jnp.asarray(rng.normal(size=(d, E)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(E, d, f)) * 0.1, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(E, f, d)) * 0.1, jnp.float32)
    dense = moe_dense(x, wr, w1, w3, w2, k)
    scatter = moe_scatter(x, wr, w1, w3, w2, k, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(scatter),
                               rtol=2e-5, atol=2e-5)


def test_swa_masks_long_range():
    """Sliding-window attention must ignore keys beyond the window."""
    arch = "mixtral-8x7b"
    cfg = dataclasses.replace(get_config(arch).reduced(),
                              compute_dtype="float32", swa_window=4)
    flags = dataclasses.replace(FLAGS, remat_policy="none", q_chunk=0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, cfg.vocab)
    ref = forward(params, cfg, tokens=toks, flags=flags)
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 5) % cfg.vocab)
    out = forward(params, cfg, tokens=toks2, flags=flags)
    # last position attends only to the last 4 -> unchanged
    np.testing.assert_allclose(np.asarray(ref[0, -1]), np.asarray(out[0, -1]),
                               rtol=1e-5, atol=1e-5)
