"""End-to-end query correctness: results == brute-force Definition 1."""

import math

import numpy as np
import pytest

from repro.core import (IndexBuilder, MultisetScheme, WeightFn,
                        WeightedScheme, query)


def brute_force_results(scheme, data_texts, q_tokens, theta):
    """All (tid, i, j) with estimated Jaccard >= theta, by definition."""
    k = scheme.k
    m = math.ceil(k * theta)
    sq = scheme.sketch(q_tokens)
    out = set()
    for tid, tokens in enumerate(data_texts):
        n = len(tokens)
        for i in range(n):
            for j in range(i, n):
                ss = scheme.sketch(tokens[i:j + 1])
                matches = sum(1 for x, y in zip(sq, ss) if x == y)
                if matches >= m:
                    out.add((tid, i, j))
    return out


def index_results(index, q_tokens, theta):
    out = set()
    for r in query(index, q_tokens, theta):
        for (i, j) in r.cells():
            out.add((r.text_id, i, j))
    return out


@pytest.mark.parametrize("method", ["mono_all", "mono_active", "allalign"])
def test_query_equals_bruteforce_multiset(method):
    rng = np.random.default_rng(0)
    data = [rng.integers(0, 8, size=24).astype(np.int64) for _ in range(3)]
    q = data[0][5:15].copy()
    scheme = MultisetScheme(seed=13, k=8)
    index = IndexBuilder(scheme=scheme, method=method).build(data)
    for theta in (0.3, 0.6, 0.9):
        assert index_results(index, q, theta) == \
            brute_force_results(scheme, data, q, theta), (method, theta)


@pytest.mark.parametrize("tf", ["raw", "log"])
def test_query_equals_bruteforce_weighted(tf):
    rng = np.random.default_rng(4)
    data = [rng.integers(0, 6, size=20).astype(np.int64) for _ in range(2)]
    q = data[1][3:13].copy()
    scheme = WeightedScheme(weight=WeightFn(tf=tf), seed=21, k=8)
    index = IndexBuilder(scheme=scheme, method="mono_active").build(data)
    for theta in (0.4, 0.75):
        assert index_results(index, q, theta) == \
            brute_force_results(scheme, data, q, theta), (tf, theta)


def test_exact_duplicate_found_at_theta_1():
    rng = np.random.default_rng(2)
    doc = rng.integers(0, 50, size=40).astype(np.int64)
    data = [np.concatenate([rng.integers(0, 50, size=10), doc,
                            rng.integers(0, 50, size=10)])]
    scheme = MultisetScheme(seed=3, k=16)
    index = IndexBuilder(scheme=scheme, method="mono_active").build(data)
    res = index_results(index, doc, theta=1.0)
    assert (0, 10, 49) in res       # the exact copy is always retrieved


def test_disjoint_query_returns_nothing():
    rng = np.random.default_rng(6)
    data = [rng.integers(0, 20, size=30).astype(np.int64)]
    q = rng.integers(100, 120, size=10).astype(np.int64)
    scheme = MultisetScheme(seed=7, k=16)
    index = IndexBuilder(scheme=scheme, method="mono_active").build(data)
    assert index_results(index, q, theta=0.2) == set()


def test_index_state_dict_roundtrip():
    rng = np.random.default_rng(8)
    data = [rng.integers(0, 10, size=25).astype(np.int64) for _ in range(2)]
    scheme = MultisetScheme(seed=9, k=8)
    index = IndexBuilder(scheme=scheme, method="mono_active").build(data)
    state = index.state_dict()
    index2 = IndexBuilder(scheme=MultisetScheme(seed=9, k=8))
    index2.load_state_dict(state)
    q = data[0][2:18]
    a = index_results(index, q, 0.5)
    b = index_results(index2, q, 0.5)
    assert a == b and a
