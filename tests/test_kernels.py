"""Pallas kernels (interpret=True) vs pure-jnp oracles: shape/dtype sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ops
from repro.kernels.icws_hash import icws_hash_grid, icws_sketch
from repro.kernels.minhash_sketch import minhash_sketch
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.selective_scan import selective_scan_pallas
from repro.kernels import ref


def _icws_inputs(rng, K, T):
    r = jnp.asarray(rng.gamma(2.0, 1.0, (K, T)), jnp.float32)
    c = jnp.asarray(rng.gamma(2.0, 1.0, (K, T)), jnp.float32)
    b = jnp.asarray(rng.uniform(0, 1, (K, T)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 5.0, (T,)), jnp.float32)
    return r, c, b, w


@pytest.mark.parametrize("K,T", [(8, 128), (16, 256), (5, 100), (64, 391),
                                 (1, 1), (9, 129)])
def test_icws_hash_grid_matches_ref(K, T):
    rng = np.random.default_rng(K * 1000 + T)
    r, c, b, w = _icws_inputs(rng, K, T)
    kint, a = icws_hash_grid(r, c, b, w, interpret=True)
    kint_r, a_r = ref.icws_hash_grid_ref(r, c, b, w)
    assert (kint == kint_r).all()
    assert_allclose(np.asarray(a), np.asarray(a_r), rtol=1e-6)


@pytest.mark.parametrize("K,T", [(8, 128), (16, 300), (3, 17), (64, 1024)])
def test_icws_sketch_matches_ref(K, T):
    rng = np.random.default_rng(K + T)
    r, c, b, w = _icws_inputs(rng, K, T)
    mina, argt, kint = icws_sketch(r, c, b, w, interpret=True)
    mina_r, argt_r, kint_r = ref.icws_sketch_ref(r, c, b, w)
    # rtol 2e-5: XLA may fma-contract the a-value expression differently in
    # the two programs; identity fields must still agree exactly.
    assert_allclose(np.asarray(mina), np.asarray(mina_r), rtol=2e-5)
    assert (argt == argt_r).all()
    assert (kint == kint_r).all()


def test_icws_sketch_masked_tokens():
    rng = np.random.default_rng(0)
    r, c, b, w = _icws_inputs(rng, 8, 64)
    w = w.at[32:].set(0.0)   # masked tail must never win the argmin
    _, argt, _ = icws_sketch(r, c, b, w, interpret=True)
    assert (np.asarray(argt) < 32).all()


@pytest.mark.parametrize("B,N,K", [(2, 128, 8), (3, 200, 16), (1, 64, 64),
                                   (4, 1000, 7)])
def test_minhash_sketch_matches_ref(B, N, K):
    rng = np.random.default_rng(B * N + K)
    tokens = rng.integers(0, 5000, (B, N)).astype(np.int32)
    tokens[:, N - N // 4:] = -1          # padding tail
    occ = rng.integers(1, 20, (B, N)).astype(np.int32)
    seeds = rng.integers(1, 2**32 - 1, (K,), dtype=np.uint32)
    out = minhash_sketch(jnp.asarray(tokens), jnp.asarray(occ),
                         jnp.asarray(seeds), interpret=True)
    exp = ref.minhash_sketch_ref(jnp.asarray(tokens), jnp.asarray(occ),
                                 jnp.asarray(seeds))
    assert (np.asarray(out) == np.asarray(exp)).all()


@pytest.mark.parametrize("B,H,KV,D,S", [(2, 8, 8, 128, 256),
                                        (1, 8, 2, 128, 300),
                                        (2, 4, 1, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(B, H, KV, D, S, dtype):
    rng = np.random.default_rng(B + H + S)
    q = jnp.asarray(rng.normal(size=(B, H, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), dtype)
    pos = jnp.int32(S - 7)
    out = decode_attention_pallas(q, k, v, pos, interpret=True)
    exp = ref.decode_attention_ref(q, k, v, pos)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    assert_allclose(np.asarray(out, np.float32), np.asarray(exp, np.float32),
                    rtol=tol, atol=tol)


def test_decode_attention_respects_pos_mask():
    # keys beyond pos must not influence the output
    rng = np.random.default_rng(5)
    B, H, D, S = 1, 4, 128, 256
    q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    pos = jnp.int32(100)
    out1 = decode_attention_pallas(q, k, v, pos, interpret=True)
    k2 = k.at[:, 101:].set(99.0)
    v2 = v.at[:, 101:].set(-99.0)
    out2 = decode_attention_pallas(q, k2, v2, pos, interpret=True)
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


@pytest.mark.parametrize("B,S,di,ds", [(2, 64, 128, 16), (1, 100, 200, 16),
                                       (2, 64, 128, 8)])
def test_selective_scan_matches_ref(B, S, di, ds):
    rng = np.random.default_rng(di + S)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, di)), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
    Cc = jnp.asarray(rng.normal(size=(B, S, ds)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, S, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 4.0, (di, ds)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(di,)), jnp.float32)
    y, hf = selective_scan_pallas(dt, Bc, Cc, x, A, D, interpret=True)
    y_r, hf_r = ref.selective_scan_ref(dt, Bc, Cc, x, A, D)
    assert_allclose(np.asarray(y), np.asarray(y_r), rtol=2e-5, atol=2e-5)
    assert_allclose(np.asarray(hf), np.asarray(hf_r), rtol=2e-5, atol=2e-5)


def test_cws_sketch_agrees_with_core_index_scheme():
    """The fused kernel sketch must equal the host WeightedScheme sketch
    (same stateless hash family) -- ties the kernel to the paper index."""
    from repro.core import WeightedScheme
    from repro.core.weights import WeightFn
    rng = np.random.default_rng(11)
    toks = np.unique(rng.integers(0, 10_000, 50)).astype(np.int64)
    freqs = rng.integers(1, 30, toks.shape[0]).astype(np.int64)
    scheme = WeightedScheme(weight=WeightFn(tf="raw", idf="unary"),
                            seed=7, k=16)
    w = scheme.weight(toks, freqs)
    t_star, kint, _ = ops.cws_sketch(7, 16, toks, w, use_pallas=True,
                                     interpret=True)
    # host-side truth, hash function by hash function
    for i, h in enumerate(scheme.hashers):
        tt, kk, _a = h.min_hash(toks, np.asarray(w))
        assert int(t_star[i]) == tt
        assert int(kint[i]) == kk
