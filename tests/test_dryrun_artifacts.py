"""Integrity of the committed dry-run artifacts (results/dryrun): the 40
assigned cells x 2 meshes all exist, compiled OK or are explicit by-design
skips, and every roofline record is internally consistent."""

import json
import math
from pathlib import Path

import pytest

from repro.configs import ARCH_IDS, arch_cells

DRYRUN = Path(__file__).resolve().parent.parent / "results" / "dryrun"

pytestmark = [
    pytest.mark.slow,
    pytest.mark.skipif(not DRYRUN.exists(),
                       reason="dry-run results not generated"),
]


def _cells():
    out = []
    for a in ARCH_IDS:
        for s in arch_cells(a):
            skip = s.endswith(":skip")
            out.append((a, s.split(":")[0], skip))
    return out


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_all_40_cells_recorded(mesh):
    cells = _cells()
    assert len(cells) == 40
    for arch, shape, skip in cells:
        p = DRYRUN / f"{arch}__{shape}__{mesh}.json"
        assert p.exists(), f"missing record {p.name}"
        r = json.loads(p.read_text())
        if skip:
            assert r["status"] == "skipped", p.name
            assert "reason" in r
        else:
            assert r["status"] == "ok", (p.name, r.get("error", "")[:200])


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_roofline_records_consistent(mesh):
    for p in sorted(DRYRUN.glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            continue
        rf = r["roofline"]
        # terms recompute from the recorded raw quantities
        assert math.isclose(rf["compute_s"],
                            r["flops_per_device"] / 197e12, rel_tol=1e-6)
        assert math.isclose(rf["memory_s"],
                            r["hbm_bytes_per_device"] / 819e9, rel_tol=1e-6)
        assert math.isclose(
            rf["collective_s"],
            r["collective"]["wire_bytes_per_device"] / 50e9, rel_tol=1e-6)
        assert rf["bottleneck"] in ("compute", "memory", "collective")
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        assert math.isclose(dom, rf[f"{rf['bottleneck']}_s"], rel_tol=1e-9)
        assert 0 <= rf["roofline_fraction"] <= 1.0
        assert r["flops_per_device"] > 0
        assert r["mesh_shape"] == ({"pod": 2, "data": 16, "model": 16}
                                   if mesh == "multi"
                                   else {"data": 16, "model": 16})


def test_multi_pod_uses_pod_collectives():
    """At least the big training cells must communicate across the pod axis
    (group size 2 collectives appear in the schedule)."""
    p = DRYRUN / "llama3-405b__train_4k__multi.json"
    r = json.loads(p.read_text())
    assert r["status"] == "ok"
    assert r["collective"]["wire_bytes_per_device"] > 0
    # optimizer ZeRO-shards over the pod: live bytes strictly below single
    s = json.loads((DRYRUN / "llama3-405b__train_4k__single.json").read_text())
    assert r["live_bytes_per_device"] < s["live_bytes_per_device"]
