"""Typed request/response API units: Match / QueryResult round-trips,
QueryOptions coercion + legacy-kwarg deprecation, the batched-sketch
fast path, and the live empty-delta probe short-circuit."""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.api import Aligner, Match, QueryOptions, QueryResult
from repro.core.results import coerce_query_options


def _mk(n_docs: int = 20, doc_len: int = 100, **kw):
    rng = np.random.default_rng(2)
    docs = [rng.integers(0, 1 << 40, size=doc_len) for _ in range(n_docs)]
    return Aligner.build(docs, similarity="multiset", seed=3, k=8, **kw), docs


# -- Match / QueryResult ----------------------------------------------------


def test_match_json_roundtrip():
    m = Match(doc_id=3, span=(2, 40), query_span=(0, 38),
              estimated_similarity=0.75,
              blocks=[(2, 10, 0, 8), (12, 40, 10, 38)])
    m2 = Match.from_dict(json.loads(json.dumps(m.to_dict())))
    assert m2 == m
    assert m2.text_id == 3                       # legacy alias
    doc_id, span, qspan, sim = m2                # tuple protocol
    assert (doc_id, span, qspan, sim) == (3, (2, 40), (0, 38), 0.75)


def test_query_result_container_and_json():
    aligner, docs = _mk()
    res = aligner.find([int(t) for t in docs[4][10:80]], 0.5)
    assert isinstance(res, QueryResult)
    assert bool(res) and len(res) == len(res.matches)
    assert res[0].doc_id in [m.doc_id for m in res]
    rt = QueryResult.from_json(res.to_json())
    assert rt == res
    assert rt.theta == 0.5 and rt.query_len == 70


def test_estimated_similarity_bounds():
    aligner, docs = _mk()
    res = aligner.find([int(t) for t in docs[0][:80]], 0.5)
    assert res
    for m in res:
        assert 0.5 <= m.estimated_similarity <= 1.0


def test_find_batch_matches_looped_find():
    aligner, docs = _mk()
    queries = [[int(t) for t in d[:60]] for d in docs[:6]]
    batched = aligner.find_batch(queries, 0.5)
    looped = [aligner.find(q, 0.5) for q in queries]
    assert batched == looped


def test_legacy_tuples_deprecated():
    aligner, docs = _mk()
    q = [int(t) for t in docs[0][:60]]
    with pytest.warns(DeprecationWarning, match="legacy_tuples"):
        raw = aligner.find(q, 0.5, legacy_tuples=True)  # repro: allow[RPR402]
    assert not isinstance(raw, QueryResult)
    assert raw and hasattr(raw[0], "blocks")     # bare Alignment list


# -- QueryOptions -----------------------------------------------------------


def test_query_options_batch_key_excludes_sketches():
    a = QueryOptions(sketches=[[1, 2]])
    b = QueryOptions(sketches=None)
    assert a.batch_key() == b.batch_key()
    assert QueryOptions(sweep="loop").batch_key() != b.batch_key()


def test_query_options_dict_roundtrip_rejects_unknown():
    opts = QueryOptions(probe_backend="percoord", sweep="loop")
    assert QueryOptions.from_dict(opts.to_dict()) == opts
    with pytest.raises(ValueError, match="unknown"):
        QueryOptions.from_dict({"probe_backnd": "numpy"})
    with pytest.raises(ValueError):
        QueryOptions.from_dict({"sketches": [[1]]})


def test_legacy_kwargs_warn_and_coerce():
    aligner, docs = _mk()
    q = [int(t) for t in docs[0][:60]]
    with pytest.warns(DeprecationWarning, match="probe_backend"):
        res = aligner.find_batch(  # repro: allow[RPR401] (tests the shim)
            [q], 0.5, probe_backend="percoord")
    assert res == aligner.find_batch(
        [q], 0.5, options=QueryOptions(probe_backend="percoord"))
    # `backend` renames to sketch_backend, and the warning says so
    with pytest.warns(DeprecationWarning, match="sketch_backend"):
        coerced = coerce_query_options(None, "find_batch", backend="exact")
    assert coerced == QueryOptions(sketch_backend="exact")


def test_mixing_options_and_legacy_kwargs_is_an_error():
    with pytest.raises(TypeError, match="both"):
        coerce_query_options(QueryOptions(), "find_batch",
                             probe_backend="numpy")


def test_alignment_index_reexport_removed():
    import repro.core
    assert not hasattr(repro.core, "AlignmentIndex")
    # repro: allow[RPR403] (the test pins the shim's canonical home)
    from repro.core.index import AlignmentIndex
    assert AlignmentIndex is not None             # repro: allow[RPR403]


# -- batched sketching ------------------------------------------------------


def test_weighted_sketch_batch_parity_with_loop():
    """The vectorized exact batch sketch must be bit-identical to the
    per-text path — mixed lengths, repeated tokens, huge token ids."""
    from repro.core import make_scheme
    rng = np.random.default_rng(8)
    corpus = [rng.integers(0, 5000, size=150) for _ in range(20)]
    scheme = make_scheme("tfidf", seed=11, k=16, corpus=corpus)
    texts = ([rng.integers(0, 5000, size=int(n))
              for n in rng.integers(1, 200, size=15)]
             + [rng.integers(0, 1 << 60, size=40) for _ in range(5)]
             + [np.array([7] * 30)])              # single distinct token
    assert scheme.sketch_batch(texts) == [scheme.sketch(t) for t in texts]


def test_weighted_sketch_batch_empty_text_falls_back():
    from repro.core import make_scheme
    scheme = make_scheme("weighted", seed=1, k=4)
    with pytest.raises((ValueError, IndexError)):
        scheme.sketch_batch([np.array([1, 2, 3]), np.array([], np.int64)])


# -- live empty-delta short-circuit -----------------------------------------


def test_live_empty_delta_skips_delta_probe(tmp_path, monkeypatch):
    """A freshly opened live store has zero delta tables; its batch
    queries must probe the frozen level only."""
    import repro.core.live as live_mod
    rng = np.random.default_rng(4)
    docs = [rng.integers(0, 1 << 40, size=100) for _ in range(12)]
    store = str(tmp_path / "idx")
    Aligner.build(docs, similarity="multiset", seed=3, k=8,
                  pipeline="columnar", store=store)
    aligner = Aligner.load(store, live=True)

    calls = []
    orig = live_mod._batch_probe

    def counting(index, sketches, **kw):
        calls.append(index)
        return orig(index, sketches, **kw)

    monkeypatch.setattr(live_mod, "_batch_probe", counting)
    queries = [[int(t) for t in docs[0][:60]]]
    res = aligner.find_batch(queries, 0.5)
    assert res[0], "self-query must hit"
    assert len(calls) == 1, \
        f"empty delta still probed: {len(calls)} level probes"

    # after one add the delta level probes too
    aligner.add([int(t) for t in rng.integers(0, 1 << 40, 100)])
    calls.clear()
    aligner.find_batch(queries, 0.5)
    assert len(calls) == 2
