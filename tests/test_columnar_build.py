"""Build-pipeline parity: the columnar pipeline must be bit-for-bit
interchangeable with the dict pipeline at every layer — KeySet, frozen
CSR tables, fused probe arena, query results, sharded builds, and the
streamed store — on all three similarity schemes."""

import numpy as np
import pytest

from repro.api import Aligner
from repro.core import (ColumnarBuilder, IndexBuilder,
                        ShardedAlignmentIndex, batch_query, make_scheme,
                        query)
from repro.core.frozen import FrozenTable, ProbeArena
from repro.core.keys import occurrence_lists
from repro.core.store import load_index, save_index


def _texts(n_docs=6, n=160, vocab=40, seed=0):
    rng = np.random.default_rng(seed)
    docs = [rng.integers(0, vocab, size=n).astype(np.int64)
            for _ in range(n_docs)]
    # plant a shared passage so queries actually hit
    if n_docs > 3:
        docs[3][20:80] = docs[0][30:90]
    return docs


def _scheme(similarity, k=6, seed=11, docs=None):
    kw = {"corpus": docs} if similarity == "tfidf" else {}
    return make_scheme(similarity, seed=seed, k=k, **kw)


def _assert_tables_equal(a, b):
    assert len(a.tables) == len(b.tables)
    for ta, tb in zip(a.tables, b.tables):
        assert ta.kind == tb.kind
        assert ta.kint_min == tb.kint_min
        assert np.array_equal(ta.keys, tb.keys)
        assert np.array_equal(ta.offsets, tb.offsets)
        assert np.array_equal(ta.windows, tb.windows)
    assert a.num_texts == b.num_texts
    assert a.num_windows == b.num_windows
    assert list(a.text_lengths) == list(b.text_lengths)


def _assert_arena_equal(x, y):
    assert x.mode == y.mode
    assert x.max_run == y.max_run
    assert x.kinds == y.kinds
    assert np.array_equal(x.kint_mins, y.kint_mins)
    assert np.array_equal(x.keys, y.keys)
    assert np.array_equal(x.coords, y.coords)
    assert np.array_equal(x.offsets, y.offsets)
    assert np.array_equal(x.windows, y.windows)


def _blocks(results):
    return [(a.text_id, a.blocks) for a in results]


SIMILARITIES = ["multiset", "weighted", "tfidf"]


# ---------------------------------------------------------------------------
# key generation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("similarity", SIMILARITIES)
@pytest.mark.parametrize("active", [True, False])
def test_key_columns_matches_keys(similarity, active):
    docs = _texts()
    scheme = _scheme(similarity, docs=docs)
    for tokens in (docs[0], np.array([5, 5, 5, 5], np.int64),
                   np.array([9], np.int64)):
        occ = occurrence_lists(tokens)
        for i in range(scheme.k):
            a = scheme.keys(tokens, i, active, occ=occ)
            b = scheme.key_columns(tokens, i, active, occ=occ)
            assert np.array_equal(a.p, b.p)
            assert np.array_equal(a.q, b.q)
            assert np.array_equal(a.freq, b.freq)
            assert np.array_equal(a.gid, b.gid)
            assert a.order.dtype == b.order.dtype
            assert np.array_equal(np.asarray(a.order), np.asarray(b.order))
            if b.gid_ident.ndim == 2:       # ICWS (token, k_int) rows
                want = np.array(a.gid_key, np.int64).reshape(-1, 2)
            else:                           # multiset uint64 hash ids
                want = np.array(a.gid_key, np.uint64)
            assert np.array_equal(want, b.gid_ident)


def test_key_columns_skips_boxed_keys():
    docs = _texts()
    scheme = _scheme("multiset")
    ks = scheme.key_columns(docs[0], 0, True)
    assert ks.gid_key == []
    assert isinstance(ks.gid_ident, np.ndarray)


# ---------------------------------------------------------------------------
# frozen-table parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("similarity", SIMILARITIES)
def test_freeze_block_identical(similarity):
    docs = _texts()
    scheme = _scheme(similarity, docs=docs)
    fz_dict = IndexBuilder(scheme=scheme).build(docs).freeze()
    fz_col = ColumnarBuilder(scheme=scheme).build(docs).freeze()
    _assert_tables_equal(fz_dict, fz_col)


@pytest.mark.parametrize("method", ["mono_all", "mono_active", "allalign"])
def test_freeze_block_identical_methods(method):
    docs = _texts(n_docs=4)
    scheme = _scheme("multiset")
    fz_dict = IndexBuilder(scheme=scheme, method=method).build(docs).freeze()
    fz_col = ColumnarBuilder(scheme=scheme, method=method).build(
        docs).freeze()
    _assert_tables_equal(fz_dict, fz_col)


def test_from_columns_matches_from_dict_directly():
    # hand-built columns with duplicate keys across appends: the global
    # stable sort must preserve append order within each key group
    table = {}
    idents, wins = [], []
    rows = [(7, 0, 0, 1, 0, 2), (3, 0, 2, 3, 1, 4), (7, 1, 5, 6, 2, 7),
            (3, 1, 0, 0, 0, 0), (7, 1, 8, 9, 3, 5)]
    for key, tid, a, b, c, d in rows:
        table.setdefault(key, []).append((tid, a, b, c, d))
        idents.append(key)
        wins.append((tid, a, b, c, d))
    want = FrozenTable.from_dict(table)
    got = FrozenTable.from_columns(
        "int", np.array(idents, np.uint64), np.array(wins, np.int32))
    assert want.kind == got.kind
    assert np.array_equal(want.keys, got.keys)
    assert np.array_equal(want.offsets, got.offsets)
    assert np.array_equal(want.windows, got.windows)


def test_empty_build_freezes_empty():
    scheme = _scheme("multiset")
    fz = ColumnarBuilder(scheme=scheme).build([]).freeze(arena=True)
    assert fz.num_texts == 0
    assert all(t.kind == "empty" for t in fz.tables)
    ref = IndexBuilder(scheme=scheme).build([]).freeze()
    _assert_tables_equal(ref, fz)
    _assert_arena_equal(ProbeArena.from_tables(ref.tables), fz.arena())


def test_pair_pack_range_check():
    scheme = _scheme("weighted")
    builder = ColumnarBuilder(scheme=scheme)
    builder.add_text(np.array([1 << 33, 1 << 33, 5], np.int64))
    with pytest.raises(ValueError, match="uint32"):
        builder.freeze()


# ---------------------------------------------------------------------------
# probe-arena parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("similarity", SIMILARITIES)
def test_arena_layout_identical(similarity):
    docs = _texts()
    scheme = _scheme(similarity, docs=docs)
    fz_dict = IndexBuilder(scheme=scheme).build(docs).freeze()
    fz_col = ColumnarBuilder(scheme=scheme).build(docs).freeze(arena=True)
    _assert_arena_equal(ProbeArena.from_tables(fz_dict.tables),
                        fz_col.arena())


def test_from_window_columns_forced_coord_mode():
    # multiset keys are 61-bit -> natural mode is "coord"; also force both
    # modes explicitly and compare against from_tables on the same tables
    docs = _texts(n_docs=4)
    scheme = _scheme("multiset")
    builder = ColumnarBuilder(scheme=scheme).build(docs)
    fz = builder.freeze()
    cols = [c.packed() for c in builder._cols]
    got = ProbeArena.from_window_columns(
        [t.kind for t in fz.tables], [p for p, _w, _m in cols],
        [w for _p, w, _m in cols], np.array([m for _p, _w, m in cols]),
        mode="coord")
    _assert_arena_equal(ProbeArena.from_tables(fz.tables, mode="coord"), got)


# ---------------------------------------------------------------------------
# query parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("similarity", SIMILARITIES)
def test_batch_query_parity(similarity):
    docs = _texts()
    scheme = _scheme(similarity, docs=docs)
    fz_dict = IndexBuilder(scheme=scheme).build(docs).freeze()
    fz_col = ColumnarBuilder(scheme=scheme).build(docs).freeze(arena=True)
    queries = [docs[0][30:90], docs[3][10:100], docs[5][:60]]
    for theta in (0.34, 0.67):
        want = batch_query(fz_dict, queries, theta)
        got = batch_query(fz_col, queries, theta)
        assert [_blocks(r) for r in want] == [_blocks(r) for r in got]
        one = query(fz_col, queries[0], theta)
        assert _blocks(one) == _blocks(want[0])


# ---------------------------------------------------------------------------
# sharded builds
# ---------------------------------------------------------------------------


def test_sharded_columnar_equals_dict():
    docs = _texts(n_docs=7)
    scheme = _scheme("multiset")
    ref = ShardedAlignmentIndex(scheme=scheme, n_shards=3).build(
        docs).freeze()
    got = ShardedAlignmentIndex(scheme=scheme, n_shards=3).build(
        docs, pipeline="columnar")
    assert got.is_frozen
    assert got.doc_map == ref.doc_map
    for s in range(3):
        _assert_tables_equal(ref.shards[s], got.shards[s])
    qs = [docs[0][30:90], docs[3][10:100]]
    assert [[_blocks(r) for r in ref.batch_query(qs, 0.5)]] == \
        [[_blocks(r) for r in got.batch_query(qs, 0.5)]]


@pytest.mark.parametrize("fanout", ["threaded", "process"])
def test_sharded_fanout_equals_serial(fanout):
    docs = _texts(n_docs=6, n=120)
    scheme = _scheme("multiset", k=4)
    serial = ShardedAlignmentIndex(scheme=scheme, n_shards=2).build(
        docs, pipeline="columnar", fanout="serial")
    other = ShardedAlignmentIndex(scheme=scheme, n_shards=2).build(
        docs, pipeline="columnar", fanout=fanout)
    assert other.doc_map == serial.doc_map
    for s in range(2):
        _assert_tables_equal(serial.shards[s], other.shards[s])


def test_sharded_process_weighted_scheme_roundtrip():
    # the scheme crosses the process boundary as its JSON spec; weighted
    # schemes carry weight-fn closures that don't pickle
    docs = _texts(n_docs=4, n=100)
    scheme = _scheme("tfidf", k=4, docs=docs)
    serial = ShardedAlignmentIndex(scheme=scheme, n_shards=2).build(
        docs, pipeline="columnar", fanout="serial")
    proc = ShardedAlignmentIndex(scheme=scheme, n_shards=2).build(
        docs, pipeline="columnar", fanout="process")
    for s in range(2):
        _assert_tables_equal(serial.shards[s], proc.shards[s])


def test_columnar_build_requires_empty_index():
    docs = _texts(n_docs=4, n=100)
    scheme = _scheme("multiset", k=4)
    idx = ShardedAlignmentIndex(scheme=scheme, n_shards=2)
    idx.add_text(docs[0])
    with pytest.raises(RuntimeError, match="empty"):
        idx.build(docs, pipeline="columnar")


def test_dict_pipeline_rejects_columnar_options():
    scheme = _scheme("multiset", k=4)
    idx = ShardedAlignmentIndex(scheme=scheme, n_shards=2)
    with pytest.raises(ValueError, match="columnar"):
        idx.build([], fanout="process")


def test_bad_fanout_leaves_index_untouched(tmp_path):
    # validation must run before doc_map / store dirs are touched: a
    # failed call stays retryable
    docs = _texts(n_docs=4, n=100)
    scheme = _scheme("multiset", k=4)
    idx = ShardedAlignmentIndex(scheme=scheme, n_shards=2)
    store = tmp_path / "never_created"
    with pytest.raises(ValueError, match="fanout"):
        idx.build(docs, pipeline="columnar", fanout="processes",
                  store=store)
    assert idx.doc_map == []
    assert not store.exists()
    idx.build(docs, pipeline="columnar")        # retry succeeds
    assert len(idx.doc_map) == 4
    with pytest.raises(ValueError, match="fanout"):
        Aligner.build(docs, similarity="multiset", pipeline="columnar",
                      fanout="procss")


# ---------------------------------------------------------------------------
# store streaming
# ---------------------------------------------------------------------------


def test_freeze_to_store_matches_save_index(tmp_path):
    docs = _texts()
    scheme = _scheme("weighted")
    ref = IndexBuilder(scheme=scheme).build(docs).freeze()
    save_index(ref, tmp_path / "dict_store")
    streamed = ColumnarBuilder(scheme=scheme).build(docs).freeze_to_store(
        tmp_path / "col_store")
    assert streamed.is_mmap()
    loaded_ref = load_index(tmp_path / "dict_store")
    _assert_tables_equal(loaded_ref, streamed)
    _assert_arena_equal(loaded_ref.arena(), streamed.arena())
    # both stores load interchangeably
    reloaded = load_index(tmp_path / "col_store", mmap=False)
    _assert_tables_equal(loaded_ref, reloaded)


def test_sharded_store_streaming(tmp_path):
    docs = _texts(n_docs=7)
    scheme = _scheme("multiset")
    root = tmp_path / "sharded"
    built = ShardedAlignmentIndex(scheme=scheme, n_shards=3).build(
        docs, pipeline="columnar", fanout="serial", store=root)
    assert built.shards[0].is_mmap()
    # the streamed dir is a complete sharded store: restorable from scratch
    fresh = ShardedAlignmentIndex(scheme=scheme, n_shards=3)
    assert fresh.restore(root, missing_ok=False, mmap=True) == []
    assert fresh.doc_map == built.doc_map
    for s in range(3):
        _assert_tables_equal(built.shards[s], fresh.shards[s])
    ref = ShardedAlignmentIndex(scheme=scheme, n_shards=3).build(
        docs).freeze()
    qs = [docs[0][30:90]]
    assert [_blocks(r) for r in built.batch_query(qs, 0.5)] == \
        [_blocks(r) for r in ref.batch_query(qs, 0.5)]


# ---------------------------------------------------------------------------
# Aligner facade
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("similarity", SIMILARITIES)
def test_aligner_columnar_pipeline(similarity):
    docs = _texts()
    ref = Aligner.build(docs, similarity=similarity, k=6, seed=11)
    col = Aligner.build(docs, similarity=similarity, k=6, seed=11,
                        pipeline="columnar")
    assert col.is_frozen
    qs = [docs[0][30:90], docs[3][10:100]]
    assert [_blocks(r) for r in ref.find_batch(qs, 0.5)] == \
        [_blocks(r) for r in col.find_batch(qs, 0.5)]


def test_aligner_columnar_one_pass_store(tmp_path):
    docs = _texts()
    store = tmp_path / "one_pass"
    built = Aligner.build(docs, similarity="multiset", k=6, seed=11,
                          pipeline="columnar", store=store)
    served = Aligner.load(store)
    ref = Aligner.build(docs, similarity="multiset", k=6, seed=11)
    qs = [docs[0][30:90]]
    want = [_blocks(r) for r in ref.find_batch(qs, 0.5)]
    assert [_blocks(r) for r in built.find_batch(qs, 0.5)] == want
    assert [_blocks(r) for r in served.find_batch(qs, 0.5)] == want


def test_aligner_dict_pipeline_rejects_store(tmp_path):
    with pytest.raises(ValueError, match="columnar"):
        Aligner.build(_texts(n_docs=2), similarity="multiset",
                      store=tmp_path / "x")
