"""Property-based tests (hypothesis) for the system's invariants."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (ICWS, MixHash, UniversalHash, WeightFn,
                        allalign_partition, generate_keys_icws,
                        generate_keys_multiset, jaccard_multiset,
                        minhash_gid_grid_icws, minhash_gid_grid_multiset,
                        monotonic_partition, validate_partition)
from repro.core.hashing import MERSENNE61, mod_m61, mulmod_m61

pytestmark = pytest.mark.slow          # tier-2: many-example property runs

texts = st.lists(st.integers(min_value=0, max_value=6), min_size=1,
                 max_size=36)


@settings(max_examples=60, deadline=None)
@given(tokens=texts, seed=st.integers(min_value=0, max_value=2**31))
def test_partition_invariants_multiset(tokens, seed):
    tokens = np.asarray(tokens, dtype=np.int64)
    h = UniversalHash.from_seed(seed, 1)[0]
    grid, table = minhash_gid_grid_multiset(tokens, h)
    for active in (False, True):
        keys = generate_keys_multiset(tokens, h, active=active)
        validate_partition(monotonic_partition(keys), grid, table)
    validate_partition(
        allalign_partition(generate_keys_multiset(tokens, h, active=False)),
        grid, table)


@settings(max_examples=30, deadline=None)
@given(tokens=texts, seed=st.integers(min_value=0, max_value=2**31),
       tf=st.sampled_from(["binary", "raw", "log", "squared"]))
def test_partition_invariants_icws(tokens, seed, tf):
    tokens = np.asarray(tokens, dtype=np.int64)
    icws = ICWS.from_seed(seed, 1)[0]
    w = WeightFn(tf=tf)
    grid, table = minhash_gid_grid_icws(tokens, icws, w)
    keys = generate_keys_icws(tokens, icws, w, active=True)
    validate_partition(monotonic_partition(keys), grid, table)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31),
       t=st.integers(min_value=0, max_value=2**31),
       fmax=st.integers(min_value=1, max_value=40),
       tf=st.sampled_from(["binary", "raw", "log", "squared"]))
def test_lemma_12_icws_monotone_in_frequency(seed, t, fmax, tf):
    """Lemma 12: h(t,1) >= h(t,2) >= ... under AoW (comparing by a)."""
    icws = ICWS.from_seed(seed, 1)[0]
    w = WeightFn(tf=tf)
    a = icws.a_value(np.full(fmax, t, dtype=np.int64), w.grid(t, fmax))
    assert np.all(np.diff(a) <= 0)


@settings(max_examples=40, deadline=None)
@given(a=st.integers(min_value=0, max_value=2**61 - 2),
       b=st.integers(min_value=0, max_value=2**61 - 2))
def test_mersenne61_mulmod_exact(a, b):
    got = int(mulmod_m61(np.uint64(a), np.uint64(b)))
    assert got == (a * b) % int(MERSENNE61)


@settings(max_examples=40, deadline=None)
@given(x=st.integers(min_value=0, max_value=2**64 - 1))
def test_mersenne61_mod_exact(x):
    assert int(mod_m61(np.uint64(x))) == x % int(MERSENNE61)


@settings(max_examples=20, deadline=None)
@given(tokens=texts, seed=st.integers(min_value=0, max_value=2**31))
def test_minhash_collision_prob_is_jaccard_smoke(tokens, seed):
    """Pr[h(T)=h(S)] = J(T,S) in expectation — smoke-level: identical texts
    always share min-hash; disjoint token sets never do."""
    tokens = np.asarray(tokens, dtype=np.int64)
    h = MixHash.from_seed(seed, 1)[0]
    grid, table = minhash_gid_grid_multiset(tokens, h)
    n = len(tokens)
    assert grid[0, n - 1] >= 0
    # identical: trivially equal. disjointness via shifted alphabet:
    shifted = tokens + 1000
    grid2, table2 = minhash_gid_grid_multiset(shifted, h)
    assert table[grid[0, n - 1]] != table2[grid2[0, n - 1]]


def test_estimator_unbiased_multiset():
    """Ĵ (Eq. 2) within 4σ of J for a large sketch."""
    rng = np.random.default_rng(0)
    from repro.core import MultisetScheme
    A = rng.integers(0, 30, size=120)
    B = np.concatenate([A[:80], rng.integers(0, 30, size=40)])
    sch = MultisetScheme(seed=1, k=1024)
    true_j = jaccard_multiset(A, B)
    est = np.mean([x == y for x, y in zip(sch.sketch(A), sch.sketch(B))])
    sigma = np.sqrt(true_j * (1 - true_j) / 1024)
    assert abs(est - true_j) < 4 * sigma + 1e-9


def test_estimator_unbiased_weighted():
    rng = np.random.default_rng(1)
    from repro.core import WeightedScheme, jaccard_weighted
    w = WeightFn(tf="log")
    A = rng.integers(0, 30, size=120)
    B = np.concatenate([A[:80], rng.integers(0, 30, size=40)])
    sch = WeightedScheme(weight=w, seed=2, k=1024)
    true_j = jaccard_weighted(A, B, w)
    est = np.mean([x == y for x, y in zip(sch.sketch(A), sch.sketch(B))])
    sigma = np.sqrt(true_j * (1 - true_j) / 1024)
    assert abs(est - true_j) < 4 * sigma + 1e-9


@settings(max_examples=25, deadline=None)
@given(tokens=texts, seed=st.integers(min_value=0, max_value=2**31))
def test_windows_bounded_by_twice_active_keys(tokens, seed):
    """Lemma 10: |P| <= 2|X(T)|."""
    tokens = np.asarray(tokens, dtype=np.int64)
    h = UniversalHash.from_seed(seed, 1)[0]
    keys = generate_keys_multiset(tokens, h, active=True)
    part = monotonic_partition(keys)
    assert len(part) <= 2 * len(keys)
