"""Frozen CSR index + batched query engine: lookup parity with the dict
tables, batch_query == looped query, kernel-batch sketch equality, and
frozen persistence round-trips (flat and sharded)."""

import numpy as np
import pytest

from repro.core import (FrozenTable, IndexBuilder, MultisetScheme,
                        QueryOptions, SearchIndex, ShardedAlignmentIndex,
                        WeightedScheme, WeightFn, batch_query, query)


def _corpus(rng, n_docs=6, vocab=30, n=50):
    return [rng.integers(0, vocab, size=n).astype(np.int64)
            for _ in range(n_docs)]


def _queries(rng, docs, n=5):
    qs = [docs[i % len(docs)][5:30].copy() for i in range(n)]
    qs.append(rng.integers(1000, 1030, size=12).astype(np.int64))  # miss
    return qs


def _frozen_copy(idx):
    clone = IndexBuilder(scheme=idx.scheme, method=idx.method)
    clone.load_state_dict(idx.state_dict())
    return clone.freeze()


def _blocks(results):
    return [(a.text_id, a.blocks) for a in results]


SCHEMES = {
    "multiset": lambda: MultisetScheme(seed=13, k=8),
    "mix": lambda: MultisetScheme(seed=13, k=8, family="mix"),
    "weighted": lambda: WeightedScheme(weight=WeightFn(tf="raw"), seed=21,
                                       k=8),
}


# --------------------------------------------------------------------------
# frozen table layout
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", list(SCHEMES))
def test_frozen_lookup_parity_with_dict_tables(kind):
    rng = np.random.default_rng(0)
    idx = IndexBuilder(scheme=SCHEMES[kind]()).build(_corpus(rng))
    frozen = _frozen_copy(idx)
    for i, table in enumerate(idx.tables):
        assert len(frozen.frozen[i]) == len(table)
        for key, wins in table.items():
            got = frozen.lookup(i, key)
            assert [tuple(int(x) for x in row) for row in got] == wins
    # absent keys miss cleanly on every key type
    assert len(frozen.frozen[0].get((10**9, 10**9)
                                    if kind == "weighted" else 10**18)) == 0


def test_frozen_is_contiguous_and_much_smaller():
    rng = np.random.default_rng(1)
    idx = IndexBuilder(scheme=MultisetScheme(seed=3, k=8)).build(
        _corpus(rng, n_docs=10, n=200))
    frozen = _frozen_copy(idx)
    for t in frozen.frozen:
        assert t.keys.dtype == np.uint64 and t.windows.dtype == np.int32
        assert np.all(t.keys[:-1] < t.keys[1:])          # sorted, unique
        assert t.offsets[0] == 0 and t.offsets[-1] == len(t.windows)
        assert np.all(np.diff(t.offsets) >= 0)
    assert frozen.nbytes() * 5 < idx.nbytes()


def test_freeze_is_idempotent_and_leaves_builder_usable():
    rng = np.random.default_rng(2)
    idx = IndexBuilder(scheme=MultisetScheme(seed=5, k=4)).build(
        _corpus(rng, n_docs=2))
    frozen = idx.freeze()
    assert frozen.freeze() is frozen                     # idempotent
    assert frozen.is_frozen and not idx.is_frozen
    # freeze() is a handoff, not a personality change: the builder keeps
    # accepting adds (the legacy in-place freeze that blocked adds lives
    # only in the AlignmentIndex shim, covered by test_api)
    idx.add_text(rng.integers(0, 9, 10).astype(np.int64))
    assert idx.num_texts == 3 and frozen.num_texts == 2


def test_frozen_table_pair_packing_rejects_oversized_tokens():
    with pytest.raises(ValueError):
        FrozenTable.from_dict({(1 << 33, 0): [(0, 0, 1, 2, 3)]})


# --------------------------------------------------------------------------
# batched query engine
# --------------------------------------------------------------------------

@pytest.mark.parametrize("kind", list(SCHEMES))
@pytest.mark.parametrize("theta", [0.3, 0.6, 1.0])
def test_batch_query_equals_looped_query(kind, theta):
    rng = np.random.default_rng(3)
    docs = _corpus(rng)
    qs = _queries(rng, docs)
    idx = IndexBuilder(scheme=SCHEMES[kind]()).build(docs)
    frozen = _frozen_copy(idx)
    looped = [_blocks(query(idx, q, theta)) for q in qs]
    assert [_blocks(r) for r in batch_query(frozen, qs, theta)] == looped
    # the engine also runs (identically) over the mutable dict tables
    assert [_blocks(r) for r in batch_query(idx, qs, theta)] == looped
    # and single-query on the frozen layout agrees too
    assert [_blocks(query(frozen, q, theta)) for q in qs] == looped


def test_batch_query_empty_batch_and_no_hits():
    rng = np.random.default_rng(4)
    frozen = IndexBuilder(scheme=MultisetScheme(seed=7, k=8)).build(
        _corpus(rng, n_docs=2)).freeze()
    assert batch_query(frozen, [], 0.5) == []
    miss = [rng.integers(500, 520, 10).astype(np.int64)]
    assert batch_query(frozen, miss, 0.5) == [[]]


def test_sketch_batch_matches_sketch():
    rng = np.random.default_rng(5)
    texts = [rng.integers(0, 25, size=40).astype(np.int64) for _ in range(4)]
    for kind in SCHEMES:
        scheme = SCHEMES[kind]()
        assert scheme.sketch_batch(texts) == \
            [scheme.sketch(t) for t in texts]


def test_pallas_batch_sketch_matches_single_kernel():
    """icws_sketch_batch must agree coordinate-for-coordinate with per-text
    icws_sketch (identical f32 math, batched grid)."""
    import jax.numpy as jnp

    from repro.kernels import icws_sketch, icws_sketch_batch, \
        icws_token_params

    rng = np.random.default_rng(6)
    K = 16
    token_lists = [np.sort(rng.choice(5000, size=int(n), replace=False))
                   .astype(np.int64) for n in rng.integers(3, 150, size=4)]
    weight_lists = [rng.integers(1, 9, size=len(t)).astype(np.float64)
                    for t in token_lists]
    Tmax = max(len(t) for t in token_lists)
    r = np.ones((len(token_lists), K, Tmax), np.float32)
    c = np.ones_like(r)
    be = np.ones_like(r)
    w = np.zeros((len(token_lists), Tmax), np.float32)
    for b, (tl, wl) in enumerate(zip(token_lists, weight_lists)):
        t = len(tl)
        r[b, :, :t], c[b, :, :t], be[b, :, :t] = icws_token_params(0, K, tl)
        w[b, :t] = wl
    _, argt_b, kint_b = icws_sketch_batch(jnp.asarray(r), jnp.asarray(c),
                                          jnp.asarray(be), jnp.asarray(w))
    for b, (tl, wl) in enumerate(zip(token_lists, weight_lists)):
        rb, cb, bb = icws_token_params(0, K, tl)
        _, argt, kint = icws_sketch(rb, cb, bb,
                                    jnp.asarray(wl, jnp.float32))
        assert np.array_equal(np.asarray(argt), np.asarray(argt_b[b]))
        assert np.array_equal(np.asarray(kint), np.asarray(kint_b[b]))


def test_pallas_sketch_backend_end_to_end():
    """batch_query with the device sketching backend finds a planted
    near-duplicate (identities may differ from exact on argmin near-ties,
    so assert retrieval, not bit-parity)."""
    rng = np.random.default_rng(7)
    docs = _corpus(rng, n_docs=4, vocab=60, n=80)
    scheme = WeightedScheme(weight=WeightFn(tf="raw"), seed=9, k=8)
    idx = IndexBuilder(scheme=scheme).build(docs).freeze()
    res = batch_query(idx, [docs[2][10:60].copy()], 0.5,
                      options=QueryOptions(sketch_backend="pallas"))
    assert any(a.text_id == 2 for a in res[0])


# --------------------------------------------------------------------------
# flat + sharded persistence of the frozen layout
# --------------------------------------------------------------------------

def test_frozen_state_dict_roundtrip_without_refreeze():
    rng = np.random.default_rng(8)
    docs = _corpus(rng)
    frozen = IndexBuilder(scheme=MultisetScheme(seed=9, k=8)).build(
        docs).freeze()
    clone = SearchIndex.from_state(MultisetScheme(seed=9, k=8),
                                   frozen.state_dict())
    assert clone.is_frozen
    q = docs[0][2:40]
    assert _blocks(query(clone, q, 0.5)) == _blocks(query(frozen, q, 0.5))


@pytest.mark.parametrize("kind", ["multiset", "weighted"])
def test_sharded_frozen_save_restore_roundtrip(tmp_path, kind):
    rng = np.random.default_rng(9)
    docs = _corpus(rng, n_docs=9)
    qs = _queries(rng, docs, n=4)
    sharded = ShardedAlignmentIndex(scheme=SCHEMES[kind](),
                                    n_shards=3).build(docs)
    looped = [_blocks(sharded.query(q, 0.5)) for q in qs]
    sharded.freeze()
    assert [_blocks(r) for r in sharded.batch_query(qs, 0.5)] == looped
    sharded.save(tmp_path)

    restored = ShardedAlignmentIndex(scheme=SCHEMES[kind](), n_shards=3)
    lost = restored.restore(tmp_path)
    assert lost == [] and restored.is_frozen
    assert [_blocks(r) for r in restored.batch_query(qs, 0.5)] == looped
