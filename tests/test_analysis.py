"""The ``repro.analysis`` invariant checker: each rule family catches a
seeded-bad fixture, dispatcher/exempt paths stay clean, suppressions
move findings aside (but keep them auditable), and the real tree is
finding-free.

Fixtures are written to ``tmp_path`` so the full-tree run never sees
them."""

from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

from repro.analysis import run_analysis
from repro.analysis.framework import render_json

REPO = Path(__file__).resolve().parents[1]


def _write(tmp_path: Path, rel: str, src: str) -> Path:
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(src)
    return p


def _rules(report):
    return sorted({f.rule for f in report.findings})


# -- RPR1xx: engine affinity ------------------------------------------------


ENGINE_FIXTURE = '''
from repro.core.guard import engine_only

class LiveIndex:
    @engine_only
    def add_text(self, tokens):
        pass

    @engine_only
    def promote_sealed(self, gen, idx):
        pass

class Handlers:
    async def handle_add_bad(self, tokens):
        return self.live.add_text(tokens)            # line 16: flagged

    async def handle_add_ok(self, tokens):
        return await self.batcher.submit_control(
            lambda: self.live.add_text(tokens), "add")

    async def compact_ok(self):
        def _seal():
            self.live.promote_sealed(1, None)        # dispatched: exempt
        await self.batcher.submit_control(_seal, "seal")

    def helper(self, tokens):
        self.live.add_text(tokens)                   # taints helper

    async def handle_indirect_bad(self, tokens):
        self.helper(tokens)                          # flagged via taint
'''


def test_engine_rule_flags_direct_and_indirect_calls(tmp_path):
    _write(tmp_path, "serve/handlers.py", ENGINE_FIXTURE)
    report = run_analysis(["serve"], rules=["RPR1"], root=tmp_path)
    assert _rules(report) == ["RPR101"]
    lines = sorted(f.line for f in report.findings)
    by_line = {f.line: f.message for f in report.findings}
    # the direct call in handle_add_bad
    assert any("handle_add_bad" in m and "add_text" in m
               for m in by_line.values())
    # the indirect call through the tainted helper
    assert any("handle_indirect_bad" in m and "helper" in m
               for m in by_line.values())
    # the helper's own direct call is a finding in its own right
    assert any(m.startswith("serve/handlers.py:Handlers.helper")
               for m in by_line.values())
    # nothing flagged inside the dispatcher-routed paths
    assert all("handle_add_ok" not in m and "compact_ok" not in m
               and "_seal" not in m for m in by_line.values())
    assert len(lines) == 3


def test_engine_rule_only_fires_in_serve_paths(tmp_path):
    # identical code outside a serve/ path: build scripts may mutate
    _write(tmp_path, "tools/handlers.py", ENGINE_FIXTURE)
    report = run_analysis(["tools"], rules=["RPR1"], root=tmp_path)
    assert report.findings == []


# -- RPR2xx: store ordering -------------------------------------------------


STORE_FIXTURE = '''
import numpy as np

def bad_commit_order(writer, root, arrays):
    writer.finalize(num_texts=1, num_windows=1, text_lengths=[1])
    for i, a in enumerate(arrays):
        np.save(root / f"t_{i}.npy", a)

def good_commit_order(writer, root, arrays):
    for i, a in enumerate(arrays):
        np.save(root / f"t_{i}.npy", a)
    writer.finalize(num_texts=1, num_windows=1, text_lengths=[1])

def bad_pointer_write(root):
    (root / "CURRENT").write_text("v000001")

def good_pointer_write(root):
    tmp = root / "CURRENT.tmp"
    tmp.write_text("v000001")
    tmp.rename(root / "CURRENT")
'''


def test_store_rules_flag_bad_order_and_raw_pointer_writes(tmp_path):
    _write(tmp_path, "pkg/writer.py", STORE_FIXTURE)
    report = run_analysis(["pkg"], rules=["RPR201", "RPR202"],
                          root=tmp_path)
    msgs = {f.rule: [] for f in report.findings}
    for f in report.findings:
        msgs[f.rule].append(f.message)
    assert sorted(msgs) == ["RPR201", "RPR202"]
    assert any("bad_commit_order" in m for m in msgs["RPR201"])
    assert all("good_commit_order" not in m for m in msgs["RPR201"])
    # the raw write is flagged; the tmp+rename one is not
    lines202 = [f.line for f in report.findings if f.rule == "RPR202"]
    assert len(lines202) == 1


def test_store_module_itself_is_exempt_from_rpr202(tmp_path):
    _write(tmp_path, "src/repro/core/store.py",
           '(root / "CURRENT").write_text("v1")\n')
    report = run_analysis(["src"], rules=["RPR202"], root=tmp_path)
    assert report.findings == []


FSIO_FIXTURE = '''
import shutil
import numpy as np
from repro.fault import fsio

def raw_manifest(root, payload):
    np.save(root / "t_00.keys.npy", payload)         # RPR203: raw np.save
    (root / "manifest.json").write_text("{}")        # RPR203 (+RPR202)

def raw_cleanup(root):
    (root / "shard_0.pkl").unlink()                  # RPR203: .pkl unlink
    shutil.rmtree(root / "old", ignore_errors=True)  # no artifact: clean

def routed(root, payload):
    fsio.np_save(root / "t_00.keys.npy", payload, site="x.arr")
    fsio.commit_text(root / "manifest.json", "{}", site="x.manifest")
    fsio.unlink(root / "shard_0.pkl", site="x.retire")

def not_a_rename(s):
    return s.replace("old", "new")                   # str.replace: clean
'''


def test_fsio_rule_flags_bypasses_and_accepts_routed_calls(tmp_path):
    _write(tmp_path, "pkg/mutators.py", FSIO_FIXTURE)
    report = run_analysis(["pkg"], rules=["RPR203"], root=tmp_path)
    lines = sorted(f.line for f in report.findings)
    src = FSIO_FIXTURE.splitlines()
    flagged = {src[ln - 1].strip() for ln in lines}
    assert len(lines) == 3
    assert any("np.save" in s for s in flagged)
    assert any("manifest.json" in s and "write_text" in s for s in flagged)
    assert any(".pkl" in s for s in flagged)
    # fsio-routed calls, artifact-free rmtree, and str.replace are clean
    assert all("fsio." not in s for s in flagged)
    assert all("shutil.rmtree" not in s for s in flagged)
    assert all("not_a_rename" not in s for s in flagged)


def test_fsio_rule_enforces_every_mutation_in_durability_modules(tmp_path):
    # inside an enforced module even artifact-free mutations must route
    # through fsio
    _write(tmp_path, "src/repro/train/checkpoint.py",
           'import shutil\n'
           'def gc(p):\n'
           '    shutil.rmtree(p, ignore_errors=True)\n')
    report = run_analysis(["src"], rules=["RPR203"], root=tmp_path)
    assert [f.line for f in report.findings] == [3]
    # the fsio module itself is exempt (it IS the indirection)
    _write(tmp_path, "src/repro/fault/fsio.py",
           'def write_bytes(path, data, *, site):\n'
           '    path.write_bytes(data)\n')
    report = run_analysis(["src/repro/fault"], rules=["RPR203"],
                          root=tmp_path)
    assert report.findings == []


# -- RPR3xx: kernel purity --------------------------------------------------


KERNEL_FIXTURE = '''
import numpy as np
from functools import partial
import jax.experimental.pallas as pl

def _sum_kernel(x_ref, o_ref, *, block):
    total = np.sum(x_ref[...])                       # RPR301
    if total > 0:                                    # RPR303 (traced)
        o_ref[...] = total
    host = total.item()                              # RPR302

def clean_body(x_ref, o_ref, *, block):
    i = pl.program_id(0)
    o_ref[...] = x_ref[...] * 2

def run(x):
    return pl.pallas_call(partial(clean_body, block=8))(x)

def host_helper(arr):
    if arr.size > 0:                                 # not a kernel: fine
        return np.sum(arr)
'''


def test_kernel_rules_flag_numpy_sync_and_traced_branch(tmp_path):
    _write(tmp_path, "kernels/bad.py", KERNEL_FIXTURE)
    report = run_analysis(["kernels"], root=tmp_path)
    assert _rules(report) == ["RPR301", "RPR302", "RPR303"]
    assert all("_sum_kernel" in f.message for f in report.findings)


def test_kernel_rules_scope_to_kernels_dirs(tmp_path):
    _write(tmp_path, "models/bad.py", KERNEL_FIXTURE)
    report = run_analysis(["models"], rules=["RPR3"], root=tmp_path)
    assert report.findings == []


# -- RPR4xx: API deprecations -----------------------------------------------


API_FIXTURE = '''
from repro.core.index import AlignmentIndex          # RPR403

def old_style(aligner, qs):
    res = aligner.find_batch(qs, 0.5, probe_backend="percoord")  # RPR401
    raw = aligner.find(qs[0], 0.5, legacy_tuples=True)           # RPR402
    idx = AlignmentIndex(scheme=None)                # RPR403
    return res, raw, idx

def new_style(aligner, qs, opts):
    return aligner.find_batch(qs, 0.5, options=opts)

def core_function_old(index, qs):
    from repro.core import batch_query
    return batch_query(index, qs, 0.5, sketch_backend="exact")  # RPR404

def core_function_ok(index, qs, opts):
    from repro.core import batch_query
    return batch_query(index, qs, 0.5, options=opts)
'''


def test_api_rules_flag_each_deprecated_surface(tmp_path):
    _write(tmp_path, "pkg/old.py", API_FIXTURE)
    report = run_analysis(["pkg"], root=tmp_path)
    assert _rules(report) == ["RPR401", "RPR402", "RPR403", "RPR404"]
    assert sum(f.rule == "RPR403" for f in report.findings) == 2
    assert all("new_style" not in f.message for f in report.findings)


def test_rpr404_method_calls_defer_overlap_to_rpr401(tmp_path):
    # on a *method* call RPR401 owns probe_backend/sweep/sketches; RPR404
    # adds only the spellings RPR401 cannot see (sketch_backend, and any
    # stage kwarg on a bare-function call) so one call site never earns
    # two findings for the same kwarg
    _write(tmp_path, "pkg/mixed.py", '''
def f(aligner, idx, qs, sk):
    from repro.core import batch_query
    aligner.find_batch(qs, 0.5, sweep="loop")              # RPR401 only
    aligner.find_batch(qs, 0.5, sketch_backend="exact")    # RPR404 only
    batch_query(idx, qs, 0.5, probe_backend="numpy", sweep="loop")  # RPR404
''')
    report = run_analysis(["pkg"], rules=["RPR4"], root=tmp_path)
    assert _rules(report) == ["RPR401", "RPR404"]
    by_line = {f.line: f.rule for f in report.findings}
    assert by_line == {4: "RPR401", 5: "RPR404", 6: "RPR404"}


# -- suppressions, parse errors, CLI ----------------------------------------


def test_allow_comment_suppresses_but_stays_auditable(tmp_path):
    _write(tmp_path, "pkg/waived.py", '''
def bad(root):
    (root / "CURRENT").write_text("v1")  # repro: allow[RPR202]

def bad_above(root):
    # repro: allow[RPR202]
    (root / "CURRENT").write_text("v2")

def bad_unwaived(root):
    (root / "CURRENT").write_text("v3")

def bad_wrong_rule(root):
    (root / "CURRENT").write_text("v4")  # repro: allow[RPR999]

def bad_wildcard(root):
    (root / "CURRENT").write_text("v5")  # repro: allow[*]
''')
    report = run_analysis(["pkg"], rules=["RPR202"], root=tmp_path)
    assert len(report.findings) == 2          # unwaived + wrong-rule
    assert len(report.suppressed) == 3        # same-line, above, wildcard
    # suppressed findings stay in the JSON artifact for audit
    payload = json.loads(render_json(report))
    assert len(payload["suppressed"]) == 3
    assert payload["checked_files"] == 1


def test_syntax_errors_surface_as_findings(tmp_path):
    _write(tmp_path, "pkg/broken.py", "def f(:\n")
    report = run_analysis(["pkg"], root=tmp_path)
    assert [f.rule for f in report.findings] == ["RPR000"]


def test_cli_exit_codes_and_json(tmp_path):
    _write(tmp_path, "pkg/bad.py",
           '(root / "CURRENT").write_text("v1")\n')
    env = {"PYTHONPATH": str(REPO / "src")}
    bad = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--format", "json", "pkg"],
        cwd=tmp_path, env=env, capture_output=True, text=True, timeout=120)
    assert bad.returncode == 1
    payload = json.loads(bad.stdout)
    assert payload["findings"][0]["rule"] == "RPR202"
    assert "RPR101" in payload["rules"]       # every family documented
    ok = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--rules", "RPR3", "pkg"],
        cwd=tmp_path, env=env, capture_output=True, text=True, timeout=120)
    assert ok.returncode == 0, ok.stdout


# -- the real tree is clean -------------------------------------------------


def test_repository_tree_has_zero_findings():
    paths = [p for p in ("src", "tests", "benchmarks", "examples")
             if (REPO / p).exists()]
    report = run_analysis(paths, root=REPO)
    assert report.findings == [], "\n".join(
        f.render() for f in report.findings)
    # the waivers on deprecation/corruption tests stay visible
    assert report.suppressed, "expected audited allow[] waivers"
