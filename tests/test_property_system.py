"""Hypothesis property tests on system invariants (beyond the paper core):
MoE dispatch equivalence, SSD-vs-sequential SSM equivalence, sharding-rule
totality, attention masking invariants, and tokenizer stability."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import (attention, moe_dense, moe_scatter,
                                 repeat_kv)
from repro.sharding import resolve_spec

pytestmark = pytest.mark.slow          # tier-2: many-example property runs


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


AXIS_NAMES = st.sampled_from(["batch", "seq", "vocab", "embed", "q_feat",
                              "kv_feat", "heads", "kv_heads", "head_dim",
                              "ffn", "experts", "moe_ff", "ssm_inner",
                              "ssm_state", "conv", "layers", None])


@settings(max_examples=120, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 512), AXIS_NAMES),
                min_size=1, max_size=5),
       st.sampled_from([{"data": 16, "model": 16},
                        {"pod": 2, "data": 16, "model": 16},
                        {"data": 4, "model": 2},
                        {"data": 1, "model": 1}]))
def test_resolve_spec_total_and_divisible(dims, mesh_shape):
    """resolve_spec never fails, never over-shards (divisibility), and
    never assigns one mesh axis to two tensor dims."""
    shape = tuple(d for d, _ in dims)
    logical = tuple(a for _, a in dims)
    spec = resolve_spec(shape, logical, FakeMesh(mesh_shape))
    used = []
    for dim, entry in zip(shape, spec):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = math.prod(mesh_shape[a] for a in axes)
        assert dim % prod == 0, (shape, logical, spec)
        used.extend(axes)
    assert len(used) == len(set(used)), spec


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 8), st.integers(2, 6),
       st.integers(1, 3), st.data())
def test_moe_scatter_equals_dense(B, S, E, k, data):
    k = min(k, E)
    d, f = 8, 16
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(B, S, d)), jnp.float32)
    wr = jnp.asarray(rng.normal(size=(d, E)), jnp.float32)
    w1 = jnp.asarray(rng.normal(size=(E, d, f)) * 0.2, jnp.float32)
    w3 = jnp.asarray(rng.normal(size=(E, d, f)) * 0.2, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(E, f, d)) * 0.2, jnp.float32)
    dense = moe_dense(x, wr, w1, w3, w2, k)
    scatter = moe_scatter(x, wr, w1, w3, w2, k, capacity_factor=float(E))
    np.testing.assert_allclose(np.asarray(dense), np.asarray(scatter),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.integers(3, 24), st.integers(1, 4),
       st.integers(0, 2**31 - 1))
def test_ssd_equals_sequential_scan(B, S, nh, seed):
    """Chunked SSD (matmul form) == per-step recurrence, any chunk size."""
    from repro.models.ssm import mamba2_block
    from repro.configs import get_config
    cfg = dataclasses.replace(
        get_config("zamba2-1.2b").reduced(), compute_dtype="float32")
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(seed % 1000))
    p = jax.tree.map(lambda a: a[0], params["layers"])
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)) * 0.1, jnp.float32)
    y_big, (ct_b, h_b) = mamba2_block(x, p, cfg, scan_chunk=max(S, 4))
    y_small, (ct_s, h_s) = mamba2_block(x, p, cfg, scan_chunk=3)
    np.testing.assert_allclose(np.asarray(y_big), np.asarray(y_small),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h_b), np.asarray(h_s),
                               rtol=2e-4, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 16), st.integers(1, 4), st.integers(0, 1000))
def test_causal_attention_ignores_future(S, H, seed):
    """Changing tokens after position t never changes output at t."""
    D = 8
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, S, H, D)), jnp.float32)
    t = S // 2
    out1 = attention(q, k, v)
    k2 = k.at[:, t + 1:].add(3.0)
    v2 = v.at[:, t + 1:].add(-5.0)
    out2 = attention(q, k2, v2)
    np.testing.assert_allclose(np.asarray(out1[:, :t + 1]),
                               np.asarray(out2[:, :t + 1]),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 8), st.integers(1, 8), st.integers(1, 64))
def test_repeat_kv_preserves_heads(KV, G, D):
    H = KV * G
    x = jnp.arange(2 * 3 * KV * D, dtype=jnp.float32).reshape(2, 3, KV, D)
    r = repeat_kv(x, H)
    assert r.shape == (2, 3, H, D)
    for h in range(H):
        np.testing.assert_array_equal(np.asarray(r[:, :, h]),
                                      np.asarray(x[:, :, h // G]))


@settings(max_examples=50, deadline=None)
@given(st.text(min_size=0, max_size=120))
def test_tokenizer_total_and_stable(text):
    from repro.data import HashWordTokenizer
    tok = HashWordTokenizer(vocab=512)
    a = tok.encode(text)
    b = tok.encode(text)
    np.testing.assert_array_equal(a, b)
    assert ((a >= 4) & (a < 512)).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=1, max_size=40),
       st.integers(0, 100))
def test_sketch_collision_estimates_multiset_jaccard(tok_list, seed):
    """E[sketch agreement] == multiset Jaccard (binomial CI, k=48)."""
    from repro.core import MultisetScheme
    from repro.core.oracle import jaccard_multiset
    a = np.asarray(tok_list, np.int64)
    b = np.concatenate([a[: max(1, len(a) // 2)],
                        np.asarray([31, 32, 33], np.int64)])
    scheme = MultisetScheme(seed=seed, k=48)
    sa, sb = scheme.sketch(a), scheme.sketch(b)
    est = np.mean([x == y for x, y in zip(sa, sb)])
    true = jaccard_multiset(a, b)
    # 4-sigma binomial bound
    assert abs(est - true) <= 4 * math.sqrt(true * (1 - true) / 48) + 1e-9
