"""Self-verifying store: manifest checksums, quarantine-and-fallback
recovery, generation pruning, and the ``python -m repro.fsck`` CLI."""

import json

import numpy as np
import pytest

from repro import fsck
from repro.core import IndexBuilder, batch_query, make_scheme, save_index
from repro.core import store as index_store
from repro.core.live import LiveIndex
from repro.core.store import (CURRENT_POINTER, current_generation,
                              load_index, prune_generations,
                              resolve_verified, verify_generation,
                              verify_store)


def _docs(rng, n=8):
    return [rng.integers(0, 40, 60).astype(np.int64) for _ in range(n)]


def _store(tmp_path, rng, name="idx"):
    scheme = make_scheme("multiset", seed=3, k=4)
    docs = _docs(rng)
    save_index(IndexBuilder(scheme=scheme).build(docs).freeze(),
               tmp_path / name)
    return tmp_path / name, scheme, docs


def _tamper(path):
    """Flip one byte in the middle of an array payload."""
    data = bytearray(path.read_bytes())
    data[len(data) // 2] ^= 0xFF
    path.write_bytes(bytes(data))  # repro: allow[RPR203] (corruption fixture)


# --------------------------------------------------------------------------
# verification
# --------------------------------------------------------------------------


def test_writer_records_checksums_and_verify_passes(tmp_path):
    root, _, _ = _store(tmp_path, np.random.default_rng(0))
    manifest = json.loads((root / "manifest.json").read_text())
    sums = manifest["checksums"]
    assert all(f.endswith(".npy") for f in sums)
    assert all(set(rec) == {"algo", "crc", "dtype", "shape"}
               for rec in sums.values())
    rep = verify_generation(root)
    assert rep.ok and rep.committed
    assert rep.checksummed == rep.arrays == len(sums)


def test_verify_catches_bitflip_truncation_and_missing_file(tmp_path):
    rng = np.random.default_rng(1)
    for breakage in ("bitflip", "truncate", "missing"):
        root, _, _ = _store(tmp_path, rng, name=f"idx_{breakage}")
        victim = root / "table_00.keys.npy"
        if breakage == "bitflip":
            _tamper(victim)
        elif breakage == "truncate":
            victim.write_bytes(victim.read_bytes()[:40])  # repro: allow[RPR203]
        else:
            victim.unlink()  # repro: allow[RPR203] (corruption fixture)
        rep = verify_generation(root)
        assert not rep.ok, breakage
        assert any("table_00.keys.npy" in p for p in rep.problems), breakage


def test_legacy_store_without_checksums_passes_structurally(tmp_path):
    root, _, _ = _store(tmp_path, np.random.default_rng(2))
    manifest = json.loads((root / "manifest.json").read_text())
    del manifest["checksums"]
    (root / "manifest.json").write_text(json.dumps(manifest))  # repro: allow[RPR202,RPR203]
    rep = verify_generation(root)
    assert rep.ok and rep.checksummed == 0 and rep.arrays > 0
    # but structural damage is still caught
    (root / "table_00.keys.npy").unlink()  # repro: allow[RPR203]
    assert not verify_generation(root).ok


# --------------------------------------------------------------------------
# recovery: quarantine + fallback
# --------------------------------------------------------------------------


def _compacted(tmp_path, rng):
    root, scheme, docs = _store(tmp_path, rng)
    live = LiveIndex.open(root, scheme=scheme)
    delta = _docs(rng, 3)
    for t in delta:
        live.add_text(t)
    assert live.compact() == 1
    return root, scheme, docs, delta


def test_corrupt_serving_generation_is_quarantined_with_fallback(tmp_path):
    rng = np.random.default_rng(3)
    root, scheme, docs, _delta = _compacted(tmp_path, rng)
    _tamper(root / "v000001" / "table_00.keys.npy")

    resolved = resolve_verified(root)
    assert resolved == root                       # fell back to gen 0
    assert current_generation(root) == 0
    assert (root / "quarantine" / "v000001" / "manifest.json").exists()
    assert not (root / "v000001").exists()
    # quarantined numbers stay reserved: the next compaction skips 1
    live = LiveIndex.open(root, scheme=scheme)
    live.add_text(_docs(rng, 1)[0])
    assert live.compact() == 2

    # the quarantined data is preserved for forensics (readable when
    # verification is bypassed — only one byte of it is bad)
    idx = load_index(root / "quarantine" / "v000001", verify=False)
    assert idx.num_texts == len(docs) + 3


def test_load_index_recovers_transparently(tmp_path):
    rng = np.random.default_rng(4)
    root, scheme, docs, _ = _compacted(tmp_path, rng)
    _tamper(root / "v000001" / "arena.keys.npy")
    idx = load_index(root, scheme=scheme)         # verify=True default
    assert idx.num_texts == len(docs)             # serving gen 0 again
    q = docs[2][5:50]
    expected = batch_query(
        IndexBuilder(scheme=make_scheme("multiset", seed=3, k=4)).build(docs),
        [q], 0.5)
    got = batch_query(idx, [q], 0.5)
    assert [(a.text_id, a.blocks) for a in got[0]] == \
        [(a.text_id, a.blocks) for a in expected[0]]


def test_flat_store_that_fails_verification_raises(tmp_path):
    root, _, _ = _store(tmp_path, np.random.default_rng(5))
    _tamper(root / "table_01.keys.npy")
    with pytest.raises(ValueError, match="fails verification"):
        resolve_verified(root)
    with pytest.raises(ValueError, match="fails verification"):
        load_index(root)
    # the data is still there for manual forensics — nothing deleted
    assert (root / "manifest.json").exists()


def test_verify_store_reports_the_whole_tree(tmp_path):
    rng = np.random.default_rng(6)
    root, scheme, _, _ = _compacted(tmp_path, rng)
    (root / "v000007").mkdir()                    # an aborted write
    rep = verify_store(root)
    assert rep["ok"]
    roles = {g["generation"]: g["role"] for g in rep["generations"]}
    assert roles[0] == "retained" and roles[1] == "serving"
    assert roles[7] == "aborted"
    # aborted dirs don't fail the store; corrupt committed ones do
    _tamper(root / "v000001" / "table_00.offsets.npy")
    rep = verify_store(root)
    assert not rep["ok"]


# --------------------------------------------------------------------------
# pruning
# --------------------------------------------------------------------------


def test_prune_keeps_serving_recent_and_quarantine(tmp_path):
    rng = np.random.default_rng(7)
    root, scheme, docs, delta = _compacted(tmp_path, rng)
    live = LiveIndex.open(root, scheme=scheme)
    for gen in (2, 3, 4):
        live.add_text(_docs(rng, 1)[0])
        assert live.compact() == gen
    # quarantine one old generation by corrupting + resolving via a
    # pointer rewind... simpler: move it through the store API
    index_store.quarantine_generation(root, "v000001")

    removed = prune_generations(root, keep=2)
    names = {p.name for p in removed}
    assert names == {"v000002"}                   # 3,4 kept; 1 quarantined
    assert (root / "v000003").exists() and (root / "v000004").exists()
    assert (root / "quarantine" / "v000001").exists()
    assert current_generation(root) == 4
    # gen 0 (the flat root) is never pruned
    assert (root / "manifest.json").exists()

    # keep_quarantined=False reclaims the quarantine tree too
    removed = prune_generations(root, keep=2, keep_quarantined=False)
    assert {p.name for p in removed} == {"quarantine"}
    assert not (root / "quarantine").exists()


def test_prune_spares_inflight_aborted_dirs(tmp_path):
    rng = np.random.default_rng(8)
    root, scheme, _, _ = _compacted(tmp_path, rng)
    (root / "v000002").mkdir()                    # in-flight: gen > serving
    (root / "v000000x").mkdir()                   # junk dir, not a version
    removed = prune_generations(root, keep=0)
    assert removed == []                          # serving=1, nothing old
    assert (root / "v000002").exists()


# --------------------------------------------------------------------------
# the CLI
# --------------------------------------------------------------------------


def test_fsck_cli_text_json_and_exit_codes(tmp_path, capsys):
    rng = np.random.default_rng(9)
    root, _, _, _ = _compacted(tmp_path, rng)

    assert fsck.main([str(root)]) == 0
    out = capsys.readouterr().out
    assert "all ok" in out and "serving generation 1" in out

    assert fsck.main(["--format", "json", str(tmp_path)]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["ok"] and rep["checked"] == 1
    assert rep["stores"][0]["serving_generation"] == 1

    _tamper(root / "v000001" / "table_00.windows.npy")
    assert fsck.main([str(root)]) == 1
    assert "FAILED" in capsys.readouterr().out

    assert fsck.main([str(tmp_path / "nothing_here")]) == 2


def test_fsck_expands_sharded_roots(tmp_path):
    from repro.api import Aligner
    rng = np.random.default_rng(10)
    docs = [rng.integers(0, 400, 60).astype(np.int64) for _ in range(6)]
    Aligner.build(docs, similarity="multiset", k=4, seed=5,
                  shards=2).save(tmp_path / "sh")
    stores = fsck.discover_stores(tmp_path / "sh")
    assert [p.name for p in stores] == ["shard_0", "shard_1"]
    assert fsck.main([str(tmp_path / "sh")]) == 0
