"""End-to-end driver: train a small LM (any assigned architecture, reduced)
on a dedup-filtered synthetic corpus for a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py --arch qwen1.5-4b --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch mixtral-8x7b --dedup

On a TPU pod the same Trainer runs the full config with the production mesh
(launch/train.py); this example keeps the CPU footprint laptop-sized.
"""

import argparse

from repro.configs import ARCH_IDS, get_config
from repro.train import OptConfig
from repro.train.loop import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dedup", action="store_true",
                    help="filter near-duplicate docs via the paper's index")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--d-model", type=int, default=128,
                    help="reduced width for CPU (default 128)")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        d_model=args.d_model, n_layers=4, vocab=2048,
        n_heads=8, n_kv_heads=4, head_dim=args.d_model // 8, d_ff=4 * args.d_model)
    print(f"arch={args.arch} (reduced): {cfg.param_count() / 1e6:.2f}M params")

    tc = TrainerConfig(steps=args.steps, batch_size=args.batch,
                       seq_len=args.seq, log_every=20,
                       ckpt_every=100 if args.ckpt else 0,
                       ckpt_dir=args.ckpt, n_docs=3000,
                       dedup_theta=0.55 if args.dedup else 0.0)
    oc = OptConfig(lr=3e-3, warmup_steps=20, decay_steps=max(args.steps, 100))
    out = Trainer(cfg, tc, ocfg=oc).run()

    print(f"\ntrained {out['steps']} steps in {out['wall_s']:.1f}s "
          f"({out['steps'] * args.batch * args.seq / out['wall_s']:.0f} tok/s)")
    print(f"loss: {out['first_loss']:.4f} -> {out['final_loss']:.4f}")
    if out["dedup"]:
        print(f"dedup: {out['dedup']}")
    assert out["final_loss"] < out["first_loss"], "training must reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
