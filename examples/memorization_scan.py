"""Memorization analysis (the paper's §1 LLM application): train a tiny LM,
sample from it with the KV-cache decode path, and align every generation
against the training corpus index -- verbatim/near-verbatim regurgitation
shows up as high-theta alignments.

    PYTHONPATH=src python examples/memorization_scan.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Aligner
from repro.configs import get_config
from repro.data import PackedDataset, synthetic_corpus, HashWordTokenizer
from repro.models import RunFlags, decode_step, init_params, prefill
from repro.train import OptConfig, init_opt_state, make_train_step


def main():
    tok = HashWordTokenizer(vocab=2048)
    # tiny corpus with one document repeated many times -> the model WILL
    # memorize it
    docs = tok.encode_batch(synthetic_corpus(60, seed=3, dup_fraction=0.0,
                                             mean_len=48))
    secret = docs[0]
    train_docs = docs + [secret] * 40

    cfg = dataclasses.replace(
        get_config("qwen1.5-4b").reduced(vocab=2048, d_model=128, n_heads=8,
                                         n_kv_heads=4, head_dim=16, d_ff=512),
        compute_dtype="float32")
    flags = RunFlags(moe_mode="dense", remat_policy="none", q_chunk=0,
                     scan_chunk=64)
    data = PackedDataset.pack(train_docs, 64)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        cfg, OptConfig(lr=5e-3, warmup_steps=10, decay_steps=400),
        flags=flags), donate_argnums=(0, 1))
    it = data.batches(8, seed=0)
    for i in range(150):
        params, opt, m = step(params, opt, next(it))
        if (i + 1) % 50 == 0:
            print(f"step {i+1} loss {float(m['loss']):.3f}")

    # index the training corpus with the paper's structure
    aligner = Aligner.build(train_docs, similarity="multiset", seed=5, k=24)

    # greedy-decode continuations of the secret prefix
    prompt = jnp.asarray(secret[:8][None, :], jnp.int32)
    logits, cache = prefill(params, cfg, tokens=prompt, max_seq=72,
                            flags=flags)
    out_tokens = []
    tok_next = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for t in range(40):
        out_tokens.append(int(tok_next[0, 0]))
        logits, cache = decode_step(params, cache, tok_next,
                                    jnp.int32(8 + t), cfg, flags=flags)
        tok_next = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    gen = np.asarray(out_tokens, np.int64)

    overlap = np.mean(gen[:len(secret) - 8] == secret[8:8 + len(gen)])
    hits = aligner.find(gen, 0.5)
    mem_docs = {h.text_id for h in hits}
    print(f"\ngenerated 40 tokens; token-overlap with memorized doc: "
          f"{overlap:.0%}")
    print(f"alignment scan: generation aligns with {len(mem_docs)} training "
          f"doc(s) at theta=0.5 -> memorization {'DETECTED' if hits else 'none'}")
    assert hits, "memorized continuation must align with the training corpus"
    print("OK")


if __name__ == "__main__":
    main()
