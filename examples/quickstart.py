"""Quickstart: build a weighted-Jaccard alignment index over a small corpus
and find every subsequence aligned with a query (the paper's Definition 1).

    PYTHONPATH=src python examples/quickstart.py
"""


from repro.core import AlignmentIndex, WeightedScheme, query
from repro.core.weights import WeightFn
from repro.data import HashWordTokenizer

CORPUS = [
    "the quick brown fox jumps over the lazy dog and then naps in the sun",
    "a completely unrelated sentence about lattice quantum entropy kernels",
    "yesterday the quick brown fox jumped over a lazy dog near the barn",
    "gradient descent on a manifold of tensor shards with pallas kernels",
]

QUERY = "the quick brown fox jumps over the lazy dog"


def main():
    tok = HashWordTokenizer(vocab=32_000)
    docs = tok.encode_batch(CORPUS)

    # TF-IDF weighted Jaccard: raw-count TF x smooth IDF over this corpus
    doc_freq = {}
    for d in docs:
        for t in set(d.tolist()):
            doc_freq[t] = doc_freq.get(t, 0) + 1
    weight = WeightFn(tf="raw", idf="smooth", n_docs=len(docs),
                      doc_freq=doc_freq)
    scheme = WeightedScheme(weight=weight, seed=0, k=32)

    index = AlignmentIndex(scheme=scheme, method="mono_active")
    index.build(docs)
    print(f"indexed {index.num_texts} docs, {index.num_windows} compact "
          f"windows (k={scheme.k})")

    q = tok.encode(QUERY)
    for theta in (0.8, 0.5, 0.3):
        hits = query(index, q, theta)
        print(f"\ntheta={theta}: {len(hits)} aligned text(s)")
        for h in hits:
            il, ih, jl, jh = h.blocks[0]
            words = CORPUS[h.text_id].split()[il:jh + 1]
            print(f"  doc {h.text_id}: tokens [{il}..{jh}] "
                  f"~ \"{' '.join(words[:12])}...\"")

    # sanity: doc 0 contains the query verbatim -> must align at theta=0.8
    assert any(h.text_id == 0 for h in query(index, q, 0.8))
    print("\nOK: verbatim container found at theta=0.8")


if __name__ == "__main__":
    main()
