"""Quickstart: build a TF-IDF weighted-Jaccard alignment index over a small
corpus and find every subsequence aligned with a query (the paper's
Definition 1) — three calls on the `Aligner` facade: build, find, save/load.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.api import Aligner

CORPUS = [
    "the quick brown fox jumps over the lazy dog and then naps in the sun",
    "a completely unrelated sentence about lattice quantum entropy kernels",
    "yesterday the quick brown fox jumped over a lazy dog near the barn",
    "gradient descent on a manifold of tensor shards with pallas kernels",
]

QUERY = "the quick brown fox jumps over the lazy dog"


def main():
    # one call: tokenize, fit TF-IDF weights from the corpus, build the
    # k inverted indexes of compact windows
    aligner = Aligner.build(CORPUS, similarity="tfidf", k=32)
    print(f"indexed {aligner.num_docs} docs, {aligner.num_windows} compact "
          f"windows (k={aligner.config.k})")

    for theta in (0.8, 0.5, 0.3):
        hits = aligner.find(QUERY, theta)
        print(f"\ntheta={theta}: {len(hits)} aligned text(s)")
        for h in hits:
            il, ih, jl, jh = h.blocks[0]
            words = CORPUS[h.text_id].split()[il:jh + 1]
            print(f"  doc {h.text_id}: tokens [{il}..{jh}] "
                  f"~ \"{' '.join(words[:12])}...\"")

    # sanity: doc 0 contains the query verbatim -> must align at theta=0.8
    assert any(h.text_id == 0 for h in aligner.find(QUERY, 0.8))
    print("\nOK: verbatim container found at theta=0.8")

    # build -> serve: persist the frozen CSR layout and serve it back
    # memory-mapped (a >RAM corpus would page windows in on demand)
    with tempfile.TemporaryDirectory() as store:
        aligner.save(store)
        server = Aligner.load(store, mmap=True)
        batch = server.find_batch([QUERY, CORPUS[2]], theta=0.5)
        assert [[h.text_id for h in r] for r in batch] == \
            [[h.text_id for h in aligner.find(q, 0.5)]
             for q in (QUERY, CORPUS[2])]
        print(f"OK: saved -> mmap-loaded -> served {len(batch)} queries "
              f"block-identically ({server!r})")


if __name__ == "__main__":
    main()
