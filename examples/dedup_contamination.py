"""Data-plane example: near-duplicate training-data filtering + test-set
contamination detection -- the paper's LLM applications, wired into the
repro.data pipeline.

    PYTHONPATH=src python examples/dedup_contamination.py
"""

import numpy as np

from repro.data import (ContaminationChecker, DedupFilter, HashWordTokenizer,
                        synthetic_corpus)


def main():
    tok = HashWordTokenizer(vocab=32_000)

    # -- dedup: 25% of the synthetic corpus are planted near-duplicates -----
    docs = tok.encode_batch(synthetic_corpus(300, seed=1, dup_fraction=0.25))
    filt = DedupFilter(theta=0.55)
    kept = [d for d in docs if filt.admit(d)]
    print(f"dedup: admitted {filt.stats['admitted']} / {len(docs)} docs, "
          f"dropped {filt.stats['dropped']} near-duplicates "
          f"({filt.index.num_windows} compact windows indexed)")

    # -- contamination: plant two test docs inside the training set ---------
    rng = np.random.default_rng(2)
    train = kept
    test = tok.encode_batch(synthetic_corpus(40, seed=99, dup_fraction=0.0))
    test[7] = np.concatenate([test[7][:15], train[3][:90]])   # leak 1
    test[21] = train[10].copy()                               # leak 2 (verbatim)

    checker = ContaminationChecker(theta=0.5).fit(train)
    hits = checker.check(test)
    leaked = sorted({h["test_doc"] for h in hits})
    print(f"contamination: {len(hits)} alignment(s) across test docs "
          f"{leaked}")
    for h in hits[:5]:
        print(f"  test doc {h['test_doc']} ~ train doc {h['train_doc']} "
              f"span {h['span']}")
    assert 7 in leaked and 21 in leaked, "planted leaks must be found"
    print("OK: both planted leaks detected, no spurious test docs flagged"
          if leaked == [7, 21] else f"note: extra flagged docs {leaked}")


if __name__ == "__main__":
    main()
