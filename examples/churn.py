"""Churn soak: interleaved add / query / compact against a live store.

    PYTHONPATH=src python examples/churn.py [--rounds N] [--docs-per-round M]

The CI `tier1-live` job runs this on every push/PR: a store is built,
loaded live, and then churned — every round ingests a few documents (one
of them a near-duplicate of an already-indexed text), queries the live
index mid-delta, compacts, and queries again.  After EVERY query the
results are checked block-for-block against a from-scratch
``IndexBuilder`` build of the exact same corpus with the exact same
scheme, and after the final compaction the on-disk generation's CSR
arrays must be bit-identical to a scratch freeze — the live path is
allowed zero drift, ever.  A second soak drives the sharded index
(per-shard deltas, one process-pool compaction) through the same oracle.

Chaos kill-loop (``--chaos N``, the CI ``tier1-chaos`` job): the same
churn workload, but each iteration runs in a child process armed with a
seeded :mod:`repro.fault` plan that ``os._exit``\\ s it at one fsio
checkpoint — every site in the ingest (``wal.append`` / ``wal.fsync`` /
``wal.rotate``) and seal → merge → promote → prune → WAL-truncate path,
both just *before* and just *after* the durable write.  The parent then
verifies in-process that the store still fscks clean with nothing
quarantined, and the next child — which reopens the store through the
recovery path, replaying the WAL — must serve results **bit-identical
to a from-scratch oracle** of exactly the recovered corpus.  The
deterministic corpus (``chaos_doc``) makes "what should be on disk" a
pure function of the recovered doc count, so no state is carried
between iterations.

The acknowledged-writes contract: children ingest through a write-ahead
log and append each doc id to an ack file only once its WAL record is
fsync-durable — exactly when a server would send the client its 200.
After every kill the parent asserts each acknowledged doc survives into
the next recovery (committed docs + durable WAL records), so "the
server said yes, then the process died" can never lose a write.
``--chaos-sites 'wal.*'`` narrows the kill schedule to the ingest path
(the CI ingest-kill leg); unfiltered, the soak sweeps every site.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.api import Aligner
from repro.core import (IndexBuilder, ShardedAlignmentIndex, batch_query,
                        make_scheme, save_index)
from repro.core.live import LiveIndex
from repro.core.store import current_generation, prune_generations
from repro.wal import WalConfig

VOCAB, DOC_LEN, K, THETA = 40, 60, 8, 0.5


def _blocks(res):
    return [[(a.text_id, a.blocks) for a in r] for r in res]


def _new_docs(rng, corpus, n):
    docs = [rng.integers(0, VOCAB, DOC_LEN).astype(np.int64)
            for _ in range(n)]
    # one near-duplicate of an indexed text per round: churn must keep
    # *finding* things, not just keep not-crashing
    docs[-1] = corpus[int(rng.integers(len(corpus)))].copy()
    return docs


def _queries(rng, corpus):
    return [corpus[2][5:50], corpus[-1][:30],
            rng.integers(1000, 1040, 20).astype(np.int64)]     # + a miss


def _check(live_results, scheme, corpus, queries, what):
    oracle = IndexBuilder(scheme=scheme).build(corpus)
    expected = _blocks(batch_query(oracle, queries, THETA))
    assert _blocks(live_results) == expected, \
        f"{what}: live results diverged from the from-scratch build"


def churn_single(rounds: int, docs_per_round: int, root: Path) -> None:
    rng = np.random.default_rng(0)
    corpus = [rng.integers(0, VOCAB, DOC_LEN).astype(np.int64)
              for _ in range(10)]
    scheme = make_scheme("multiset", seed=11, k=K)
    save_index(IndexBuilder(scheme=scheme).build(corpus).freeze(), root)
    live = LiveIndex.open(root, mmap=True)

    for r in range(rounds):
        fresh = _new_docs(rng, corpus, docs_per_round)
        for t in fresh:
            live.add_text(t)
        corpus.extend(fresh)
        qs = _queries(rng, corpus)
        _check(live.batch_query(qs, THETA), scheme, corpus, qs,
               f"round {r} pre-compact (delta={live.delta.num_texts})")
        live.compact()
        _check(live.batch_query(qs, THETA), scheme, corpus, qs,
               f"round {r} post-compact (gen={live.generation})")

    assert live.generation == rounds == current_generation(root)
    # after N compactions the serving arrays are bit-identical to a
    # from-scratch freeze of the same corpus — not merely result-identical
    scratch = IndexBuilder(scheme=scheme).build(corpus).freeze()
    for ta, tb in zip(live.frozen.tables, scratch.tables):
        assert ta.kind == tb.kind
        assert np.array_equal(ta.keys, tb.keys)
        assert np.array_equal(ta.offsets, tb.offsets)
        assert np.array_equal(ta.windows, tb.windows)
    print(f"single-store soak OK: {rounds} compactions, "
          f"{len(corpus)} docs, serving arrays bit-identical to scratch")


def churn_sharded(rounds: int, docs_per_round: int, root: Path) -> None:
    rng = np.random.default_rng(1)
    corpus = [rng.integers(0, VOCAB, DOC_LEN).astype(np.int64)
              for _ in range(12)]
    a = Aligner.build(corpus, similarity="tfidf", k=K, seed=12, shards=3)
    a.save(root)
    live = Aligner.load(root, live=True, mmap=True)
    scheme = live.scheme

    def oracle_results(qs):
        oracle = ShardedAlignmentIndex(scheme=scheme, n_shards=3)
        for t in corpus:
            oracle.add_text(t)
        return _blocks(oracle.batch_query(qs, THETA))

    for r in range(rounds):
        fresh = _new_docs(rng, corpus, docs_per_round)
        for t in fresh:
            live.add(t)
        corpus.extend(fresh)
        qs = _queries(rng, corpus)
        assert _blocks(live.find_batch(qs, THETA)) == oracle_results(qs), \
            f"sharded round {r} pre-compact diverged"
        # last round exercises the process-pool fan-out, earlier ones serial
        live.compact(fanout="process" if r == rounds - 1 else "serial")
        assert _blocks(live.find_batch(qs, THETA)) == oracle_results(qs), \
            f"sharded round {r} post-compact diverged"

    # a cold reader of the churned store agrees with the warm server
    qs = _queries(rng, corpus)
    cold = Aligner.load(root, live=True)
    assert cold.num_docs == len(corpus)
    assert _blocks(cold.find_batch(qs, THETA)) == \
        _blocks(live.find_batch(qs, THETA)), "cold restore diverged"
    print(f"sharded soak OK: {rounds} compactions across 3 shards "
          f"(last one process-pool), {len(corpus)} docs, cold restore agrees")


# --------------------------------------------------------------------------
# chaos kill-loop (--chaos N)
# --------------------------------------------------------------------------

CHAOS_SEED_DOCS = 8
CHAOS_MODES = ("crash", "crash_after")
#: small segments + group commit so a short soak still crosses segment
#: rotation AND compaction-time truncation of covered segments (~one
#: 60-token record per segment), and leaves an fsync-vs-ack window
#: (odd-numbered adds stay pending until the next fsync — a kill there
#: must lose only UNacked docs)
CHAOS_WAL = WalConfig(fsync_every_n=2, segment_bytes=600)


def chaos_doc(i: int) -> np.ndarray:
    """Document ``i`` of the chaos corpus — a pure function of ``i``.

    A killed child leaves no hand-off state: whatever doc count the
    store actually committed before the kill, the next child regenerates
    exactly that corpus prefix and oracle-checks against it.  Every 5th
    doc from 10 on duplicates an earlier one so compactions keep folding
    real matches, not just surviving."""
    rng = np.random.default_rng(100_000 + i)
    if i >= 10 and i % 5 == 0:
        return chaos_doc(int(rng.integers(0, i - 1)))
    return rng.integers(0, VOCAB, DOC_LEN).astype(np.int64)


def _chaos_queries(corpus):
    rng = np.random.default_rng(200_000 + len(corpus))
    return [corpus[2][5:50], corpus[-1][:30],
            rng.integers(1000, 1040, 20).astype(np.int64)]


def chaos_child(store: Path, add_n: int, ack_file: Path | None) -> None:
    """One chaos iteration, run in a subprocess with ``REPRO_FAULT_PLAN``
    armed: recover the store (replaying the WAL), verify it serves
    exactly the recovered corpus, ingest through the WAL, compact,
    prune, verify again.  A fault plan kills this process (``os._exit``)
    at one durable-write checkpoint.

    Each ingested doc id is appended to ``ack_file`` (flush + fsync)
    only once its WAL record is fsync-durable — the moment a server
    would acknowledge the write.  The parent holds every acked id
    against the next recovery."""
    scheme = make_scheme("multiset", seed=11, k=K)
    live = LiveIndex.open(store, mmap=True, wal=CHAOS_WAL)  # recovery path
    n = live.num_texts              # committed + replayed-from-WAL
    corpus = [chaos_doc(i) for i in range(n)]
    qs = _chaos_queries(corpus)
    _check(live.batch_query(qs, THETA), scheme, corpus, qs,
           f"chaos child: recovered store ({n} docs, "
           f"{live.wal_replayed} replayed)")

    acks = open(ack_file, "a") if ack_file is not None else None
    acked_upto = n                  # doc ids below this are acked

    def ack_durable():
        nonlocal acked_upto
        while acked_upto < n + (live.wal.durable_lsn - base_lsn):
            if acks is not None:
                acks.write(f"{acked_upto}\n")
                acks.flush()
                os.fsync(acks.fileno())
            acked_upto += 1

    base_lsn = live.wal.next_lsn
    for i in range(n, n + add_n):
        live.add_text(chaos_doc(i), request_id=f"doc-{i}")
        ack_durable()               # group commit: acks trail the fsync
    live.wal_commit()               # the durability barrier: ack the rest
    ack_durable()
    assert acked_upto == n + add_n
    corpus = [chaos_doc(i) for i in range(n + add_n)]
    qs = _chaos_queries(corpus)
    _check(live.batch_query(qs, THETA), scheme, corpus, qs,
           "chaos child: pre-compact")

    gen = live.compact()
    prune_generations(store, keep=2)
    _check(live.batch_query(qs, THETA), scheme, corpus, qs,
           f"chaos child: post-compact (gen {gen})")
    # the recovered-and-compacted store is bit-identical to a from-scratch
    # build of the same corpus, no matter what the previous kill left
    scratch = IndexBuilder(scheme=scheme).build(corpus).freeze()
    for ta, tb in zip(live.frozen.tables, scratch.tables):
        assert np.array_equal(ta.keys, tb.keys)
        assert np.array_equal(ta.offsets, tb.offsets)
        assert np.array_equal(ta.windows, tb.windows)
    if acks is not None:
        acks.close()
    print(f"chaos child OK: {n} -> {n + add_n} docs, gen {gen}")


def _recovered_count(store: Path) -> int:
    """What the next recovery must serve — committed docs plus durable
    un-covered WAL records — computed READ-ONLY (no tail repair, no
    replay), so the child's recovery path stays the one under test."""
    from repro.core.store import read_manifest, resolve_store
    from repro.wal import iter_records, wal_dir
    manifest = read_manifest(resolve_store(store))
    n = int(manifest["num_texts"])
    known = set(manifest.get("doc_map") or range(n))
    watermark = int(manifest.get("wal_watermark") or 0)
    return n + sum(1 for rec in iter_records(wal_dir(store))
                   if rec.lsn >= watermark and rec.gid not in known)


def _record_chaos_schedule(add_n: int) -> list:
    """One clean in-process run of the child workload under
    ``fault.record_sites()``: the (site, occurrence) pairs it returns ARE
    the kill schedule — every durable write the workload performs (WAL
    appends/fsyncs/rotations included), with no hand-maintained site
    list to go stale."""
    from repro import fault
    tmp = Path(tempfile.mkdtemp())
    try:
        root = tmp / "rec"
        scheme = make_scheme("multiset", seed=11, k=K)
        corpus = [chaos_doc(i) for i in range(CHAOS_SEED_DOCS)]
        save_index(IndexBuilder(scheme=scheme).build(corpus).freeze(), root)
        live = LiveIndex.open(root, mmap=True, wal=CHAOS_WAL)
        with fault.record_sites() as sites:
            for i in range(CHAOS_SEED_DOCS, CHAOS_SEED_DOCS + add_n):
                live.add_text(chaos_doc(i), request_id=f"doc-{i}")
            live.wal_commit()
            live.compact()
            prune_generations(root, keep=2)
        return sorted(set(sites))
    finally:
        shutil.rmtree(tmp)


def chaos_soak(iters: int, seed: int, store: Path, add_n: int,
               out_path: Path | None, sites_glob: str | None = None) -> None:
    """The headline robustness proof: ``iters`` child runs, each killed
    at a seeded fault site in the ingest (``wal.*``) or seal → merge →
    promote → prune → truncate path; after every kill the store must
    fsck clean with nothing quarantined, EVERY acknowledged write must
    survive into the next recovery, and the next child must serve
    bit-identical to a from-scratch oracle.  Ends with one clean run
    that must converge.  ``sites_glob`` (fnmatch) narrows the kill
    schedule — ``'wal.*'`` is the CI ingest-kill leg."""
    from repro import fault
    from repro.fsck import check_store

    if store.exists():
        shutil.rmtree(store)
    store.mkdir(parents=True)
    scheme = make_scheme("multiset", seed=11, k=K)
    corpus = [chaos_doc(i) for i in range(CHAOS_SEED_DOCS)]
    save_index(IndexBuilder(scheme=scheme).build(corpus).freeze(), store)
    # the ack file lives OUTSIDE the store dir: it stands in for the
    # clients' view of which writes were acknowledged
    ack_file = store.parent / (store.name + ".acks")

    schedule = _record_chaos_schedule(add_n)
    if sites_glob:
        schedule = [(s, h) for (s, h) in schedule
                    if fnmatch.fnmatch(s, sites_glob)]
        assert schedule, f"no recorded fault sites match {sites_glob!r}"
    cases = [(site, hit, mode) for (site, hit) in schedule
             for mode in CHAOS_MODES]
    order = np.random.default_rng(seed).permutation(len(cases))
    print(f"chaos soak: {len(schedule)} durable-write sites"
          + (f" (filter {sites_glob!r})" if sites_glob else "")
          + f" x {len(CHAOS_MODES)} kill modes = {len(cases)} cases, "
            f"{iters} iterations (seed {seed})")

    src_root = Path(__file__).resolve().parent.parent / "src"
    env = {**os.environ}
    env["PYTHONPATH"] = str(src_root) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.pop("REPRO_FAULT_PLAN", None)

    def run_child(extra_env):
        if ack_file.exists():
            ack_file.unlink()
        return subprocess.run(
            [sys.executable, str(Path(__file__).resolve()), "--chaos-child",
             "--store", str(store), "--docs-per-round", str(add_n),
             "--ack-file", str(ack_file)],
            env={**env, **extra_env}, capture_output=True, text=True)

    outcomes = []
    killed = survived = acked_total = 0
    recovered = CHAOS_SEED_DOCS
    for it in range(iters):
        site, hit, mode = cases[int(order[it % len(cases)])]
        plan = fault.FaultPlan(
            triggers=[fault.Trigger(site=site, hit=hit, mode=mode)],
            seed=seed)
        n_before = recovered
        proc = run_child({"REPRO_FAULT_PLAN": plan.to_json()})
        if proc.returncode not in (0, fault.FAULT_EXIT):
            raise AssertionError(
                f"chaos iteration {it} ({mode} at {site}@{hit}) exited "
                f"{proc.returncode}, not a clean kill:\n"
                f"{proc.stdout}\n{proc.stderr}")
        rep = check_store(store)
        assert rep["ok"], (
            f"chaos iteration {it}: store fails fsck after {mode} at "
            f"{site}@{hit}: {rep}")
        assert not rep["quarantined"], (
            f"chaos iteration {it}: a valid generation was quarantined "
            f"after {mode} at {site}@{hit}: {rep['quarantined']}")
        # the acknowledged-writes contract: every doc id the child acked
        # (= its WAL record was fsync-durable) must be served by the next
        # recovery, kill or no kill
        acked = ([int(x) for x in ack_file.read_text().split()]
                 if ack_file.exists() else [])
        recovered = _recovered_count(store)
        assert n_before <= recovered <= n_before + add_n, (
            f"chaos iteration {it}: recovery went backwards or invented "
            f"docs ({n_before} -> {recovered}, {mode} at {site}@{hit})")
        if acked:
            assert acked == list(range(n_before, n_before + len(acked))), (
                f"chaos iteration {it}: ack stream not contiguous: {acked}")
            assert acked[-1] < recovered, (
                f"chaos iteration {it}: ACKNOWLEDGED WRITE LOST — doc "
                f"{acked[-1]} was acked but recovery serves only "
                f"{recovered} docs ({mode} at {site}@{hit})")
        acked_total += len(acked)
        if proc.returncode == fault.FAULT_EXIT:
            killed += 1
        else:
            survived += 1          # the plan's site wasn't reached this run
        outcomes.append({"iteration": it, "site": site, "hit": hit,
                         "mode": mode, "exit": proc.returncode,
                         "acked": len(acked), "recovered": recovered,
                         "generation": current_generation(store)})
        if (it + 1) % 10 == 0 or it + 1 == iters:
            print(f"  {it + 1}/{iters}: {killed} killed, {survived} "
                  f"survived, serving gen {current_generation(store)}, "
                  f"{recovered} docs recovered, fsck clean")

    # convergence: one clean run must recover whatever the last kill left
    proc = run_child({})
    assert proc.returncode == 0, (
        f"clean convergence run failed (exit {proc.returncode}):\n"
        f"{proc.stdout}\n{proc.stderr}")
    rep = check_store(store)
    assert rep["ok"] and not rep["quarantined"]

    result = {"iterations": iters, "seed": seed,
              "docs_per_iteration": add_n,
              "schedule": [{"site": s, "hit": h} for s, h in schedule],
              "sites_glob": sites_glob,
              "modes": list(CHAOS_MODES), "killed": killed,
              "survived": survived, "acked_total": acked_total,
              "final_generation": current_generation(store),
              "outcomes": outcomes, "ok": True}
    if out_path is not None:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(result, indent=2))
        print(f"chaos schedule + outcomes written to {out_path}")
    print(f"chaos soak OK: {iters} fault-injected runs ({killed} killed, "
          f"{survived} survived), {acked_total} acknowledged writes all "
          f"recovered, store fsck-clean throughout, nothing quarantined, "
          f"converged at generation {current_generation(store)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3,
                    help="add/query/compact rounds per soak")
    ap.add_argument("--docs-per-round", type=int, default=3)
    ap.add_argument("--keep-store", type=Path, default=None, metavar="DIR",
                    help="build the churn stores here (persisted for a "
                         "later `python -m repro.fsck`) instead of a "
                         "temp dir")
    ap.add_argument("--chaos", type=int, default=0, metavar="N",
                    help="run the seeded kill-loop soak for N iterations "
                         "instead of the plain churn soaks")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-store", type=Path, default=None, metavar="DIR",
                    help="store directory for the kill-loop (wiped; "
                         "persisted for a later fsck); default: temp dir")
    ap.add_argument("--chaos-out", type=Path, default=None, metavar="JSON",
                    help="write the kill schedule + per-iteration "
                         "outcomes here")
    ap.add_argument("--chaos-sites", default=None, metavar="GLOB",
                    help="fnmatch filter over the recorded kill schedule "
                         "('wal.*' = ingest-kill leg; default: all sites)")
    # internal: one kill-loop iteration, run as a subprocess with
    # REPRO_FAULT_PLAN armed
    ap.add_argument("--chaos-child", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--store", type=Path, help=argparse.SUPPRESS)
    ap.add_argument("--ack-file", type=Path, help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.chaos_child:
        chaos_child(args.store, args.docs_per_round, args.ack_file)
        return

    t0 = time.time()
    if args.chaos:
        if args.chaos_store is not None:
            chaos_soak(args.chaos, args.chaos_seed, args.chaos_store,
                       args.docs_per_round, args.chaos_out,
                       args.chaos_sites)
        else:
            with tempfile.TemporaryDirectory() as d:
                chaos_soak(args.chaos, args.chaos_seed, Path(d) / "chaos",
                           args.docs_per_round, args.chaos_out,
                           args.chaos_sites)
        print(f"chaos soak passed in {time.time() - t0:.1f}s")
        return

    if args.keep_store is not None:
        args.keep_store.mkdir(parents=True, exist_ok=True)
        churn_single(args.rounds, args.docs_per_round,
                     args.keep_store / "flat")
        churn_sharded(args.rounds, args.docs_per_round,
                      args.keep_store / "sharded")
    else:
        with tempfile.TemporaryDirectory() as d:
            churn_single(args.rounds, args.docs_per_round, Path(d) / "flat")
            churn_sharded(args.rounds, args.docs_per_round,
                          Path(d) / "sharded")
    print(f"churn soak passed in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
