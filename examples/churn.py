"""Churn soak: interleaved add / query / compact against a live store.

    PYTHONPATH=src python examples/churn.py [--rounds N] [--docs-per-round M]

The CI `tier1-live` job runs this on every push/PR: a store is built,
loaded live, and then churned — every round ingests a few documents (one
of them a near-duplicate of an already-indexed text), queries the live
index mid-delta, compacts, and queries again.  After EVERY query the
results are checked block-for-block against a from-scratch
``IndexBuilder`` build of the exact same corpus with the exact same
scheme, and after the final compaction the on-disk generation's CSR
arrays must be bit-identical to a scratch freeze — the live path is
allowed zero drift, ever.  A second soak drives the sharded index
(per-shard deltas, one process-pool compaction) through the same oracle.
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.api import Aligner
from repro.core import (IndexBuilder, ShardedAlignmentIndex, batch_query,
                        make_scheme, save_index)
from repro.core.live import LiveIndex
from repro.core.store import current_generation

VOCAB, DOC_LEN, K, THETA = 40, 60, 8, 0.5


def _blocks(res):
    return [[(a.text_id, a.blocks) for a in r] for r in res]


def _new_docs(rng, corpus, n):
    docs = [rng.integers(0, VOCAB, DOC_LEN).astype(np.int64)
            for _ in range(n)]
    # one near-duplicate of an indexed text per round: churn must keep
    # *finding* things, not just keep not-crashing
    docs[-1] = corpus[int(rng.integers(len(corpus)))].copy()
    return docs


def _queries(rng, corpus):
    return [corpus[2][5:50], corpus[-1][:30],
            rng.integers(1000, 1040, 20).astype(np.int64)]     # + a miss


def _check(live_results, scheme, corpus, queries, what):
    oracle = IndexBuilder(scheme=scheme).build(corpus)
    expected = _blocks(batch_query(oracle, queries, THETA))
    assert _blocks(live_results) == expected, \
        f"{what}: live results diverged from the from-scratch build"


def churn_single(rounds: int, docs_per_round: int, root: Path) -> None:
    rng = np.random.default_rng(0)
    corpus = [rng.integers(0, VOCAB, DOC_LEN).astype(np.int64)
              for _ in range(10)]
    scheme = make_scheme("multiset", seed=11, k=K)
    save_index(IndexBuilder(scheme=scheme).build(corpus).freeze(), root)
    live = LiveIndex.open(root, mmap=True)

    for r in range(rounds):
        fresh = _new_docs(rng, corpus, docs_per_round)
        for t in fresh:
            live.add_text(t)
        corpus.extend(fresh)
        qs = _queries(rng, corpus)
        _check(live.batch_query(qs, THETA), scheme, corpus, qs,
               f"round {r} pre-compact (delta={live.delta.num_texts})")
        live.compact()
        _check(live.batch_query(qs, THETA), scheme, corpus, qs,
               f"round {r} post-compact (gen={live.generation})")

    assert live.generation == rounds == current_generation(root)
    # after N compactions the serving arrays are bit-identical to a
    # from-scratch freeze of the same corpus — not merely result-identical
    scratch = IndexBuilder(scheme=scheme).build(corpus).freeze()
    for ta, tb in zip(live.frozen.tables, scratch.tables):
        assert ta.kind == tb.kind
        assert np.array_equal(ta.keys, tb.keys)
        assert np.array_equal(ta.offsets, tb.offsets)
        assert np.array_equal(ta.windows, tb.windows)
    print(f"single-store soak OK: {rounds} compactions, "
          f"{len(corpus)} docs, serving arrays bit-identical to scratch")


def churn_sharded(rounds: int, docs_per_round: int, root: Path) -> None:
    rng = np.random.default_rng(1)
    corpus = [rng.integers(0, VOCAB, DOC_LEN).astype(np.int64)
              for _ in range(12)]
    a = Aligner.build(corpus, similarity="tfidf", k=K, seed=12, shards=3)
    a.save(root)
    live = Aligner.load(root, live=True, mmap=True)
    scheme = live.scheme

    def oracle_results(qs):
        oracle = ShardedAlignmentIndex(scheme=scheme, n_shards=3)
        for t in corpus:
            oracle.add_text(t)
        return _blocks(oracle.batch_query(qs, THETA))

    for r in range(rounds):
        fresh = _new_docs(rng, corpus, docs_per_round)
        for t in fresh:
            live.add(t)
        corpus.extend(fresh)
        qs = _queries(rng, corpus)
        assert _blocks(live.find_batch(qs, THETA)) == oracle_results(qs), \
            f"sharded round {r} pre-compact diverged"
        # last round exercises the process-pool fan-out, earlier ones serial
        live.compact(fanout="process" if r == rounds - 1 else "serial")
        assert _blocks(live.find_batch(qs, THETA)) == oracle_results(qs), \
            f"sharded round {r} post-compact diverged"

    # a cold reader of the churned store agrees with the warm server
    qs = _queries(rng, corpus)
    cold = Aligner.load(root, live=True)
    assert cold.num_docs == len(corpus)
    assert _blocks(cold.find_batch(qs, THETA)) == \
        _blocks(live.find_batch(qs, THETA)), "cold restore diverged"
    print(f"sharded soak OK: {rounds} compactions across 3 shards "
          f"(last one process-pool), {len(corpus)} docs, cold restore agrees")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3,
                    help="add/query/compact rounds per soak")
    ap.add_argument("--docs-per-round", type=int, default=3)
    args = ap.parse_args()
    t0 = time.time()
    with tempfile.TemporaryDirectory() as d:
        churn_single(args.rounds, args.docs_per_round, Path(d) / "flat")
        churn_sharded(args.rounds, args.docs_per_round, Path(d) / "sharded")
    print(f"churn soak passed in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
