"""Corpora: synthetic generation (with planted near-duplicates), file
loading, and token packing into fixed (batch, seq) training arrays."""

from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path

import numpy as np

from .tokenizer import EOS, HashWordTokenizer

_STOP = ("the of and to in a is that for it as was with be by on not he "
         "at are this but from or have an they which one you were all").split()
# 5000 distinct content words -- two random docs then share only stop words,
# so near-duplicate detection is non-trivial (not a degenerate vocabulary).
_WORDS = _STOP + [f"w{i:04d}" for i in range(5000)]


def synthetic_corpus(n_docs: int, *, seed: int = 0, mean_len: int = 120,
                     dup_fraction: float = 0.25, edit_rate: float = 0.08
                     ) -> list[str]:
    """Random word documents; `dup_fraction` of them are near-duplicates of
    earlier docs with `edit_rate` token perturbations (the workload the
    paper's index exists for)."""
    rng = np.random.default_rng(seed)
    docs: list[str] = []
    for i in range(n_docs):
        if docs and rng.random() < dup_fraction:
            src = docs[rng.integers(0, len(docs))].split()
            out = [w if rng.random() > edit_rate
                   else _WORDS[rng.integers(0, len(_WORDS))] for w in src]
            # occasionally embed the near-dup inside fresh text
            if rng.random() < 0.5:
                pre = [_WORDS[j] for j in rng.integers(0, len(_WORDS), 20)]
                out = pre + out
            docs.append(" ".join(out))
        else:
            n = max(8, int(rng.normal(mean_len, mean_len / 4)))
            docs.append(" ".join(_WORDS[j]
                                 for j in rng.integers(0, len(_WORDS), n)))
    return docs


def load_corpus(path: str | Path) -> list[str]:
    """One document per line (blank lines skipped)."""
    return [ln for ln in Path(path).read_text().splitlines() if ln.strip()]


@dataclass
class PackedDataset:
    """Documents tokenized, EOS-joined, packed to (n, seq+1) rows."""

    tokens: np.ndarray                # (n, seq_len + 1) int32

    @classmethod
    def pack(cls, token_docs, seq_len: int) -> "PackedDataset":
        stream = []
        for d in token_docs:
            stream.append(np.asarray(d, np.int32))
            stream.append(np.array([EOS], np.int32))
        flat = np.concatenate(stream) if stream else np.zeros(0, np.int32)
        n = max(1, len(flat) // (seq_len + 1))
        flat = flat[:n * (seq_len + 1)]
        if len(flat) < n * (seq_len + 1):
            flat = np.pad(flat, (0, n * (seq_len + 1) - len(flat)))
        return cls(tokens=flat.reshape(n, seq_len + 1))

    def batches(self, batch_size: int, *, seed: int = 0, epochs: int = 1000):
        """Yield {"tokens","labels"} dicts forever (deterministic order)."""
        n = self.tokens.shape[0]
        rng = np.random.default_rng(seed)
        for _ in range(epochs):
            order = rng.permutation(n)
            for i in range(0, n - batch_size + 1, batch_size):
                rows = self.tokens[order[i:i + batch_size]]
                yield {"tokens": rows[:, :-1].astype(np.int32),
                       "labels": rows[:, 1:].astype(np.int32)}


def make_training_data(n_docs: int, seq_len: int, *, vocab: int = 32_000,
                       seed: int = 0, dedup=None):
    """Synthetic corpus -> (optionally deduplicated) packed dataset.

    `dedup`: a data-plane filter with .admit(tokens) -> bool (see
    repro.data.dedup.DedupFilter -- the paper's index as a first-class
    pipeline stage)."""
    tok = HashWordTokenizer(vocab=vocab)
    docs = synthetic_corpus(n_docs, seed=seed)
    token_docs = tok.encode_batch(docs)
    kept = dropped = 0
    if dedup is not None:
        out = []
        for d in token_docs:
            if dedup.admit(d):
                out.append(d)
                kept += 1
            else:
                dropped += 1
        token_docs = out
    stats = {"docs": n_docs, "kept": kept or n_docs, "dropped": dropped}
    return PackedDataset.pack(token_docs, seq_len), stats
