"""The paper's index as a data-plane feature: training-data deduplication
and test-set contamination detection (the LLM applications motivating the
paper -- Lee et al. '22, Magar & Schwartz '22).

DedupFilter keeps an IndexBuilder over admitted documents; a new document
is dropped when any of its prefixes/subsequences aligns with an indexed
document above `theta` (weighted Jaccard, Eq. 5), i.e., when `query()`
returns any block.  ContaminationChecker indexes the *training* corpus and
reports which held-out documents leak into it.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core import IndexBuilder, make_scheme, query


def default_scheme(kind: str = "weighted", *, seed: int = 0, k: int = 16,
                   tf: str = "raw", idf: str = "unary"):
    """Deprecated alias for :func:`repro.core.make_scheme` (kept so old
    call sites and checkpoint scripts keep working)."""
    return make_scheme(kind, seed=seed, k=k, tf=tf, idf=idf)


@dataclass
class DedupFilter:
    """Admit-or-drop near-duplicate filter over a growing corpus."""

    theta: float = 0.7
    scheme: object = None
    method: str = "mono_active"
    max_doc_tokens: int = 2048          # truncate pathological docs
    index: IndexBuilder = field(init=False)
    stats: dict = field(default_factory=lambda: {"admitted": 0, "dropped": 0})

    def __post_init__(self):
        if self.scheme is None:
            self.scheme = default_scheme()
        self.index = IndexBuilder(scheme=self.scheme, method=self.method)

    def admit(self, tokens) -> bool:
        tokens = np.asarray(tokens, np.int64)[:self.max_doc_tokens]
        if len(tokens) == 0:
            return False
        hits = query(self.index, tokens, self.theta)
        if hits:
            self.stats["dropped"] += 1
            return False
        self.index.add_text(tokens)
        self.stats["admitted"] += 1
        return True


@dataclass
class ContaminationChecker:
    """Index the training corpus; report held-out docs that leak into it."""

    theta: float = 0.6
    scheme: object = None
    method: str = "mono_active"
    index: IndexBuilder = field(init=False)

    def __post_init__(self):
        if self.scheme is None:
            self.scheme = default_scheme()
        self.index = IndexBuilder(scheme=self.scheme, method=self.method)

    def fit(self, train_token_docs) -> "ContaminationChecker":
        for d in train_token_docs:
            self.index.add_text(np.asarray(d, np.int64))
        return self

    def check(self, test_token_docs) -> list[dict]:
        """Per contaminated test doc: which train doc + aligned span."""
        out = []
        for qi, d in enumerate(test_token_docs):
            hits = query(self.index, np.asarray(d, np.int64), self.theta)
            for h in hits:
                il, ih, jl, jh = h.blocks[0]
                out.append({"test_doc": qi, "train_doc": h.text_id,
                            "span": (il, jh), "n_blocks": len(h.blocks)})
        return out
