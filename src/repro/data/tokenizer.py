"""Deterministic stateless tokenizers.

`HashWordTokenizer` maps whitespace words -> stable ids via splitmix64 mod
(vocab - reserved); no vocabulary files, so every distributed worker agrees
without broadcast (same design as the stateless hash families).  `ByteTokenizer`
is the exact-roundtrip fallback.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.hashing import splitmix64

PAD, BOS, EOS, RESERVED = 0, 1, 2, 4


def _fnv1a(w: str) -> int:
    h = 0xCBF29CE484222325
    for b in w.encode("utf-8"):
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


@dataclass(frozen=True)
class HashWordTokenizer:
    vocab: int = 32_000
    lowercase: bool = True

    def encode(self, text: str) -> np.ndarray:
        if self.lowercase:
            text = text.lower()
        words = text.split()
        if not words:
            return np.zeros(0, dtype=np.int32)
        # FNV-1a (not Python's hash(): that is salted per process and would
        # break multi-host determinism)
        hs = splitmix64(np.array([_fnv1a(w) for w in words], dtype=np.uint64))
        ids = (hs % np.uint64(self.vocab - RESERVED)).astype(np.int32) + RESERVED
        return ids

    def encode_batch(self, texts) -> list[np.ndarray]:
        return [self.encode(t) for t in texts]


@dataclass(frozen=True)
class ByteTokenizer:
    vocab: int = 256 + RESERVED

    def encode(self, text: str) -> np.ndarray:
        return np.frombuffer(text.encode("utf-8"), np.uint8).astype(np.int32) \
            + RESERVED

    def decode(self, ids) -> str:
        b = (np.asarray(ids, np.int32) - RESERVED).clip(0, 255).astype(np.uint8)
        return b.tobytes().decode("utf-8", errors="replace")
