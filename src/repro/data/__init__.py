from .corpus import (PackedDataset, load_corpus, make_training_data,
                     synthetic_corpus)
from .dedup import ContaminationChecker, DedupFilter, default_scheme
from .tokenizer import ByteTokenizer, HashWordTokenizer

__all__ = ["PackedDataset", "synthetic_corpus", "load_corpus",
           "make_training_data", "DedupFilter", "ContaminationChecker",
           "default_scheme", "HashWordTokenizer", "ByteTokenizer"]
