"""Abstract parameter descriptors + initialization for every arch family.

`abstract_params(cfg)` returns a pytree of ParamDesc (shape + logical axes +
init law).  From it we derive, without ever materializing weights:
  * `init_params(cfg, rng)`          -- real arrays (smoke tests / training)
  * `param_shapedtypes(cfg, dtype)`  -- ShapeDtypeStructs (dry-run lowering)
  * sharding specs via repro.sharding.tree_specs
Layer parameters are stacked on a leading "layers" axis so the decoder runs
as one `lax.scan` -- HLO size is O(1) in depth (required for 126-layer 405B).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig


@dataclass(frozen=True)
class ParamDesc:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]     # logical axis names, len == ndim
    init: str = "normal"             # normal | zeros | ones
    scale: float = 0.0               # 0 -> 1/sqrt(fan_in)

    def shapedtype(self, dtype) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, dtype)


def _dense_layer(cfg: ModelConfig) -> dict:
    L, d = cfg.n_layers, cfg.d_model
    qf = cfg.n_heads * cfg.head_dim
    kf = cfg.n_kv_heads * cfg.head_dim
    p = {
        "ln1": ParamDesc((L, d), ("layers", "embed"), "ones"),
        "ln2": ParamDesc((L, d), ("layers", "embed"), "ones"),
        "wq": ParamDesc((L, d, qf), ("layers", "embed", "q_feat")),
        "wk": ParamDesc((L, d, kf), ("layers", "embed", "kv_feat")),
        "wv": ParamDesc((L, d, kf), ("layers", "embed", "kv_feat")),
        "wo": ParamDesc((L, qf, d), ("layers", "q_feat", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamDesc((L, qf), ("layers", "q_feat"), "zeros")
        p["bk"] = ParamDesc((L, kf), ("layers", "kv_feat"), "zeros")
        p["bv"] = ParamDesc((L, kf), ("layers", "kv_feat"), "zeros")
    if cfg.family == "moe":
        E, m = cfg.n_experts, cfg.moe_dff
        p["router"] = ParamDesc((L, d, E), ("layers", "embed", None))
        p["w1"] = ParamDesc((L, E, d, m), ("layers", "experts", "embed", "moe_ff"))
        p["w3"] = ParamDesc((L, E, d, m), ("layers", "experts", "embed", "moe_ff"))
        p["w2"] = ParamDesc((L, E, m, d), ("layers", "experts", "moe_ff", "embed"))
    else:
        f = cfg.d_ff
        p["w1"] = ParamDesc((L, d, f), ("layers", "embed", "ffn"))
        p["w3"] = ParamDesc((L, d, f), ("layers", "embed", "ffn"))
        p["w2"] = ParamDesc((L, f, d), ("layers", "ffn", "embed"))
    return p


def _mamba1_layer(cfg: ModelConfig) -> dict:
    # Projections are SPLIT per output segment (x / z) rather than fused:
    # slicing a 'model'-sharded fused output forces GSPMD reshards every
    # layer (observed as a collective-permute storm in the dry-run HLO).
    L, d, di, ds = cfg.n_layers, cfg.d_model, cfg.d_inner, cfg.ssm_state
    dtr, ck = cfg.ssm_dt_rank, cfg.ssm_conv
    return {
        "ln": ParamDesc((L, d), ("layers", "embed"), "ones"),
        "x_in": ParamDesc((L, d, di), ("layers", "embed", "ssm_inner")),
        "z_in": ParamDesc((L, d, di), ("layers", "embed", "ssm_inner")),
        "conv_w": ParamDesc((L, ck, di), ("layers", "conv", "ssm_inner")),
        "conv_b": ParamDesc((L, di), ("layers", "ssm_inner"), "zeros"),
        "x_proj": ParamDesc((L, di, dtr + 2 * ds), ("layers", "ssm_inner", None)),
        "dt_proj": ParamDesc((L, dtr, di), ("layers", "dt_rank", "ssm_inner")),
        "dt_bias": ParamDesc((L, di), ("layers", "ssm_inner"), "dt_bias"),
        "A_log": ParamDesc((L, di, ds), ("layers", "ssm_inner", "ssm_state"), "a_log"),
        "D": ParamDesc((L, di), ("layers", "ssm_inner"), "ones"),
        "out_proj": ParamDesc((L, di, d), ("layers", "ssm_inner", "embed")),
    }


def _mamba2_layer(cfg: ModelConfig) -> dict:
    # Split projections (see _mamba1_layer).  B/C are per-group (ng=1) and
    # stay replicated; x/z shard over ssm_inner; dt over heads.
    L, d, di, ds = cfg.n_layers, cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, ck = cfg.ssm_nheads, cfg.ssm_conv
    return {
        "ln": ParamDesc((L, d), ("layers", "embed"), "ones"),
        "x_in": ParamDesc((L, d, di), ("layers", "embed", "ssm_inner")),
        "z_in": ParamDesc((L, d, di), ("layers", "embed", "ssm_inner")),
        "B_in": ParamDesc((L, d, ds), ("layers", "embed", None)),
        "C_in": ParamDesc((L, d, ds), ("layers", "embed", None)),
        "dt_in": ParamDesc((L, d, nh), ("layers", "embed", "ssm_heads")),
        "conv_x": ParamDesc((L, ck, di), ("layers", "conv", "ssm_inner")),
        "conv_xb": ParamDesc((L, di), ("layers", "ssm_inner"), "zeros"),
        "conv_B": ParamDesc((L, ck, ds), ("layers", "conv", None)),
        "conv_Bb": ParamDesc((L, ds), ("layers", None), "zeros"),
        "conv_C": ParamDesc((L, ck, ds), ("layers", "conv", None)),
        "conv_Cb": ParamDesc((L, ds), ("layers", None), "zeros"),
        "A_log": ParamDesc((L, nh), ("layers", "ssm_heads"), "a_log2"),
        "D": ParamDesc((L, nh), ("layers", "ssm_heads"), "ones"),
        "dt_bias": ParamDesc((L, nh), ("layers", "ssm_heads"), "dt_bias"),
        "ln_inner": ParamDesc((L, di), ("layers", "ssm_inner"), "ones"),
        "out_proj": ParamDesc((L, di, d), ("layers", "ssm_inner", "embed")),
    }


def _shared_attn(cfg: ModelConfig) -> dict:
    """zamba2-style shared attention block over concat(x, x_embed0)."""
    d = cfg.d_model
    qf = cfg.n_heads * cfg.head_dim
    kf = cfg.n_kv_heads * cfg.head_dim
    return {
        "ln": ParamDesc((2 * d,), ("embed",), "ones"),
        "wq": ParamDesc((2 * d, qf), ("embed", "q_feat")),
        "wk": ParamDesc((2 * d, kf), ("embed", "kv_feat")),
        "wv": ParamDesc((2 * d, kf), ("embed", "kv_feat")),
        "wo": ParamDesc((qf, d), ("q_feat", "embed")),
    }


def abstract_params(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab
    tree: dict = {
        "embed": ParamDesc((v, d), ("vocab", "embed"), "embed"),
        "final_ln": ParamDesc((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = ParamDesc((d, v), ("embed", "vocab"))
    if cfg.family in ("dense", "moe"):
        tree["layers"] = _dense_layer(cfg)
    elif cfg.family == "ssm":
        tree["layers"] = _mamba1_layer(cfg)
    elif cfg.family == "hybrid":
        tree["layers"] = _mamba2_layer(cfg)
        if cfg.attn_every:
            tree["shared"] = _shared_attn(cfg)
    else:
        raise ValueError(cfg.family)
    return tree


def _materialize(desc: ParamDesc, key, dtype):
    if desc.init == "zeros":
        return jnp.zeros(desc.shape, dtype)
    if desc.init == "ones":
        return jnp.ones(desc.shape, dtype)
    if desc.init == "embed":
        return (0.02 * jax.random.normal(key, desc.shape)).astype(dtype)
    if desc.init == "dt_bias":
        # softplus^{-1}(dt) for dt ~ U[1e-3, 1e-1]
        u = jax.random.uniform(key, desc.shape, minval=1e-3, maxval=1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)
    if desc.init == "a_log":        # mamba1: A = -exp(A_log), A_log=log(1..ds)
        ds = desc.shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32),
                             desc.shape)
        return jnp.log(a).astype(dtype)
    if desc.init == "a_log2":       # mamba2: scalar per head, A in [1, 16]
        a = jax.random.uniform(key, desc.shape, minval=1.0, maxval=16.0)
        return jnp.log(a).astype(dtype)
    fan_in = desc.shape[-2] if len(desc.shape) >= 2 else desc.shape[-1]
    scale = desc.scale or 1.0 / math.sqrt(fan_in)
    return (scale * jax.random.normal(key, desc.shape)).astype(dtype)


def init_params(cfg: ModelConfig, rng, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    abstract = abstract_params(cfg)
    leaves, treedef = jax.tree.flatten(
        abstract, is_leaf=lambda x: isinstance(x, ParamDesc))
    keys = jax.random.split(rng, len(leaves))
    vals = [_materialize(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def param_shapedtypes(cfg: ModelConfig, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda d: d.shapedtype(dtype), abstract_params(cfg),
                        is_leaf=lambda x: isinstance(x, ParamDesc))


def param_count_tree(cfg: ModelConfig) -> int:
    return sum(int(np.prod(d.shape)) for d in jax.tree.leaves(
        abstract_params(cfg), is_leaf=lambda x: isinstance(x, ParamDesc)))
