from .config import SHAPES, ModelConfig, ShapeConfig
from .lm import (RunFlags, cache_abstract, cache_shapedtypes, decode_step,
                 forward, init_cache, lm_loss, prefill)
from .params import (ParamDesc, abstract_params, init_params,
                     param_count_tree, param_shapedtypes)

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "RunFlags",
    "forward", "lm_loss", "prefill", "decode_step", "init_cache",
    "cache_abstract", "cache_shapedtypes",
    "ParamDesc", "abstract_params", "init_params", "param_shapedtypes",
    "param_count_tree",
]
