"""Shared neural building blocks: RMSNorm, RoPE, GQA attention, SwiGLU, MoE.

All functions are pure; dtypes follow cfg.compute_dtype with f32 softmax /
norm statistics.  Attention supports causal masking, sliding windows
(mixtral), query chunking (memory-bounded 32k prefill), and single-token
decode against a KV cache.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

if hasattr(jax, "shard_map"):                       # jax >= 0.6
    _shard_map = jax.shard_map
    _SHARD_MAP_NOCHECK = {"check_vma": False}
else:                                               # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_NOCHECK = {"check_rep": False}

NEG_INF = -1e30


def rms_norm(x, gamma, eps: float):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def rope_angles(positions, head_dim: int, theta: float):
    """positions (...,) int32 -> cos/sin (..., head_dim//2) f32."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B,S,H,D); cos/sin (B,S,D/2) or (S,D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    cos, sin = cos.astype(x.dtype), sin.astype(x.dtype)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def _scores_mask(q_pos, k_pos, window: int):
    """(Sq, Sk) additive mask: causal + optional sliding window."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _constrain(x, mesh, *logical):
    if mesh is None:
        return x
    from ..sharding import constrain
    return constrain(x, mesh, *logical)


def _attend(q, k, v, mask, mesh=None, cp=False):
    """q (B,Sq,H,D), k/v (B,Sk,H,D) (kv already repeated to H heads).

    cp=True: context parallelism -- shard the query rows over `model`
    (used when n_heads does not divide the TP width; kv stays replicated).
    """
    B, Sq, H, D = q.shape
    if cp:
        q = _constrain(q, mesh, "batch", "seq_sp", None, None)
    s = jnp.einsum("bqhd,bshd->bhqs", q, k) / math.sqrt(D)
    s = s.astype(jnp.float32)
    if mask is not None:
        s = s + mask[None, None]
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhqs,bshd->bqhd", w, v)
    if cp:
        o = _constrain(o, mesh, "batch", "seq_sp", None, None)
    return o


def repeat_kv(k, n_heads: int):
    """(B,S,KV,D) -> (B,S,H,D): Megatron-style KV duplication so head
    sharding is uniform even when TP width > n_kv_heads."""
    KV = k.shape[2]
    if KV == n_heads:
        return k
    return jnp.repeat(k, n_heads // KV, axis=2)


def attention(q, k, v, *, q_offset=0, window: int = 0, q_chunk: int = 0,
              mesh=None, cp=False):
    """Causal GQA attention.  q (B,Sq,H,D); k,v (B,Sk,KV,D).

    q_offset: absolute position of q[0] relative to k[0] (prefill: 0).
    q_chunk:  if >0 and Sq > q_chunk, scan over query chunks (memory).
    cp:       context-parallel fallback (when heads % TP width != 0).
    """
    H = q.shape[2]
    if cp:
        k = _constrain(k, mesh, "batch", None, None, None)
        v = _constrain(v, mesh, "batch", None, None, None)
    k, v = repeat_kv(k, H), repeat_kv(v, H)
    if not cp and mesh is not None:
        q = _constrain(q, mesh, "batch", None, "heads", "head_dim")
        k = _constrain(k, mesh, "batch", None, "heads", "head_dim")
        v = _constrain(v, mesh, "batch", None, "heads", "head_dim")
    Sq, Sk = q.shape[1], k.shape[1]
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    if not q_chunk or Sq <= q_chunk or Sq % q_chunk:
        return _attend(q, k, v, _scores_mask(q_pos, k_pos, window), mesh, cp)

    n = Sq // q_chunk

    def body(_, qc_i):
        qc, i = qc_i
        qp = q_offset + i * q_chunk + jnp.arange(q_chunk)
        mask = jnp.where(
            (k_pos[None, :] <= qp[:, None])
            & ((k_pos[None, :] > qp[:, None] - window) if window else True),
            0.0, NEG_INF).astype(jnp.float32)
        return None, _attend(qc, k, v, mask, mesh, cp)

    qs = q.reshape(q.shape[0], n, q_chunk, *q.shape[2:]).swapaxes(0, 1)
    _, outs = lax.scan(body, None, (qs, jnp.arange(n)))
    outs = outs.swapaxes(0, 1)
    return outs.reshape(q.shape)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     mesh=None):
    """q (B,1,H,D) vs cache (B,Smax,KV,D); positions > pos are masked.

    Split-KV (flash-decode) sharding: the cache stays sharded on seq
    (`seq_kv` -> model); scores/softmax-stats are computed per KV shard with
    explicit constraints so GSPMD never gathers the cache (the unconstrained
    einsum replicated it -- 9.8 TB/device on llama3-405b decode_32k,
    EXPERIMENTS.md §Perf cell B).  The Pallas `decode_attention` kernel is
    the fused single-chip version of the same schedule.
    """
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    Smax = k_cache.shape[1]
    k_pos = jnp.arange(Smax)
    ok = k_pos <= pos
    if window:
        ok &= k_pos > pos - window
    mask = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
    qh = q.reshape(B, KV, G, D)
    if mesh is not None:
        qh = _constrain(qh, mesh, "batch", None, None, None)
    s = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache) / math.sqrt(D)
    s = s.astype(jnp.float32) + mask[None, None, None]
    if mesh is not None:
        s = _constrain(s, mesh, "batch", None, None, "seq_kv")
    # softmax over the sharded axis: XLA partitions max/sum with small psums
    w = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", w, v_cache)
    if mesh is not None:
        o = _constrain(o, mesh, "batch", None, None, None)
    return o.reshape(B, 1, H, D)


def swiglu(x, w1, w3, w2):
    h = jnp.einsum("bsd,df->bsf", x, w1)
    g = jnp.einsum("bsd,df->bsf", x, w3)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(h) * g, w2)


# --------------------------------------------------------------------------
# Mixture of Experts
# --------------------------------------------------------------------------

def moe_router(xf, router_w, top_k: int):
    """xf (N,d) -> gates (N,k) f32 (softmax over selected), idx (N,k) i32."""
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    gate_logits, idx = lax.top_k(logits, top_k)
    gates = jax.nn.softmax(gate_logits, axis=-1)
    return gates, idx


def moe_dense(x, router_w, w1, w3, w2, top_k: int):
    """Reference all-experts path (smoke tests / correctness oracle)."""
    B, S, d = x.shape
    xf = x.reshape(-1, d)
    gates, idx = moe_router(xf, router_w, top_k)
    h = jnp.einsum("nd,edf->nef", xf, w1)
    g = jnp.einsum("nd,edf->nef", xf, w3)
    y = jnp.einsum("nef,efd->ned", jax.nn.silu(h) * g, w2)   # (N,E,d)
    sel = jnp.take_along_axis(y, idx[:, :, None], axis=1)     # (N,k,d)
    out = jnp.sum(sel * gates[:, :, None].astype(sel.dtype), axis=1)
    return out.reshape(B, S, d)


def moe_scatter(x, router_w, w1, w3, w2, top_k: int,
                capacity_factor: float = 1.25, mesh=None):
    """Production path: *group-local* sort-based dispatch into per-expert
    capacity buffers (grouped matmul), Switch-Transformer style.

    The batch dim is the dispatch group: every scatter/gather is local to a
    data-parallel shard (a global argsort over all tokens forces GSPMD to
    replicate the (N, d) activations -- measured 106 TB/device of collective
    traffic on qwen3-moe train_4k; see EXPERIMENTS.md §Perf cell A).  The
    (group, expert) buffer is then resharded expert-parallel -- one
    all-to-all, the EP exchange -- so expert matmuls run with E local to the
    `model` axis.  Tokens over an expert's per-group capacity are dropped
    (capacity-factor routing).
    """
    B, S, d = x.shape
    E = router_w.shape[-1]
    C = max(1, math.ceil(S * top_k * capacity_factor / E))
    gates, idx = moe_router(x.reshape(-1, d), router_w, top_k)
    gates = gates.reshape(B, S * top_k)
    flat_e = idx.reshape(B, S * top_k)

    order = jnp.argsort(flat_e, axis=-1, stable=True)         # (B, S*k)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    ar = jnp.arange(S * top_k, dtype=jnp.int32)
    change = jnp.concatenate(
        [jnp.ones((B, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    seg_start = lax.cummax(jnp.where(change, ar, 0), axis=1)
    slot = ar - seg_start                                     # rank in expert
    keep = slot < C
    dest = jnp.where(keep, sorted_e * C + slot, E * C)        # E*C = dropped
    tok = order // top_k                                      # (B, S*k)

    rows = jnp.arange(B)[:, None]
    xf = x  # (B, S, d)
    vals = jnp.take_along_axis(
        xf, tok[..., None].astype(jnp.int32), axis=1)         # (B, S*k, d)
    if mesh is not None:
        from ..sharding import constrain
        # GSPMD's batched-gather partitioning can fall back to replicating
        # the (B, S*k, d) routed copies at global size (measured 12 TB/dev
        # of gathers on qwen3-moe prefill_32k); pin it to the batch shards.
        vals = constrain(vals, mesh, "batch", None, "embed_act")
    buf = jnp.zeros((B, E * C + 1, d), x.dtype).at[rows, dest].set(vals)
    buf = buf[:, :-1].reshape(B, E, C, d)
    if mesh is not None:
        from ..sharding import constrain
        # two explicit stages (§Perf cell A iteration 2): (1) the scatter
        # lands batch-local / expert-UNsharded -- GSPMD partitions a scatter
        # across the expert axis as partial buffers + a full-size all-reduce
        # (103 TB/device measured); (2) the dense reshard to expert-parallel
        # is then one all-to-all of exactly the routed tokens.
        buf = constrain(buf, mesh, "batch", None, None, "embed_act")
        buf = constrain(buf, mesh, "batch", "experts", None, "embed_act")
    h = jnp.einsum("becd,edf->becf", buf, w1)
    g = jnp.einsum("becd,edf->becf", buf, w3)
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * g, w2)
    if mesh is not None:
        from ..sharding import constrain
        y = constrain(y, mesh, "batch", "experts", None, "embed_act")
        # return to batch-local before the combine gather (mirror all-to-all)
        y = constrain(y, mesh, "batch", None, None, "embed_act")
    yf = jnp.concatenate([y.reshape(B, E * C, d),
                          jnp.zeros((B, 1, d), y.dtype)], axis=1)
    contrib = jnp.take_along_axis(yf, dest[..., None], axis=1) \
        * (gates_sorted := jnp.take_along_axis(gates, order, axis=-1)
           )[..., None].astype(y.dtype) * keep[..., None]
    if mesh is not None:
        from ..sharding import constrain
        contrib = constrain(contrib, mesh, "batch", None, "embed_act")
    out = jnp.zeros((B, S, d), x.dtype).at[rows, tok].add(contrib)
    return out


def _dispatch_local(x, gates, idx, E: int, C: int):
    """Row-local sort-based dispatch (no mesh interaction).
    x (B,S,d); idx (B*S, k) -> buf (B,E,C,d) + combine metadata."""
    B, S, d = x.shape
    top_k = idx.shape[-1]
    flat_e = idx.reshape(B, S * top_k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    ar = jnp.arange(S * top_k, dtype=jnp.int32)
    change = jnp.concatenate(
        [jnp.ones((B, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    seg_start = lax.cummax(jnp.where(change, ar, 0), axis=1)
    slot = ar - seg_start
    keep = slot < C
    dest = jnp.where(keep, sorted_e * C + slot, E * C)
    tok = order // top_k
    rows = jnp.arange(B)[:, None]
    vals = jnp.take_along_axis(x, tok[..., None].astype(jnp.int32), axis=1)
    buf = jnp.zeros((B, E * C + 1, d), x.dtype).at[rows, dest].set(vals)
    return buf[:, :-1].reshape(B, E, C, d), (dest, keep, tok, order, rows)


def _combine_local(y, gates, meta, B, S, d, E, C, top_k):
    """Inverse of _dispatch_local: gather expert outputs back per token."""
    dest, keep, tok, order, rows = meta
    yf = jnp.concatenate([y.reshape(B, E * C, d),
                          jnp.zeros((B, 1, d), y.dtype)], axis=1)
    g_sorted = jnp.take_along_axis(gates.reshape(B, S * top_k), order,
                                   axis=-1)
    contrib = jnp.take_along_axis(yf, dest[..., None], axis=1) \
        * g_sorted[..., None].astype(y.dtype) * keep[..., None]
    return jnp.zeros((B, S, d), y.dtype).at[rows, tok].add(contrib)


def moe_shardmap(x, router_w, w1, w3, w2, top_k: int,
                 capacity_factor: float, mesh):
    """Expert parallelism with explicit collectives (shard_map).

    GSPMD partitions data-dependent gather/scatter by replication (measured
    12 TB/device on qwen3-moe prefill); inside shard_map every dispatch op
    is shard-local by construction and the EP exchange is two explicit
    tiled all-to-alls + one sequence all-gather:

      tokens seq-split over `model` -> local top-k dispatch ->
      all_to_all (experts <-> capacity) -> local grouped matmul ->
      all_to_all back -> local combine -> all_gather seq chunks.

    Requires E % tp == 0 and S % tp == 0 (caller falls back to
    `moe_scatter` otherwise).
    """
    from jax.sharding import PartitionSpec as P
    B, S, d = x.shape
    E = w1.shape[0]
    tp = mesh.shape["model"]
    batch = tuple(a for a in ("pod", "data") if a in mesh.shape)
    S_loc = S // tp
    C = max(1, math.ceil(S_loc * top_k * capacity_factor / E))

    def body(xl, wr, w1l, w3l, w2l):
        r = lax.axis_index("model")
        xs = lax.dynamic_slice_in_dim(xl, r * S_loc, S_loc, axis=1)
        gates, idx = moe_router(xs.reshape(-1, d), wr, top_k)
        buf, meta = _dispatch_local(xs, gates, idx, E, C)      # (B,E,C,d)
        recv = lax.all_to_all(buf, "model", split_axis=1, concat_axis=2,
                              tiled=True)                      # (B,E/tp,C*tp,d)
        h = jnp.einsum("becd,edf->becf", recv, w1l)
        g = jnp.einsum("becd,edf->becf", recv, w3l)
        y = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * g, w2l)
        back = lax.all_to_all(y, "model", split_axis=2, concat_axis=1,
                              tiled=True)                      # (B,E,C,d)
        out = _combine_local(back, gates, meta, xs.shape[0], S_loc, d, E, C,
                             top_k)
        return lax.all_gather(out, "model", axis=1, tiled=True)

    fn = _shard_map(
        body, mesh=mesh,
        in_specs=(P(batch, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(batch, None, None), **_SHARD_MAP_NOCHECK)
    return fn(x, router_w, w1, w3, w2)


def causal_conv1d(x, w, b):
    """Depthwise causal conv.  x (B,S,C), w (K,C), b (C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):
        out = out + pad[:, i:i + x.shape[1], :] * w[i]
    return out + b
