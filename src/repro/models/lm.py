"""The unified decoder stack: forward / loss / prefill / decode for all
10 assigned architectures (dense GQA, MoE, Mamba-1, Mamba-2 hybrid,
audio/vlm-stub frontends).

Depth runs as one `lax.scan` over stacked per-layer parameters with optional
`jax.checkpoint` (remat) on the layer body -- HLO is O(1) in n_layers, which
is what makes the 126-layer / 405B dry-run lowerable.  The zamba2 shared
attention block is applied inside the scan under `lax.cond` on layer index.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import (apply_rope, attention, decode_attention, moe_dense,
                     moe_scatter, rms_norm, rope_angles, swiglu)
from .params import ParamDesc
from .ssm import (mamba1_block, mamba1_decode, mamba2_block, mamba2_decode)

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


@dataclass(frozen=True)
class RunFlags:
    """Per-call performance knobs (the §Perf hillclimb levers)."""
    q_chunk: int = 2048          # query-chunked attention above this Sq
    scan_chunk: int = 256        # SSM chunked-scan inner length
    remat_policy: str = "full"   # REMAT_POLICIES key
    moe_mode: str = "scatter"    # scatter | dense
    seq_shard_carry: bool = False  # Megatron-SP: shard scanned carry on seq
    logits_f32: bool = True


def _cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def _constrain(x, mesh, *logical):
    if mesh is None:
        return x
    from ..sharding import constrain
    return constrain(x, mesh, *logical)


def _needs_cp(n_heads: int, mesh) -> bool:
    """Context-parallel attention when heads don't divide the TP width."""
    if mesh is None or "model" not in mesh.shape:
        return False
    return n_heads % mesh.shape["model"] != 0


# --------------------------------------------------------------------------
# Layer bodies (full-sequence: train / prefill).  Each returns (x, cache_y)
# where cache_y is this layer's contribution to a decode cache (or ()).
# --------------------------------------------------------------------------

def _attn_layer(x, p, cfg: ModelConfig, flags: RunFlags, mesh, positions,
                want_cache: bool):
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", h, p["wq"])
    k = jnp.einsum("bsd,de->bse", h, p["wk"])
    v = jnp.einsum("bsd,de->bse", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, Dh)
    k = k.reshape(B, S, KV, Dh)
    v = v.reshape(B, S, KV, Dh)
    cos, sin = rope_angles(positions, Dh, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    cp = _needs_cp(H, mesh)
    o = attention(q, k, v, window=cfg.swa_window, q_chunk=flags.q_chunk,
                  mesh=mesh, cp=cp)
    x = x + jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * Dh), p["wo"])
    x = _constrain(x, mesh, "batch", None, None)

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m = _moe_forward(h2, p, cfg, flags, mesh)
    else:
        m = swiglu(h2, p["w1"], p["w3"], p["w2"])
    x = x + m
    x = _constrain(x, mesh, "batch", None, None)
    cache_y = (k, v) if want_cache else ()
    return x, cache_y


def _moe_forward(h2, p, cfg: ModelConfig, flags: RunFlags, mesh):
    args = (h2, p["router"], p["w1"], p["w3"], p["w2"], cfg.top_k)
    if flags.moe_mode == "dense":
        return moe_dense(*args)
    S = h2.shape[1]
    if flags.moe_mode == "shardmap" and mesh is not None:
        tp = mesh.shape.get("model", 1)
        if cfg.n_experts % tp == 0 and S % tp == 0 and S >= tp:
            from .layers import moe_shardmap
            return moe_shardmap(h2, p["router"], p["w1"], p["w3"], p["w2"],
                                cfg.top_k, cfg.capacity_factor, mesh)
    return moe_scatter(*args[:-1], cfg.top_k, cfg.capacity_factor, mesh)


def _shared_attn_apply(x, x0, sp, cfg: ModelConfig, flags: RunFlags,
                       positions, want_cache: bool, mesh=None):
    """zamba2 shared block: attention over concat(x, embed0)."""
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    hin = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(hin, sp["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", h, sp["wq"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", h, sp["wk"]).reshape(B, S, KV, Dh)
    v = jnp.einsum("bsd,de->bse", h, sp["wv"]).reshape(B, S, KV, Dh)
    cos, sin = rope_angles(positions, Dh, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    o = attention(q, k, v, q_chunk=flags.q_chunk, mesh=mesh,
                  cp=_needs_cp(H, mesh))
    x = x + jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * Dh), sp["wo"])
    return x, ((k, v) if want_cache else ())


def _make_layer_body(cfg: ModelConfig, flags: RunFlags, mesh, positions,
                     want_cache: bool, x0, shared):
    """Returns body(x, (layer_params, layer_idx)) -> (x, cache_y)."""

    def body(x, scanned):
        p, li = scanned
        if cfg.family in ("dense", "moe"):
            return _attn_layer(x, p, cfg, flags, mesh, positions, want_cache)
        if cfg.family == "ssm":
            h = rms_norm(x, p["ln"], cfg.norm_eps)
            out, (conv_tail, hs) = mamba1_block(
                h, p, cfg, scan_chunk=flags.scan_chunk)
            x = x + out
            x = _constrain(x, mesh, "batch", None, None)
            return x, ((conv_tail, hs) if want_cache else ())
        # hybrid: mamba2 + shared attention every cfg.attn_every layers
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        out, (conv_tail, hs) = mamba2_block(
            h, p, cfg, scan_chunk=flags.scan_chunk)
        x = x + out
        x = _constrain(x, mesh, "batch", None, None)
        if cfg.attn_every:
            def with_attn(x):
                return _shared_attn_apply(x, x0, shared, cfg, flags,
                                          positions, want_cache, mesh)

            def without(x):
                if want_cache:
                    B, S = x.shape[:2]
                    z = jnp.zeros((B, S, cfg.n_kv_heads, cfg.head_dim),
                                  x.dtype)
                    return x, (z, z)
                return x, ()

            x, akv = lax.cond(li % cfg.attn_every == cfg.attn_every - 1,
                              with_attn, without, x)
        else:
            akv = ()
        cache_y = ((conv_tail, hs), akv) if want_cache else ()
        return x, cache_y

    return body


def _run_stack(x, params, cfg: ModelConfig, flags: RunFlags, mesh,
               positions, want_cache: bool):
    cdt = jnp.dtype(cfg.compute_dtype)
    layers = _cast(params["layers"], cdt)
    shared = _cast(params.get("shared"), cdt) if "shared" in params else None
    x0 = x if cfg.family == "hybrid" else None
    body = _make_layer_body(cfg, flags, mesh, positions, want_cache, x0,
                            shared)
    if flags.remat_policy != "none":
        # prevent_cse=True: XLA:CPU CSEs the recomputation away otherwise,
        # silently reverting remat to save-everything (70 GB temps observed).
        body = jax.checkpoint(body, policy=REMAT_POLICIES[flags.remat_policy],
                              prevent_cse=True)

    def wrapped(carry, scanned):
        if flags.seq_shard_carry:
            carry = _constrain(carry, mesh, "batch", "seq_sp", None)
        return body(carry, scanned)

    li = jnp.arange(cfg.n_layers)
    x, cache_ys = lax.scan(wrapped, x, (layers, li))
    return x, cache_ys


def embed_tokens(params, tokens, cfg: ModelConfig, mesh=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    table = params["embed"].astype(cdt)
    if mesh is not None and tokens.size <= 4096:
        # decode path: GSPMD lowers a gather from the vocab-sharded table to
        # an involuntary full replication; a one-hot matmul keeps the table
        # sharded (partial products + one small psum).  §Perf cell B.
        oh = jax.nn.one_hot(tokens, cfg.vocab, dtype=cdt)
        return jnp.einsum("bsv,vd->bsd", oh, table)
    return table[tokens]


def unembed(x, params, cfg: ModelConfig, flags: RunFlags, mesh):
    x = rms_norm(x, params["final_ln"].astype(x.dtype), cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    if flags.logits_f32:
        logits = logits.astype(jnp.float32)
    return _constrain(logits, mesh, "batch", None, "vocab")


def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            mesh=None, flags: RunFlags = RunFlags()):
    """Full-sequence forward -> logits (B,S,V)."""
    if embeds is None:
        x = embed_tokens(params, tokens, cfg)
    else:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    x = _constrain(x, mesh, "batch", None, None)
    S = x.shape[1]
    positions = jnp.arange(S)
    x, _ = _run_stack(x, params, cfg, flags, mesh, positions,
                      want_cache=False)
    return unembed(x, params, cfg, flags, mesh)


def lm_loss(params, cfg: ModelConfig, batch, mesh=None,
            flags: RunFlags = RunFlags()):
    """Mean next-token cross entropy.  batch: tokens|embeds + labels + mask."""
    logits = forward(params, cfg, tokens=batch.get("tokens"),
                     embeds=batch.get("embeds"), mesh=mesh, flags=flags)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    m = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = m - ll
    mask = batch.get("mask")
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------------------
# KV / SSM caches
# --------------------------------------------------------------------------

def cache_abstract(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype_name: str | None = None) -> dict:
    """ParamDesc tree for the decode cache (shapes + logical axes)."""
    dt = dtype_name or cfg.compute_dtype
    L, B, S = cfg.n_layers, batch, max_seq
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    kv_axes = ("layers", "batch", "seq_kv", "kv_heads", "head_dim")
    if cfg.family in ("dense", "moe"):
        return {
            "k": ParamDesc((L, B, S, KV, Dh), kv_axes, "zeros"),
            "v": ParamDesc((L, B, S, KV, Dh), kv_axes, "zeros"),
        }
    if cfg.family == "ssm":
        di, ds, ck = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
        return {
            "conv": ParamDesc((L, B, ck - 1, di),
                              ("layers", "batch", "conv", "ssm_inner"),
                              "zeros"),
            "h": ParamDesc((L, B, di, ds),
                           ("layers", "batch", "ssm_inner", "ssm_state"),
                           "zeros"),
        }
    # hybrid
    di, ds, ck = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    nh, hd = cfg.ssm_nheads, cfg.ssm_headdim
    tree = {
        "conv_x": ParamDesc((L, B, ck - 1, di),
                            ("layers", "batch", "conv", "ssm_inner"), "zeros"),
        "conv_B": ParamDesc((L, B, ck - 1, ds),
                            ("layers", "batch", "conv", None), "zeros"),
        "conv_C": ParamDesc((L, B, ck - 1, ds),
                            ("layers", "batch", "conv", None), "zeros"),
        "h": ParamDesc((L, B, nh, hd, ds),
                       ("layers", "batch", "ssm_heads", "head_dim",
                        "ssm_state"), "zeros"),
    }
    if cfg.attn_every:
        napp = max(1, cfg.n_layers // cfg.attn_every)  # shared-block slots
        tree["ak"] = ParamDesc((napp, B, S, KV, Dh), kv_axes, "zeros")
        tree["av"] = ParamDesc((napp, B, S, KV, Dh), kv_axes, "zeros")
    return tree


def _cache_dtype(name: str, cfg: ModelConfig):
    # SSM running state stays f32 (recurrence numerics); kv/conv use compute.
    return jnp.float32 if name == "h" else jnp.dtype(cfg.compute_dtype)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    ab = cache_abstract(cfg, batch, max_seq)
    return {k: jnp.zeros(d.shape, _cache_dtype(k, cfg))
            for k, d in ab.items()}


def cache_shapedtypes(cfg: ModelConfig, batch: int, max_seq: int):
    ab = cache_abstract(cfg, batch, max_seq)
    return {k: jax.ShapeDtypeStruct(d.shape, _cache_dtype(k, cfg))
            for k, d in ab.items()}


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None, *,
            max_seq: int | None = None, mesh=None,
            flags: RunFlags = RunFlags()):
    """Forward the prompt, return (logits, cache filled up to S)."""
    if embeds is None:
        x = embed_tokens(params, tokens, cfg)
    else:
        x = embeds.astype(jnp.dtype(cfg.compute_dtype))
    x = _constrain(x, mesh, "batch", None, None)
    B, S = x.shape[:2]
    max_seq = max_seq or S
    positions = jnp.arange(S)
    x, cache_ys = _run_stack(x, params, cfg, flags, mesh, positions,
                             want_cache=True)
    logits = unembed(x[:, -1:], params, cfg, flags, mesh)
    cache = init_cache(cfg, B, max_seq)
    if cfg.family in ("dense", "moe"):
        ks, vs = cache_ys
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0, 0))
    elif cfg.family == "ssm":
        conv_tails, hs = cache_ys
        cache["conv"] = conv_tails.astype(cache["conv"].dtype)
        cache["h"] = hs
    else:
        (conv_tails, hs), akv = cache_ys
        cx, cB, cC = conv_tails
        cache["conv_x"] = cx.astype(cache["conv_x"].dtype)
        cache["conv_B"] = cB.astype(cache["conv_B"].dtype)
        cache["conv_C"] = cC.astype(cache["conv_C"].dtype)
        cache["h"] = hs
        if cfg.attn_every:
            ak, av = akv           # (L, B, S, KV, Dh); rows where applied
            napp = cache["ak"].shape[0]
            sel = ak[cfg.attn_every - 1::cfg.attn_every][:napp]
            cache["ak"] = jax.lax.dynamic_update_slice(
                cache["ak"], sel.astype(cache["ak"].dtype), (0, 0, 0, 0, 0))
            sel = av[cfg.attn_every - 1::cfg.attn_every][:napp]
            cache["av"] = jax.lax.dynamic_update_slice(
                cache["av"], sel.astype(cache["av"].dtype), (0, 0, 0, 0, 0))
    return logits, cache


# --------------------------------------------------------------------------
# Single-token decode
# --------------------------------------------------------------------------

def _attn_decode_layer(x, p, kc, vc, pos, cfg, flags, mesh):
    B = x.shape[0]
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", h, p["wq"])
    k = jnp.einsum("bsd,de->bse", h, p["wk"])
    v = jnp.einsum("bsd,de->bse", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, 1, H, Dh)
    k = k.reshape(B, 1, KV, Dh)
    v = v.reshape(B, 1, KV, Dh)
    cos, sin = rope_angles(pos[None], Dh, cfg.rope_theta)
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    kc = lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
    vc = lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
    o = decode_attention(q, kc, vc, pos, window=cfg.swa_window, mesh=mesh)
    x = x + jnp.einsum("bse,ed->bsd", o.reshape(B, 1, H * Dh), p["wo"])
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        m = moe_dense(h2, p["router"], p["w1"], p["w3"], p["w2"], cfg.top_k) \
            if flags.moe_mode == "dense" else \
            moe_scatter(h2, p["router"], p["w1"], p["w3"], p["w2"],
                        cfg.top_k, cfg.capacity_factor, mesh)
    else:
        m = swiglu(h2, p["w1"], p["w3"], p["w2"])
    return x + m, kc, vc


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, *,
                mesh=None, flags: RunFlags = RunFlags()):
    """One decode step.  tokens (B,1) int32; pos scalar int32 (0-based).
    Returns (logits (B,1,V), new cache)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed_tokens(params, tokens, cfg, mesh)
    x = _constrain(x, mesh, "batch", None, None)
    layers = _cast(params["layers"], cdt)
    x0 = x if cfg.family == "hybrid" else None
    shared = _cast(params.get("shared"), cdt) if "shared" in params else None

    if cfg.family in ("dense", "moe"):
        def body(x, scanned):
            p, kc, vc = scanned
            x, kc, vc = _attn_decode_layer(x, p, kc, vc, pos, cfg, flags,
                                           mesh)
            return x, (kc, vc)

        x, (ks, vs) = lax.scan(body, x, (layers, cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    elif cfg.family == "ssm":
        def body(x, scanned):
            p, conv, h = scanned
            hh = rms_norm(x, p["ln"], cfg.norm_eps)
            out, conv, h = mamba1_decode(hh, conv, h, p, cfg)
            return x + out, (conv, h)

        x, (convs, hs) = lax.scan(body, x, (layers, cache["conv"],
                                            cache["h"]))
        new_cache = {"conv": convs, "h": hs}
    else:
        def body(carry, scanned):
            x, ak_all, av_all = carry
            p, li, cx, cB, cC, h = scanned
            hh = rms_norm(x, p["ln"], cfg.norm_eps)
            out, (cx, cB, cC), h = mamba2_decode(hh, (cx, cB, cC), h, p, cfg)
            x = x + out
            if cfg.attn_every:
                app = li // cfg.attn_every

                def with_attn(args):
                    x, ak_all, av_all = args
                    akl = lax.dynamic_index_in_dim(ak_all, app, 0, False)
                    avl = lax.dynamic_index_in_dim(av_all, app, 0, False)
                    B = x.shape[0]
                    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
                    hin = jnp.concatenate([x, x0], axis=-1)
                    hn = rms_norm(hin, shared["ln"], cfg.norm_eps)
                    q = jnp.einsum("bsd,de->bse", hn,
                                   shared["wq"]).reshape(B, 1, H, Dh)
                    k = jnp.einsum("bsd,de->bse", hn,
                                   shared["wk"]).reshape(B, 1, KV, Dh)
                    v = jnp.einsum("bsd,de->bse", hn,
                                   shared["wv"]).reshape(B, 1, KV, Dh)
                    cos, sin = rope_angles(pos[None], Dh, cfg.rope_theta)
                    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
                    akl = lax.dynamic_update_slice(akl, k.astype(akl.dtype),
                                                   (0, pos, 0, 0))
                    avl = lax.dynamic_update_slice(avl, v.astype(avl.dtype),
                                                   (0, pos, 0, 0))
                    o = decode_attention(q, akl, avl, pos, mesh=mesh)
                    x = x + jnp.einsum("bse,ed->bsd",
                                       o.reshape(B, 1, H * Dh), shared["wo"])
                    ak_all = lax.dynamic_update_index_in_dim(
                        ak_all, akl, app, 0)
                    av_all = lax.dynamic_update_index_in_dim(
                        av_all, avl, app, 0)
                    return x, ak_all, av_all

                x, ak_all, av_all = lax.cond(
                    li % cfg.attn_every == cfg.attn_every - 1,
                    with_attn, lambda a: a, (x, ak_all, av_all))
            return (x, ak_all, av_all), (cx, cB, cC, h)

        li = jnp.arange(cfg.n_layers)
        (x, aks, avs), (cxs, cBs, cCs, hs) = lax.scan(
            body, (x, cache["ak"], cache["av"]),
            (layers, li, cache["conv_x"], cache["conv_B"], cache["conv_C"],
             cache["h"]))
        new_cache = {"conv_x": cxs, "conv_B": cBs, "conv_C": cCs, "h": hs,
                     "ak": aks, "av": avs}

    logits = unembed(x, params, cfg, flags, mesh)
    return logits, new_cache
