"""Mamba-1 (falcon-mamba) and Mamba-2/SSD (zamba2) blocks.

TPU adaptation (DESIGN.md §2.1):

* Mamba-2 runs the *chunked SSD algorithm*: within a chunk the recurrence is
  evaluated as masked matmuls (MXU work, like attention over the chunk), and
  only chunk-boundary states are materialized.  The naive per-step scan
  materializes (B,S,nh,hd,ds) f32 state tensors -- measured 123 TB of HBM
  traffic per train step on zamba2; the SSD form reduces state traffic by
  ~ds x log(chunk).

* Mamba-1's decay is per-(channel, state) -- the SSD matmul trick does not
  apply.  We keep a chunked associative scan (outer lax.scan carries the
  boundary state, inner lax.associative_scan parallelizes within the chunk);
  the Pallas `selective_scan` kernel (kernels/) is the fused TPU answer.

* All projections are split per output segment (x/z/B/C/dt).  A fused
  in_proj sliced along a 'model'-sharded axis forces GSPMD to reshard at
  every split -- observed as a collective-permute storm in dry-run HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .layers import causal_conv1d, rms_norm


def _conv_tail(x_raw, K):
    """Last K-1 pre-conv inputs (decode conv state), left-padded if short."""
    S = x_raw.shape[1]
    tail = x_raw[:, max(0, S - (K - 1)):, :]
    if S < K - 1:
        tail = jnp.pad(tail, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return tail


def _combine(left, right):
    """Compose two affine recurrence elements (a, b): h -> a*h + b."""
    al, bl = left
    ar, br = right
    return al * ar, bl * ar + br


def _pad_chunks(x, n_chunks, chunk):
    # (B, S, ...) -> (n_chunks, B, chunk, ...), zero-padding the tail.
    B, S = x.shape[:2]
    pad = n_chunks * chunk - S
    if pad:
        x = jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))
    return x.reshape(B, n_chunks, chunk, *x.shape[2:]).swapaxes(0, 1)


def _unpad_chunks(y, S):
    y = y.swapaxes(0, 1)
    y = y.reshape(y.shape[0], -1, *y.shape[3:])
    return y[:, :S]


# --------------------------------------------------------------------------
# Mamba-1 (selective scan; falcon-mamba)
# --------------------------------------------------------------------------

def mamba1_block(x, p, cfg, *, scan_chunk: int = 256):
    """x (B,S,d) -> (out (B,S,d), (conv_tail, h_last))."""
    B, S, d = x.shape
    di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    xr_raw = jnp.einsum("bsd,de->bse", x, p["x_in"])
    z = jnp.einsum("bsd,de->bse", x, p["z_in"])
    conv_tail = _conv_tail(xr_raw, cfg.ssm_conv)
    xr = jax.nn.silu(causal_conv1d(xr_raw, p["conv_w"], p["conv_b"]))
    prm = jnp.einsum("bse,ef->bsf", xr, p["x_proj"])
    dt_r = prm[..., :dtr]
    Bc = prm[..., dtr:dtr + ds].astype(jnp.float32)
    Cc = prm[..., dtr + ds:].astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                      # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                 # (di,ds)

    chunk = min(scan_chunk, S)
    n_chunks = -(-S // chunk)
    xs = jax.tree.map(lambda t: _pad_chunks(t, n_chunks, chunk),
                      (dt, Bc, Cc, xr.astype(jnp.float32)))

    def body(h_prev, args):
        dt_c, B_c, C_c, x_c = args
        a = jnp.exp(dt_c[..., None] * A)                          # (B,c,di,ds)
        b = (dt_c * x_c)[..., None] * B_c[:, :, None, :]
        aa, hh = lax.associative_scan(_combine, (a, b), axis=1)
        hh = hh + aa * h_prev[:, None]
        y = jnp.einsum("bcds,bcs->bcd", hh, C_c)
        return hh[:, -1], y

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    h_last, ys = lax.scan(body, h0, xs)
    y = _unpad_chunks(ys, S).astype(x.dtype)
    y = y + xr * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), (conv_tail, h_last)


def mamba1_decode(x_t, conv_state, h, p, cfg):
    """Single-token decode.  x_t (B,1,d); conv_state (B,K-1,di); h (B,di,ds)."""
    xr = jnp.einsum("bsd,de->bse", x_t, p["x_in"])
    z = jnp.einsum("bsd,de->bse", x_t, p["z_in"])
    window = jnp.concatenate([conv_state, xr], axis=1)            # (B,K,di)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xr_t = jax.nn.silu(conv)[:, None, :]                          # (B,1,di)
    prm = jnp.einsum("bse,ef->bsf", xr_t, p["x_proj"])[:, 0]
    dtr, ds = cfg.ssm_dt_rank, cfg.ssm_state
    dt_r, Bc, Cc = prm[:, :dtr], prm[:, dtr:dtr + ds], prm[:, dtr + ds:]
    dt = jax.nn.softplus(
        jnp.einsum("br,re->be", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                       # (B,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[..., None] * A)                                # (B,di,ds)
    b = (dt * xr_t[:, 0].astype(jnp.float32))[..., None] \
        * Bc.astype(jnp.float32)[:, None, :]
    h = a * h + b
    y = jnp.einsum("bds,bs->bd", h, Cc.astype(jnp.float32)).astype(x_t.dtype)
    y = y + xr_t[:, 0] * p["D"].astype(x_t.dtype)
    y = (y * jax.nn.silu(z[:, 0]))[:, None, :]
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, window[:, 1:], h


# --------------------------------------------------------------------------
# Mamba-2 / SSD (zamba2): chunked matmul form
# --------------------------------------------------------------------------

def mamba2_block(x, p, cfg, *, scan_chunk: int = 256):
    """Chunked SSD.  Per chunk of length c (log-decay cum_t = sum dt*A):

      y_t     = sum_{s<=t} exp(cum_t - cum_s) dt_s (C_t . B_s) x_s   (intra)
              + exp(cum_t) C_t . h_0                                 (inter)
      h_next  = exp(cum_c) h_0 + sum_s exp(cum_c - cum_s) dt_s x_s B_s^T
    """
    B, S, d = x.shape
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    xr_raw = jnp.einsum("bsd,de->bse", x, p["x_in"])
    z = jnp.einsum("bsd,de->bse", x, p["z_in"])
    B_raw = jnp.einsum("bsd,de->bse", x, p["B_in"])
    C_raw = jnp.einsum("bsd,de->bse", x, p["C_in"])
    dt_raw = jnp.einsum("bsd,de->bse", x, p["dt_in"])
    conv_tails = (_conv_tail(xr_raw, cfg.ssm_conv),
                  _conv_tail(B_raw, cfg.ssm_conv),
                  _conv_tail(C_raw, cfg.ssm_conv))
    xr = jax.nn.silu(causal_conv1d(xr_raw, p["conv_x"], p["conv_xb"]))
    Bc = jax.nn.silu(causal_conv1d(B_raw, p["conv_B"], p["conv_Bb"]))
    Cc = jax.nn.silu(causal_conv1d(C_raw, p["conv_C"], p["conv_Cb"]))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))      # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (nh,)
    xh = xr.reshape(B, S, nh, hd)

    chunk = min(scan_chunk, S)
    n_chunks = -(-S // chunk)
    xs = jax.tree.map(lambda t: _pad_chunks(t, n_chunks, chunk),
                      (dt, Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                       xh.astype(jnp.float32)))

    def body(h_prev, args):
        dt_c, B_c, C_c, x_c = args                # (B,c,nh) (B,c,ds) (B,c,nh,hd)
        la = dt_c * A                              # (B,c,nh), <= 0
        cum = jnp.cumsum(la, axis=1)               # (B,c,nh)
        # intra-chunk masked matmul
        cb = jnp.einsum("btn,bsn->bts", C_c, B_c)  # (B,c,c)
        decay = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,t,s,nh)
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
        m = cb[:, :, :, None] * decay * dt_c[:, None, :, :] \
            * tri[None, :, :, None]                # (B,t,s,nh)
        y_intra = jnp.einsum("btsh,bshd->bthd", m, x_c)
        # inter-chunk contribution from the carried state
        y_inter = jnp.einsum("btn,bhdn->bthd", C_c, h_prev) \
            * jnp.exp(cum)[:, :, :, None]
        # boundary state
        w = jnp.exp(cum[:, -1:, :] - cum) * dt_c   # (B,c,nh)
        h_delta = jnp.einsum("bshd,bsn,bsh->bhdn", x_c, B_c, w)
        h_next = jnp.exp(cum[:, -1, :])[:, :, None, None] * h_prev + h_delta
        return h_next, y_intra + y_inter

    h0 = jnp.zeros((B, nh, hd, ds), jnp.float32)
    h_last, ys = lax.scan(body, h0, xs)
    y = _unpad_chunks(ys, S).astype(x.dtype)                       # (B,S,nh,hd)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["ln_inner"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), (conv_tails, h_last)


def mamba2_decode(x_t, conv_states, h, p, cfg):
    """x_t (B,1,d); conv_states (cx (B,K-1,di), cB, cC (B,K-1,ds));
    h (B,nh,hd,ds)."""
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    cx, cB, cC = conv_states
    xr = jnp.einsum("bsd,de->bse", x_t, p["x_in"])
    z = jnp.einsum("bsd,de->bse", x_t, p["z_in"])
    B_raw = jnp.einsum("bsd,de->bse", x_t, p["B_in"])
    C_raw = jnp.einsum("bsd,de->bse", x_t, p["C_in"])
    dt_raw = jnp.einsum("bsd,de->bse", x_t, p["dt_in"])

    def conv_step(state, new, w, b):
        win = jnp.concatenate([state, new], axis=1)               # (B,K,C)
        out = jnp.einsum("bkc,kc->bc", win, w) + b
        return jax.nn.silu(out), win[:, 1:]

    xr_t, cx = conv_step(cx, xr, p["conv_x"], p["conv_xb"])
    B_t, cB = conv_step(cB, B_raw, p["conv_B"], p["conv_Bb"])
    C_t, cC = conv_step(cC, C_raw, p["conv_C"], p["conv_Cb"])
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))       # (B,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xhh = xr_t.reshape(-1, nh, hd).astype(jnp.float32)
    a = jnp.exp(dt * A)[..., None, None]
    b = (dt[..., None] * xhh)[..., None] \
        * B_t.astype(jnp.float32)[:, None, None, :]
    h = a * h + b
    y = jnp.einsum("bhdn,bn->bhd", h, C_t.astype(jnp.float32)).astype(x_t.dtype)
    y = y + xhh.astype(x_t.dtype) * p["D"].astype(x_t.dtype)[None, :, None]
    y = y.reshape(-1, di)
    y = rms_norm(y * jax.nn.silu(z[:, 0]), p["ln_inner"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None, :]
    return out, (cx, cB, cC), h
