"""Unified model configuration for the 10 assigned architectures."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                 # dense | moe | ssm | hybrid
    frontend: str = "none"      # none | audio | vision  (stub frontends)

    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0           # 0 -> d_model // n_heads
    d_ff: int = 128
    vocab: int = 128
    qkv_bias: bool = False
    swa_window: int = 0         # 0 -> full attention; >0 -> sliding window
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dff: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba1 / mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_headdim: int = 64       # mamba2 head dim
    ssm_dt_rank: int = 0        # 0 -> ceil(d_model / 16)   (mamba1)

    # hybrid (zamba2-style shared attention block)
    attn_every: int = 0         # 0 -> no interleaved shared block

    # numerics / training
    param_dtype: str = "float32"    # master weights
    compute_dtype: str = "bfloat16"
    remat: bool = True

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.ssm_dt_rank == 0 and self.family in ("ssm", "hybrid"):
            object.__setattr__(self, "ssm_dt_rank", -(-self.d_model // 16))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_subquadratic_context(self) -> bool:
        """True if long-context (500k) cost is sub-quadratic in prefill:
        SSM/hybrid state-space recurrence, or sliding-window attention."""
        return self.family in ("ssm", "hybrid") or self.swa_window > 0

    def param_count(self) -> int:
        """Analytic total parameter count (embedding + stack + head)."""
        d, v = self.d_model, self.vocab
        total = v * d                                 # embed
        if not self.tie_embeddings:
            total += d * v                            # head
        total += d                                    # final norm
        hd = self.head_dim
        per_layer = 0
        if self.family in ("dense", "moe"):
            qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
            attn = qkv + (self.n_heads * hd) * d
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * hd
            per_layer = attn + 2 * d                  # + 2 norms
            if self.family == "dense":
                per_layer += 3 * d * self.d_ff
            else:
                per_layer += d * self.n_experts       # router
                per_layer += self.n_experts * 3 * d * self.moe_dff
        elif self.family in ("ssm", "hybrid"):
            di, ds = self.d_inner, self.ssm_state
            if self.arch_id.startswith("falcon") or self.family == "ssm":
                # mamba1 block
                per_layer = (d * 2 * di + di * self.ssm_conv +
                             di * (self.ssm_dt_rank + 2 * ds) +
                             self.ssm_dt_rank * di + di * ds + di + di * d + d)
            else:
                # mamba2 (SSD) block
                nh, ng = self.ssm_nheads, 1
                proj_in = d * (2 * di + 2 * ng * ds + nh)
                per_layer = (proj_in + (di + 2 * ng * ds) * self.ssm_conv +
                             nh * 2 + di + di * d + d)
        total += self.n_layers * per_layer
        if self.attn_every:  # one shared attention block over concat(x, x0)
            hd2 = self.head_dim
            total += (2 * d + (2 * d) * (self.n_heads * hd2) +
                      2 * (2 * d) * (self.n_kv_heads * hd2) +
                      (self.n_heads * hd2) * d)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        dense_share = self.param_count() - \
            self.n_layers * self.n_experts * 3 * self.d_model * self.moe_dff
        return dense_share + self.n_layers * self.top_k * 3 * self.d_model * self.moe_dff

    def reduced(self, **over) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            arch_id=self.arch_id + "-smoke",
            family=self.family,
            frontend=self.frontend,
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab=256,
            qkv_bias=self.qkv_bias,
            swa_window=8 if self.swa_window else 0,
            n_experts=4 if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_dff=64 if self.moe_dff else 0,
            ssm_state=8 if self.ssm_state else 0,
            ssm_expand=2,
            ssm_conv=4,
            ssm_headdim=16,
            ssm_dt_rank=8 if self.family in ("ssm", "hybrid") else 0,
            attn_every=2 if self.attn_every else 0,
            tie_embeddings=self.tie_embeddings,
        )
        kw.update(over)
        return ModelConfig(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    phase: str                  # train | prefill | decode
    microbatches: int = 1       # grad-accumulation splits (train only)

    def with_microbatches(self, m: int) -> "ShapeConfig":
        return dataclasses.replace(self, microbatches=m)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
