"""Store integrity checker: ``python -m repro.fsck [store_dir ...]``.

Walks each argument and verifies every index store it finds against the
per-array CRC32 checksums recorded in the manifests
(:func:`repro.core.store.verify_store`):

* a plain store root (flat layout or versioned generations) is checked
  directly — serving chain, retained generations, aborted dirs, and
  anything already in ``quarantine/``;
* a sharded save root (``meta.json`` + ``shard_{s}/`` dirs,
  :meth:`ShardedAlignmentIndex.save`) is expanded into one check per
  shard store;
* any other directory is scanned one level deep for store roots, so
  pointing fsck at a results/ or tmp tree checks everything inside;
* a store's write-ahead log (``wal/`` segments), when present, is
  verified too (:func:`repro.wal.verify_wal`): frame CRCs, segment
  chain continuity, and manifest ``wal_watermark`` consistency — a torn
  tail on the last segment is reported but is expected crash debris
  (repaired on the next open), not corruption.

Exit status is 1 iff any *committed, non-quarantined* generation fails —
aborted write dirs and already-quarantined generations are reported but
are expected debris, not corruption.  ``--format json`` emits the full
per-generation reports for CI artifacts.

fsck only reads; it never quarantines or repairs.  Recovery happens on
load (:func:`repro.core.store.resolve_verified`).
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .core import store as index_store


def _is_store_root(path: Path) -> bool:
    if not path.is_dir():
        return False
    if (path / "manifest.json").exists():
        return True
    if (path / index_store.CURRENT_POINTER).exists():
        return True
    return any(path.glob("v[0-9][0-9][0-9][0-9][0-9][0-9]/manifest.json"))


def _is_sharded_root(path: Path) -> bool:
    return ((path / "meta.json").exists()
            and any(p.is_dir() for p in path.glob("shard_*")))


def discover_stores(path) -> list[Path]:
    """Expand one CLI argument into the store roots to verify."""
    path = Path(path)
    if _is_sharded_root(path):
        return sorted(p for p in path.glob("shard_*") if _is_store_root(p))
    if _is_store_root(path):
        return [path]
    if path.is_dir():
        found = []
        for child in sorted(path.iterdir()):
            if _is_sharded_root(child):
                found.extend(sorted(p for p in child.glob("shard_*")
                                    if _is_store_root(p)))
            elif _is_store_root(child):
                found.append(child)
        return found
    return []


def check_store(root) -> dict:
    """Verify one store root; returns the ``verify_store`` report."""
    return index_store.verify_store(root)


def check_paths(paths) -> dict:
    """Verify every store found under ``paths``.  Returns
    ``{"stores": [report...], "checked": n, "ok": bool}`` where ``ok``
    follows the per-store ``ok`` (serving chain + committed gens)."""
    reports = []
    for arg in paths:
        for root in discover_stores(arg):
            reports.append(check_store(root))
    return {"stores": reports, "checked": len(reports),
            "ok": all(r["ok"] for r in reports)}


def render_text(result: dict) -> str:
    lines = []
    for rep in result["stores"]:
        status = "ok" if rep["ok"] else "FAILED"
        lines.append(f"{rep['root']}: {status} "
                     f"(serving generation {rep['serving_generation']})")
        for g in rep["generations"]:
            mark = "ok" if g["ok"] else (
                "aborted" if g["role"] == "aborted" else "FAILED")
            lines.append(f"  gen {g['generation']} [{g['role']}] {mark}  "
                         f"{g['checksummed']}/{g['arrays']} arrays "
                         "checksummed")
            for p in g["problems"]:
                lines.append(f"    - {p}")
        for g in rep["quarantined"]:
            lines.append(f"  quarantined {Path(g['path']).name}: "
                         f"{len(g['problems'])} problem(s)")
        wal = rep.get("wal")
        if wal and wal.get("present"):
            mark = "ok" if wal["ok"] else "FAILED"
            lines.append(
                f"  wal {mark}  {wal['segments']} segment(s), "
                f"{wal['records']} record(s), lsn [{wal['first_lsn']}, "
                f"{wal['end_lsn']})"
                + (", torn tail (repairable)" if wal["torn_tail"] else ""))
            for p in wal["problems"]:
                lines.append(f"    - {p}")
    lines.append(f"{result['checked']} store(s) checked: "
                 + ("all ok" if result["ok"] else "FAILURES found"))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fsck",
        description="verify index store checksums (manifest CRC32s vs the "
                    "array files on disk)")
    ap.add_argument("paths", nargs="+",
                    help="store roots, sharded save roots, or directories "
                         "to scan one level deep")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    args = ap.parse_args(argv)

    result = check_paths(args.paths)
    if args.format == "json":
        print(json.dumps(result, indent=2))
    else:
        print(render_text(result))
    if not result["checked"]:
        print("no stores found", file=sys.stderr)
        return 2
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
