"""Site-tagged durable-mutation helpers — the only sanctioned way for
store/checkpoint code to touch the filesystem (static-analysis rule
RPR203 flags bypasses).

Each helper names its fault *site* and runs :func:`repro.fault.checkpoint`
first, so an armed :class:`~repro.fault.FaultPlan` can turn the mutation
into an injected ``OSError``, a torn (half-length) write, or a hard
``os._exit`` crash either side of the op.  ``commit_text``/``commit_bytes``
are the atomic-publish primitives (write ``<name>.tmp``, then rename over
the destination) and expose *two* checkpoints — ``<site>.tmp_write`` and
``<site>.rename`` — so crash schedules can land between staging and
publication.

When no plan is armed every helper degrades to the plain
``pathlib``/``numpy``/``shutil`` call it wraps.
"""

from __future__ import annotations

import io
import os
import shutil
from pathlib import Path

import numpy as np

from . import FaultInjected, Trigger, checkpoint


def _post(trig: Trigger | None) -> None:
    if trig is not None and trig.mode == "crash_after":
        os._exit(trig.exit_code)


def _torn(path: Path, data: bytes, trig: Trigger) -> None:
    """Write roughly the first half of ``data`` and raise — a torn write."""
    path.write_bytes(data[: max(1, len(data) // 2)])
    raise FaultInjected(trig.site, trig.hit, "torn")


def write_bytes(path, data: bytes, *, site: str) -> None:
    path = Path(path)
    trig = checkpoint(site)
    if trig is not None and trig.mode == "torn":
        _torn(path, data, trig)
    path.write_bytes(data)
    _post(trig)


def write_text(path, text: str, *, site: str) -> None:
    write_bytes(path, text.encode("utf-8"), site=site)


def np_save(path, arr, *, site: str) -> None:
    path = Path(path)
    trig = checkpoint(site)
    if trig is not None and trig.mode == "torn":
        buf = io.BytesIO()
        np.save(buf, arr)
        _torn(path, buf.getvalue(), trig)
    np.save(path, arr)
    _post(trig)


def np_savez(path, *, site: str, **arrays) -> None:
    path = Path(path)
    trig = checkpoint(site)
    if trig is not None and trig.mode == "torn":
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        _torn(path, buf.getvalue(), trig)
    np.savez(path, **arrays)
    _post(trig)


def replace(src, dst, *, site: str) -> None:
    """Atomic rename ``src`` over ``dst`` (``os.replace`` semantics)."""
    trig = checkpoint(site)
    Path(src).replace(dst)
    _post(trig)


def commit_text(path, text: str, *, site: str) -> None:
    """Atomically publish ``text`` at ``path`` via tmp-stage + rename."""
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    write_text(tmp, text, site=site + ".tmp_write")
    replace(tmp, path, site=site + ".rename")


def commit_bytes(path, data: bytes, *, site: str) -> None:
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    write_bytes(tmp, data, site=site + ".tmp_write")
    replace(tmp, path, site=site + ".rename")


def open_append(path, *, site: str):
    """Open ``path`` in append-binary mode (creating it), as a named
    checkpoint — the WAL's segment-creation / reopen primitive."""
    trig = checkpoint(site)
    f = open(Path(path), "ab")
    _post(trig)
    return f


def append_bytes(f, data: bytes, *, site: str) -> None:
    """Append ``data`` to an open binary file handle and flush it to the
    OS (``os._exit`` crash modes must not lose userspace-buffered bytes —
    the crash model is process death, where a completed ``write(2)``
    survives in the page cache).  Torn mode leaves roughly half of
    ``data`` on disk, the WAL's torn-frame case."""
    trig = checkpoint(site)
    if trig is not None and trig.mode == "torn":
        f.write(data[: max(1, len(data) // 2)])
        f.flush()
        raise FaultInjected(trig.site, trig.hit, "torn")
    f.write(data)
    f.flush()
    _post(trig)


def fsync(f, *, site: str) -> None:
    """Flush + ``os.fsync`` an open file handle — the durability barrier
    group-commit acks wait on."""
    trig = checkpoint(site)
    f.flush()
    os.fsync(f.fileno())
    _post(trig)


def truncate(target, size: int, *, site: str) -> None:
    """Truncate an open handle or a path to ``size`` bytes (torn-tail
    repair: everything past the last complete frame is discarded)."""
    trig = checkpoint(site)
    if hasattr(target, "truncate"):
        target.flush()
        target.truncate(size)
    else:
        with open(Path(target), "r+b") as f:
            f.truncate(size)
    _post(trig)


def unlink(path, *, site: str, missing_ok: bool = False) -> None:
    trig = checkpoint(site)
    Path(path).unlink(missing_ok=missing_ok)
    _post(trig)


def rmtree(path, *, site: str, ignore_errors: bool = False) -> None:
    trig = checkpoint(site)
    shutil.rmtree(path, ignore_errors=ignore_errors)
    _post(trig)
