"""``repro.fault`` — deterministic, seeded fault injection for durable I/O.

Every store/checkpoint filesystem mutation in this repo routes through
:mod:`repro.fault.fsio` (enforced by static-analysis rule RPR203).  Each
fsio helper names its call *site* (``"store.writer.manifest"``,
``"ckpt.shards"``, ...) and calls :func:`checkpoint` before mutating
anything.  A :class:`FaultPlan` armed via the ``REPRO_FAULT_PLAN``
environment variable (JSON, read once at import — the same zero-overhead
pattern as ``REPRO_THREAD_GUARD`` in :mod:`repro.core.guard`) or
programmatically via :func:`arm` turns chosen checkpoints into:

* ``error``        raise :class:`FaultInjected` (an ``OSError``) before the op
* ``torn``         write roughly half the bytes, then raise (fsio ops only)
* ``crash``        ``os._exit`` *before* the op — a hard ``kill -9``
* ``crash_after``  ``os._exit`` after the op durably completed
* ``slow``         sleep ``delay_s`` before the op (serve-path latency tests)

Triggers select sites by ``fnmatch`` glob and fire on the ``hit``-th
matching occurrence (1-based); ``sticky`` triggers keep firing from that
occurrence on.  When nothing is armed, :func:`checkpoint` is two global
``None`` checks — the serving hot path never pays for this module.

:func:`record_sites` enumerates the (site, occurrence) stream of a
workload so chaos harnesses can build exhaustive fault schedules, and
:func:`stats` exposes injection counters for ``/metrics``.
"""

from __future__ import annotations

import fnmatch
import json
import os
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field

ENV_VAR = "REPRO_FAULT_PLAN"

#: exit code used by ``crash``/``crash_after`` triggers (distinct from
#: common signal codes so harnesses can tell an injected crash from a
#: genuine SIGKILL/SIGSEGV)
FAULT_EXIT = 87


class FaultInjected(OSError):
    """An error injected by the armed :class:`FaultPlan`.

    Subclasses ``OSError`` so injected faults exercise exactly the
    ``except OSError`` paths a real disk failure would.
    """

    def __init__(self, site: str, hit: int, mode: str):
        super().__init__(f"injected {mode} fault at {site!r} (occurrence {hit})")
        self.site = site
        self.hit = hit
        self.mode = mode


_MODES = ("error", "torn", "crash", "crash_after", "slow")


@dataclass(frozen=True)
class Trigger:
    """One scheduled fault: fire ``mode`` on the ``hit``-th occurrence of
    any site matching the ``site`` glob (every occurrence from ``hit`` on
    when ``sticky``)."""

    site: str
    hit: int = 1
    mode: str = "error"
    sticky: bool = False
    delay_s: float = 0.05
    exit_code: int = FAULT_EXIT

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"unknown fault mode {self.mode!r}; expected one of {_MODES}")
        if self.hit < 1:
            raise ValueError("hit is 1-based and must be >= 1")

    def to_dict(self) -> dict:
        return {"site": self.site, "hit": self.hit, "mode": self.mode,
                "sticky": self.sticky, "delay_s": self.delay_s,
                "exit_code": self.exit_code}

    @classmethod
    def from_dict(cls, d: dict) -> "Trigger":
        return cls(**d)


@dataclass
class FaultPlan:
    """A seeded, deterministic schedule of :class:`Trigger`s."""

    triggers: list = field(default_factory=list)
    seed: int = 0

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "triggers": [t.to_dict() for t in self.triggers]})

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        return cls(seed=int(d.get("seed", 0)),
                   triggers=[Trigger.from_dict(t) for t in d.get("triggers", [])])


# -- armed state --------------------------------------------------------------
#
# Module-level, guarded by _LOCK on the slow path only.  ``checkpoint``
# early-returns on two plain global reads when nothing is armed.

_LOCK = threading.Lock()
_PLAN: FaultPlan | None = None
_HITS: list[int] | None = None      # per-trigger occurrence counters
_RECORDER: list | None = None       # (site, occurrence) stream when recording
_REC_COUNTS: dict | None = None
_STATS = {"checkpoints": 0, "injected": 0,
          "by_mode": {m: 0 for m in _MODES}}


def arm(plan: FaultPlan) -> None:
    """Arm ``plan``: subsequent checkpoints consult it.  Resets hit counts."""
    global _PLAN, _HITS
    with _LOCK:
        _PLAN = plan
        _HITS = [0] * len(plan.triggers)


def disarm() -> None:
    global _PLAN, _HITS
    with _LOCK:
        _PLAN = None
        _HITS = None


def active_plan() -> FaultPlan | None:
    return _PLAN


@contextmanager
def armed(plan: FaultPlan):
    """``with fault.armed(plan): ...`` — arm for the block, always disarm."""
    arm(plan)
    try:
        yield plan
    finally:
        disarm()


@contextmanager
def record_sites():
    """Record every checkpoint as ``(site, occurrence)`` (1-based per site).

    Yields the list being filled; used to enumerate a workload's fault
    sites so a chaos schedule can cover all of them.
    """
    global _RECORDER, _REC_COUNTS
    out: list = []
    with _LOCK:
        _RECORDER = out
        _REC_COUNTS = {}
    try:
        yield out
    finally:
        with _LOCK:
            _RECORDER = None
            _REC_COUNTS = None


def stats() -> dict:
    """Injection counters (merged into the serve ``/metrics`` snapshot)."""
    with _LOCK:
        return {"armed": _PLAN is not None,
                "checkpoints": _STATS["checkpoints"],
                "injected": _STATS["injected"],
                "by_mode": dict(_STATS["by_mode"])}


def reset_stats() -> None:
    with _LOCK:
        _STATS["checkpoints"] = 0
        _STATS["injected"] = 0
        for m in _MODES:
            _STATS["by_mode"][m] = 0


def checkpoint(site: str) -> Trigger | None:
    """The hot entry: called by every fsio helper (and the serve-path
    injection hooks) with its site name.

    Handles ``error`` (raises), ``crash`` (``os._exit``), and ``slow``
    (sleeps) itself.  ``torn`` and ``crash_after`` need cooperation from
    the mutation in progress, so the matched trigger is *returned* for
    the fsio caller to execute mid-op; non-fsio callers may ignore it.
    Returns ``None`` when nothing fires.
    """
    if _PLAN is None and _RECORDER is None:
        return None
    return _checkpoint_slow(site)


def _checkpoint_slow(site: str) -> Trigger | None:
    with _LOCK:
        if _RECORDER is not None:
            n = _REC_COUNTS.get(site, 0) + 1
            _REC_COUNTS[site] = n
            _RECORDER.append((site, n))
        plan, hits = _PLAN, _HITS
        if plan is None:
            return None
        _STATS["checkpoints"] += 1
        fired = None
        for i, trig in enumerate(plan.triggers):
            if not fnmatch.fnmatchcase(site, trig.site):
                continue
            hits[i] += 1
            if hits[i] == trig.hit or (trig.sticky and hits[i] > trig.hit):
                fired = (trig, hits[i])
                break
        if fired is None:
            return None
        trig, occurrence = fired
        _STATS["injected"] += 1
        _STATS["by_mode"][trig.mode] += 1
    if trig.mode == "error":
        raise FaultInjected(site, occurrence, "error")
    if trig.mode == "crash":
        os._exit(trig.exit_code)
    if trig.mode == "slow":
        time.sleep(trig.delay_s)
        return None
    # torn / crash_after: the caller performs the partial write / the
    # post-op exit
    return trig


def _arm_from_env() -> None:
    spec = os.environ.get(ENV_VAR, "")
    if spec:
        arm(FaultPlan.from_json(spec))


_arm_from_env()
