"""Registry of the 10 assigned architectures (+ the paper's own workload).

Each module defines CONFIG: ModelConfig with the exact published shape.
Full configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); smoke tests use `CONFIG.reduced()`.
"""

from __future__ import annotations

import importlib

from ..models.config import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "musicgen-medium": "musicgen_medium",
    "llama3-405b": "llama3_405b",
    "granite-34b": "granite_34b",
    "qwen1.5-4b": "qwen1_5_4b",
    "mistral-large-123b": "mistral_large_123b",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "mixtral-8x7b": "mixtral_8x7b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[arch_id]}", __package__)
    return mod.CONFIG


def arch_cells(arch_id: str) -> list[str]:
    """The shape cells assigned to this arch; long_500k only where the
    context path is sub-quadratic (DESIGN.md §Arch-applicability)."""
    cfg = get_config(arch_id)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.has_subquadratic_context:
        cells.append("long_500k")
    else:
        cells.append("long_500k:skip")
    return cells


# Per-(arch, shape) performance knobs chosen by the §Perf iteration:
# microbatches trade activation memory against per-microbatch FSDP weight
# re-gathers (llama3-405b: 8 -> 4 raised MFU* 0.161 -> 0.194, §Perf cell C).
TRAIN_MICROBATCHES: dict[str, int] = {
    "llama3-405b": 4,
    "mistral-large-123b": 4,
    "granite-34b": 2,
    "qwen3-moe-235b-a22b": 4,
    "mixtral-8x7b": 2,
}

__all__ = ["ARCH_IDS", "get_config", "arch_cells", "SHAPES", "ModelConfig",
           "ShapeConfig", "TRAIN_MICROBATCHES"]
