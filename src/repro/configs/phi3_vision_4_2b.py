"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 -- phi3-mini + CLIP [hf:microsoft/Phi-3-vision-128k-instruct].
CLIP frontend is a STUB: input_specs() supplies precomputed patch embeddings
interleaved with text for train/prefill; decode embeds text tokens."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b", family="dense", frontend="vision",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064)
