"""musicgen-medium [audio]: 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048 -- decoder-only over EnCodec tokens [arXiv:2306.05284; hf].
The EnCodec frontend is a STUB: input_specs() supplies precomputed frame
embeddings for train/prefill; decode embeds discrete codebook ids."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-medium", family="dense", frontend="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab=2048, rope_theta=10_000.0)
