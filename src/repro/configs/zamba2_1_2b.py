"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000
ssm_state=64 -- Mamba2 backbone + shared attention block [arXiv:2411.15242]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000, ssm_state=64, ssm_expand=2, ssm_conv=4,
    ssm_headdim=64, attn_every=6)
