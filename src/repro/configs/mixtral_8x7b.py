"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) moe_dff=14336
vocab=32000, 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000, n_experts=8, top_k=2, moe_dff=14336,
    swa_window=4096)
