"""Intra-repo call-graph builder for the engine-affinity rule.

Python resolution is undecidable statically, so this graph is pragmatic
and tuned to this repo's idiom.  A call site resolves to project
function definitions by, in order:

1. ``self.m()`` — the enclosing class's own ``m`` (exact);
2. receiver name affinity — ``live.seal_delta()`` resolves to
   ``LiveIndex.seal_delta`` because exactly one class whose lowercase
   name extends the receiver hint (``live``/``aligner``/``batcher``…)
   defines ``m``;
3. bare-name calls — the nested or module-level def of that name in the
   same file;
4. name-unique fallback — any project def named ``m``, **except** for
   generic container/executor method names (``add``, ``get``,
   ``close``…) that would collide with builtins.

Rules consume :class:`DefInfo` (one per function/method, with decorator
names, async-ness and nesting) and :meth:`CallGraph.resolve`.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

from .framework import Project, SourceFile, dotted_name, receiver_hint

#: method names too generic to resolve by bare-name uniqueness — they
#: collide with set/dict/queue/executor builtins all over the tree
GENERIC_NAMES = frozenset({
    "add", "append", "extend", "insert", "update", "pop", "remove",
    "discard", "clear", "get", "put", "put_nowait", "get_nowait",
    "close", "open", "start", "stop", "run", "cancel", "done", "result",
    "set_result", "set_exception", "items", "keys", "values", "copy",
    "join", "split", "write", "read", "send", "submit", "freeze", "load",
    "save", "build", "query",
})


@dataclass(eq=False)
class DefInfo:
    """One function/method definition (identity-hashed, so defs can live
    in taint sets)."""

    name: str
    cls: str | None                  # enclosing class, if a method
    node: ast.AST                    # FunctionDef | AsyncFunctionDef
    file: SourceFile
    is_async: bool
    decorators: frozenset[str]       # trailing dotted names, e.g. engine_only
    parent: "DefInfo | None" = None  # enclosing def for nested functions
    dispatched: bool = False         # referenced by name in dispatcher args

    @property
    def qualname(self) -> str:
        return f"{self.file.rel}:{self.cls + '.' if self.cls else ''}" \
               f"{self.name}"

    def has_decorator(self, *names: str) -> bool:
        return any(d == n or d.endswith("." + n)
                   for d in self.decorators for n in names)


def _decorator_names(node) -> frozenset[str]:
    out = set()
    for dec in node.decorator_list:
        name = dotted_name(dec)
        if name:
            out.add(name)
    return frozenset(out)


@dataclass
class CallGraph:
    defs: list[DefInfo] = field(default_factory=list)
    #: method/function name -> every def with that name
    by_name: dict[str, list[DefInfo]] = field(default_factory=dict)
    #: class name -> {method name -> DefInfo}
    by_class: dict[str, dict[str, DefInfo]] = field(default_factory=dict)
    #: (file rel, parent def id, name) -> nested/module-level def
    _scoped: dict[tuple, DefInfo] = field(default_factory=dict)

    def _add(self, d: DefInfo) -> None:
        self.defs.append(d)
        self.by_name.setdefault(d.name, []).append(d)
        if d.cls is not None:
            self.by_class.setdefault(d.cls, {})[d.name] = d
        self._scoped[(d.file.rel, id(d.parent.node) if d.parent else None,
                      d.name)] = d

    def scoped_lookup(self, file: SourceFile, enclosing: DefInfo | None,
                      name: str) -> DefInfo | None:
        """A bare-name callee: the nested def in ``enclosing`` (walking
        outward), else the module-level def in the same file."""
        d: DefInfo | None = enclosing
        while d is not None:
            hit = self._scoped.get((file.rel, id(d.node), name))
            if hit is not None:
                return hit
            d = d.parent
        return self._scoped.get((file.rel, None, name))

    def resolve(self, call: ast.Call, caller: DefInfo) -> DefInfo | None:
        """The project def a call most plausibly targets (None: external
        or unresolvable).  See the module docstring for the heuristics."""
        func = call.func
        if isinstance(func, ast.Attribute):
            m = func.attr
            recv = func.value
            if isinstance(recv, ast.Name) and recv.id == "self" \
                    and caller.cls is not None:
                return self.by_class.get(caller.cls, {}).get(m)
            hint = receiver_hint(recv)
            if hint:
                hl = hint.lower().lstrip("_")
                owners = [c for c, methods in self.by_class.items()
                          if m in methods
                          and (c.lower().startswith(hl)
                               or hl.startswith(c.lower()))]
                if len(owners) == 1:
                    return self.by_class[owners[0]][m]
            if m in GENERIC_NAMES:
                return None
            candidates = self.by_name.get(m, [])
            return candidates[0] if candidates else None
        if isinstance(func, ast.Name):
            return self.scoped_lookup(caller.file, caller, func.id)
        return None

    def candidates(self, call: ast.Call, caller: DefInfo) -> list[DefInfo]:
        """Every def the call could target under the same heuristics
        (used for taint: a call is tainted when ANY candidate is)."""
        func = call.func
        if isinstance(func, ast.Attribute):
            m = func.attr
            recv = func.value
            if isinstance(recv, ast.Name) and recv.id == "self" \
                    and caller.cls is not None:
                own = self.by_class.get(caller.cls, {}).get(m)
                return [own] if own is not None else []
            hint = receiver_hint(recv)
            if hint:
                hl = hint.lower().lstrip("_")
                owners = [c for c, methods in self.by_class.items()
                          if m in methods
                          and (c.lower().startswith(hl)
                               or hl.startswith(c.lower()))]
                if len(owners) == 1:
                    return [self.by_class[owners[0]][m]]
            if m in GENERIC_NAMES:
                return []
            return list(self.by_name.get(m, []))
        if isinstance(func, ast.Name):
            hit = self.scoped_lookup(caller.file, caller, func.id)
            return [hit] if hit is not None else []
        return []


def build_callgraph(project: Project) -> CallGraph:
    graph = CallGraph()
    for sf in project.files:
        _collect(graph, sf, sf.tree, cls=None, parent=None)
    return graph


def _collect(graph: CallGraph, sf: SourceFile, node: ast.AST,
             cls: str | None, parent: DefInfo | None) -> None:
    for child in ast.iter_child_nodes(node):
        if isinstance(child, ast.ClassDef):
            _collect(graph, sf, child, cls=child.name, parent=parent)
        elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
            info = DefInfo(
                name=child.name, cls=cls, node=child, file=sf,
                is_async=isinstance(child, ast.AsyncFunctionDef),
                decorators=_decorator_names(child), parent=parent)
            graph._add(info)
            # nested defs belong to the function, not the class namespace
            _collect(graph, sf, child, cls=None, parent=info)


def project_callgraph(project: Project) -> CallGraph:
    return project.shared("callgraph", build_callgraph)
