"""CLI: ``python -m repro.analysis [--rules RPR1,RPR403] [--format json]
[paths...]`` — exits nonzero iff unsuppressed findings remain."""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import RULE_DOCS, render_json, render_text, run_analysis

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST invariant checker: engine-thread race lint, "
                    "store crash-safety ordering, kernel purity, API "
                    "deprecations.")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to check (default: "
                         f"{', '.join(DEFAULT_PATHS)} where present)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule-id prefixes to keep "
                         "(e.g. RPR2 or RPR101,RPR403)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print every rule id and summary, then exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule_id, summary in sorted(RULE_DOCS.items()):
            print(f"{rule_id}  {summary}")
        return 0

    paths = args.paths or [p for p in DEFAULT_PATHS if Path(p).exists()]
    if not paths:
        print("no paths to check", file=sys.stderr)
        return 2
    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)
    report = run_analysis(paths, rules=rules)
    out = render_json(report) if args.format == "json" \
        else render_text(report)
    print(out)
    return 1 if report.findings else 0


if __name__ == "__main__":
    sys.exit(main())
