"""Core of the ``repro.analysis`` static checker: file loading with a
per-file AST cache, the rule registry, ``# repro: allow[...]``
suppressions, and the text/JSON reporters.

The checker is deliberately stdlib-only (``ast`` + ``re``) and import-free
with respect to the code it analyzes: rules read syntax, never execute the
tree, so it runs in milliseconds inside CI's ``static-analysis`` job with
no numpy/jax import cost.

Suppressions are line-scoped: ``# repro: allow[RPR202]`` on the flagged
line (or alone on the line directly above it) moves that finding from
``findings`` to ``suppressed``; ``allow[RPR202,RPR403]`` lists several
rules, ``allow[*]`` allows everything on that line.  Suppressed findings
still appear in the JSON report so a reviewer can audit every waiver.
"""

from __future__ import annotations

import ast
import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable

_ALLOW_RE = re.compile(r"#\s*repro:\s*allow\[([^\]]+)\]")

#: (path, mtime_ns, size) -> parsed module.  Re-running the analyzer in
#: one process (the fixture tests do, repeatedly) never re-parses a file
#: that has not changed on disk.
_AST_CACHE: dict[tuple[str, int, int], ast.Module] = {}


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str            # project-root-relative, '/'-separated
    line: int
    message: str

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message}

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"

    def sort_key(self):
        return (self.path, self.line, self.rule)


@dataclass
class SourceFile:
    """One parsed file plus its suppression table."""

    path: Path           # absolute
    rel: str             # root-relative display path
    text: str
    tree: ast.Module
    allow: dict[int, set[str]] = field(default_factory=dict)

    @property
    def parts(self) -> tuple[str, ...]:
        return Path(self.rel).parts

    def is_allowed(self, line: int, rule: str) -> bool:
        """Suppression applies on the flagged line or the line above."""
        for ln in (line, line - 1):
            ids = self.allow.get(ln)
            if ids and ("*" in ids or rule in ids):
                return True
        return False


@dataclass
class Project:
    """The analyzed tree: parsed files plus lazily built shared state
    (rules stash cross-file structures like the call graph here)."""

    root: Path
    files: list[SourceFile]
    skipped: list[Finding] = field(default_factory=list)  # parse errors
    _shared: dict = field(default_factory=dict)

    def shared(self, key: str, build: Callable[["Project"], object]):
        if key not in self._shared:
            self._shared[key] = build(self)
        return self._shared[key]


def _parse_allow(text: str) -> dict[int, set[str]]:
    allow: dict[int, set[str]] = {}
    for lineno, line in enumerate(text.splitlines(), start=1):
        for m in _ALLOW_RE.finditer(line):
            ids = {s.strip() for s in m.group(1).split(",") if s.strip()}
            allow.setdefault(lineno, set()).update(ids)
    return allow


def _load_file(path: Path, rel: str) -> SourceFile | Finding:
    text = path.read_text(encoding="utf-8", errors="replace")
    st = path.stat()
    key = (str(path), st.st_mtime_ns, st.st_size)
    tree = _AST_CACHE.get(key)
    if tree is None:
        try:
            tree = ast.parse(text, filename=str(path))
        except SyntaxError as e:
            return Finding(rule="RPR000", path=rel, line=e.lineno or 1,
                           message=f"file does not parse: {e.msg}")
        _AST_CACHE[key] = tree
    return SourceFile(path=path, rel=rel, text=text, tree=tree,
                      allow=_parse_allow(text))


def load_project(paths, *, root=None) -> Project:
    """Collect and parse every ``.py`` file under ``paths`` (files or
    directories, resolved against ``root``, default cwd)."""
    root = Path(root) if root is not None else Path.cwd()
    seen: set[Path] = set()
    files: list[SourceFile] = []
    skipped: list[Finding] = []
    for p in paths:
        p = Path(p)
        if not p.is_absolute():
            p = root / p
        candidates = ([p] if p.is_file() else
                      sorted(p.rglob("*.py")) if p.is_dir() else [])
        for f in candidates:
            if f in seen or "__pycache__" in f.parts:
                continue
            seen.add(f)
            try:
                rel = str(f.relative_to(root)).replace("\\", "/")
            except ValueError:
                rel = str(f)
            loaded = _load_file(f, rel)
            if isinstance(loaded, Finding):
                skipped.append(loaded)
            else:
                files.append(loaded)
    return Project(root=root, files=files, skipped=skipped)


# --------------------------------------------------------------------------
# rule registry
# --------------------------------------------------------------------------

#: rule id -> one-line summary (what the rule protects)
RULE_DOCS: dict[str, str] = {}

#: registered checkers; each maps Project -> list[Finding]
CHECKERS: list[Callable[[Project], list[Finding]]] = []


def checker(*rules: tuple[str, str]):
    """Register a checker implementing one or more rule ids."""
    def deco(fn):
        for rule_id, summary in rules:
            RULE_DOCS[rule_id] = summary
        CHECKERS.append(fn)
        return fn
    return deco


@dataclass
class Report:
    findings: list[Finding]
    suppressed: list[Finding]
    checked_files: int

    def to_dict(self) -> dict:
        return {
            "checked_files": self.checked_files,
            "rules": dict(sorted(RULE_DOCS.items())),
            "findings": [f.to_dict() for f in self.findings],
            "suppressed": [f.to_dict() for f in self.suppressed],
        }


def run_analysis(paths, *, rules=None, root=None) -> Report:
    """Run every registered checker over ``paths`` and split the results
    into unsuppressed findings and allow-listed ones.  ``rules`` filters
    by rule-id prefix (``["RPR2"]`` keeps the store-ordering family)."""
    project = load_project(paths, root=root)
    by_rel = {sf.rel: sf for sf in project.files}
    findings: list[Finding] = list(project.skipped)
    suppressed: list[Finding] = []
    for check in CHECKERS:
        for f in check(project):
            if rules and not any(f.rule.startswith(r) for r in rules):
                continue
            sf = by_rel.get(f.path)
            if sf is not None and sf.is_allowed(f.line, f.rule):
                suppressed.append(f)
            else:
                findings.append(f)
    findings.sort(key=Finding.sort_key)
    suppressed.sort(key=Finding.sort_key)
    return Report(findings=findings, suppressed=suppressed,
                  checked_files=len(project.files))


def render_text(report: Report) -> str:
    lines = [f.render() for f in report.findings]
    lines.append(f"{len(report.findings)} finding(s), "
                 f"{len(report.suppressed)} suppressed, "
                 f"{report.checked_files} file(s) checked")
    return "\n".join(lines)


def render_json(report: Report) -> str:
    return json.dumps(report.to_dict(), indent=1)


# --------------------------------------------------------------------------
# small AST helpers shared by the rule modules
# --------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains (through Call: the callee's
    name), else None."""
    if isinstance(node, ast.Call):
        return dotted_name(node.func)
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def receiver_hint(node: ast.AST) -> str | None:
    """The last identifier of a call receiver (``self.aligner`` ->
    ``aligner``; ``self.shards[k]`` -> ``shards``), used to resolve
    methods to classes by name affinity."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, (ast.Subscript, ast.Call)):
        return receiver_hint(node.value if isinstance(node, ast.Subscript)
                             else node.func)
    return None


def string_constants(node: ast.AST):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub.value
