"""``repro.analysis`` — stdlib-only AST invariant checker for this repo.

Rule families (run ``python -m repro.analysis --list-rules``):

* ``RPR1xx`` engine-affinity race lint (:mod:`.rules_engine`)
* ``RPR2xx`` store crash-safety ordering (:mod:`.rules_store`)
* ``RPR3xx`` Pallas kernel purity (:mod:`.rules_kernel`)
* ``RPR4xx`` deprecated API surfaces (:mod:`.rules_api`)

Importing this package registers every rule module with the framework's
checker registry; ``run_analysis`` is the one-call entry point.
"""

from .framework import (CHECKERS, RULE_DOCS, Finding, Project, Report,
                        checker, load_project, render_json, render_text,
                        run_analysis)
from . import (rules_api, rules_engine, rules_kernel,  # noqa: F401  (import registers the checkers)
               rules_store)

__all__ = [
    "CHECKERS", "RULE_DOCS", "Finding", "Project", "Report", "checker",
    "load_project", "render_json", "render_text", "run_analysis",
]
