"""RPR301/RPR302/RPR303 — Pallas kernel purity.

A Pallas kernel body executes as a trace over device references; host
NumPy, host syncs and Python control flow on traced values either crash
at trace time or silently bake one traced value into the compiled
kernel.  These rules fence the ``kernels/`` tree:

* **RPR301** — ``np.``/``numpy.`` attribute use inside a kernel body
  (use ``jnp``/``lax``/``pl`` primitives; host NumPy belongs in the
  wrapper that builds inputs).
* **RPR302** — host-sync calls inside a kernel body: ``.item()``,
  ``.tolist()``, ``.block_until_ready()``, ``device_get`` — these force
  a device round-trip that cannot exist at trace time.
* **RPR303** — Python ``if``/``while`` whose test reads a traced value
  (a ref parameter or something derived from one).  Use ``pl.when``,
  ``jnp.where`` or ``lax.cond``; Python branching on a tracer raises
  ``TracerBoolConversionError``.

Kernel bodies are found two ways: defs named ``*_kernel``, and any def
passed as the first argument of ``pl.pallas_call`` (directly or through
``functools.partial``).  Keyword-only parameters are treated as static
(this repo binds block shapes via ``partial``); positional parameters
are the traced refs.
"""

from __future__ import annotations

import ast

from .framework import Finding, Project, checker, dotted_name

RPR301 = ("RPR301",
          "host NumPy call inside a Pallas kernel body (use jnp/lax/pl)")
RPR302 = ("RPR302",
          "host sync (.item/.tolist/block_until_ready/device_get) inside "
          "a Pallas kernel body")
RPR303 = ("RPR303",
          "Python if/while on a traced value inside a Pallas kernel body "
          "(use pl.when / jnp.where / lax.cond)")

_SYNC_METHODS = frozenset({"item", "tolist", "block_until_ready"})

#: calls whose results stay traced when fed traced operands
_TRACED_PRODUCERS = ("program_id", "load", "dot", "where", "sum", "max",
                     "min", "dot_general")


def _kernel_arg_names(tree: ast.Module) -> set[str]:
    """Names passed as the kernel argument of ``pl.pallas_call`` —
    directly or wrapped in ``functools.partial(name, ...)``."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        callee = dotted_name(node.func) or ""
        if callee.rsplit(".", 1)[-1] != "pallas_call" or not node.args:
            continue
        first = node.args[0]
        if isinstance(first, ast.Call):
            inner = dotted_name(first.func) or ""
            if inner.rsplit(".", 1)[-1] == "partial" and first.args:
                first = first.args[0]
        if isinstance(first, ast.Name):
            names.add(first.id)
    return names


def _traced_names(fn) -> set[str]:
    """Positional params (the refs) plus names assigned from expressions
    that read a traced name — a one-pass forward propagation, enough for
    straight-line kernel bodies."""
    traced = {a.arg for a in fn.args.args + fn.args.posonlyargs}
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = node.value
                if value is None or not _reads_traced(value, traced):
                    continue
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Name) \
                                and leaf.id not in traced:
                            traced.add(leaf.id)
                            changed = True
    return traced


def _reads_traced(expr: ast.AST, traced: set[str]) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in traced:
            return True
        if isinstance(node, ast.Call):
            callee = dotted_name(node.func) or ""
            if callee.rsplit(".", 1)[-1] in _TRACED_PRODUCERS:
                return True
    return False


@checker(RPR301, RPR302, RPR303)
def check_kernel_purity(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for sf in project.files:
        if "kernels" not in sf.parts:
            continue
        called = _kernel_arg_names(sf.tree)
        for node in ast.walk(sf.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if not (node.name.endswith("_kernel") or node.name in called):
                continue
            findings.extend(_check_kernel(sf, node))
    return findings


def _check_kernel(sf, fn) -> list[Finding]:
    findings: list[Finding] = []
    traced = _traced_names(fn)
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute):
            base = dotted_name(node.value)
            if base in ("np", "numpy"):
                findings.append(Finding(
                    rule="RPR301", path=sf.rel, line=node.lineno,
                    message=f"{fn.name} uses host NumPy ({base}."
                            f"{node.attr}) inside a kernel body; use "
                            "jnp/lax/pl primitives"))
        if isinstance(node, ast.Call):
            callee = dotted_name(node.func) or ""
            leaf = callee.rsplit(".", 1)[-1]
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _SYNC_METHODS) \
                    or leaf == "device_get":
                findings.append(Finding(
                    rule="RPR302", path=sf.rel, line=node.lineno,
                    message=f"{fn.name} forces a host sync "
                            f"({leaf}) inside a kernel body"))
        if isinstance(node, (ast.If, ast.While)) \
                and _reads_traced(node.test, traced):
            kind = "if" if isinstance(node, ast.If) else "while"
            findings.append(Finding(
                rule="RPR303", path=sf.rel, line=node.lineno,
                message=f"{fn.name} branches with Python `{kind}` on a "
                        "traced value; use pl.when / jnp.where / "
                        "lax.cond"))
    return findings
