"""RPR201/RPR202 — store crash-safety ordering.

The on-disk store's crash-safety contract (:mod:`repro.core.store`) is
strictly ordered: array payloads land first, then the generation's
``manifest.json`` commits them (tmp + atomic rename), then the
``CURRENT`` pointer promotes the generation (tmp + atomic rename).  A
reader that follows ``CURRENT`` therefore never observes a manifest
naming missing arrays, and a crash at any point leaves the previous
generation intact.

* **RPR201** — within one function, a *commit event* (``finalize()``,
  ``promote_generation()``, or an evidence-bearing durable write/rename)
  appears on a line before an *array event* (``add_table``/``add_arena``/
  ``np.save*``).  Committing before the payload exists publishes a
  manifest that can name missing files after a crash.

* **RPR202** — outside ``src/repro/core/store.py``, a direct
  non-tmp write to a manifest/pointer path (``write_text``/
  ``write_bytes``/``open(..., "w")`` whose expression mentions
  ``manifest.json``, ``CURRENT`` or ``CURRENT_POINTER`` without a
  ``.tmp`` staging name).  Pointer files must only be produced by the
  store's tmp + rename helpers; an in-place write can be observed
  half-written.
"""

from __future__ import annotations

import ast

from .framework import (Finding, Project, checker, dotted_name,
                        string_constants)

RPR201 = ("RPR201",
          "manifest/pointer committed before the array payload it names "
          "(crash window: manifest references missing files)")
RPR202 = ("RPR202",
          "direct non-atomic write to a manifest/CURRENT path outside "
          "core/store.py (must go through tmp + rename)")

STORE_FILE = "src/repro/core/store.py"

_ARRAY_METHODS = frozenset({"add_table", "add_arena"})
_NP_SAVE = frozenset({"save", "savez", "savez_compressed"})
_WRITE_METHODS = frozenset({"write_text", "write_bytes"})


def _has_evidence(call: ast.Call) -> bool:
    """Does the call expression mention a manifest/pointer path?"""
    for s in string_constants(call):
        if "manifest.json" in s or s == "CURRENT":
            return True
    for sub in ast.walk(call):
        if isinstance(sub, ast.Name) and sub.id == "CURRENT_POINTER":
            return True
        if isinstance(sub, ast.Attribute) and sub.attr == "CURRENT_POINTER":
            return True
    return False


def _is_tmp_staged(call: ast.Call) -> bool:
    return any(".tmp" in s for s in string_constants(call))


def _durable_write(call: ast.Call) -> bool:
    """write_text/write_bytes, or open(..., mode containing 'w')."""
    name = dotted_name(call.func)
    if not name:
        return False
    leaf = name.rsplit(".", 1)[-1]
    if leaf in _WRITE_METHODS:
        return True
    if leaf == "open":
        for arg in list(call.args[1:]) + [kw.value for kw in call.keywords
                                          if kw.arg == "mode"]:
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str) \
                    and "w" in arg.value:
                return True
    return False


def _classify(call: ast.Call) -> str | None:
    """'array', 'commit', or None."""
    name = dotted_name(call.func)
    leaf = name.rsplit(".", 1)[-1] if name else ""
    if leaf in _ARRAY_METHODS:
        return "array"
    if name and leaf in _NP_SAVE and \
            name.rsplit(".", 1)[0].rsplit(".", 1)[-1] in ("np", "numpy"):
        return "array"
    if leaf in ("finalize", "promote_generation"):
        return "commit"
    if (_durable_write(call) or leaf in ("rename", "replace")) \
            and _has_evidence(call) and not _is_tmp_staged(call):
        return "commit"
    return None


@checker(RPR201, RPR202)
def check_store_ordering(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for sf in project.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings.extend(_check_function(sf, node))
        if sf.rel != STORE_FILE:
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Call) and _durable_write(node) \
                        and _has_evidence(node) and not _is_tmp_staged(node):
                    findings.append(Finding(
                        rule="RPR202", path=sf.rel, line=node.lineno,
                        message="direct write to a manifest/CURRENT path; "
                                "stage to .tmp and rename (or use the "
                                "store helpers) so readers never see a "
                                "torn pointer"))
    return findings


def _check_function(sf, fn) -> list[Finding]:
    """Flag commit events that precede an array event inside ``fn``
    (lexical line order stands in for program order — the store API is
    written straight-line)."""
    events: list[tuple[int, str]] = []
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            continue
        if isinstance(node, ast.Call):
            kind = _classify(node)
            if kind:
                events.append((node.lineno, kind))
    if not events:
        return []
    last_array = max((ln for ln, kind in events if kind == "array"),
                     default=None)
    if last_array is None:
        return []
    return [
        Finding(rule="RPR201", path=sf.rel, line=ln,
                message=f"{fn.name} commits the manifest/pointer at line "
                        f"{ln} before the array payload written at line "
                        f"{last_array}; write arrays first, then "
                        "finalize, then promote")
        for ln, kind in events if kind == "commit" and ln < last_array
    ]
