"""RPR201-RPR204 — store crash-safety ordering and fault routing.

The on-disk store's crash-safety contract (:mod:`repro.core.store`) is
strictly ordered: array payloads land first, then the generation's
``manifest.json`` commits them (tmp + atomic rename), then the
``CURRENT`` pointer promotes the generation (tmp + atomic rename).  A
reader that follows ``CURRENT`` therefore never observes a manifest
naming missing arrays, and a crash at any point leaves the previous
generation intact.

* **RPR201** — within one function, a *commit event* (``finalize()``,
  ``promote_generation()``, or an evidence-bearing durable write/rename)
  appears on a line before an *array event* (``add_table``/``add_arena``/
  ``np.save*``).  Committing before the payload exists publishes a
  manifest that can name missing files after a crash.

* **RPR202** — outside ``src/repro/core/store.py``, a direct
  non-tmp write to a manifest/pointer path (``write_text``/
  ``write_bytes``/``open(..., "w")`` whose expression mentions
  ``manifest.json``, ``CURRENT`` or ``CURRENT_POINTER`` without a
  ``.tmp`` staging name).  Pointer files must only be produced by the
  store's tmp + rename helpers; an in-place write can be observed
  half-written.

* **RPR203** — a store/checkpoint filesystem mutation that bypasses
  :mod:`repro.fault.fsio`.  The fault-injection harness can only crash,
  tear, or fail writes that route through the ``fsio`` indirection; a
  direct ``write_bytes``/``rename``/``rmtree``/``np.save`` against store
  artifacts is a blind spot the chaos soak cannot exercise.  Fires on
  any mutation inside the enforced durability modules (``core/store.py``,
  ``core/sharded_index.py``, ``train/checkpoint.py``) and, elsewhere, on
  mutations whose expression names store artifacts (``manifest.json``,
  ``CURRENT``, ``COMMITTED``, ``meta.json``, ``.npy``/``.npz``/``.pkl``).
  Deliberate-corruption fixtures waive it line-by-line with
  ``# repro: allow[RPR203]``.

* **RPR204** — an ``fsio`` call inside the WAL module
  (``src/repro/wal.py``) without a literal ``site="wal.*"`` keyword.
  The ingest-kill chaos leg records the WAL's checkpoint names from one
  clean run and replays process kills against each of them; a dynamic,
  missing, or mis-prefixed site name is a mutation the acknowledged-
  writes contract silently never exercises.
"""

from __future__ import annotations

import ast

from .framework import (Finding, Project, checker, dotted_name,
                        string_constants)

RPR201 = ("RPR201",
          "manifest/pointer committed before the array payload it names "
          "(crash window: manifest references missing files)")
RPR202 = ("RPR202",
          "direct non-atomic write to a manifest/CURRENT path outside "
          "core/store.py (must go through tmp + rename)")
RPR203 = ("RPR203",
          "store/checkpoint filesystem mutation bypasses repro.fault.fsio "
          "(fault injection cannot reach it)")
RPR204 = ("RPR204",
          "fsio call in the WAL module without a literal site=\"wal.*\" "
          "name (the ingest-kill chaos schedule cannot target it)")

STORE_FILE = "src/repro/core/store.py"
FSIO_FILE = "src/repro/fault/fsio.py"
WAL_FILE = "src/repro/wal.py"

#: modules whose durable mutations must ALL route through fsio (they
#: implement the store/checkpoint formats the chaos harness exercises)
FSIO_ENFORCED = frozenset({STORE_FILE, WAL_FILE,
                           "src/repro/core/sharded_index.py",
                           "src/repro/train/checkpoint.py"})

_ARRAY_METHODS = frozenset({"add_table", "add_arena"})
_NP_SAVE = frozenset({"save", "savez", "savez_compressed"})
_FSIO_SAVE = frozenset({"np_save", "np_savez"})
_FSIO_COMMIT = frozenset({"commit_text", "commit_bytes"})
_WRITE_METHODS = frozenset({"write_text", "write_bytes"})
_MUTATION_LEAVES = _WRITE_METHODS | frozenset(
    {"rename", "replace", "rmtree", "unlink", "truncate"})
#: substrings that mark a call as touching store/checkpoint artifacts
_STORE_ARTIFACTS = ("manifest.json", "meta.json", "COMMITTED",
                    ".npy", ".npz", ".pkl", ".wal")


def _has_evidence(call: ast.Call) -> bool:
    """Does the call expression mention a manifest/pointer path?"""
    for s in string_constants(call):
        if "manifest.json" in s or s == "CURRENT":
            return True
    for sub in ast.walk(call):
        if isinstance(sub, ast.Name) and sub.id == "CURRENT_POINTER":
            return True
        if isinstance(sub, ast.Attribute) and sub.attr == "CURRENT_POINTER":
            return True
    return False


def _is_tmp_staged(call: ast.Call) -> bool:
    return any(".tmp" in s for s in string_constants(call))


def _durable_write(call: ast.Call) -> bool:
    """write_text/write_bytes, or open(..., mode containing 'w')."""
    name = dotted_name(call.func)
    if not name:
        return False
    leaf = name.rsplit(".", 1)[-1]
    if leaf in _WRITE_METHODS:
        return True
    if leaf == "open":
        for arg in list(call.args[1:]) + [kw.value for kw in call.keywords
                                          if kw.arg == "mode"]:
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str) \
                    and ("w" in arg.value or "a" in arg.value):
                return True
    return False


def _is_fsio_call(call: ast.Call) -> bool:
    """Routed through the repro.fault.fsio indirection?"""
    name = dotted_name(call.func)
    return bool(name) and "fsio" in name.split(".")[:-1]


def _classify(call: ast.Call) -> str | None:
    """'array', 'commit', or None."""
    name = dotted_name(call.func)
    leaf = name.rsplit(".", 1)[-1] if name else ""
    if leaf in _ARRAY_METHODS or leaf in _FSIO_SAVE:
        return "array"
    if name and leaf in _NP_SAVE and \
            name.rsplit(".", 1)[0].rsplit(".", 1)[-1] in ("np", "numpy"):
        return "array"
    if leaf in ("finalize", "promote_generation"):
        return "commit"
    if leaf in _FSIO_COMMIT and _has_evidence(call):
        return "commit"
    if (_durable_write(call) or leaf in ("rename", "replace")) \
            and _has_evidence(call) and not _is_tmp_staged(call):
        return "commit"
    return None


@checker(RPR201, RPR202)
def check_store_ordering(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for sf in project.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                findings.extend(_check_function(sf, node))
        if sf.rel != STORE_FILE:
            for node in ast.walk(sf.tree):
                if isinstance(node, ast.Call) and _durable_write(node) \
                        and not _is_fsio_call(node) \
                        and _has_evidence(node) and not _is_tmp_staged(node):
                    findings.append(Finding(
                        rule="RPR202", path=sf.rel, line=node.lineno,
                        message="direct write to a manifest/CURRENT path; "
                                "stage to .tmp and rename (or use the "
                                "store helpers) so readers never see a "
                                "torn pointer"))
    return findings


def _rpr203_evidence(call: ast.Call) -> bool:
    """Does the call expression name a store/checkpoint artifact?"""
    if _has_evidence(call):            # manifest.json / CURRENT[_POINTER]
        return True
    return any(tok in s for s in string_constants(call)
               for tok in _STORE_ARTIFACTS)


def _is_store_mutation(call: ast.Call) -> bool:
    name = dotted_name(call.func)
    leaf = name.rsplit(".", 1)[-1] if name else ""
    if leaf in _MUTATION_LEAVES:
        # str.replace heuristic: two positional string-constant args is a
        # string substitution, not a filesystem rename
        if leaf == "replace" and len(call.args) == 2 and all(
                isinstance(a, ast.Constant) and isinstance(a.value, str)
                for a in call.args):
            return False
        return True
    if name and leaf in _NP_SAVE and \
            name.rsplit(".", 1)[0].rsplit(".", 1)[-1] in ("np", "numpy"):
        return True
    return _durable_write(call)


@checker(RPR203)
def check_fsio_routing(project: Project) -> list[Finding]:
    """Durable store/checkpoint mutations must route through
    :mod:`repro.fault.fsio` so fault plans can reach them."""
    findings: list[Finding] = []
    for sf in project.files:
        if sf.rel == FSIO_FILE:
            continue                   # the indirection itself
        enforced = sf.rel in FSIO_ENFORCED
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call) or _is_fsio_call(node):
                continue
            if not _is_store_mutation(node):
                continue
            if not (enforced or _rpr203_evidence(node)):
                continue
            findings.append(Finding(
                rule="RPR203", path=sf.rel, line=node.lineno,
                message="store/checkpoint mutation bypasses "
                        "repro.fault.fsio; route it through the fsio "
                        "helpers so fault plans can crash/tear/fail it "
                        "(deliberate-corruption fixtures: "
                        "# repro: allow[RPR203])"))
    return findings


@checker(RPR204)
def check_wal_sites(project: Project) -> list[Finding]:
    """Every fsio call inside the WAL module must name its checkpoint
    with a literal ``site="wal.*"`` keyword: the ingest-kill chaos leg
    records those names from one clean run and replays process kills
    against each, so a dynamic or mis-prefixed site is a durability
    mutation the acknowledged-writes contract never exercises."""
    findings: list[Finding] = []
    for sf in project.files:
        if sf.rel != WAL_FILE:
            continue
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call) or not _is_fsio_call(node):
                continue
            site = next((kw.value for kw in node.keywords
                         if kw.arg == "site"), None)
            if not (isinstance(site, ast.Constant)
                    and isinstance(site.value, str)
                    and site.value.startswith("wal.")):
                findings.append(Finding(
                    rule="RPR204", path=sf.rel, line=node.lineno,
                    message='fsio call needs a literal site="wal.*" name '
                            "so the ingest-kill chaos schedule can record "
                            "and target it"))
    return findings


def _check_function(sf, fn) -> list[Finding]:
    """Flag commit events that precede an array event inside ``fn``
    (lexical line order stands in for program order — the store API is
    written straight-line)."""
    events: list[tuple[int, str]] = []
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            continue
        if isinstance(node, ast.Call):
            kind = _classify(node)
            if kind:
                events.append((node.lineno, kind))
    if not events:
        return []
    last_array = max((ln for ln, kind in events if kind == "array"),
                     default=None)
    if last_array is None:
        return []
    return [
        Finding(rule="RPR201", path=sf.rel, line=ln,
                message=f"{fn.name} commits the manifest/pointer at line "
                        f"{ln} before the array payload written at line "
                        f"{last_array}; write arrays first, then "
                        "finalize, then promote")
        for ln, kind in events if kind == "commit" and ln < last_array
    ]
