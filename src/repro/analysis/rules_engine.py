"""RPR101 — engine-affinity race lint.

Every mutating index API is declared ``@engine_only``
(:mod:`repro.core.guard`).  Inside :mod:`repro.serve`, the ONLY
sanctioned way to reach one is to submit it to the
``DynamicBatcher`` engine (``submit_query``/``submit_control``; the
off-band ``run_offband``/``loop.run_in_executor`` dispatchers cover the
immutable-read merge).  This rule taints every project def that can
reach an engine-only function through the call graph, then flags any
call in a serve-side, non-engine context that targets a tainted def
outside a dispatcher's argument list.

Exempt contexts: defs themselves decorated ``@engine_only`` (they run on
the engine), nested defs referenced by name in a dispatcher call
(``submit_control(_seal, "seal")``), and call nodes lexically inside
dispatcher arguments (``submit_control(lambda: idx.promote_sealed(...),
"promote")``).
"""

from __future__ import annotations

import ast

from .callgraph import DefInfo, project_callgraph
from .framework import Finding, Project, checker, dotted_name

#: the sanctioned engine/off-band hand-off points
DISPATCHERS = frozenset({"submit_query", "submit_control", "submit",
                         "run_offband", "run_in_executor"})

RPR101 = ("RPR101",
          "engine-only API reached from a non-engine context in "
          "repro.serve without going through the DynamicBatcher")


def _is_dispatcher_call(node: ast.Call) -> bool:
    name = dotted_name(node.func)
    return bool(name) and name.rsplit(".", 1)[-1] in DISPATCHERS


def _body_calls(d: DefInfo) -> tuple[list[ast.Call], set[str]]:
    """Call nodes lexically belonging to ``d`` (not to nested defs, not
    inside dispatcher arguments), plus the names ``d`` passes to
    dispatchers (its dispatched nested defs)."""
    calls: list[ast.Call] = []
    dispatched_names: set[str] = set()

    def walk(node: ast.AST, top: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue                      # nested defs analyzed solo
            if isinstance(child, ast.Call) and _is_dispatcher_call(child):
                calls.append(child)           # the dispatcher call itself
                for arg in list(child.args) + \
                        [kw.value for kw in child.keywords]:
                    if isinstance(arg, ast.Name):
                        dispatched_names.add(arg.id)
                walk(child.func, False)       # receiver may contain calls
                continue                      # argument subtree is exempt
            if isinstance(child, ast.Call):
                calls.append(child)
            walk(child, False)

    walk(d.node, True)
    return calls, dispatched_names


@checker(RPR101)
def check_engine_affinity(project: Project) -> list[Finding]:
    graph = project_callgraph(project)
    body: dict[DefInfo, list[ast.Call]] = {}
    for d in graph.defs:
        calls, dispatched = _body_calls(d)
        body[d] = calls
        for name in dispatched:
            nested = graph.scoped_lookup(d.file, d, name)
            if nested is not None and nested.parent is d:
                nested.dispatched = True

    # taint fixpoint from the @engine_only seeds
    tainted = {d for d in graph.defs if d.has_decorator("engine_only")}
    changed = True
    while changed:
        changed = False
        for d in graph.defs:
            if d in tainted:
                continue
            for call in body[d]:
                if any(c in tainted for c in graph.candidates(call, d)):
                    tainted.add(d)
                    changed = True
                    break

    findings: list[Finding] = []
    for d in graph.defs:
        if "serve" not in d.file.parts:
            continue
        if _engine_context(d):
            continue
        for call in body[d]:
            hits = [c for c in graph.candidates(call, d) if c in tainted]
            if not hits:
                continue
            target = hits[0]
            root = target if target.has_decorator("engine_only") else None
            what = (f"engine-only {target.qualname}" if root
                    else f"{target.qualname}, which reaches an "
                         "engine-only API")
            findings.append(Finding(
                rule="RPR101", path=d.file.rel, line=call.lineno,
                message=f"{d.qualname} calls {what} outside the engine "
                        "thread; submit it via DynamicBatcher."
                        "submit_control/submit_query"))
    return findings


def _engine_context(d: DefInfo) -> bool:
    """True when ``d``'s body runs on the engine thread (or is handed to
    a dispatcher wholesale) — its calls need no further routing."""
    cur: DefInfo | None = d
    while cur is not None:
        if cur.has_decorator("engine_only") or cur.dispatched:
            return True
        cur = cur.parent
    return False
