"""RPR401/RPR402/RPR403/RPR404 — deprecated API surfaces.

The PR 4 API redesign consolidated query configuration into
:class:`repro.core.results.QueryOptions` and split the legacy
``AlignmentIndex`` god-object into ``IndexBuilder`` + ``SearchIndex``.
The old spellings still work through shims that emit
``DeprecationWarning`` — these rules keep new first-party code off them
so the shims can eventually be deleted:

* **RPR401** — legacy per-call query kwargs (``backend=``,
  ``probe_backend=``, ``sweep=``, ``fanout=``, ``sketches=``) on
  ``find``/``find_batch``/``batch_query`` method calls; pass
  ``options=QueryOptions(...)``.
* **RPR402** — any call using ``legacy_tuples=``; consume
  :class:`QueryResult`/:class:`Alignment` objects instead.
* **RPR403** — any mention of ``AlignmentIndex`` outside its shim module
  (``src/repro/core/index.py``); use ``IndexBuilder`` (mutable) or
  ``SearchIndex`` (frozen).
* **RPR404** — per-stage backend kwargs (``sketch_backend=``,
  ``probe_backend=``, ``sweep=``, ``sketches=``) on *any* call to
  ``query``/``batch_query``/``find``/``find_batch``, bare functions
  included.  The PR 10 execution-plan redesign folded these into
  ``QueryOptions``; pass ``options=QueryOptions(plan=..., ...)``.
  RPR401 predates the plan API and only sees method receivers — RPR404
  closes the gap for the core ``batch_query(...)`` function (the spelling
  benchmarks use), so on method calls it reports only the kwargs RPR401
  does not already cover.

Deprecation *tests* exercise these surfaces on purpose — they carry
line-scoped ``# repro: allow[...]`` waivers.
"""

from __future__ import annotations

import ast

from .framework import Finding, Project, checker

RPR401 = ("RPR401",
          "legacy query kwarg on find/find_batch/batch_query; use "
          "options=QueryOptions(...)")
RPR402 = ("RPR402",
          "legacy_tuples= is deprecated; consume QueryResult/Alignment "
          "objects")
RPR403 = ("RPR403",
          "AlignmentIndex is deprecated outside its shim; use "
          "IndexBuilder/SearchIndex")
RPR404 = ("RPR404",
          "per-stage backend kwarg on a query call; use "
          "options=QueryOptions(plan=..., ...)")

SHIM_FILE = "src/repro/core/index.py"

_QUERY_METHODS = frozenset({"find", "find_batch", "batch_query"})
_LEGACY_KWARGS = frozenset({"backend", "probe_backend", "sweep", "fanout",
                            "sketches"})
_QUERY_CALLS = frozenset({"query", "batch_query", "find", "find_batch"})
_STAGE_KWARGS = frozenset({"sketch_backend", "probe_backend", "sweep",
                           "sketches"})


@checker(RPR401, RPR402, RPR403, RPR404)
def check_api_deprecations(project: Project) -> list[Finding]:
    findings: list[Finding] = []
    for sf in project.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                kwargs = {kw.arg for kw in node.keywords if kw.arg}
                is_method = isinstance(node.func, ast.Attribute)
                callee = (node.func.attr if is_method else
                          node.func.id if isinstance(node.func, ast.Name)
                          else None)
                # method calls only: the core `query`/`batch_query`
                # *functions* take these as real parameters
                if is_method and callee in _QUERY_METHODS:
                    legacy = sorted(kwargs & _LEGACY_KWARGS)
                    if legacy:
                        findings.append(Finding(
                            rule="RPR401", path=sf.rel, line=node.lineno,
                            message=f".{callee}(..., "
                                    f"{'=, '.join(legacy)}=) uses legacy "
                                    "query kwargs; pass options="
                                    "QueryOptions(...)"))
                if callee in _QUERY_CALLS:
                    stage = kwargs & _STAGE_KWARGS
                    if is_method:
                        # RPR401 already reports these on methods
                        stage -= _LEGACY_KWARGS
                    if stage:
                        shown = sorted(stage)
                        findings.append(Finding(
                            rule="RPR404", path=sf.rel, line=node.lineno,
                            message=f"{callee}(..., {'=, '.join(shown)}=) "
                                    "passes deprecated per-stage kwargs; "
                                    "pass options=QueryOptions(plan=..., "
                                    "...)"))
                if "legacy_tuples" in kwargs:
                    findings.append(Finding(
                        rule="RPR402", path=sf.rel, line=node.lineno,
                        message="legacy_tuples= requests deprecated "
                                "tuple results; consume QueryResult/"
                                "Alignment objects"))
            if sf.rel != SHIM_FILE:
                findings.extend(_alignment_index_use(sf, node))
    return findings


def _alignment_index_use(sf, node: ast.AST) -> list[Finding]:
    hit = None
    if isinstance(node, ast.Name) and node.id == "AlignmentIndex":
        hit = node.lineno
    elif isinstance(node, ast.Attribute) and node.attr == "AlignmentIndex":
        hit = node.lineno
    elif isinstance(node, ast.ImportFrom) and any(
            a.name == "AlignmentIndex" for a in node.names):
        hit = node.lineno
    if hit is None:
        return []
    return [Finding(
        rule="RPR403", path=sf.rel, line=hit,
        message="AlignmentIndex is a deprecated shim; build with "
                "IndexBuilder and freeze() to SearchIndex")]
