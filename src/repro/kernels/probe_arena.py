"""Pallas TPU kernel: device-side probe of the fused CSR arena.

One launch binary-searches every probe key of a batch against the arena's
sorted key array (``repro.core.frozen.ProbeArena``).  Keys are uint64 on
the host but TPU VPUs have no 64-bit integer lanes, so arena and probe
keys are split into (hi, lo) uint32 halves and compared lexicographically;
the coordinate tag of the arena's "coord" mode rides along as a third
comparison word (all-zero in "packed" mode, where the coordinate already
lives in the key's top bits).

Per probe the kernel returns the leftmost arena slot whose
``(key, coord) >= (probe key, probe coord)`` — exactly the slot the host
path's ``np.searchsorted(..., side="left")`` plus duplicate-run advance
lands on — so hit detection and the CSR offsets/windows gather stay on the
host and the two probe backends are bit-for-bit identical.

Grid: one step per BP-probe block; the key arena is a single VMEM-resident
block shared by every step (per-step binary search is O(log n) gathers via
``jnp.take``).  On a real TPU deployment the arena upload is amortized
across batches by donation/caching; in interpret mode (CPU CI) the arrays
pass through as NumPy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BP = 128                       # probes per grid step (one VPU lane row)


def _lex_less(ahi, alo, atag, bhi, blo, btag):
    """(ahi, alo, atag) < (bhi, blo, btag), all uint32, elementwise."""
    return (ahi < bhi) | ((ahi == bhi) & ((alo < blo) |
                                          ((alo == blo) & (atag < btag))))


def _search_kernel(khi_ref, klo_ref, ktag_ref, qhi_ref, qlo_ref, qtag_ref,
                   pos_ref, *, n: int, iters: int):
    khi, klo, ktag = khi_ref[0, :], klo_ref[0, :], ktag_ref[0, :]
    qhi, qlo, qtag = qhi_ref[0, :], qlo_ref[0, :], qtag_ref[0, :]
    lo = jnp.zeros(qhi.shape, jnp.int32)
    hi = jnp.full(qhi.shape, n, jnp.int32)

    def body(_, carry):
        lo, hi = carry
        active = lo < hi
        mid = (lo + hi) // 2             # < hi <= n, so a safe gather index
        safe = jnp.minimum(mid, n - 1)
        less = _lex_less(jnp.take(khi, safe), jnp.take(klo, safe),
                         jnp.take(ktag, safe), qhi, qlo, qtag)
        lo = jnp.where(active & less, mid + 1, lo)
        hi = jnp.where(active & ~less, mid, hi)
        return lo, hi

    lo, _ = jax.lax.fori_loop(0, iters, body, (lo, hi))
    pos_ref[0, :] = lo


@functools.partial(jax.jit, static_argnames=("interpret",))
def _arena_search(khi, klo, ktag, qhi, qlo, qtag, *, interpret: bool = True):
    n = khi.shape[0]
    P = qhi.shape[0]
    iters = max(1, int(n).bit_length())      # floor(log2 n) + 1 halvings
    Np = max(BP, -(-n // BP) * BP)
    Pp = max(BP, -(-P // BP) * BP)
    pad_k = lambda a: jnp.pad(a, (0, Np - n))[None, :]
    pad_q = lambda a: jnp.pad(a, (0, Pp - P))[None, :]
    pos = pl.pallas_call(
        functools.partial(_search_kernel, n=n, iters=iters),
        grid=(Pp // BP,),
        in_specs=[
            pl.BlockSpec((1, Np), lambda p: (0, 0)),
            pl.BlockSpec((1, Np), lambda p: (0, 0)),
            pl.BlockSpec((1, Np), lambda p: (0, 0)),
            pl.BlockSpec((1, BP), lambda p: (0, p)),
            pl.BlockSpec((1, BP), lambda p: (0, p)),
            pl.BlockSpec((1, BP), lambda p: (0, p)),
        ],
        out_specs=pl.BlockSpec((1, BP), lambda p: (0, p)),
        out_shape=jax.ShapeDtypeStruct((1, Pp), jnp.int32),
        interpret=interpret,
    )(pad_k(khi), pad_k(klo), pad_k(ktag), pad_q(qhi), pad_q(qlo),
      pad_q(qtag))
    return pos[0, :P]


def _split_u64(a: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    a = np.ascontiguousarray(a, dtype=np.uint64)
    return ((a >> np.uint64(32)).astype(np.uint32), a.astype(np.uint32))


def arena_search(keys: np.ndarray, tags: np.ndarray, qkeys: np.ndarray,
                 qtags: np.ndarray, *, interpret: bool | None = None
                 ) -> np.ndarray:
    """Leftmost slot with (key, tag) >= (qkey, qtag) per probe, int32 (P,).

    keys (n,) u64 sorted lexicographically with tags (n,) u32 as the tie
    break; qkeys (P,) u64, qtags (P,) u32.
    """
    if len(keys) == 0:
        return np.zeros(len(qkeys), np.int32)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    khi, klo = _split_u64(keys)
    qhi, qlo = _split_u64(qkeys)
    return np.asarray(_arena_search(
        jnp.asarray(khi), jnp.asarray(klo),
        jnp.asarray(tags, dtype=jnp.uint32),
        jnp.asarray(qhi), jnp.asarray(qlo),
        jnp.asarray(qtags, dtype=jnp.uint32), interpret=bool(interpret)))
