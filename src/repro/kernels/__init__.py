from .ops import (cws_sketch, cws_sketch_batch, decode_attention_pallas,
                  flash_decode_attention, fused_selective_scan,
                  icws_hash_grid, icws_sketch, icws_sketch_batch,
                  icws_token_params, minhash_sketch, multiset_sketch,
                  selective_scan_pallas)
from .probe_arena import arena_search

__all__ = ["cws_sketch", "cws_sketch_batch", "multiset_sketch",
           "flash_decode_attention", "fused_selective_scan",
           "icws_token_params", "icws_hash_grid", "icws_sketch",
           "icws_sketch_batch", "minhash_sketch", "decode_attention_pallas",
           "selective_scan_pallas", "arena_search"]
