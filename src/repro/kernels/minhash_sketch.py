"""Pallas TPU kernel: batched multi-set min-hash sketches.

Computes, for a batch of (padded) token streams, the k-coordinate multi-set
min-hash sketch min over positions of h_k(token, occurrence-index) -- the
device-side half of the paper's pipeline (the host partitioner consumes
per-text sketches; the data-pipeline dedup filter consumes per-document
sketches at corpus scale).

Grid: (B, K/BK, N/BN); the N axis is innermost and accumulates a running
min into the (1, BK) output block.  Hashing is the 32-bit counter family
(common.py) -- TPU has no 64-bit integer VPU lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import hash32

BK, BN = 8, 128
_U32MAX = np.uint32(0xFFFFFFFF)


def _minhash_kernel(tok_ref, occ_ref, seed_ref, out_ref):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, _U32MAX, out_ref.dtype)

    toks = tok_ref[...]                     # (1, BN) i32
    occ = occ_ref[...]                      # (1, BN) i32
    seeds = seed_ref[...]                   # (1, BK) u32
    valid = toks >= 0
    h = hash32(seeds[0][:, None], toks[0][None, :].astype(jnp.uint32),
               occ[0][None, :].astype(jnp.uint32))          # (BK, BN)
    h = jnp.where(valid[0][None, :], h, _U32MAX)
    out_ref[0, :] = jnp.minimum(out_ref[0, :], jnp.min(h, axis=1))


@functools.partial(jax.jit, static_argnames=("interpret",))
def minhash_sketch(tokens, occ, seeds, *, interpret: bool = True):
    """tokens (B,N) i32 (pad=-1), occ (B,N) i32 (1-based occurrence index),
    seeds (K,) u32 -> sketches (B,K) u32."""
    B, N = tokens.shape
    K = seeds.shape[0]
    Kp, Np = -(-K // BK) * BK, -(-N // BN) * BN
    tok = jnp.pad(tokens, ((0, 0), (0, Np - N)), constant_values=-1)
    occ = jnp.pad(occ, ((0, 0), (0, Np - N)))
    sd = jnp.pad(seeds, (0, Kp - K))[None, :]
    out = pl.pallas_call(
        _minhash_kernel,
        grid=(B, Kp // BK, Np // BN),
        in_specs=[
            pl.BlockSpec((1, BN), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, BN), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, BK), lambda b, i, j: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, BK), lambda b, i, j: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, Kp), jnp.uint32),
        interpret=interpret,
    )(tok, occ, sd)
    return out[:, :K]
