"""Pallas TPU kernel: flash-decode (split-KV online-softmax) attention.

One new query token per sequence attends to a long KV cache.  Grid
(B, H, S/BS): the S axis is innermost; running (m, l, acc) statistics live
in VMEM scratch and accumulate across KV tiles, so the cache streams through
VMEM exactly once (the decode step is HBM-bandwidth-bound; see §Roofline).
GQA is folded into the k/v BlockSpec index map (h -> h // group) -- no
repeated KV is ever materialized.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BS = 128
_NEG = -1.0e30


def _decode_attn_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                        m_scr, l_scr, acc_scr):
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, _NEG, m_scr.dtype)
        l_scr[...] = jnp.zeros(l_scr.shape, l_scr.dtype)
        acc_scr[...] = jnp.zeros(acc_scr.shape, acc_scr.dtype)

    q = q_ref[0, 0, :]                                  # (D,)
    k = k_ref[0, :, 0, :]                               # (BS, D)
    v = v_ref[0, :, 0, :]
    pos = pos_ref[0]
    idx = s * BS + jax.lax.iota(jnp.int32, BS)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.sum(q[None, :].astype(jnp.float32)
                     * k.astype(jnp.float32), axis=-1) * scale
    scores = jnp.where(idx <= pos, scores, _NEG)

    m_prev = m_scr[0]
    m_new = jnp.maximum(m_prev, jnp.max(scores))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)
    l_scr[0] = l_scr[0] * alpha + jnp.sum(p)
    acc_scr[...] = acc_scr[...] * alpha + \
        jnp.sum(p[:, None] * v.astype(jnp.float32), axis=0)[None]
    m_scr[0] = m_new

    @pl.when(s == ns - 1)
    def _fin():
        o_ref[0, 0, :] = (acc_scr[0] / l_scr[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attention_pallas(q, k_cache, v_cache, pos, *,
                            interpret: bool = True):
    """q (B,H,D); k/v cache (B,S,KV,D); pos scalar i32 -> out (B,H,D)."""
    B, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    Sp = -(-S // BS) * BS
    if Sp != S:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    pos_arr = jnp.full((1,), pos, jnp.int32)
    grid = (B, H, Sp // BS)
    out = pl.pallas_call(
        _decode_attn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, s: (0,)),
            pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, BS, 1, D), lambda b, h, s: (b, s, h // G, 0)),
            pl.BlockSpec((1, BS, 1, D), lambda b, h, s: (b, s, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, k_cache, v_cache)
    return out
