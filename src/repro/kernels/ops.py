"""Public jit'd wrappers around the Pallas kernels.

`interpret` defaults to True off-TPU (the container is CPU-only; interpret
mode executes the kernel body exactly, which is what the allclose tests
validate).  On a real TPU backend pass interpret=False (or rely on the
default) to run the compiled Mosaic kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..core.icws import _token_params
from .decode_attention import decode_attention_pallas
from .icws_hash import icws_hash_grid, icws_sketch, icws_sketch_batch
from .minhash_sketch import minhash_sketch
from .ref import (decode_attention_ref, icws_sketch_ref,
                  minhash_sketch_ref, selective_scan_ref)
from .selective_scan import selective_scan_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def icws_token_params(seed: int, k: int, tokens) -> tuple:
    """Host-side stateless (r, c, beta) grids (K, T) f32 for the kernels --
    identical to the ICWS family used by the index (core/icws.py)."""
    from ..core.hashing import mix2
    seeds = mix2(np.uint64(seed), np.arange(k, dtype=np.uint64))
    r = np.empty((k, len(tokens)), np.float32)
    c = np.empty_like(r)
    b = np.empty_like(r)
    for i, s in enumerate(seeds):
        ri, ci, bi = _token_params(int(s), np.asarray(tokens))
        r[i], c[i], b[i] = ri, ci, bi
    return jnp.asarray(r), jnp.asarray(c), jnp.asarray(b)


def cws_sketch(seed: int, k: int, tokens, weights, *,
               use_pallas: bool = True, interpret: bool | None = None):
    """k-coordinate CWS sketch of one text: (argmin token id, k_int) pairs.

    tokens: distinct token ids; weights: their w(t, f) > 0.
    """
    r, c, b = icws_token_params(seed, k, tokens)
    w = jnp.asarray(weights, jnp.float32)
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        mina, argt, kint = icws_sketch(r, c, b, w, interpret=interp)
    else:
        mina, argt, kint = icws_sketch_ref(r, c, b, w)
    toks = jnp.asarray(np.asarray(tokens), jnp.int32)
    return toks[argt], kint, mina


def cws_sketch_batch(seed: int, k: int, token_lists, weight_lists, *,
                     interpret: bool | None = None):
    """CWS sketch identities for a batch of texts in ONE pallas launch.

    token_lists[b]: distinct token ids of text b; weight_lists[b]: their
    w(t, f) > 0.  Returns per-text identity lists [(token, k_int), ...] of
    length k — the sketch-coordinate format `batch_query` probes with.
    """
    B = len(token_lists)
    if B == 0:
        return []
    Tmax = max(1, max(len(t) for t in token_lists))
    r = np.empty((B, k, Tmax), np.float32)
    c = np.empty_like(r)
    be = np.empty_like(r)
    w = np.zeros((B, Tmax), np.float32)          # w<=0 masks the padding
    toks = np.zeros((B, Tmax), np.int64)
    for b, (tl, wl) in enumerate(zip(token_lists, weight_lists)):
        t = len(tl)
        rb, cb, bb = icws_token_params(seed, k, tl)
        r[b, :, :t], c[b, :, :t], be[b, :, :t] = rb, cb, bb
        r[b, :, t:] = c[b, :, t:] = be[b, :, t:] = 1.0
        w[b, :t] = np.asarray(wl, np.float32)
        toks[b, :t] = np.asarray(tl, np.int64)
    interp = _default_interpret() if interpret is None else interpret
    _mina, argt, kint = icws_sketch_batch(jnp.asarray(r), jnp.asarray(c),
                                          jnp.asarray(be), jnp.asarray(w),
                                          interpret=interp)
    argt = np.asarray(argt)
    kint = np.asarray(kint)
    return [[(int(toks[b, argt[b, i]]), int(kint[b, i])) for i in range(k)]
            for b in range(B)]


def multiset_sketch(tokens, occ, seeds, *, use_pallas: bool = True,
                    interpret: bool | None = None):
    """Batched multiset min-hash sketches (B, K) u32."""
    tokens = jnp.asarray(tokens, jnp.int32)
    occ = jnp.asarray(occ, jnp.int32)
    seeds = jnp.asarray(seeds, jnp.uint32)
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        return minhash_sketch(tokens, occ, seeds, interpret=interp)
    return minhash_sketch_ref(tokens, occ, seeds)


def flash_decode_attention(q, k_cache, v_cache, pos, *,
                           use_pallas: bool = True,
                           interpret: bool | None = None):
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        return decode_attention_pallas(q, k_cache, v_cache, pos,
                                       interpret=interp)
    return decode_attention_ref(q, k_cache, v_cache, pos)


def fused_selective_scan(dt, Bc, Cc, x, A, D, *, use_pallas: bool = True,
                         interpret: bool | None = None):
    if use_pallas:
        interp = _default_interpret() if interpret is None else interpret
        return selective_scan_pallas(dt, Bc, Cc, x, A, D, interpret=interp)
    return selective_scan_ref(dt, Bc, Cc, x, A, D)


__all__ = ["cws_sketch", "cws_sketch_batch", "multiset_sketch",
           "flash_decode_attention", "fused_selective_scan",
           "icws_token_params", "icws_hash_grid", "icws_sketch",
           "icws_sketch_batch", "minhash_sketch", "decode_attention_pallas",
           "selective_scan_pallas"]
