"""Pallas TPU kernels for ICWS (improved consistent weighted sampling).

Three kernels over the (K hash functions x T distinct tokens) grid:

* `icws_hash_grid`    -- materializes (k_int, a) for every (k, t): feeds the
  MonoActive partitioner's active-hash generation (the paper's indexing
  hot loop).
* `icws_sketch`       -- fused hash + running arg-min reduction: produces
  the k-coordinate CWS sketch of a text without materializing the grid (one
  HBM pass; this is the query/sketching fast path).
* `icws_sketch_batch` -- the same fused reduction with a leading batch grid
  axis: the sketches of a whole query batch in ONE pallas launch (the
  `batch_query` serving path).

Tiling: (BK, BT) = (8, 128) f32 blocks in VMEM -- one (sublane x lane)
register tile per step; the grid's T axis is innermost so the arg-min
accumulates sequentially into the (BK,) output block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BK, BT = 8, 128
_BIG = 3.0e38  # python literal: pallas kernels cannot capture array constants


def _hash_grid_kernel(r_ref, c_ref, b_ref, w_ref, kint_ref, a_ref):
    r = r_ref[...]
    c = c_ref[...]
    beta = b_ref[...]
    w = w_ref[...]                      # (1, BT) -- broadcast over K rows
    valid = w > 0.0
    lw = jnp.log(jnp.where(valid, w, 1.0))
    kint = jnp.floor(lw / r + beta)
    a = c * jnp.exp(-r * (kint - beta) - r)
    kint_ref[...] = jnp.where(valid, kint, 0.0).astype(jnp.int32)
    a_ref[...] = jnp.where(valid, a, _BIG)


@functools.partial(jax.jit, static_argnames=("interpret",))
def icws_hash_grid(r, c, beta, w, *, interpret: bool = True):
    """r,c,beta (K,T) f32; w (T,) f32 (w<=0 = masked) -> (kint i32, a f32)."""
    K, T = r.shape
    Kp, Tp = -(-K // BK) * BK, -(-T // BT) * BT
    pad2 = lambda x: jnp.pad(x, ((0, Kp - K), (0, Tp - T)), constant_values=1.0)
    wp = jnp.pad(w, (0, Tp - T))[None, :]
    grid = (Kp // BK, Tp // BT)
    kint, a = pl.pallas_call(
        _hash_grid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BK, BT), lambda i, j: (i, j)),
            pl.BlockSpec((BK, BT), lambda i, j: (i, j)),
            pl.BlockSpec((BK, BT), lambda i, j: (i, j)),
            pl.BlockSpec((1, BT), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((BK, BT), lambda i, j: (i, j)),
            pl.BlockSpec((BK, BT), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Kp, Tp), jnp.int32),
            jax.ShapeDtypeStruct((Kp, Tp), jnp.float32),
        ],
        interpret=interpret,
    )(pad2(r), pad2(c), pad2(beta), wp)
    return kint[:K, :T], a[:K, :T]


def _sketch_kernel(r_ref, c_ref, b_ref, w_ref,
                   mina_ref, argt_ref, kint_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        mina_ref[...] = jnp.full(mina_ref.shape, _BIG, mina_ref.dtype)
        argt_ref[...] = jnp.full(argt_ref.shape, -1, argt_ref.dtype)
        kint_ref[...] = jnp.zeros(kint_ref.shape, kint_ref.dtype)

    r = r_ref[...]
    c = c_ref[...]
    beta = b_ref[...]
    w = w_ref[...]
    valid = w > 0.0
    lw = jnp.log(jnp.where(valid, w, 1.0))
    kint = jnp.floor(lw / r + beta)
    a = jnp.where(valid, c * jnp.exp(-r * (kint - beta) - r), _BIG)

    loc = jnp.argmin(a, axis=1)                       # (BK,)
    rows = jnp.arange(a.shape[0])
    amin = a[rows, loc]
    upd = amin < mina_ref[..., 0]
    tglob = (j * BT + loc).astype(jnp.int32)
    mina_ref[..., 0] = jnp.where(upd, amin, mina_ref[..., 0])
    argt_ref[..., 0] = jnp.where(upd, tglob, argt_ref[..., 0])
    kint_ref[..., 0] = jnp.where(upd, kint[rows, loc].astype(jnp.int32),
                                 kint_ref[..., 0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def icws_sketch(r, c, beta, w, *, interpret: bool = True):
    """Fused CWS sketch: -> (min_a (K,), argmin_token (K,), k_int (K,))."""
    K, T = r.shape
    Kp, Tp = -(-K // BK) * BK, -(-T // BT) * BT
    pad2 = lambda x: jnp.pad(x, ((0, Kp - K), (0, Tp - T)), constant_values=1.0)
    wp = jnp.pad(w, (0, Tp - T))[None, :]
    grid = (Kp // BK, Tp // BT)
    mina, argt, kint = pl.pallas_call(
        _sketch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BK, BT), lambda i, j: (i, j)),
            pl.BlockSpec((BK, BT), lambda i, j: (i, j)),
            pl.BlockSpec((BK, BT), lambda i, j: (i, j)),
            pl.BlockSpec((1, BT), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((BK, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((BK, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((BK, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((Kp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Kp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(pad2(r), pad2(c), pad2(beta), wp)
    return mina[:K, 0], argt[:K, 0], kint[:K, 0]


def _sketch_batch_kernel(r_ref, c_ref, b_ref, w_ref,
                         mina_ref, argt_ref, kint_ref):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        mina_ref[...] = jnp.full(mina_ref.shape, _BIG, mina_ref.dtype)
        argt_ref[...] = jnp.full(argt_ref.shape, -1, argt_ref.dtype)
        kint_ref[...] = jnp.zeros(kint_ref.shape, kint_ref.dtype)

    r = r_ref[0]                        # (BK, BT)
    c = c_ref[0]
    beta = b_ref[0]
    w = w_ref[0]                        # (1, BT) -- broadcast over K rows
    valid = w > 0.0
    lw = jnp.log(jnp.where(valid, w, 1.0))
    kint = jnp.floor(lw / r + beta)
    a = jnp.where(valid, c * jnp.exp(-r * (kint - beta) - r), _BIG)

    loc = jnp.argmin(a, axis=1)                       # (BK,)
    rows = jnp.arange(a.shape[0])
    amin = a[rows, loc]
    upd = amin < mina_ref[0, :, 0]
    tglob = (j * BT + loc).astype(jnp.int32)
    mina_ref[0, :, 0] = jnp.where(upd, amin, mina_ref[0, :, 0])
    argt_ref[0, :, 0] = jnp.where(upd, tglob, argt_ref[0, :, 0])
    kint_ref[0, :, 0] = jnp.where(upd, kint[rows, loc].astype(jnp.int32),
                                  kint_ref[0, :, 0])


@functools.partial(jax.jit, static_argnames=("interpret",))
def icws_sketch_batch(r, c, beta, w, *, interpret: bool = True):
    """Batched fused CWS sketch, one launch for the whole query batch.

    r,c,beta (B,K,T) f32; w (B,T) f32 (w<=0 = padding mask) ->
    (min_a (B,K) f32, argmin_token (B,K) i32, k_int (B,K) i32).
    """
    B, K, T = r.shape
    Kp, Tp = -(-K // BK) * BK, -(-T // BT) * BT
    pad3 = lambda x: jnp.pad(x, ((0, 0), (0, Kp - K), (0, Tp - T)),
                             constant_values=1.0)
    wp = jnp.pad(w, ((0, 0), (0, Tp - T)))[:, None, :]
    grid = (B, Kp // BK, Tp // BT)
    mina, argt, kint = pl.pallas_call(
        _sketch_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BK, BT), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, BK, BT), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, BK, BT), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, 1, BT), lambda b, i, j: (b, 0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, BK, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Kp, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, Kp, 1), jnp.int32),
            jax.ShapeDtypeStruct((B, Kp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(pad3(r), pad3(c), pad3(beta), wp)
    return mina[:, :K, 0], argt[:, :K, 0], kint[:, :K, 0]
