"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import hash32


def icws_hash_grid_ref(r, c, beta, w):
    valid = w[None, :] > 0.0
    lw = jnp.log(jnp.where(valid, w[None, :], 1.0))
    kint = jnp.floor(lw / r + beta)
    a = c * jnp.exp(-r * (kint - beta) - r)
    return (jnp.where(valid, kint, 0.0).astype(jnp.int32),
            jnp.where(valid, a, jnp.float32(3.0e38)))


def icws_sketch_ref(r, c, beta, w):
    kint, a = icws_hash_grid_ref(r, c, beta, w)
    idx = jnp.argmin(a, axis=1)
    rows = jnp.arange(a.shape[0])
    return a[rows, idx], idx.astype(jnp.int32), kint[rows, idx]


def minhash_sketch_ref(tokens, occ, seeds):
    valid = tokens >= 0
    h = hash32(seeds[None, :, None],
               tokens[:, None, :].astype(jnp.uint32),
               occ[:, None, :].astype(jnp.uint32))       # (B,K,N)
    h = jnp.where(valid[:, None, :], h, jnp.uint32(0xFFFFFFFF))
    return jnp.min(h, axis=2)


def decode_attention_ref(q, k_cache, v_cache, pos):
    B, H, D = q.shape
    KV = k_cache.shape[2]
    k = jnp.repeat(k_cache, H // KV, axis=2)
    v = jnp.repeat(v_cache, H // KV, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    idx = jnp.arange(k.shape[1])
    s = jnp.where(idx[None, None, :] <= pos, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshd->bhd", w, v.astype(jnp.float32))
    return o.astype(q.dtype)


def selective_scan_ref(dt, Bc, Cc, x, A, D):
    B, S, di = x.shape

    def step(h, args):
        dt_t, B_t, C_t, x_t = args            # (B,di) (B,ds) (B,ds) (B,di)
        a = jnp.exp(dt_t[..., None] * A)
        h = a * h + (dt_t * x_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bds,bs->bd", h, C_t) + D * x_t
        return h, y

    h0 = jnp.zeros((B, di, A.shape[1]), jnp.float32)
    xs = (dt.swapaxes(0, 1), Bc.swapaxes(0, 1), Cc.swapaxes(0, 1),
          x.swapaxes(0, 1))
    hf, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), hf
