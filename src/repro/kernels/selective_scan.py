"""Pallas TPU kernel: fused Mamba-1 selective scan.

The CUDA selective-scan keeps per-channel states resident in SRAM while
streaming the sequence; the TPU adaptation tiles channels into VMEM blocks
(BD x d_state f32 state scratch) and streams sequence chunks HBM->VMEM.
Grid (B, di/BD, S/BS): the S axis is innermost/sequential, so the state
scratch carries across chunks -- per-step states never round-trip to HBM
(vs. the XLA associative-scan path, which materializes log-depth
(B, chunk, di, ds) tensors; see DESIGN.md §2.1 and EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BD, BS = 128, 64


def _sel_scan_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, d_ref,
                     y_ref, hout_ref, h_scr):
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        h_scr[...] = jnp.zeros(h_scr.shape, h_scr.dtype)

    A = a_ref[...]                           # (BD, ds)
    D = d_ref[...]                           # (1, BD)

    def step(t, h):
        # all-Slice indexers: jax 0.4.x interpret-mode discharge cannot mix
        # plain-int axes with a traced index (fori_loop t)
        row = lambda ref: pl.load(
            ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)))[0, 0]
        dt_t = row(dt_ref)                   # (BD,)
        x_t = row(x_ref)
        B_t = row(b_ref)                     # (ds,)
        C_t = row(c_ref)
        a = jnp.exp(dt_t[:, None] * A)       # (BD, ds)
        h = a * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y_t = jnp.sum(h * C_t[None, :], axis=1) + D[0] * x_t
        pl.store(y_ref, (pl.dslice(0, 1), pl.dslice(t, 1), slice(None)),
                 y_t[None, None, :].astype(y_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, dt_ref.shape[1], step, h_scr[...])
    h_scr[...] = h

    @pl.when(s == ns - 1)
    def _fin():
        hout_ref[0, :, :] = h


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_scan_pallas(dt, Bc, Cc, x, A, D, *, interpret: bool = True):
    """dt,x (B,S,di) f32; Bc,Cc (B,S,ds) f32; A (di,ds) f32 (negative);
    D (di,) -> (y (B,S,di) f32, h_final (B,di,ds) f32).

    Computes h_t = exp(dt_t*A) h_{t-1} + dt_t*B_t*x_t; y_t = h_t.C_t + D*x_t.
    """
    B, S, di = x.shape
    ds = A.shape[1]
    Dp = -(-di // BD) * BD
    Sp = -(-S // BS) * BS
    pad3 = lambda t: jnp.pad(t, ((0, 0), (0, Sp - S), (0, Dp - di)))
    pads = lambda t: jnp.pad(t, ((0, 0), (0, Sp - S), (0, 0)))
    A_p = jnp.pad(A, ((0, Dp - di), (0, 0)), constant_values=-1.0)
    D_p = jnp.pad(D, (0, Dp - di))[None, :]
    grid = (B, Dp // BD, Sp // BS)
    y, hf = pl.pallas_call(
        _sel_scan_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BS, BD), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, BS, ds), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((1, BS, ds), lambda b, d, s: (b, s, 0)),
            pl.BlockSpec((1, BS, BD), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((BD, ds), lambda b, d, s: (d, 0)),
            pl.BlockSpec((1, BD), lambda b, d, s: (0, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, BS, BD), lambda b, d, s: (b, s, d)),
            pl.BlockSpec((1, BD, ds), lambda b, d, s: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Dp), jnp.float32),
            jax.ShapeDtypeStruct((B, Dp, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((BD, ds), jnp.float32)],
        interpret=interpret,
    )(pad3(dt), pads(Bc), pads(Cc), pad3(x), A_p, D_p)
    return y[:, :S, :di], hf[:, :di, :]
