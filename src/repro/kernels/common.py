"""Shared in-kernel primitives (32-bit TPU-native hashing).

TPU VPUs have no 64-bit integer lanes, so device-side sketching uses a
32-bit counter-based family (murmur3 finalizer); the host-side index keeps
the paper's exact Mersenne-61 universal family.  Both implement the same
(t, x) -> hash interface; DESIGN.md §4 records the substitution.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_M1 = np.uint32(0x85EBCA6B)
_M2 = np.uint32(0xC2B2AE35)
_G = np.uint32(0x9E3779B9)
_P1 = np.uint32(0xCC9E2D51)
_P2 = np.uint32(0x1B873593)


def mix32(z):
    """murmur3 finalizer; uint32 -> uint32, bijective."""
    z = z.astype(jnp.uint32)
    z = (z ^ (z >> 16)) * _M1
    z = (z ^ (z >> 13)) * _M2
    return z ^ (z >> 16)


def hash32(seed, t, x):
    """Counter-based h(t, x) for one hash function `seed` (all uint32)."""
    a = mix32(seed.astype(jnp.uint32) ^ (t.astype(jnp.uint32) * _P1) ^ _G)
    return mix32(a ^ (x.astype(jnp.uint32) * _P2))
