"""Pallas TPU kernel: the grouped small-group plane sweep's coverage grid.

``repro.core.query._sweep_small_batch`` counts, for G small (query, text)
window groups at once, how many collided rectangles cover each cell of the
group's compressed boundary grid: a bincount scatter of ±1 corner pulses
followed by a double cumulative sum.  Scatter is the one primitive TPUs do
not do well, so the kernel computes the *same integer grid* through an
MXU-shaped identity: with ``xi_a/xi_b`` (``yi_c/yi_d``) the searchsorted-
left ranks of each rectangle's boundaries,

    count[i, j] = Σ_s w_s · [xi_a(s) ≤ i < xi_b(s)] · [yi_c(s) ≤ j < yi_d(s)]

— exactly the double-cumsummed pulse grid, but expressed as one batched
``dot_general`` of 0/1 stripe indicators (counts ≤ S ≤ 32, exact in f32).
Ranks need no sort (a rank is a count of strictly-smaller boundaries) and
the sorted boundary vectors ``xs``/``ys`` are reconstructed with a stable
position + one-hot gather, so every intermediate is integer-exact and the
kernel is bit-identical to the NumPy dispatcher by construction.

Padding follows the host normalization exactly: slots past ``sizes[g]``
become zero-width rectangles at the group's max boundary, contributing no
coverage and only duplicating existing compressed coordinates.

The kernel returns the hot mask (coverage ≥ m, zero-width x-stripes masked
cold) plus ``xs``/``ys`` — a few KB per batch — and the host extracts
maximal runs with the same code the NumPy path uses
(``repro.core.query._extract_runs``), which is what makes
``sweep="device"`` block-for-block identical to ``sweep="grouped"``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BG = 8                         # groups per grid step (f32 sublane tile)

_NEG32 = -(1 << 30)            # int32 min // 2 (the host pad sentinel)


def _ranks_kernel_helper(bounds, vals):
    """searchsorted-left of each ``vals`` entry in its row's boundary
    multiset: rank = #{boundary < value}.  bounds (BG, NX), vals (BG, P)
    -> int32 (BG, P)."""
    lt = bounds[:, :, None] < vals[:, None, :]           # (BG, NX, P)
    return jnp.sum(lt.astype(jnp.int32), axis=1)


def _sort_rows(vals):
    """Stable ascending sort of each row without lax.sort: an element's
    sorted position is (#strictly-smaller) + (#equal at earlier index);
    the position vector is a permutation, so a one-hot masked sum places
    every element exactly once.  vals int32 (BG, NX) -> (BG, NX)."""
    n = vals.shape[1]
    lt = vals[:, :, None] < vals[:, None, :]             # vals[j] < vals[i]
    eq = vals[:, :, None] == vals[:, None, :]
    j_idx = jax.lax.broadcasted_iota(jnp.int32, lt.shape, 1)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, lt.shape, 2)
    pos = jnp.sum((lt | (eq & (j_idx < i_idx))).astype(jnp.int32), axis=1)
    p_idx = jax.lax.broadcasted_iota(jnp.int32, (vals.shape[0], n, n), 2)
    onehot = pos[:, :, None] == p_idx                    # (BG, NX, NX)
    return jnp.sum(jnp.where(onehot, vals[:, :, None], 0), axis=1)


def _sweep_kernel(a_ref, b_ref, c_ref, d_ref, size_ref,
                  hot_ref, xs_ref, ys_ref, *, m: int):
    a = a_ref[...]                                       # (BG, S) int32
    b1 = b_ref[...] + 1
    c = c_ref[...]
    d1 = d_ref[...] + 1
    sizes = size_ref[...]                                # (BG, 1) int32
    slot = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    pad = slot >= sizes                                  # (BG, S)

    # host-identical padding normalization: zero-width rects at the
    # group's max exclusive boundary (duplicates an existing coordinate)
    neg = jnp.int32(_NEG32)
    bmax = jnp.max(jnp.where(pad, neg, b1), axis=1, keepdims=True)
    dmax = jnp.max(jnp.where(pad, neg, d1), axis=1, keepdims=True)
    a = jnp.where(pad, bmax, a)
    b1 = jnp.where(pad, bmax, b1)
    c = jnp.where(pad, dmax, c)
    d1 = jnp.where(pad, dmax, d1)

    bx = jnp.concatenate([a, b1], axis=1)                # (BG, NX)
    by = jnp.concatenate([c, d1], axis=1)
    xi_a = _ranks_kernel_helper(bx, a)
    xi_b = _ranks_kernel_helper(bx, b1)
    yi_c = _ranks_kernel_helper(by, c)
    yi_d = _ranks_kernel_helper(by, d1)

    # coverage as an indicator matmul (the cumsummed pulse grid, exactly)
    nx = bx.shape[1]
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (a.shape[0], a.shape[1], nx),
                                     2)
    w = jnp.where(pad, 0.0, 1.0).astype(jnp.float32)
    xind = ((xi_a[:, :, None] <= i_idx) & (i_idx < xi_b[:, :, None]))
    xind = xind.astype(jnp.float32) * w[:, :, None]
    yind = ((yi_c[:, :, None] <= i_idx) & (i_idx < yi_d[:, :, None]))
    count = jax.lax.dot_general(
        xind, yind.astype(jnp.float32),
        dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)              # (BG, NX, NX)
    count = count.astype(jnp.int32)                      # counts <= S: exact

    xs = _sort_rows(bx)
    ys = _sort_rows(by)
    # zero-width x stripes are cold (each x stripe emits its own block);
    # zero-width y stripes pass through run extraction unchanged, as on
    # the host
    nz = jnp.concatenate(
        [xs[:, 1:] > xs[:, :-1],
         jnp.zeros((xs.shape[0], 1), jnp.bool_)], axis=1)
    hot = (count >= m) & nz[:, :, None]
    hot_ref[...] = hot.astype(jnp.int32)
    xs_ref[...] = xs
    ys_ref[...] = ys


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def sweep_grid(rects, sizes, *, m: int, interpret: bool = True):
    """Coverage grids for G padded rectangle groups, one Pallas launch.

    rects int32 (G, S, 4) — (a, b, c, d) rows, slots past ``sizes[g]``
    ignored; sizes int32 (G,).  Returns (hot int32 (G, NX, NX), xs int32
    (G, NX), ys int32 (G, NX)) with NX = 2S; the host consumes
    ``hot[:, :NX-1, :NX-1]`` (stripe i spans ``xs[i]..xs[i+1]-1``).
    """
    G, S, _ = rects.shape
    NX = 2 * S
    Gp = max(BG, -(-G // BG) * BG)
    rects = jnp.pad(jnp.asarray(rects, jnp.int32),
                    ((0, Gp - G), (0, 0), (0, 0)))
    sizes = jnp.pad(jnp.asarray(sizes, jnp.int32), (0, Gp - G))[:, None]
    kern = functools.partial(_sweep_kernel, m=m)
    hot, xs, ys = pl.pallas_call(
        kern,
        grid=(Gp // BG,),
        in_specs=[
            pl.BlockSpec((BG, S), lambda g: (g, 0)),
            pl.BlockSpec((BG, S), lambda g: (g, 0)),
            pl.BlockSpec((BG, S), lambda g: (g, 0)),
            pl.BlockSpec((BG, S), lambda g: (g, 0)),
            pl.BlockSpec((BG, 1), lambda g: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BG, NX, NX), lambda g: (g, 0, 0)),
            pl.BlockSpec((BG, NX), lambda g: (g, 0)),
            pl.BlockSpec((BG, NX), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Gp, NX, NX), jnp.int32),
            jax.ShapeDtypeStruct((Gp, NX), jnp.int32),
            jax.ShapeDtypeStruct((Gp, NX), jnp.int32),
        ],
        interpret=interpret,
    )(rects[..., 0], rects[..., 1], rects[..., 2], rects[..., 3], sizes)
    return hot[:G], xs[:G], ys[:G]


def sweep_small_batch_device(arr: np.ndarray, sizes: np.ndarray, m: int, *,
                             interpret: bool | None = None
                             ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-array wrapper: (G, S, 4) rect rows -> (hot bool (G, NX-1, NX-1),
    xs (G, NX), ys (G, NX)) as NumPy, ready for ``_extract_runs``."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    hot, xs, ys = sweep_grid(jnp.asarray(arr, jnp.int32),
                             jnp.asarray(sizes, jnp.int32),
                             m=int(m), interpret=bool(interpret))
    NX = xs.shape[1]
    # cast to bool on-device: the coverage grid crosses the bus at one
    # byte per cell instead of four
    return (np.asarray(hot[:, :NX - 1, :NX - 1].astype(jnp.bool_)),
            np.asarray(xs, np.int64), np.asarray(ys, np.int64))
