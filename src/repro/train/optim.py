"""AdamW (built from scratch), global-norm clipping, LR schedules, and
optional gradient compression (bf16 accumulate with f32 error feedback).

Optimizer moments are f32 trees shaped like the parameters; in multi-pod
meshes they are additionally sharded over the `pod` axis (ZeRO-style) via
`opt_shardings` -- GSPMD then reduce-scatters gradients into the moment
layout and all-gathers updated parameters, which is exactly the
ZeRO-3-across-pods communication pattern.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_compression: str = "none"   # none | bf16


def lr_at(oc: OptConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = oc.lr * jnp.minimum(1.0, (step + 1) / max(oc.warmup_steps, 1))
    t = jnp.clip((step - oc.warmup_steps)
                 / max(oc.decay_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.min_lr_ratio + (1 - oc.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < oc.warmup_steps, warm, oc.lr * cos)


def init_opt_state(params, master: bool = False):
    """master=True: keep an f32 master copy in the optimizer so the live
    parameters can be bf16-at-rest -- halves every FSDP weight all-gather
    and stops remat from re-gathering the f32 master (§Perf cell C)."""
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    out = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }
    if master:
        out["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return out


def opt_shapedtypes(param_sds, master: bool = False):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    out = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(f32, param_sds),
        "v": jax.tree.map(f32, param_sds),
    }
    if master:
        out["master"] = jax.tree.map(f32, param_sds)
    return out


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


_NO_DECAY = ("ln", "final_ln", "bias", "bq", "bk", "bv", "dt_bias", "A_log",
             "D", "conv_b", "ln1", "ln2", "ln_inner")


def _decay_mask(params):
    def mask(path, p):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        return 0.0 if name in _NO_DECAY else 1.0
    return jax.tree_util.tree_map_with_path(mask, params)


def adamw_update(params, grads, opt, oc: OptConfig):
    """One AdamW step.  Returns (new_params, new_opt, metrics).

    With opt["master"] present, the update is applied to the f32 master and
    the live (bf16) params are refreshed from it."""
    grads, gnorm = clip_by_global_norm(grads, oc.clip_norm)
    step = opt["step"] + 1
    lr = lr_at(oc, step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    wd_mask = _decay_mask(params)
    masters = opt.get("master")

    def upd(p, g, m, v, wd, pm):
        ref = pm if pm is not None else p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * wd * ref
        new_master = ref - lr * delta
        return new_master.astype(p.dtype), m, v, new_master

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    flat_w = jax.tree.leaves(wd_mask)
    flat_pm = jax.tree.leaves(masters) if masters is not None \
        else [None] * len(flat_p)
    out = [upd(p, g, m, v, w, pm) for p, g, m, v, w, pm in
           zip(flat_p, flat_g, flat_m, flat_v, flat_w, flat_pm)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_opt = {"step": step,
               "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
               "v": jax.tree.unflatten(treedef, [o[2] for o in out])}
    if masters is not None:
        new_opt["master"] = jax.tree.unflatten(treedef,
                                               [o[3] for o in out])
    return new_p, new_opt, {"grad_norm": gnorm, "lr": lr}


def compress_grads(grads, method: str, error_buf=None):
    """Gradient compression for the cross-pod all-reduce (bf16 + error
    feedback).  Returns (compressed, new_error_buf)."""
    if method == "none":
        return grads, error_buf
    if method == "bf16":
        if error_buf is None:
            error_buf = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                     grads)
        corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                                 grads, error_buf)
        comp = jax.tree.map(lambda c: c.astype(jnp.bfloat16), corrected)
        new_err = jax.tree.map(lambda c, q: c - q.astype(jnp.float32),
                               corrected, comp)
        return comp, new_err
    raise ValueError(method)
