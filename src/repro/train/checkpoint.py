"""Sharded numpy checkpoints with atomic commit, auto-resume, and elastic
restore (a checkpoint written on one mesh restores onto another).

Layout:   <root>/step_<N>/
              shard_<i>.npz     -- flat {path -> local array block} per host
              manifest.json     -- global shapes, dtypes, shard boxes, mesh
          <root>/step_<N>/COMMITTED   -- written last (atomic marker)

On restore we reassemble global arrays from shard boxes and re-slice for the
current mesh -- so a (2,16,16)-mesh checkpoint restores onto (16,16) or a
CPU test mesh (elastic re-scale), and a missing final step (no COMMITTED
marker) is skipped automatically (fault tolerance).
"""

from __future__ import annotations

import json
import threading
from pathlib import Path

import jax
import numpy as np

from ..fault import fsio


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = v
    return root


def save_checkpoint(root: str | Path, step: int, tree, *,
                    keep: int = 3, async_: bool = False):
    """Save a pytree of (possibly sharded) jax arrays.

    Each array is written as the set of its addressable shards with index
    boxes -- on a real multi-host pod every host writes only its shards;
    here one process owns all of them.
    """
    root = Path(root)
    dest = root / f"step_{step:08d}"

    shards: dict[str, np.ndarray] = {}
    manifest = {"step": step, "arrays": {}}
    flat = _flatten(tree)
    for path, arr in flat.items():
        arr = jax.device_get(arr) if not hasattr(arr, "addressable_shards") \
            else arr
        if hasattr(arr, "addressable_shards"):
            boxes = []
            for i, sh in enumerate(arr.addressable_shards):
                idx = sh.index
                box = [[(s.start or 0),
                        (s.stop if s.stop is not None else arr.shape[d])]
                       for d, s in enumerate(idx)]
                key = f"{path}@{i}"
                shards[key] = np.asarray(sh.data)
                boxes.append({"key": key, "box": box})
            manifest["arrays"][path] = {
                "shape": list(arr.shape), "dtype": str(arr.dtype),
                "boxes": boxes}
        else:
            a = np.asarray(arr)
            shards[f"{path}@0"] = a
            manifest["arrays"][path] = {
                "shape": list(a.shape), "dtype": str(a.dtype),
                "boxes": [{"key": f"{path}@0",
                           "box": [[0, s] for s in a.shape]}]}

    def _write():
        dest.mkdir(parents=True, exist_ok=True)
        fsio.np_savez(dest / "shard_0.npz", site="ckpt.shards", **shards)
        # manifest lands via tmp + rename so a crash mid-write can never
        # leave a torn manifest next to a COMMITTED marker
        fsio.commit_text(dest / "manifest.json", json.dumps(manifest),
                         site="ckpt.manifest")
        fsio.write_text(dest / "COMMITTED", "ok",
                        site="ckpt.committed")         # atomic marker
        _gc(root, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(root: Path, keep: int):
    steps = sorted(p for p in root.glob("step_*") if (p / "COMMITTED").exists())
    for p in steps[:-keep]:
        # retire the marker first so a crash mid-rmtree leaves an
        # uncommitted (skipped) step, never a half-valid one
        fsio.unlink(p / "COMMITTED", site="ckpt.gc.retire", missing_ok=True)
        fsio.rmtree(p, site="ckpt.gc", ignore_errors=True)


def latest_step(root: str | Path) -> int | None:
    root = Path(root)
    if not root.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in root.glob("step_*")
             if (p / "COMMITTED").exists()]
    return max(steps) if steps else None


def restore_checkpoint(root: str | Path, step: int, *, shardings=None):
    """Reassemble global arrays; if `shardings` (a matching pytree) is given,
    device_put each array with it (elastic re-shard onto the current mesh)."""
    dest = Path(root) / f"step_{step:08d}"
    if not (dest / "COMMITTED").exists():
        raise FileNotFoundError(f"no committed checkpoint at {dest}")
    manifest = json.loads((dest / "manifest.json").read_text())
    with np.load(dest / "shard_0.npz") as z:
        flat = {}
        for path, info in manifest["arrays"].items():
            out = np.zeros(info["shape"], dtype=np.dtype(info["dtype"]))
            for b in info["boxes"]:
                sl = tuple(slice(lo, hi) for lo, hi in b["box"])
                out[sl] = z[b["key"]]
            flat[path] = out
    tree = _unflatten(flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["step"]
