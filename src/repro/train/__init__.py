from .optim import (OptConfig, adamw_update, clip_by_global_norm,
                    compress_grads, global_norm, init_opt_state, lr_at,
                    opt_shapedtypes)
from .steps import make_prefill_step, make_serve_step, make_train_step

__all__ = ["OptConfig", "adamw_update", "init_opt_state", "opt_shapedtypes",
           "lr_at", "global_norm", "clip_by_global_norm", "compress_grads",
           "make_train_step", "make_prefill_step", "make_serve_step"]
