"""The training driver: data -> (dedup) -> sharded train steps ->
checkpoint/resume, with straggler mitigation hooks."""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax

from ..data.corpus import make_training_data
from ..data.dedup import DedupFilter
from ..models import RunFlags, init_params
from ..models.config import ModelConfig
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from .optim import OptConfig, init_opt_state
from .steps import make_train_step


@dataclass
class TrainerConfig:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 128
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    microbatches: int = 1
    dedup_theta: float = 0.0          # 0 = dedup off
    n_docs: int = 2000
    seed: int = 0
    # straggler mitigation: max seconds to wait for a step before the
    # controller flags the host (simulated on CPU; on a real pod this wires
    # to the coordination-service barrier timeout)
    step_timeout_s: float = 0.0


@dataclass
class Trainer:
    cfg: ModelConfig
    tcfg: TrainerConfig = field(default_factory=TrainerConfig)
    ocfg: OptConfig = field(default_factory=lambda: OptConfig(
        warmup_steps=10, decay_steps=1000))
    flags: RunFlags = field(default_factory=lambda: RunFlags(
        moe_mode="dense", remat_policy="none", q_chunk=0, scan_chunk=64))
    mesh: object = None

    def run(self, *, resume: bool = True) -> dict:
        t = self.tcfg
        dedup = DedupFilter(theta=t.dedup_theta) if t.dedup_theta else None
        data, dstats = make_training_data(
            t.n_docs, t.seq_len, vocab=self.cfg.vocab, seed=t.seed,
            dedup=dedup)
        params = init_params(self.cfg, jax.random.PRNGKey(t.seed))
        opt = init_opt_state(params)
        start = 0
        if resume and t.ckpt_dir and (ls := latest_step(t.ckpt_dir)) is not None:
            state, start = restore_checkpoint(t.ckpt_dir, ls)
            params, opt = state["params"], state["opt"]
            opt["step"] = jax.numpy.asarray(opt["step"], jax.numpy.int32)

        step_fn = jax.jit(make_train_step(
            self.cfg, self.ocfg, self.mesh, self.flags, t.microbatches),
            donate_argnums=(0, 1))
        it = data.batches(t.batch_size, seed=t.seed)
        losses, slow_steps = [], 0
        t0 = time.time()
        for step in range(start, t.steps):
            s0 = time.time()
            batch = next(it)
            params, opt, metrics = step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if t.step_timeout_s and (time.time() - s0) > t.step_timeout_s:
                slow_steps += 1          # straggler flag (see TrainerConfig)
            if t.log_every and (step + 1) % t.log_every == 0:
                print(f"step {step+1:5d} loss {loss:7.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f}", flush=True)
            if t.ckpt_dir and t.ckpt_every and (step + 1) % t.ckpt_every == 0:
                save_checkpoint(t.ckpt_dir, step + 1,
                                {"params": params, "opt": opt})
        return {
            "final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "losses": losses,
            "steps": len(losses),
            "wall_s": time.time() - t0,
            "slow_steps": slow_steps,
            "data": dstats,
            "dedup": dedup.stats if dedup else None,
            "params": params,
            "opt": opt,
        }
