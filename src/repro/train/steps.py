"""jit-able train / prefill / serve steps shared by the trainer, the serving
path, and the multi-pod dry-run."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models import RunFlags, decode_step, lm_loss, prefill
from ..models.config import ModelConfig
from .optim import OptConfig, adamw_update


def _split_microbatches(batch, m: int):
    def sp(x):
        return x.reshape(m, x.shape[0] // m, *x.shape[1:])
    return jax.tree.map(sp, batch)


def make_train_step(cfg: ModelConfig, oc: OptConfig, mesh=None,
                    flags: RunFlags = RunFlags(), microbatches: int = 1):
    """(params, opt, batch) -> (params, opt, metrics).  Gradient accumulation
    over `microbatches` runs as a lax.scan (activations live for one
    microbatch at a time).

    The accumulator is explicitly constrained to the parameter sharding:
    unconstrained, GSPMD kept it replicated and emitted a full all-reduce
    per microbatch (9.7 TB/device on llama3-405b train_4k); constrained, the
    per-microbatch reduction is a reduce-scatter into the FSDP shard
    (EXPERIMENTS.md §Perf cell C)."""

    grad_shardings = None
    if mesh is not None:
        from jax.sharding import NamedSharding
        from ..models.params import abstract_params
        from ..sharding import tree_specs
        grad_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            tree_specs(abstract_params(cfg), mesh))

    def _constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def loss_fn(params, mb):
        return lm_loss(params, cfg, mb, mesh, flags)

    def train_step(params, opt, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _constrain_grads(grads)
        else:
            mbs = _split_microbatches(batch, microbatches)

            def accum(carry, mb):
                loss_sum, gsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (loss_sum + l, _constrain_grads(gsum)), None

            zeros = _constrain_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss_sum, grads), _ = jax.lax.scan(
                accum, (jnp.zeros((), jnp.float32), zeros), mbs)
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt, metrics = adamw_update(params, grads, opt, oc)
        metrics["loss"] = loss
        return params, opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh=None,
                      flags: RunFlags = RunFlags(),
                      max_seq: int | None = None):
    def prefill_step(params, batch):
        return prefill(params, cfg, tokens=batch.get("tokens"),
                       embeds=batch.get("embeds"), max_seq=max_seq,
                       mesh=mesh, flags=flags)
    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh=None,
                    flags: RunFlags = RunFlags()):
    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, cfg, mesh=mesh,
                           flags=flags)
    return serve_step
