"""Segmented write-ahead log for live ingest durability.

Every ``LiveIndex.add_text`` lands here *before* it is indexed: a crash
between the acknowledged write and the next compaction no longer loses
the document — reopening the store replays the un-compacted records into
a fresh delta (idempotent: replay skips gids the serving manifest's
``doc_map`` already covers, and whole-log replay twice equals once).

Layout, under the store root::

    store/
      wal/
        00000000000000000000.wal    segment named by its first record's LSN
        00000000000000000137.wal    ...

Each segment starts with an 8-byte magic header and then CRC32-framed
records::

    u32 payload_len | u32 crc32(payload) | payload
    payload = i64 gid | i32 request_id_len (-1: none) | u32 ntokens
              | request_id utf-8 | ntokens * i64 tokens

LSNs are implicit: the segment name carries the base, frames count up
from it — so the chain is self-describing and a missing middle segment
is detectable as a base/frame-count mismatch.

Durability policy (``WalConfig``):

* ``fsync_every_n=1`` — per-record fsync (safest, slowest);
* ``fsync_every_n=N`` — group commit: ``maybe_sync`` fsyncs once every N
  appends (the serve path instead sets 0 and calls ``sync()`` once per
  batcher micro-batch, so the batcher's linger window IS the group-commit
  window and one fsync covers the whole group);
* ``fsync_every_n=0`` — async: never auto-fsync; only explicit ``sync()``
  barriers (seal/close) hit the disk.

Crash model: the fault harness kills with ``os._exit``, which cannot lose
OS page cache — a completed (flushed) ``write(2)`` survives.  "Durable"
therefore means *the frame is complete on the OS side*; ``fsync`` is the
extra barrier for power-loss-grade durability and for the acknowledged-
writes contract the serve path exposes.  A kill mid-``write`` leaves a
torn trailing frame, which replay truncates away (``wal.truncate.tail``)
— only ever an un-acknowledged record.

Truncation: after a compaction promotes a generation whose manifest
records ``wal_watermark = W``, every segment wholly below ``W`` is
removed (``truncate_upto``), ascending — a crash mid-truncate leaves a
removed *prefix*, never a mid-chain gap.  Rollback (``unseal_delta``)
touches no segments.

Every durable mutation routes through :mod:`repro.fault.fsio` with a
site literal under the ``wal.`` prefix (``wal.append``, ``wal.fsync``,
``wal.rotate``, ``wal.truncate.tail``, ``wal.truncate.segment``) —
machine-checked by static-analysis rule RPR204 — so ingest chaos
schedules can kill either side of every WAL write.
"""

from __future__ import annotations

import struct
import time
from dataclasses import dataclass
from pathlib import Path
from zlib import crc32

import numpy as np

from .fault import fsio

WAL_DIR = "wal"
SEGMENT_SUFFIX = ".wal"
_HEADER = b"MWAL\x01\x00\x00\x00"
_FRAME = struct.Struct("<II")       # payload length, crc32(payload)
_RECORD = struct.Struct("<qiI")     # gid, request-id length (-1: none), ntokens
_MAX_PAYLOAD = 1 << 28              # sanity bound: a longer length field is
#                                     garbage (torn/overwritten), not a frame


class WalError(RuntimeError):
    """Structural WAL corruption that replay cannot repair (mid-chain
    torn frames, segment gaps, foreign files) — torn *tails* are normal
    crash debris and are repaired, never raised."""


@dataclass(frozen=True)
class WalConfig:
    """Durability policy knobs (see the module docstring's table)."""

    fsync_every_n: int = 1          # 0: async (explicit sync() only)
    segment_bytes: int = 4 << 20    # rotate when the active segment exceeds


@dataclass(frozen=True)
class WalRecord:
    lsn: int
    gid: int
    request_id: str | None
    tokens: np.ndarray


def wal_dir(store_root) -> Path:
    return Path(store_root) / WAL_DIR


def _segment_name(base_lsn: int) -> str:
    return f"{base_lsn:020d}{SEGMENT_SUFFIX}"


def segment_paths(waldir) -> list[Path]:
    """The segment chain in LSN order (foreign files ignored)."""
    d = Path(waldir)
    if not d.is_dir():
        return []
    return sorted(p for p in d.iterdir()
                  if p.suffix == SEGMENT_SUFFIX and p.stem.isdigit())


def _encode(gid: int, request_id: str | None, tokens) -> bytes:
    rid = b"" if request_id is None else request_id.encode("utf-8")
    toks = np.ascontiguousarray(tokens, dtype=np.int64)
    payload = (_RECORD.pack(int(gid),
                            -1 if request_id is None else len(rid),
                            len(toks))
               + rid + toks.tobytes())
    return _FRAME.pack(len(payload), crc32(payload) & 0xFFFFFFFF) + payload


def _decode(payload: bytes, lsn: int) -> WalRecord:
    gid, rid_len, ntok = _RECORD.unpack_from(payload, 0)
    off = _RECORD.size
    rid = None
    if rid_len >= 0:
        rid = payload[off:off + rid_len].decode("utf-8")
        off += rid_len
    tokens = np.frombuffer(payload, np.int64, count=ntok, offset=off).copy()
    return WalRecord(lsn=lsn, gid=int(gid), request_id=rid, tokens=tokens)


def _scan_segment(path) -> dict:
    """Parse one segment: how many complete CRC-valid frames it holds,
    where the valid prefix ends, and whether a torn tail follows."""
    data = Path(path).read_bytes()
    if len(data) < len(_HEADER):
        return {"count": 0, "valid_size": 0, "torn": True, "error": None,
                "size": len(data)}
    if data[:len(_HEADER)] != _HEADER:
        return {"count": 0, "valid_size": 0, "torn": False,
                "error": "bad segment header", "size": len(data)}
    off, n = len(_HEADER), 0
    while off + _FRAME.size <= len(data):
        length, crc = _FRAME.unpack_from(data, off)
        end = off + _FRAME.size + length
        if length > _MAX_PAYLOAD or end > len(data):
            break
        if crc32(data[off + _FRAME.size:end]) & 0xFFFFFFFF != crc:
            break
        n += 1
        off = end
    return {"count": n, "valid_size": off, "torn": off < len(data),
            "error": None, "size": len(data)}


def iter_records(waldir):
    """Read-only scan of every complete frame in LSN order; torn tails
    are tolerated (stopped at), never repaired — safe for an observer
    process while a writer is live."""
    for path in segment_paths(waldir):
        base = int(path.stem)
        data = path.read_bytes()
        if len(data) < len(_HEADER) or data[:len(_HEADER)] != _HEADER:
            continue
        off, i = len(_HEADER), 0
        while off + _FRAME.size <= len(data):
            length, crc = _FRAME.unpack_from(data, off)
            end = off + _FRAME.size + length
            if length > _MAX_PAYLOAD or end > len(data):
                break
            payload = data[off + _FRAME.size:end]
            if crc32(payload) & 0xFFFFFFFF != crc:
                break
            yield _decode(payload, base + i)
            i += 1
            off = end


class WriteAheadLog:
    """The writer side: open (repairing crash debris), append, group-
    commit fsync, rotate, truncate.  One writer per store — the engine
    thread owns it, like the ``LiveIndex`` it fronts."""

    def __init__(self, waldir, *, config: WalConfig | None = None,
                 start_lsn: int = 0):
        """Open the log at ``waldir``, repairing any torn tail left by a
        crash.  ``start_lsn`` seeds numbering for an empty log (the
        serving manifest's watermark), so LSNs stay monotone across a
        full truncation."""
        self.dir = Path(waldir)
        self.config = config or WalConfig()
        self.dir.mkdir(parents=True, exist_ok=True)
        self._f = None                    # active segment handle (lazy)
        self._size = 0                    # its current byte length
        self._dirty = False               # bytes appended since last fsync
        self._poisoned: str | None = None
        self._born: float | None = None   # monotonic ts: oldest pending rec
        self.counters = {"appends": 0, "fsyncs": 0, "rotations": 0,
                         "truncated_segments": 0, "tail_repairs": 0}
        self._catalog: list[tuple[int, Path]] = []   # (base_lsn, path) asc
        next_lsn = int(start_lsn)
        segs = segment_paths(self.dir)
        expected = None
        for i, path in enumerate(segs):
            base = int(path.stem)
            last = i == len(segs) - 1
            if expected is not None and base != expected:
                raise WalError(f"{path}: segment gap (expected base lsn "
                               f"{expected}, got {base})")
            scan = _scan_segment(path)
            if scan["error"] or (scan["torn"] and not last):
                raise WalError(f"{path}: "
                               f"{scan['error'] or 'torn frame mid-chain'}")
            if scan["torn"]:
                # crash debris: an incomplete trailing frame (never
                # acknowledged) — truncate it away; a file too short to
                # even hold the header carries no records at all
                if scan["valid_size"] < len(_HEADER):
                    fsio.unlink(path, site="wal.truncate.tail")
                    self.counters["tail_repairs"] += 1
                    expected = base
                    continue
                fsio.truncate(path, scan["valid_size"],
                              site="wal.truncate.tail")
                self.counters["tail_repairs"] += 1
            self._catalog.append((base, path))
            expected = base + scan["count"]
        if expected is not None:
            next_lsn = max(next_lsn, expected)
        self._next_lsn = next_lsn
        self._durable_lsn = next_lsn      # what's on disk survived a crash
        if self._catalog:
            self._born = time.monotonic()

    # -- positions ----------------------------------------------------------

    @property
    def next_lsn(self) -> int:
        """LSN the next append will get (exclusive end of the log)."""
        return self._next_lsn

    @property
    def durable_lsn(self) -> int:
        """Exclusive upper bound of fsync-covered records."""
        return self._durable_lsn

    @property
    def pending_records(self) -> int:
        """Appended but not yet fsync-covered."""
        return self._next_lsn - self._durable_lsn

    @property
    def age_s(self) -> float:
        """Seconds since the oldest record not yet folded into a promoted
        generation (0.0 when fully truncated) — the supervisor's WAL-age
        compaction trigger."""
        return 0.0 if self._born is None else time.monotonic() - self._born

    def size_bytes(self) -> int:
        total = 0
        for _, p in list(self._catalog):
            try:
                total += p.stat().st_size
            except OSError:
                pass
        return total

    def stats(self) -> dict:
        return {"segments": len(self._catalog), "bytes": self.size_bytes(),
                "next_lsn": self._next_lsn, "durable_lsn": self._durable_lsn,
                "pending": self.pending_records, **self.counters}

    # -- the write path -----------------------------------------------------

    def _start_segment(self) -> None:
        path = self.dir / _segment_name(self._next_lsn)
        self._f = fsio.open_append(path, site="wal.rotate")
        if self._f.tell() < len(_HEADER):
            fsio.append_bytes(self._f, _HEADER, site="wal.rotate")
        self._size = self._f.tell()
        self._catalog.append((self._next_lsn, path))
        self.counters["rotations"] += 1

    def _open_tail(self) -> None:
        if not self._catalog:
            self._start_segment()
            return
        _, path = self._catalog[-1]
        self._f = fsio.open_append(path, site="wal.rotate")
        self._size = self._f.tell()

    def append(self, gid: int, request_id: str | None, tokens) -> int:
        """Frame and append one record; returns its LSN.  NOT yet durable
        — pair with :meth:`maybe_sync`/:meth:`sync` before acknowledging.
        A torn/failed append truncates the partial frame back off; if even
        that repair fails the log poisons itself (appends raise) until a
        reopen replays and repairs it."""
        if self._poisoned is not None:
            raise WalError("write-ahead log poisoned after a failed tail "
                           f"repair ({self._poisoned}); reopen the store "
                           "to replay and repair")
        frame = _encode(gid, request_id, tokens)
        if self._f is None:
            self._open_tail()
        if self._size + len(frame) > self.config.segment_bytes \
                and self._size > len(_HEADER):
            self._roll()
        pos = self._size
        try:
            fsio.append_bytes(self._f, frame, site="wal.append")
        except BaseException as exc:
            self._repair_tail(pos, exc)
            raise
        self._size = pos + len(frame)
        lsn = self._next_lsn
        self._next_lsn = lsn + 1
        self._dirty = True
        self.counters["appends"] += 1
        if self._born is None:
            self._born = time.monotonic()
        return lsn

    def _roll(self) -> None:
        """Finish the active segment (fsync'd so the chain never loses a
        closed segment's tail) and start the next one at the current LSN."""
        self.sync()
        self._f.close()
        self._f = None
        self._start_segment()

    def _repair_tail(self, pos: int, cause: BaseException) -> None:
        try:
            fsio.truncate(self._f, pos, site="wal.truncate.tail")
            self._size = pos
            self.counters["tail_repairs"] += 1
        except BaseException as exc:
            self._poisoned = f"{type(cause).__name__} then {type(exc).__name__}"

    def sync(self) -> int:
        """The durability barrier: fsync the active segment (no-op when
        nothing was appended since the last one).  Returns the new
        ``durable_lsn`` — every record below it survives power loss."""
        if self._f is not None and self._dirty:
            fsio.fsync(self._f, site="wal.fsync")
            self.counters["fsyncs"] += 1
            self._dirty = False
        self._durable_lsn = self._next_lsn
        return self._durable_lsn

    def maybe_sync(self) -> int:
        """Group-commit policy point: sync iff ``fsync_every_n`` appends
        accumulated (0 = async, never)."""
        n = self.config.fsync_every_n
        if n > 0 and self.pending_records >= n:
            self.sync()
        return self._durable_lsn

    # -- replay + truncation ------------------------------------------------

    def records(self):
        """Every durable record on disk in LSN order (the replay input;
        call before the first append)."""
        return iter_records(self.dir)

    def truncate_upto(self, watermark: int) -> int:
        """Drop whole segments wholly below ``watermark`` (their records
        are covered by a promoted generation's manifest).  The active tail
        segment is never removed — at most one segment of covered debris
        survives, and it keeps LSN numbering continuous.  Removal is
        ascending, so a crash mid-way leaves a removed prefix, never a
        mid-chain gap."""
        removed = 0
        while len(self._catalog) > 1 and self._catalog[1][0] <= watermark:
            _, path = self._catalog.pop(0)
            fsio.unlink(path, site="wal.truncate.segment", missing_ok=True)
            removed += 1
        self.counters["truncated_segments"] += removed
        self._born = None if watermark >= self._next_lsn else time.monotonic()
        return removed

    def close(self) -> None:
        if self._f is not None:
            self.sync()
            self._f.close()
            self._f = None


# --------------------------------------------------------------------------
# fsck integration
# --------------------------------------------------------------------------

def verify_wal(store_root, *, serving_watermark: int | None = None) -> dict:
    """Integrity-check the WAL chain under ``store_root`` (absent = ok).

    Problems: bad headers, torn/CRC-failing frames anywhere but the final
    tail, base-LSN gaps between segments, and watermark inconsistency —
    the chain starting *after* the serving manifest's ``wal_watermark``
    (an un-replayable gap: acknowledged writes lost), or the watermark
    pointing past the end of the chain (the manifest covers records that
    never became durable).  A torn tail on the LAST segment is expected
    crash debris (replay repairs it) and is reported but not a failure.
    """
    waldir = wal_dir(store_root)
    out = {"present": waldir.is_dir(), "segments": 0, "records": 0,
           "bytes": 0, "torn_tail": False, "first_lsn": None,
           "end_lsn": None, "problems": [], "ok": True}
    if not out["present"]:
        return out
    segs = segment_paths(waldir)
    expected = None
    for i, path in enumerate(segs):
        base = int(path.stem)
        last = i == len(segs) - 1
        scan = _scan_segment(path)
        out["segments"] += 1
        out["records"] += scan["count"]
        out["bytes"] += scan["size"]
        if out["first_lsn"] is None:
            out["first_lsn"] = base
        if expected is not None and base != expected:
            out["problems"].append(
                f"{path.name}: segment gap (expected base lsn {expected})")
        if scan["error"]:
            out["problems"].append(f"{path.name}: {scan['error']}")
        elif scan["torn"]:
            if last:
                out["torn_tail"] = True
            else:
                out["problems"].append(
                    f"{path.name}: torn/CRC-failing frame mid-chain")
        expected = base + scan["count"]
        out["end_lsn"] = expected
    if serving_watermark is not None and segs:
        if out["first_lsn"] > serving_watermark:
            out["problems"].append(
                f"chain starts at lsn {out['first_lsn']} but the serving "
                f"manifest's wal_watermark is {serving_watermark}: records "
                "in the replay window are gone")
        if serving_watermark > out["end_lsn"]:
            out["problems"].append(
                f"serving manifest's wal_watermark {serving_watermark} is "
                f"past the end of the chain ({out['end_lsn']}): the "
                "manifest covers records that were never durable")
    out["ok"] = not out["problems"]
    return out
