"""Columnar build pipeline: partition -> CSR without dict tables.

The paper's headline result is index *construction* speed, and the dict
:class:`~repro.core.builder.IndexBuilder` pays for its incrementality on
every window: a boxed ``(tid, a, b, c, d)`` tuple, an ``int()`` coercion
per coordinate, and a ``setdefault().append()`` per posting — then
``freeze()`` re-walks every dict to build the CSR serving arrays.

``ColumnarBuilder`` never materializes a dict.  Per text it runs the
vectorized columnar key generation (``scheme.key_columns`` — identities
stay NumPy arrays, no per-gid Python objects), partitions, and appends the
``Partition``'s already-columnar ``(key, tid, a, b, c, d)`` arrays into
chunked per-table append buffers.  ``freeze()`` turns each table's buffers
into a :class:`~repro.core.frozen.FrozenTable` with ONE global stable sort
(``FrozenTable.from_packed_columns``) and can feed the fused
:class:`~repro.core.frozen.ProbeArena` directly from the same window
columns (``arena=True``) — the intermediate per-table regroup of
``ProbeArena.from_tables`` is skipped.  Both outputs are block-identical
to the dict pipeline's (asserted in ``tests/test_columnar_build.py`` and
gated by the ``columnar_freeze_block_identical`` bench claim).

``freeze_to_store(path)`` is the streaming variant: each table's ``.npy``
files are written the moment its columns are finalized and the buffers are
released, so the peak footprint never holds all k frozen tables *and* the
build buffers; the returned :class:`~repro.core.search.SearchIndex` serves
straight from the mmap'd store.

``_shard_build_payload`` is the process-pool worker used by
``ShardedAlignmentIndex.build(fanout="process")`` — the columnar path is
NumPy-heavy rather than dict-mutation-bound, so shards parallelize across
processes (schemes travel as JSON ``scheme_spec``; weight closures don't
pickle).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

from .builder import _METHODS
from .frozen import (KIND_EMPTY, KIND_INT, KIND_PAIR, FrozenTable,
                     ProbeArena, pack_ident_columns)
from .keys import occurrence_lists
from .search import SearchIndex


@dataclass
class _TableColumns:
    """Chunked append buffers for one inverted table's window columns."""

    kind: str = KIND_EMPTY
    idents: list = field(default_factory=list)   # per-text identity chunks
    windows: list = field(default_factory=list)  # per-text int32 (n, 5)

    def append(self, ident: np.ndarray, windows: np.ndarray) -> None:
        if self.kind == KIND_EMPTY:
            self.kind = KIND_PAIR if ident.ndim == 2 else KIND_INT
        self.idents.append(ident)
        self.windows.append(windows)

    def concat(self) -> tuple[np.ndarray, np.ndarray]:
        if not self.windows:
            return np.empty(0, np.uint64), np.empty((0, 5), np.int32)
        return np.concatenate(self.idents), np.concatenate(self.windows)

    def packed(self) -> tuple[np.ndarray, np.ndarray, int]:
        """(packed u64 keys, windows, kint_min) in append order."""
        ident, windows = self.concat()
        if self.kind == KIND_EMPTY:
            return np.empty(0, np.uint64), windows, 0
        packed, kint_min = pack_ident_columns(self.kind, ident)
        return packed, windows, kint_min

    def clear(self) -> None:
        self.idents, self.windows = [], []

    @property
    def nbytes(self) -> int:
        return sum(a.nbytes for a in self.idents) + \
            sum(a.nbytes for a in self.windows)


@dataclass
class ColumnarBuilder:
    """Batch build-side index: chunked window columns, one-sort freeze.

    Duck-types the build half of ``IndexBuilder`` (``add_text`` / ``build``
    / ``freeze`` / ``nbytes``) but is a *batch* builder: it cannot be
    probed pre-freeze (no ``lookup``) — admit-as-you-go workloads like
    ``DedupFilter`` keep using the dict ``IndexBuilder``.
    """

    scheme: object
    method: str = "mono_active"
    num_texts: int = 0
    num_windows: int = 0
    text_lengths: list[int] = field(default_factory=list)
    _cols: list[_TableColumns] = field(default_factory=list)

    def __post_init__(self):
        if not self._cols:
            self._cols = [_TableColumns() for _ in range(self.scheme.k)]

    @property
    def is_frozen(self) -> bool:
        return False

    def add_text(self, tokens) -> int:
        """Partition one text under all k hash functions and append its
        window columns (no per-window Python loop)."""
        tokens = np.asarray(tokens, dtype=np.int64)
        tid = self.num_texts
        self.num_texts += 1
        self.text_lengths.append(len(tokens))
        partition_fn, active = _METHODS[self.method]
        occ = occurrence_lists(tokens)
        for i in range(self.scheme.k):
            keys = self.scheme.key_columns(tokens, i, active, occ=occ)
            part = partition_fn(keys)
            nw = len(part)
            self.num_windows += nw
            if nw == 0:
                continue
            win = np.empty((nw, 5), np.int32)
            win[:, 0] = tid
            win[:, 1] = part.a
            win[:, 2] = part.b
            win[:, 3] = part.c
            win[:, 4] = part.d
            self._cols[i].append(keys.gid_ident[part.gid], win)
        return tid

    def build(self, texts: Iterable) -> "ColumnarBuilder":
        for tokens in texts:
            self.add_text(tokens)
        return self

    # -- merge-compaction ingestion -----------------------------------------

    def absorb_index(self, index) -> "ColumnarBuilder":
        """Append a frozen ``SearchIndex``'s windows to the build buffers
        without re-sketching: each table's CSR arrays unpack straight back
        into append columns (``FrozenTable.ident_columns``), with text ids
        re-based after the texts already in this builder.  This is the
        merge-compaction fast path — the old corpus is folded in as pure
        array traffic (mmap-backed tables stream through the page cache).
        """
        self._absorb(index, (t.ident_columns() for t in index.tables))
        return self

    def absorb_builder(self, builder) -> "ColumnarBuilder":
        """Append a mutable ``IndexBuilder``'s windows (the live delta) to
        the build buffers, re-based like :meth:`absorb_index` — its dict
        tables export as key-grouped columns (``table_columns``), already
        sketched at ``add_text`` time."""
        self._absorb(builder, (builder.table_columns(i)
                               for i in range(len(builder.tables))))
        return self

    def _absorb(self, index, columns) -> None:
        if getattr(index.scheme, "k", len(self._cols)) != len(self._cols):
            raise ValueError(
                f"cannot absorb a k={index.scheme.k} index into a "
                f"k={len(self._cols)} builder (different sketch widths)")
        base = self.num_texts
        for i, (ident, windows) in enumerate(columns):
            if len(windows) == 0:
                continue
            win = np.array(windows, np.int32)   # own it: re-base the tids
            win[:, 0] += base
            self._cols[i].append(ident, win)
        self.num_texts += index.num_texts
        self.num_windows += index.num_windows
        self.text_lengths.extend(int(n) for n in index.text_lengths)

    def nbytes(self) -> int:
        """Resident bytes of the append buffers (exact array bytes)."""
        return sum(c.nbytes for c in self._cols)

    # -- freeze paths -------------------------------------------------------

    def freeze(self, *, arena: bool = False) -> SearchIndex:
        """Compact the window columns into an immutable ``SearchIndex``.

        ``arena=True`` additionally builds the fused probe arena straight
        from the window columns (``ProbeArena.from_window_columns`` — one
        global lexsort, no per-table regroup) and caches it on the index.
        """
        tables, packed_cols, win_cols, kint_mins = [], [], [], []
        for col in self._cols:
            packed, windows, kint_min = col.packed()
            tables.append(FrozenTable.from_packed_columns(
                col.kind if len(windows) else KIND_EMPTY,
                packed, windows, kint_min))
            if arena:
                packed_cols.append(packed)
                win_cols.append(windows)
                kint_mins.append(kint_min)
        idx = SearchIndex(
            scheme=self.scheme, method=self.method, tables=tables,
            num_texts=self.num_texts, num_windows=self.num_windows,
            text_lengths=list(self.text_lengths))
        if arena:
            idx._arena = ProbeArena.from_window_columns(
                [t.kind for t in tables], packed_cols, win_cols,
                np.array(kint_mins, np.int64))
        return idx

    def freeze_to_store(self, path, *, mmap: bool = True,
                        include_scheme: bool = True,
                        doc_map=None, wal_watermark=None) -> SearchIndex:
        """Freeze straight into a versioned store directory, streaming.

        Each table's ``.npy`` files are written the moment its columns are
        finalized (``store.IndexWriter``) and its buffers are released —
        the k frozen tables are never all resident at once.  The arena is
        then built from the retained window columns, persisted, and the
        finished store is loaded back (``mmap=True`` maps it read-only) as
        the returned serving ``SearchIndex`` — corpus to mmap-backed store
        in one pass.
        """
        from .store import IndexWriter, load_index
        writer = IndexWriter(
            path, scheme=self.scheme if include_scheme else None,
            method=self.method)
        kinds, packed_cols, win_cols, kint_mins = [], [], [], []
        for i, col in enumerate(self._cols):
            packed, windows, kint_min = col.packed()
            kind = col.kind if len(windows) else KIND_EMPTY
            writer.add_table(i, FrozenTable.from_packed_columns(
                kind, packed, windows, kint_min))
            kinds.append(kind)
            packed_cols.append(packed)
            win_cols.append(windows)
            kint_mins.append(kint_min)
            col.clear()                      # buffers consumed -> release
        writer.add_arena(ProbeArena.from_window_columns(
            kinds, packed_cols, win_cols, np.array(kint_mins, np.int64)))
        del packed_cols, win_cols
        writer.finalize(num_texts=self.num_texts,
                        num_windows=self.num_windows,
                        text_lengths=self.text_lengths, doc_map=doc_map,
                        wal_watermark=wal_watermark)
        # just-written store: skip the load-time checksum verification
        return load_index(path, mmap=mmap, scheme=self.scheme, verify=False)


def _shard_build_payload(spec: dict, method: str, docs: list,
                         store_dir: str | None, doc_map=None):
    """Process-pool worker: columnar-build one shard.

    With ``store_dir``, the shard is frozen straight into that store
    directory (arrays never cross the process boundary; the parent
    mmap-loads the finished store) and ``None`` is returned.  Without it,
    the frozen shard travels back as its array ``state_dict`` (the scheme
    stays behind — weight closures don't pickle — and the parent rebinds
    its own).
    """
    from .schemes import scheme_from_spec
    scheme = scheme_from_spec(spec)
    builder = ColumnarBuilder(scheme=scheme, method=method).build(docs)
    if store_dir is not None:
        builder.freeze_to_store(store_dir, include_scheme=False,
                                doc_map=doc_map)
        return None
    return builder.freeze().state_dict()
