"""Live incremental serving: frozen mmap shards + a mutable delta index,
folded together by columnar merge-compaction into new store generations.

The hash-based framework indexes a *static* corpus, but a production
service takes writes while it serves.  Because CWS samplings are
consistent per subsequence, a document sketched once never needs
re-sketching — so a :class:`LiveIndex` pairs the serving halves that
already exist:

* ``frozen`` — an mmap-backed :class:`~repro.core.search.SearchIndex`
  (plus its fused :class:`~repro.core.frozen.ProbeArena`), loaded from a
  versioned store directory;
* ``delta``  — a small mutable :class:`~repro.core.builder.IndexBuilder`
  that absorbs ``add_text`` writes between compactions.

Queries merge deterministically: one arena probe over the frozen index,
one dict probe over the delta, delta text ids re-based after the frozen
corpus, and ONE shared plane-sweep over the union — block-identical to a
from-scratch build of the same corpus (every text id belongs to exactly
one side, so each (query, text) sweep group comes entirely from one probe
and keeps its coordinate-ascending order).  Results are remapped to
*global* doc ids through ``doc_map`` (the store manifest's mapping,
extended by live adds), so sharded serving keeps one id space.

``compact()`` folds the delta in: the frozen CSR tables unpack straight
back into append columns (``FrozenTable.ident_columns``), the delta's
dict tables export theirs (``IndexBuilder.table_columns``), and the
columnar pipeline freezes the concatenation — one stable sort per table,
zero re-sketching — streaming into a NEW ``v{N:06d}`` generation
directory via ``store.IndexWriter``.  Promotion is atomic and ordered
(arrays → manifest → ``CURRENT`` pointer flip), the old generation stays
on disk for rollback, and readers flip via
:func:`repro.core.store.resolve_store`.

``LiveIndex.query``/``batch_query`` return global-id results (like
``ShardedAlignmentIndex``); the module-level query functions, handed a
``LiveIndex`` directly, work in its local id space.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from ..wal import WalConfig, WriteAheadLog, wal_dir
from . import store as index_store
from .builder import IndexBuilder
from .guard import engine_only
from .plan import resolve_plan
from .query import (Alignment, _sweep_gathered, batch_probe as _batch_probe,
                    query as _query)
from .results import UNSET, QueryOptions, coerce_query_options
from .search import SearchIndex


@dataclass
class LiveIndex:
    """A frozen serving index that accepts writes without thawing.

    Local text id order is ``frozen`` ids first, then ``sealed`` (a delta
    level snapshotted by an in-progress overlapped compaction), then the
    active ``delta`` — and it is STABLE across promotion: when a merged
    frozen+sealed generation is promoted, the sealed texts keep the same
    local ids (now inside the new frozen) and the active delta keeps its
    offsets, so in-flight queries and compactions never see ids move.
    """

    frozen: SearchIndex
    delta: IndexBuilder
    doc_map: list[int]                  # local text id -> global doc id
    root: Path | None = None            # versioned store root (compact target)
    generation: int = 0                 # serving generation under ``root``
    mmap: bool = True                   # how compacted generations load back
    scheme_in_manifest: bool = True     # sharded shards omit the scheme spec
    sealed: IndexBuilder | None = None  # delta level an overlapped compaction
    #                                     is folding in (immutable once set)
    wal: WriteAheadLog | None = None    # durable ingest log (opt-in)
    _sealed_docs: list[int] = field(default_factory=list, init=False,
                                    repr=False)
    _next_gid: int = field(default=0, init=False, repr=False)
    # monotonic timestamp of the first add into the current delta (None
    # while it is empty) — the supervisor's age-based compaction trigger
    _delta_born: float | None = field(default=None, init=False, repr=False)
    # request-id -> local text id, for at-least-once clients: a retried
    # /add with the same id returns the original doc instead of indexing
    # a duplicate.  Entries live for the un-compacted window (dropped once
    # their doc folds into a promoted generation) and are rebuilt from the
    # WAL on replay, so the window survives a crash.
    _requests: dict[str, int] = field(default_factory=dict, init=False,
                                      repr=False)
    _dedup_hits: int = field(default=0, init=False, repr=False)
    wal_replayed: int = field(default=0, init=False, repr=False)
    # WAL positions: _wal_covered is the serving generation's watermark
    # (records below it are folded in); _sealed_watermark is the pending
    # one an in-flight overlapped compaction will promote
    _wal_covered: int = field(default=0, init=False, repr=False)
    _sealed_watermark: int | None = field(default=None, init=False,
                                          repr=False)

    def __post_init__(self):
        self._next_gid = max(self.doc_map, default=-1) + 1

    # -- construction -------------------------------------------------------

    @classmethod
    def open(cls, root, *, mmap: bool = True, scheme=None,
             wal: "bool | WalConfig" = False) -> "LiveIndex":
        """Open a store directory for live serving: mmap-load the serving
        generation, start an empty delta, and adopt the manifest's
        ``doc_map`` (identity when the store never recorded one).

        Resolution goes through :func:`~repro.core.store.resolve_verified`
        — a serving generation that fails its checksum verification is
        quarantined and the newest verifying generation is served instead
        (recovery happens here, at open time; queries never re-verify).

        ``wal`` (``True`` or a :class:`~repro.wal.WalConfig`) makes ingest
        durable: adds append to ``<root>/wal/`` before indexing, and this
        open REPLAYS every un-compacted record into the fresh delta —
        idempotent, because records below the manifest's ``wal_watermark``
        or whose gid the ``doc_map`` already holds are skipped, so
        replaying twice equals replaying once.
        """
        root = Path(root)
        serve_dir = index_store.resolve_verified(root)
        # resolve_verified already checksum-verified serve_dir
        frozen = index_store.load_index(serve_dir, mmap=mmap, scheme=scheme,
                                        verify=False)
        manifest = index_store.read_manifest(serve_dir)
        doc_map = manifest.get("doc_map") or list(range(frozen.num_texts))
        live = cls(frozen=frozen,
                   delta=IndexBuilder(scheme=frozen.scheme,
                                      method=frozen.method),
                   doc_map=[int(g) for g in doc_map], root=root,
                   generation=index_store.current_generation(root),
                   mmap=mmap,
                   scheme_in_manifest=manifest.get("scheme") is not None)
        if wal:
            watermark = int(manifest.get("wal_watermark") or 0)
            live.wal = WriteAheadLog(
                wal_dir(root),
                config=wal if isinstance(wal, WalConfig) else None,
                start_lsn=watermark)
            live._wal_covered = watermark
            known = set(live.doc_map)
            for rec in live.wal.records():
                if rec.lsn < watermark or rec.gid in known:
                    continue            # already folded into the frozen gen
                live._apply_add(rec.tokens, gid=rec.gid,
                                request_id=rec.request_id)
                live.wal_replayed += 1
        return live

    # -- query-engine surface -----------------------------------------------

    @property
    def scheme(self):
        return self.frozen.scheme

    @property
    def method(self) -> str:
        return self.frozen.method

    @property
    def is_frozen(self) -> bool:
        return False            # accepts adds (the whole point)

    @property
    def is_live(self) -> bool:
        return True             # query.batch_probe dispatches on this

    def _levels(self):
        """The index levels in local-id order (frozen, sealed?, delta)."""
        if self.sealed is not None:
            return (self.frozen, self.sealed, self.delta)
        return (self.frozen, self.delta)

    @property
    def num_texts(self) -> int:
        return sum(lv.num_texts for lv in self._levels())

    @property
    def num_windows(self) -> int:
        return sum(lv.num_windows for lv in self._levels())

    @property
    def text_lengths(self) -> list[int]:
        out: list[int] = []
        for lv in self._levels():
            out.extend(lv.text_lengths)
        return out

    @property
    def delta_fraction(self) -> float:
        """Unfolded (sealed + delta) share of the corpus — the compaction
        trigger metric."""
        folded = self.frozen.num_texts
        return (self.num_texts - folded) / max(1, self.num_texts)

    @property
    def delta_age_s(self) -> float:
        """Seconds since the first add into the current delta (0.0 while
        it is empty) — the supervisor's age-based compaction trigger."""
        if self._delta_born is None or self.delta.num_texts == 0:
            return 0.0
        return time.monotonic() - self._delta_born

    def nbytes(self) -> int:
        return sum(lv.nbytes() for lv in self._levels())

    # -- writes -------------------------------------------------------------

    @engine_only
    def add_text(self, tokens, *, gid: int | None = None,
                 request_id: str | None = None) -> int:
        """Index one more document into the delta; returns its LOCAL text
        id (frozen ids come first, delta ids after — stable across
        compactions).  ``gid`` pins the global doc id (the sharded index
        assigns those); default is one past the largest id seen.

        ``request_id`` makes the add idempotent within the un-compacted
        window: a repeat of an id already indexed (including one replayed
        from the WAL after a crash) returns the original local id without
        indexing anything — the server-side half of safe client retries.

        With a WAL attached the record is appended (and group-commit
        policy applied) BEFORE the document becomes visible, so anything
        a query can see is at worst one fsync away from durable; call
        :meth:`wal_commit` for the hard acknowledgement barrier.
        """
        if request_id is not None:
            lid = self._requests.get(request_id)
            if lid is not None:
                self._dedup_hits += 1
                return lid
        tokens = np.asarray(tokens, np.int64)
        if gid is None:
            gid = self._next_gid
        if self.wal is not None:
            self.wal.append(int(gid), request_id, tokens)
            self.wal.maybe_sync()
        return self._apply_add(tokens, gid=int(gid), request_id=request_id)

    def _apply_add(self, tokens, *, gid: int,
                   request_id: str | None = None) -> int:
        """Index a document WITHOUT logging it — the shared tail of
        ``add_text`` and WAL replay (whose records are already on disk)."""
        if self.delta.num_texts == 0:
            self._delta_born = time.monotonic()
        base = self.frozen.num_texts + \
            (self.sealed.num_texts if self.sealed is not None else 0)
        lid = base + self.delta.add_text(np.asarray(tokens, np.int64))
        self.doc_map.append(int(gid))
        self._next_gid = max(self._next_gid, int(gid) + 1)
        if request_id is not None:
            self._requests[request_id] = lid
        return lid

    @engine_only
    def wal_commit(self) -> None:
        """Durability barrier for acknowledgements: fsync the WAL so every
        add so far survives power loss (no-op without a WAL, or when
        nothing is pending).  The serve path calls this once per batcher
        micro-batch — group commit with the batcher's linger window."""
        if self.wal is not None:
            self.wal.sync()

    def wal_status(self) -> dict | None:
        """Operator view of ingest durability (``None`` without a WAL):
        the log's counters plus replay/lag/dedup — ``lag_records`` is how
        many logged records the serving generation does not yet cover
        (what a crash would replay)."""
        if self.wal is None:
            return None
        st = self.wal.stats()
        st["replayed"] = self.wal_replayed
        st["dedup_hits"] = self._dedup_hits
        st["lag_records"] = max(0, self.wal.next_lsn - self._wal_covered)
        st["age_s"] = self.wal.age_s
        return st

    # -- queries ------------------------------------------------------------

    def lookup(self, i: int, v):
        """Merged postings of identity ``v``: frozen rows first, then each
        delta level's rows re-based after it (grouped by tid, as ``query``
        expects)."""
        rows = [tuple(int(x) for x in r) for r in self.frozen.lookup(i, v)]
        base = self.frozen.num_texts
        for lv in self._levels()[1:]:
            rows.extend((tid + base, a, b, c, d)
                        for (tid, a, b, c, d) in lv.lookup(i, v))
            base += lv.num_texts
        return rows

    def batch_probe(self, sketches, *, probe_backend: str = "numpy"
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The live probe stage: one arena probe of the frozen index plus
        one dict probe per non-empty delta level, level tids re-based into
        the local id order — a single gathered (query ids, windows,
        coordinate ids) triple for the shared sweep.

        Empty levels are skipped before probing: a freshly opened live
        store (zero delta tables) pays exactly the frozen arena probe and
        nothing else.
        """
        chunks = []
        base = 0
        for lv in self._levels():
            if lv.num_texts:
                q, w, c = _batch_probe(lv, sketches,
                                       probe_backend=probe_backend)
                if len(q):
                    if base:
                        w = w.copy()
                        w[:, 0] += base
                    chunks.append((q, w, c))
            base += lv.num_texts
        if not chunks:
            return (np.empty(0, np.int64), np.empty((0, 5), np.int64),
                    np.empty(0, np.int64))
        if len(chunks) == 1:
            return chunks[0]
        return tuple(np.concatenate(parts)
                     for parts in zip(*chunks))

    def query(self, tokens, theta: float) -> list[Alignment]:
        """Definition-1 alignment over frozen + deltas, in global doc ids."""
        return sorted((Alignment(text_id=self.doc_map[al.text_id],
                                 blocks=al.blocks, ncoords=al.ncoords)
                       for al in _query(self, tokens, theta)),
                      key=lambda a: a.text_id)

    def batch_query(self, texts, theta: float, *,
                    options: QueryOptions | None = None,
                    sketches=UNSET, backend=UNSET, probe_backend=UNSET,
                    sweep=UNSET,
                    stage_times: dict | None = None) -> list[list[Alignment]]:
        """Batched :meth:`query` (the serving path): sketch once, merge the
        frozen and delta probes, sweep the union, remap to global ids.

        Execution comes in as ``options=QueryOptions(...)``; the ``plan``
        field is resolved once per batch (:func:`repro.core.plan.
        resolve_plan`).  Under ``plan="device"`` the frozen level probes
        the device-resident arena while the mutable delta level keeps the
        host dict probe (live writes stay served without re-upload churn),
        and the merged union sweeps on-device.  The pre-redesign
        ``sketches``/``backend``/``probe_backend``/``sweep`` keywords
        still work behind a ``DeprecationWarning``.  ``stage_times``
        accumulates per-stage wall seconds under
        ``"sketch"``/``"probe"``/``"sweep"`` when given.
        """
        opts = coerce_query_options(options, "LiveIndex.batch_query",
                                    sketches=sketches, backend=backend,
                                    probe_backend=probe_backend, sweep=sweep)
        xp = resolve_plan(opts)
        if not len(texts):
            return []
        t0 = time.perf_counter()
        sk = opts.sketches
        if sk is None:
            sk = self.scheme.sketch_batch(texts, backend=xp.sketch_backend)
        m = max(1, math.ceil(self.scheme.k * theta))
        t1 = time.perf_counter()
        gathered = self.batch_probe(sk, probe_backend=xp.probe_backend)
        t2 = time.perf_counter()
        out = [sorted((Alignment(text_id=self.doc_map[al.text_id],
                                 blocks=al.blocks, ncoords=al.ncoords)
                       for al in res),
                      key=lambda a: a.text_id)
               for res in _sweep_gathered(gathered, len(texts), m,
                                          xp.sweep)]
        if stage_times is not None:
            t3 = time.perf_counter()
            stage_times["sketch"] = stage_times.get("sketch", 0.) + (t1 - t0)
            stage_times["probe"] = stage_times.get("probe", 0.) + (t2 - t1)
            stage_times["sweep"] = stage_times.get("sweep", 0.) + (t3 - t2)
        return out

    # -- compaction ---------------------------------------------------------

    def _merged_builder(self, *, levels=None):
        """The given levels (default: all of them), absorbed into one
        columnar builder — block-identical to a from-scratch build of the
        same corpus."""
        from .columnar import ColumnarBuilder
        builder = ColumnarBuilder(scheme=self.scheme, method=self.method)
        for lv in (self._levels() if levels is None else levels):
            if lv.is_frozen:
                builder.absorb_index(lv)
            else:
                builder.absorb_builder(lv)
        return builder

    def freeze(self) -> SearchIndex:
        """Merge frozen + deltas into one in-memory ``SearchIndex`` (the
        build→serve handoff; use :meth:`compact` to persist in place)."""
        return self._merged_builder().freeze(arena=True)

    # Overlapped (two-phase) compaction: the server's engine thread calls
    # ``seal_delta`` (cheap pointer swap), a background thread runs
    # ``merge_sealed`` over the now-immutable frozen + sealed levels while
    # queries and adds keep flowing, and the engine thread finishes with
    # ``promote_sealed`` between batches.  Local ids never move (sealed
    # texts keep their offsets inside the new frozen), so queries started
    # before, during, or after any phase see identical results.

    @engine_only
    def seal_delta(self) -> int:
        """Phase 1: freeze the active delta as the ``sealed`` level and
        start a fresh one; returns the number of texts sealed.  Must not
        overlap a previous unfinished seal."""
        if self.sealed is not None:
            raise RuntimeError("a sealed delta is already being compacted")
        if len(self.doc_map) != self.num_texts:
            raise RuntimeError(
                f"doc_map has {len(self.doc_map)} entries for "
                f"{self.num_texts} texts; refusing to seal a torn state")
        self.sealed = self.delta
        self.delta = IndexBuilder(scheme=self.scheme, method=self.method)
        self._delta_born = None
        # snapshot the doc ids the merged generation will cover; adds keep
        # appending to doc_map but never touch this prefix
        self._sealed_docs = list(self.doc_map[:self.frozen.num_texts +
                                              self.sealed.num_texts])
        # every sealed doc's WAL record has an LSN below next_lsn (appends
        # precede indexing), so this is the watermark the merged
        # generation's manifest will carry
        if self.wal is not None:
            self._sealed_watermark = self.wal.next_lsn
        return self.sealed.num_texts

    @engine_only
    def unseal_delta(self) -> bool:
        """Roll back an unfinished overlapped compaction: restore the
        sealed level as the active delta, as if ``seal_delta`` never ran.

        Only possible while the active delta is still empty (no add
        landed since the seal).  Otherwise the sealed level stays — it is
        still served correctly as a middle level — and returns ``False``
        so the caller retries ``merge_sealed`` later instead.
        """
        if self.sealed is None:
            return False
        if self.delta.num_texts:
            return False
        self.delta = self.sealed
        self.sealed = None
        self._sealed_docs = []
        # rollback keeps every WAL segment: the un-promoted records are
        # live again and must replay after a crash
        self._sealed_watermark = None
        self._delta_born = (time.monotonic() if self.delta.num_texts
                            else None)
        return True

    @engine_only(reads_immutable=True)
    def merge_sealed(self) -> tuple[int, SearchIndex]:
        """Phase 2: fold frozen + sealed into a NEW committed (manifest on
        disk, ``CURRENT`` untouched) store generation.  Reads only
        immutable state, so it can run off-thread under live traffic.
        Returns ``(generation, its SearchIndex)`` for ``promote_sealed``."""
        if self.sealed is None:
            raise RuntimeError("nothing sealed: call seal_delta() first")
        if self.root is None:
            raise RuntimeError(
                "this LiveIndex is not store-backed; compaction writes a "
                "new store generation — open it with LiveIndex.open(path) "
                "(or use freeze() for an in-memory merge)")
        gen = index_store.next_generation(self.root)
        gen_dir = index_store.generation_dir(self.root, gen)
        new_idx = self._merged_builder(
            levels=(self.frozen, self.sealed)).freeze_to_store(
            gen_dir, mmap=self.mmap, include_scheme=self.scheme_in_manifest,
            doc_map=self._sealed_docs, wal_watermark=self._sealed_watermark)
        return gen, new_idx

    @engine_only
    def promote_sealed(self, gen: int, new_idx: SearchIndex) -> int:
        """Phase 3: flip the store's ``CURRENT`` pointer to ``gen`` and
        swap serving onto its index, retiring the sealed level.  Atomic
        from a query's point of view: local ids are unchanged, and
        in-flight queries holding the old (frozen, sealed, delta) refs
        finish against them bit-identically."""
        if self.sealed is None:
            raise RuntimeError("nothing sealed: call seal_delta() first")
        index_store.promote_generation(self.root, gen)
        self.frozen = new_idx
        self.sealed = None
        self._sealed_docs = []
        self.generation = gen
        if self.wal is not None and self._sealed_watermark is not None:
            # the promoted manifest covers everything below the watermark:
            # drop the covered segments and the dedup entries whose docs
            # now live in the frozen generation (the retry window is the
            # un-compacted suffix, by contract)
            self._wal_covered = self._sealed_watermark
            self.wal.truncate_upto(self._sealed_watermark)
            self._requests = {rid: lid for rid, lid in self._requests.items()
                              if lid >= new_idx.num_texts}
        self._sealed_watermark = None
        return gen

    @engine_only
    def compact(self, *, promote: bool = True) -> int:
        """Fold the delta into a NEW store generation and promote it.

        Streams the merged columns through ``IndexWriter`` into
        ``v{N:06d}/`` (arrays first, manifest last), then atomically flips
        the ``CURRENT`` pointer and swaps serving onto the mmap'd new
        generation with a fresh empty delta.  The old generation is
        retained for rollback; an interrupted compaction leaves the
        serving generation untouched (no manifest → never promoted) and
        this index still serving frozen + delta.  ``promote=False`` stops
        after the manifest commit and returns the generation number — the
        sharded process fan-out promotes from the parent.

        This is the synchronous form of the seal → merge → promote
        overlapped sequence above (all three phases inline).
        """
        if self.root is None:
            raise RuntimeError(
                "this LiveIndex is not store-backed; compaction writes a "
                "new store generation — open it with LiveIndex.open(path) "
                "(or use freeze() for an in-memory merge)")
        if self.sealed is None and self.delta.num_texts == 0:
            # nothing to fold in: don't rewrite the whole corpus into a
            # duplicate generation (timer-driven compactors hit this)
            return self.generation
        if self.sealed is None:
            self.seal_delta()
            try:
                gen, new_idx = self.merge_sealed()
            except BaseException:
                # synchronous path: no add can have landed between seal and
                # merge, so un-seal and restore the pre-call state (a crash
                # mid-merge must leave the index exactly as it was)
                self.unseal_delta()
                raise
        else:
            gen, new_idx = self.merge_sealed()
        if promote:
            self.promote_sealed(gen, new_idx)
        return gen


def _shard_compact_payload(spec: dict, root: str, delta_state: dict,
                           doc_map: list[int]) -> int:
    """Process-pool worker: compact one shard's store, WITHOUT promoting.

    The delta travels as its pickled ``state_dict`` (dict tables of plain
    tuples); the scheme as its JSON spec (weight closures don't pickle).
    The worker commits the new generation's manifest and returns its
    number — the parent flips each shard's pointer and mmap-reloads, so a
    mid-fan-out crash leaves every shard serving its old generation.
    """
    from .schemes import scheme_from_spec
    live = LiveIndex.open(root, mmap=False, scheme=scheme_from_spec(spec))
    live.delta.load_state_dict(delta_state)
    live.doc_map = [int(g) for g in doc_map]
    return live.compact(promote=False)
