"""Live incremental serving: frozen mmap shards + a mutable delta index,
folded together by columnar merge-compaction into new store generations.

The hash-based framework indexes a *static* corpus, but a production
service takes writes while it serves.  Because CWS samplings are
consistent per subsequence, a document sketched once never needs
re-sketching — so a :class:`LiveIndex` pairs the serving halves that
already exist:

* ``frozen`` — an mmap-backed :class:`~repro.core.search.SearchIndex`
  (plus its fused :class:`~repro.core.frozen.ProbeArena`), loaded from a
  versioned store directory;
* ``delta``  — a small mutable :class:`~repro.core.builder.IndexBuilder`
  that absorbs ``add_text`` writes between compactions.

Queries merge deterministically: one arena probe over the frozen index,
one dict probe over the delta, delta text ids re-based after the frozen
corpus, and ONE shared plane-sweep over the union — block-identical to a
from-scratch build of the same corpus (every text id belongs to exactly
one side, so each (query, text) sweep group comes entirely from one probe
and keeps its coordinate-ascending order).  Results are remapped to
*global* doc ids through ``doc_map`` (the store manifest's mapping,
extended by live adds), so sharded serving keeps one id space.

``compact()`` folds the delta in: the frozen CSR tables unpack straight
back into append columns (``FrozenTable.ident_columns``), the delta's
dict tables export theirs (``IndexBuilder.table_columns``), and the
columnar pipeline freezes the concatenation — one stable sort per table,
zero re-sketching — streaming into a NEW ``v{N:06d}`` generation
directory via ``store.IndexWriter``.  Promotion is atomic and ordered
(arrays → manifest → ``CURRENT`` pointer flip), the old generation stays
on disk for rollback, and readers flip via
:func:`repro.core.store.resolve_store`.

``LiveIndex.query``/``batch_query`` return global-id results (like
``ShardedAlignmentIndex``); the module-level query functions, handed a
``LiveIndex`` directly, work in its local id space.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from . import store as index_store
from .builder import IndexBuilder
from .query import (Alignment, _sweep_gathered, batch_probe as _batch_probe,
                    query as _query)
from .search import SearchIndex


@dataclass
class LiveIndex:
    """A frozen serving index that accepts writes without thawing."""

    frozen: SearchIndex
    delta: IndexBuilder
    doc_map: list[int]                  # local text id -> global doc id
    root: Path | None = None            # versioned store root (compact target)
    generation: int = 0                 # serving generation under ``root``
    mmap: bool = True                   # how compacted generations load back
    scheme_in_manifest: bool = True     # sharded shards omit the scheme spec
    _next_gid: int = field(default=0, init=False, repr=False)

    def __post_init__(self):
        self._next_gid = max(self.doc_map, default=-1) + 1

    # -- construction -------------------------------------------------------

    @classmethod
    def open(cls, root, *, mmap: bool = True, scheme=None) -> "LiveIndex":
        """Open a store directory for live serving: mmap-load the serving
        generation, start an empty delta, and adopt the manifest's
        ``doc_map`` (identity when the store never recorded one)."""
        root = Path(root)
        serve_dir = index_store.resolve_store(root)
        frozen = index_store.load_index(serve_dir, mmap=mmap, scheme=scheme)
        manifest = index_store.read_manifest(serve_dir)
        doc_map = manifest.get("doc_map") or list(range(frozen.num_texts))
        return cls(frozen=frozen,
                   delta=IndexBuilder(scheme=frozen.scheme,
                                      method=frozen.method),
                   doc_map=[int(g) for g in doc_map], root=root,
                   generation=index_store.current_generation(root),
                   mmap=mmap,
                   scheme_in_manifest=manifest.get("scheme") is not None)

    # -- query-engine surface -----------------------------------------------

    @property
    def scheme(self):
        return self.frozen.scheme

    @property
    def method(self) -> str:
        return self.frozen.method

    @property
    def is_frozen(self) -> bool:
        return False            # accepts adds (the whole point)

    @property
    def is_live(self) -> bool:
        return True             # query.batch_probe dispatches on this

    @property
    def num_texts(self) -> int:
        return self.frozen.num_texts + self.delta.num_texts

    @property
    def num_windows(self) -> int:
        return self.frozen.num_windows + self.delta.num_windows

    @property
    def text_lengths(self) -> list[int]:
        return list(self.frozen.text_lengths) + list(self.delta.text_lengths)

    @property
    def delta_fraction(self) -> float:
        """Delta share of the corpus — the compaction trigger metric."""
        return self.delta.num_texts / max(1, self.num_texts)

    def nbytes(self) -> int:
        return self.frozen.nbytes() + self.delta.nbytes()

    # -- writes -------------------------------------------------------------

    def add_text(self, tokens, *, gid: int | None = None) -> int:
        """Index one more document into the delta; returns its LOCAL text
        id (frozen ids come first, delta ids after — stable across
        compactions).  ``gid`` pins the global doc id (the sharded index
        assigns those); default is one past the largest id seen."""
        if gid is None:
            gid = self._next_gid
        lid = self.frozen.num_texts + \
            self.delta.add_text(np.asarray(tokens, np.int64))
        self.doc_map.append(int(gid))
        self._next_gid = max(self._next_gid, int(gid) + 1)
        return lid

    # -- queries ------------------------------------------------------------

    def lookup(self, i: int, v):
        """Merged postings of identity ``v``: frozen rows first, delta rows
        re-based after them (grouped by tid, as ``query`` expects)."""
        rows = [tuple(int(x) for x in r) for r in self.frozen.lookup(i, v)]
        base = self.frozen.num_texts
        rows.extend((tid + base, a, b, c, d)
                    for (tid, a, b, c, d) in self.delta.lookup(i, v))
        return rows

    def batch_probe(self, sketches, *, probe_backend: str = "numpy"
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The live probe stage: one arena probe of the frozen index, one
        dict probe of the delta, delta tids re-based — a single gathered
        (query ids, windows, coordinate ids) triple for the shared sweep."""
        fq, fw, fc = _batch_probe(self.frozen, sketches,
                                  probe_backend=probe_backend)
        dq, dw, dc = _batch_probe(self.delta, sketches,
                                  probe_backend=probe_backend)
        if not len(dq):
            return fq, fw, fc
        dw = dw.copy()
        dw[:, 0] += self.frozen.num_texts
        return (np.concatenate([fq, dq]), np.concatenate([fw, dw]),
                np.concatenate([fc, dc]))

    def query(self, tokens, theta: float) -> list[Alignment]:
        """Definition-1 alignment over frozen + delta, in global doc ids."""
        return sorted((Alignment(text_id=self.doc_map[al.text_id],
                                 blocks=al.blocks)
                       for al in _query(self, tokens, theta)),
                      key=lambda a: a.text_id)

    def batch_query(self, texts, theta: float, *,
                    sketches: list[list] | None = None,
                    backend: str = "exact", probe_backend: str = "numpy",
                    sweep: str = "grouped") -> list[list[Alignment]]:
        """Batched :meth:`query` (the serving path): sketch once, merge the
        frozen and delta probes, sweep the union, remap to global ids."""
        if not len(texts):
            return []
        if sketches is None:
            sketches = self.scheme.sketch_batch(texts, backend=backend)
        m = max(1, math.ceil(self.scheme.k * theta))
        gathered = self.batch_probe(sketches, probe_backend=probe_backend)
        return [sorted((Alignment(text_id=self.doc_map[al.text_id],
                                  blocks=al.blocks) for al in res),
                       key=lambda a: a.text_id)
                for res in _sweep_gathered(gathered, len(texts), m, sweep)]

    # -- compaction ---------------------------------------------------------

    def _merged_builder(self):
        """Frozen tables + delta, absorbed into one columnar builder —
        block-identical to a from-scratch build of the union corpus."""
        from .columnar import ColumnarBuilder
        builder = ColumnarBuilder(scheme=self.scheme, method=self.method)
        builder.absorb_index(self.frozen)
        builder.absorb_builder(self.delta)
        return builder

    def freeze(self) -> SearchIndex:
        """Merge frozen + delta into one in-memory ``SearchIndex`` (the
        build→serve handoff; use :meth:`compact` to persist in place)."""
        return self._merged_builder().freeze(arena=True)

    def compact(self, *, promote: bool = True) -> int:
        """Fold the delta into a NEW store generation and promote it.

        Streams the merged columns through ``IndexWriter`` into
        ``v{N:06d}/`` (arrays first, manifest last), then atomically flips
        the ``CURRENT`` pointer and swaps serving onto the mmap'd new
        generation with a fresh empty delta.  The old generation is
        retained for rollback; an interrupted compaction leaves the
        serving generation untouched (no manifest → never promoted) and
        this index still serving frozen + delta.  ``promote=False`` stops
        after the manifest commit and returns the generation number — the
        sharded process fan-out promotes from the parent.
        """
        if self.root is None:
            raise RuntimeError(
                "this LiveIndex is not store-backed; compaction writes a "
                "new store generation — open it with LiveIndex.open(path) "
                "(or use freeze() for an in-memory merge)")
        if self.delta.num_texts == 0:
            # nothing to fold in: don't rewrite the whole corpus into a
            # duplicate generation (timer-driven compactors hit this)
            return self.generation
        if len(self.doc_map) != self.num_texts:
            raise RuntimeError(
                f"doc_map has {len(self.doc_map)} entries for "
                f"{self.num_texts} texts; refusing to write a torn manifest")
        gen = index_store.next_generation(self.root)
        gen_dir = index_store.generation_dir(self.root, gen)
        new_idx = self._merged_builder().freeze_to_store(
            gen_dir, mmap=self.mmap, include_scheme=self.scheme_in_manifest,
            doc_map=self.doc_map)
        if promote:
            index_store.promote_generation(self.root, gen)
            self.frozen = new_idx
            self.delta = IndexBuilder(scheme=self.scheme, method=self.method)
            self.generation = gen
        return gen


def _shard_compact_payload(spec: dict, root: str, delta_state: dict,
                           doc_map: list[int]) -> int:
    """Process-pool worker: compact one shard's store, WITHOUT promoting.

    The delta travels as its pickled ``state_dict`` (dict tables of plain
    tuples); the scheme as its JSON spec (weight closures don't pickle).
    The worker commits the new generation's manifest and returns its
    number — the parent flips each shard's pointer and mmap-reloads, so a
    mid-fan-out crash leaves every shard serving its old generation.
    """
    from .schemes import scheme_from_spec
    live = LiveIndex.open(root, mmap=False, scheme=scheme_from_spec(spec))
    live.delta.load_state_dict(delta_state)
    live.doc_map = [int(g) for g in doc_map]
    return live.compact(promote=False)
