"""Deprecated: the pre-PR-2 dual-personality ``AlignmentIndex``.

The index API was split into an explicit build→serve lifecycle:

  * :class:`repro.core.builder.IndexBuilder` — mutable dict tables,
    ``add_text``/``build``.
  * :class:`repro.core.search.SearchIndex` — immutable CSR tables,
    mmap-able persistence (``save``/``load``), produced by
    ``IndexBuilder.freeze()``.
  * :class:`repro.api.Aligner` — the one-object facade most callers want.

``AlignmentIndex`` remains as a thin shim so existing code and pickled
checkpoints keep working: it delegates to an internal ``IndexBuilder``
until ``freeze()``, then to a ``SearchIndex``, preserving the legacy
surface (``tables``/``frozen`` attributes, ``state_dict`` round-trip, the
``RuntimeError`` on post-freeze ``add_text``).  New code should use the
split types or the facade directly.

``MultisetScheme``/``WeightedScheme`` moved to :mod:`repro.core.schemes`
and are re-exported here unchanged.
"""

from __future__ import annotations

import warnings

from .builder import _METHODS, IndexBuilder  # noqa: F401  (re-export)
from .schemes import MultisetScheme, WeightedScheme  # noqa: F401
from .search import SearchIndex


class AlignmentIndex:
    """Deprecated facade over ``IndexBuilder`` + ``SearchIndex``.

    Starts in the build state; ``freeze()`` switches to an immutable
    ``SearchIndex`` in place.  Prefer the split types (or ``repro.api.
    Aligner``) in new code — they make the lifecycle explicit instead of
    changing behavior at runtime.
    """

    def __init__(self, scheme=None, method: str = "mono_active", *,
                 _impl=None):
        if _impl is None:
            _impl = IndexBuilder(scheme=scheme, method=method)
        self._impl = _impl
        warnings.warn(
            "AlignmentIndex is deprecated; use repro.api.Aligner or the "
            "IndexBuilder/SearchIndex pair (repro.core.builder/search)",
            DeprecationWarning, stacklevel=2)

    # -- lifecycle ----------------------------------------------------------

    @property
    def is_frozen(self) -> bool:
        return self._impl.is_frozen

    def freeze(self) -> "AlignmentIndex":
        """Compact into the CSR serving layout (idempotent).  Drops the
        dict tables — freezing is the build->serve handoff, not a view."""
        if not self._impl.is_frozen:
            self._impl = self._impl.freeze()
        return self

    def add_text(self, tokens) -> int:
        if self._impl.is_frozen:
            raise RuntimeError("index is frozen; freeze() is a build->serve "
                               "handoff and does not support further adds")
        return self._impl.add_text(tokens)

    def build(self, texts) -> "AlignmentIndex":
        for tokens in texts:
            self.add_text(tokens)
        return self

    # -- legacy attribute surface ------------------------------------------

    @property
    def scheme(self):
        return self._impl.scheme

    @property
    def method(self) -> str:
        return self._impl.method

    @property
    def tables(self) -> list:
        return [] if self._impl.is_frozen else self._impl.tables

    @property
    def frozen(self):
        return self._impl.tables if self._impl.is_frozen else None

    @property
    def num_texts(self) -> int:
        return self._impl.num_texts

    @property
    def num_windows(self) -> int:
        return self._impl.num_windows

    @property
    def text_lengths(self) -> list[int]:
        return self._impl.text_lengths

    def lookup(self, i: int, v):
        return self._impl.lookup(i, v)

    def arena(self):
        """Fused probe arena of the frozen tables (serving stage only)."""
        if not self._impl.is_frozen:
            raise RuntimeError("index is not frozen; the probe arena is a "
                               "serving-stage structure — call freeze()")
        return self._impl.arena()

    def nbytes(self) -> int:
        return self._impl.nbytes()

    # -- persistence (legacy dict-state; the store format lives on
    #    SearchIndex.save / repro.core.store) ------------------------------

    def state_dict(self) -> dict:
        return self._impl.state_dict()

    def load_state_dict(self, state: dict) -> None:
        if state.get("frozen") is not None:
            # frozen arrays round-trip as-is — no re-freeze on restore
            self._impl = SearchIndex.from_state(self._impl.scheme, state)
        else:
            builder = IndexBuilder(scheme=self._impl.scheme,
                                   method=state["method"])
            builder.load_state_dict(state)
            self._impl = builder
