"""Indexing (Algorithm 1): k inverted indexes of compact windows.

The index maps, per sketch coordinate i ∈ [k], a hash-value identity to the
list of compact windows carrying it: I_i[v] -> [(text_id, a, b, c, d), ...].

Schemes:
  * ``MultisetScheme``  — integer universal min-hash (§2), index key int(h).
  * ``WeightedScheme``  — ICWS (§5), index key (token, k_int).

Partition methods: "mono_active" (default), "mono_all", "allalign".
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

from .allalign import allalign_partition
from .frozen import FrozenTable, dict_tables_nbytes
from .hashing import UniversalHash
from .icws import ICWS
from .keys import generate_keys_icws, generate_keys_multiset
from .partition import monotonic_partition
from .weights import WeightFn


@dataclass
class MultisetScheme:
    """Sketch scheme for multi-set Jaccard (standard min-hash over (t, x)).

    family="universal" is the paper's linear family (§2.2).  family="mix"
    (splitmix64) is our beyond-paper variant: the linear family is an
    arithmetic progression in x, which empirically inflates the number of
    active hash values (≈1.7× at f=256) over the idealized i.i.d. analysis
    of Lemma 11 — splitmix removes that structure, shrinking keys, windows,
    and thus the index (see EXPERIMENTS.md §Beyond-paper).
    """

    seed: int = 0
    k: int = 16
    family: str = "universal"
    hashers: list = field(init=False)

    def __post_init__(self):
        from .hashing import MixHash
        cls = {"universal": UniversalHash, "mix": MixHash}[self.family]
        self.hashers = cls.from_seed(self.seed, self.k)

    def keys(self, tokens, i: int, active: bool, occ=None):
        return generate_keys_multiset(tokens, self.hashers[i], active=active,
                                      occ=occ)

    def sketch(self, tokens) -> list:
        """k min-hash identities of a whole text (Eq. 1)."""
        from .keys import occurrence_lists
        occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
        out = []
        for h in self.hashers:
            best = None
            for t, pos in occ.items():
                hv = h(np.full(len(pos), t, dtype=np.int64),
                       np.arange(1, len(pos) + 1))
                m = int(hv.min())
                if best is None or m < best:
                    best = m
            out.append(best)
        return out

    def sketch_batch(self, texts, *, backend: str = "exact") -> list[list]:
        """Sketches of many texts; bit-identical to per-text ``sketch``
        (integer hashes are exact on every backend, so ``backend`` is
        accepted for signature parity and ignored).

        One vectorized hash call per (text, hasher) over the flat (t, x)
        grid instead of a Python loop per token — the batched query
        engine's sketching path.
        """
        from .keys import _flat_grid, occurrence_lists
        out = []
        for tokens in texts:
            occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
            _toks, _fs, t_rep, x_rep, _bounds = _flat_grid(occ)
            out.append([int(h(t_rep, x_rep).min()) for h in self.hashers])
        return out


@dataclass
class WeightedScheme:
    """Sketch scheme for weighted Jaccard (ICWS over (t, w(t, f)))."""

    weight: WeightFn
    seed: int = 0
    k: int = 16
    hashers: list[ICWS] = field(init=False)

    def __post_init__(self):
        self.hashers = ICWS.from_seed(self.seed, self.k)

    def keys(self, tokens, i: int, active: bool, occ=None):
        return generate_keys_icws(tokens, self.hashers[i], self.weight,
                                  active=active, occ=occ)

    def sketch(self, tokens) -> list:
        from .keys import occurrence_lists
        occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
        toks = np.array(sorted(occ), dtype=np.int64)
        freqs = np.array([len(occ[int(t)]) for t in toks], dtype=np.int64)
        w = self.weight(toks, freqs)
        out = []
        for h in self.hashers:
            t_star, k_star, _a = h.min_hash(toks, w)
            out.append((t_star, k_star))
        return out

    def sketch_batch(self, texts, *, backend: str = "exact") -> list[list]:
        """Sketches of many texts.

        backend="exact"  — per-text float64 host math, bit-identical to
        ``sketch`` (the default; what result-parity guarantees assume).
        backend="pallas" — all texts through the fused ``icws_sketch_batch``
        kernel in one launch (f32 device math; identities can differ from
        the exact path only on argmin near-ties).
        """
        if backend == "pallas":
            from ..kernels.ops import cws_sketch_batch
            from .keys import occurrence_lists
            token_lists, weight_lists = [], []
            for tokens in texts:
                occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
                toks = np.array(sorted(occ), dtype=np.int64)
                freqs = np.array([len(occ[int(t)]) for t in toks],
                                 dtype=np.int64)
                token_lists.append(toks)
                weight_lists.append(self.weight(toks, freqs))
            return cws_sketch_batch(self.seed, self.k, token_lists,
                                    weight_lists)
        return [self.sketch(t) for t in texts]


_METHODS = {
    "mono_all": (monotonic_partition, False),
    "mono_active": (monotonic_partition, True),
    "allalign": (allalign_partition, False),
}


@dataclass
class AlignmentIndex:
    """k inverted indexes of compact windows over a text collection.

    Two storage regimes:

    * **mutable** (after ``build``/``add_text``): each table is a Python
      dict ``key -> list[(tid, a, b, c, d)]``.
    * **frozen** (after ``freeze``): each table is a contiguous CSR
      :class:`~repro.core.frozen.FrozenTable`; ``add_text`` is rejected and
      lookups become vectorized ``searchsorted`` probes (~10x smaller
      resident size, and the layout ``batch_query`` requires).
    """

    scheme: MultisetScheme | WeightedScheme
    method: str = "mono_active"
    tables: list[dict] = field(default_factory=list)
    num_texts: int = 0
    num_windows: int = 0
    text_lengths: list[int] = field(default_factory=list)
    frozen: list[FrozenTable] | None = None

    def __post_init__(self):
        if not self.tables and self.frozen is None:
            self.tables = [dict() for _ in range(self.scheme.k)]

    @property
    def is_frozen(self) -> bool:
        return self.frozen is not None

    def freeze(self) -> "AlignmentIndex":
        """Compact every dict table into a CSR FrozenTable (idempotent).

        Drops the dict tables afterwards — freezing is the build->serve
        handoff, not a view.
        """
        if self.frozen is None:
            self.frozen = [FrozenTable.from_dict(t) for t in self.tables]
            self.tables = []
        return self

    def nbytes(self) -> int:
        """Resident size of the inverted tables (frozen: exact array bytes;
        mutable: recursive ``sys.getsizeof`` estimate)."""
        if self.frozen is not None:
            return sum(t.nbytes for t in self.frozen)
        return dict_tables_nbytes(self.tables)

    def add_text(self, tokens) -> int:
        """Partition one text under all k hash functions and index it."""
        if self.frozen is not None:
            raise RuntimeError("index is frozen; freeze() is a build->serve "
                               "handoff and does not support further adds")
        tid = self.num_texts
        self.num_texts += 1
        self.text_lengths.append(len(tokens))
        partition_fn, active = _METHODS[self.method]
        from .keys import occurrence_lists
        occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
        for i in range(self.scheme.k):
            keys = self.scheme.keys(tokens, i, active, occ=occ)
            part = partition_fn(keys)
            self.num_windows += len(part)
            table = self.tables[i]
            for w in range(len(part)):
                v = part.gid_key[int(part.gid[w])]
                table.setdefault(v, []).append(
                    (tid, int(part.a[w]), int(part.b[w]),
                     int(part.c[w]), int(part.d[w])))
        return tid

    def build(self, texts: Iterable) -> "AlignmentIndex":
        for tokens in texts:
            self.add_text(tokens)
        return self

    def lookup(self, i: int, v):
        """Postings of hash identity ``v`` in table ``i``: a list of
        (tid, a, b, c, d) tuples (mutable) or an int32 (m, 5) row view
        (frozen) — both iterate as 5-sequences."""
        if self.frozen is not None:
            return self.frozen[i].get(v)
        return self.tables[i].get(v, [])

    # -- persistence (used by the sharded/distributed index) ---------------

    def state_dict(self) -> dict:
        state = {
            "method": self.method,
            "num_texts": self.num_texts,
            "num_windows": self.num_windows,
            "text_lengths": self.text_lengths,
            "tables": self.tables,
        }
        if self.frozen is not None:
            state["frozen"] = [t.state_dict() for t in self.frozen]
        return state

    def load_state_dict(self, state: dict) -> None:
        self.method = state["method"]
        self.num_texts = state["num_texts"]
        self.num_windows = state["num_windows"]
        self.text_lengths = list(state["text_lengths"])
        self.tables = state["tables"]
        if state.get("frozen") is not None:
            # frozen arrays round-trip as-is — no re-freeze on restore
            self.frozen = [FrozenTable.from_state(s) for s in state["frozen"]]
        else:
            self.frozen = None
