"""Indexing (Algorithm 1): k inverted indexes of compact windows.

The index maps, per sketch coordinate i ∈ [k], a hash-value identity to the
list of compact windows carrying it: I_i[v] -> [(text_id, a, b, c, d), ...].

Schemes:
  * ``MultisetScheme``  — integer universal min-hash (§2), index key int(h).
  * ``WeightedScheme``  — ICWS (§5), index key (token, k_int).

Partition methods: "mono_active" (default), "mono_all", "allalign".
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

from .allalign import allalign_partition
from .hashing import UniversalHash
from .icws import ICWS
from .keys import generate_keys_icws, generate_keys_multiset
from .partition import Partition, monotonic_partition
from .weights import WeightFn


@dataclass
class MultisetScheme:
    """Sketch scheme for multi-set Jaccard (standard min-hash over (t, x)).

    family="universal" is the paper's linear family (§2.2).  family="mix"
    (splitmix64) is our beyond-paper variant: the linear family is an
    arithmetic progression in x, which empirically inflates the number of
    active hash values (≈1.7× at f=256) over the idealized i.i.d. analysis
    of Lemma 11 — splitmix removes that structure, shrinking keys, windows,
    and thus the index (see EXPERIMENTS.md §Beyond-paper).
    """

    seed: int = 0
    k: int = 16
    family: str = "universal"
    hashers: list = field(init=False)

    def __post_init__(self):
        from .hashing import MixHash
        cls = {"universal": UniversalHash, "mix": MixHash}[self.family]
        self.hashers = cls.from_seed(self.seed, self.k)

    def keys(self, tokens, i: int, active: bool, occ=None):
        return generate_keys_multiset(tokens, self.hashers[i], active=active,
                                      occ=occ)

    def sketch(self, tokens) -> list:
        """k min-hash identities of a whole text (Eq. 1)."""
        from .keys import occurrence_lists
        occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
        out = []
        for h in self.hashers:
            best = None
            for t, pos in occ.items():
                hv = h(np.full(len(pos), t, dtype=np.int64),
                       np.arange(1, len(pos) + 1))
                m = int(hv.min())
                if best is None or m < best:
                    best = m
            out.append(best)
        return out


@dataclass
class WeightedScheme:
    """Sketch scheme for weighted Jaccard (ICWS over (t, w(t, f)))."""

    weight: WeightFn
    seed: int = 0
    k: int = 16
    hashers: list[ICWS] = field(init=False)

    def __post_init__(self):
        self.hashers = ICWS.from_seed(self.seed, self.k)

    def keys(self, tokens, i: int, active: bool, occ=None):
        return generate_keys_icws(tokens, self.hashers[i], self.weight,
                                  active=active, occ=occ)

    def sketch(self, tokens) -> list:
        from .keys import occurrence_lists
        occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
        toks = np.array(sorted(occ), dtype=np.int64)
        freqs = np.array([len(occ[int(t)]) for t in toks], dtype=np.int64)
        w = self.weight(toks, freqs)
        out = []
        for h in self.hashers:
            t_star, k_star, _a = h.min_hash(toks, w)
            out.append((t_star, k_star))
        return out


_METHODS = {
    "mono_all": (monotonic_partition, False),
    "mono_active": (monotonic_partition, True),
    "allalign": (allalign_partition, False),
}


@dataclass
class AlignmentIndex:
    """k inverted indexes of compact windows over a text collection."""

    scheme: MultisetScheme | WeightedScheme
    method: str = "mono_active"
    tables: list[dict] = field(default_factory=list)
    num_texts: int = 0
    num_windows: int = 0
    text_lengths: list[int] = field(default_factory=list)

    def __post_init__(self):
        if not self.tables:
            self.tables = [dict() for _ in range(self.scheme.k)]

    def add_text(self, tokens) -> int:
        """Partition one text under all k hash functions and index it."""
        tid = self.num_texts
        self.num_texts += 1
        self.text_lengths.append(len(tokens))
        partition_fn, active = _METHODS[self.method]
        from .keys import occurrence_lists
        occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
        for i in range(self.scheme.k):
            keys = self.scheme.keys(tokens, i, active, occ=occ)
            part = partition_fn(keys)
            self.num_windows += len(part)
            table = self.tables[i]
            for w in range(len(part)):
                v = part.gid_key[int(part.gid[w])]
                table.setdefault(v, []).append(
                    (tid, int(part.a[w]), int(part.b[w]),
                     int(part.c[w]), int(part.d[w])))
        return tid

    def build(self, texts: Iterable) -> "AlignmentIndex":
        for tokens in texts:
            self.add_text(tokens)
        return self

    def lookup(self, i: int, v) -> list:
        return self.tables[i].get(v, [])

    # -- persistence (used by the sharded/distributed index) ---------------

    def state_dict(self) -> dict:
        return {
            "method": self.method,
            "num_texts": self.num_texts,
            "num_windows": self.num_windows,
            "text_lengths": self.text_lengths,
            "tables": self.tables,
        }

    def load_state_dict(self, state: dict) -> None:
        self.method = state["method"]
        self.num_texts = state["num_texts"]
        self.num_windows = state["num_windows"]
        self.text_lengths = list(state["text_lengths"])
        self.tables = state["tables"]
