"""Frozen CSR-style inverted tables (the serving-side index layout).

A built ``AlignmentIndex`` stores each of its k tables as a Python dict
``key -> list[(tid, a, b, c, d)]``.  That layout is ideal for incremental
builds but terrible for serving: every posting is a 5-tuple of boxed ints
(~240 B/window vs 20 B of payload) and probes chase pointers.  Following the
frozen-layout direction of BagMinHash (Ertl '18), ``freeze_table`` compacts
one dict table into three contiguous arrays:

  keys    uint64 (nkeys,)    sorted packed hash identities
  offsets int64  (nkeys+1,)  CSR row pointers into ``windows``
  windows int32  (nwin, 5)   (tid, a, b, c, d) rows, grouped by key

Lookup is ``np.searchsorted`` (O(log nkeys)); a batch of probes is a single
vectorized searchsorted, which is what the batched query engine
(``repro.core.query.batch_query``) rides on.

Key packing
-----------
Multiset tables key by ``int(h)`` (a 61/64-bit hash) -> stored directly as
uint64.  ICWS tables key by the exact integer identity ``(token, k_int)``
(DESIGN.md §6) -> packed as ``(token << 32) | (k_int - kint_min)``; tokens
are vocabulary ids (< 2**32) and observed k_int spans are tiny, so the pack
is exact.  Probe keys that fall outside the packable range simply miss —
they cannot equal any stored key.
"""

from __future__ import annotations

import sys
from dataclasses import dataclass

import numpy as np

KIND_EMPTY = "empty"
KIND_INT = "int"
KIND_PAIR = "pair"

_MISS = np.uint64(0xFFFFFFFFFFFFFFFF)  # sentinel for unpackable probe keys


def _concat_ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenate [s, s+c) ranges into one index vector, vectorized."""
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64)
    rep_starts = np.repeat(starts, counts)
    ends = np.cumsum(counts)
    seq = np.arange(total, dtype=np.int64) - np.repeat(ends - counts, counts)
    return rep_starts + seq


@dataclass
class FrozenTable:
    """One immutable CSR inverted table (one sketch coordinate)."""

    kind: str
    keys: np.ndarray        # uint64 (nkeys,), sorted
    offsets: np.ndarray     # int64 (nkeys + 1,)
    windows: np.ndarray     # int32 (nwin, 5): tid, a, b, c, d
    kint_min: int = 0       # pair-pack bias (kind == "pair" only)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_dict(cls, table: dict) -> "FrozenTable":
        if not table:
            return cls(kind=KIND_EMPTY, keys=np.empty(0, np.uint64),
                       offsets=np.zeros(1, np.int64),
                       windows=np.empty((0, 5), np.int32))
        first = next(iter(table))
        kind = KIND_PAIR if isinstance(first, tuple) else KIND_INT
        kint_min = 0
        if kind == KIND_PAIR:
            toks = np.fromiter((k[0] for k in table), np.int64, len(table))
            kints = np.fromiter((k[1] for k in table), np.int64, len(table))
            if toks.min() < 0 or toks.max() >= 1 << 32:
                raise ValueError("token id out of uint32 range: cannot "
                                 "pack (token, k_int) keys for freezing")
            kint_min = int(kints.min())
            if int(kints.max()) - kint_min >= 1 << 32:
                raise ValueError("k_int span exceeds uint32: cannot pack "
                                 "(token, k_int) keys for freezing")
            packed = (toks.astype(np.uint64) << np.uint64(32)) | \
                (kints - kint_min).astype(np.uint64)
        else:
            packed = np.fromiter((int(k) for k in table), np.uint64,
                                 len(table))
        order = np.argsort(packed, kind="stable")
        packed = packed[order]
        items = list(table.values())
        counts = np.array([len(items[i]) for i in order], np.int64)
        offsets = np.zeros(len(packed) + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        # one concatenate over the key-ordered posting lists (C fast path)
        # instead of a per-key Python copy loop — freeze time is part of the
        # paper's index-construction cost
        windows = np.concatenate(
            [np.asarray(items[i], np.int32).reshape(-1, 5) for i in order],
            axis=0) if len(order) else np.empty((0, 5), np.int32)
        return cls(kind=kind, keys=packed, offsets=offsets, windows=windows,
                   kint_min=kint_min)

    # -- probing ------------------------------------------------------------

    def encode(self, values) -> np.ndarray:
        """Pack a list of probe keys -> uint64 (P,); unpackable -> _MISS."""
        if self.kind == KIND_PAIR:
            toks = np.array([v[0] for v in values], np.int64)
            kints = np.array([v[1] for v in values], np.int64)
            rel = kints - self.kint_min
            ok = (toks >= 0) & (toks < 1 << 32) & (rel >= 0) & (rel < 1 << 32)
            packed = (np.where(ok, toks, 0).astype(np.uint64) << np.uint64(32)) \
                | np.where(ok, rel, 0).astype(np.uint64)
            return np.where(ok, packed, _MISS)
        if self.kind == KIND_INT:
            return np.array([int(v) for v in values], np.uint64)
        return np.full(len(values), _MISS, np.uint64)

    def probe(self, packed: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized lookup: packed (P,) u64 -> CSR (starts, ends) int64.

        Misses get an empty range (start == end == 0).
        """
        n = len(self.keys)
        if n == 0:
            z = np.zeros(len(packed), np.int64)
            return z, z
        pos = np.searchsorted(self.keys, packed)
        safe = np.where(pos < n, pos, 0)
        hit = (pos < n) & (self.keys[safe] == packed)
        starts = np.where(hit, self.offsets[safe], 0)
        ends = np.where(hit, self.offsets[safe + 1], 0)
        return starts, ends

    def get(self, v, default=None):
        """dict.get-compatible single lookup -> int32 (m, 5) rows."""
        packed = self.encode([v])
        s, e = self.probe(packed)
        if e[0] > s[0]:
            return self.windows[s[0]:e[0]]
        return default if default is not None else self.windows[:0]

    # -- introspection / persistence ----------------------------------------

    def __len__(self) -> int:
        return len(self.keys)

    @property
    def nbytes(self) -> int:
        return self.keys.nbytes + self.offsets.nbytes + self.windows.nbytes

    def state_dict(self) -> dict:
        return {"kind": self.kind, "keys": self.keys, "offsets": self.offsets,
                "windows": self.windows, "kint_min": self.kint_min}

    @classmethod
    def from_state(cls, state: dict) -> "FrozenTable":
        return cls(kind=state["kind"],
                   keys=np.asarray(state["keys"], np.uint64),
                   offsets=np.asarray(state["offsets"], np.int64),
                   windows=np.asarray(state["windows"], np.int32),
                   kint_min=int(state["kint_min"]))


def dict_tables_nbytes(tables: list[dict]) -> int:
    """Resident size of dict-of-lists-of-tuples tables (recursive sizeof)."""
    total = 0
    for table in tables:
        total += sys.getsizeof(table)
        for key, wins in table.items():
            total += sys.getsizeof(key) + sys.getsizeof(wins)
            for w in wins:
                total += sys.getsizeof(w) + sum(sys.getsizeof(x) for x in w)
    return total
