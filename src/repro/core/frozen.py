"""Frozen CSR-style inverted tables (the serving-side index layout).

A built ``AlignmentIndex`` stores each of its k tables as a Python dict
``key -> list[(tid, a, b, c, d)]``.  That layout is ideal for incremental
builds but terrible for serving: every posting is a 5-tuple of boxed ints
(~240 B/window vs 20 B of payload) and probes chase pointers.  Following the
frozen-layout direction of BagMinHash (Ertl '18), ``freeze_table`` compacts
one dict table into three contiguous arrays:

  keys    uint64 (nkeys,)    sorted packed hash identities
  offsets int64  (nkeys+1,)  CSR row pointers into ``windows``
  windows int32  (nwin, 5)   (tid, a, b, c, d) rows, grouped by key

Lookup is ``np.searchsorted`` (O(log nkeys)); a batch of probes is a single
vectorized searchsorted, which is what the batched query engine
(``repro.core.query.batch_query``) rides on.

Key packing
-----------
Multiset tables key by ``int(h)`` (a 61/64-bit hash) -> stored directly as
uint64.  ICWS tables key by the exact integer identity ``(token, k_int)``
(DESIGN.md §6) -> packed as ``(token << 32) | (k_int - kint_min)``; tokens
are vocabulary ids (< 2**32) and observed k_int spans are tiny, so the pack
is exact.  Probe keys that fall outside the packable range simply miss —
they cannot equal any stored key.

Probe arena
-----------
``ProbeArena`` fuses the k per-coordinate tables into ONE sorted key arena
with one global CSR offsets array and one windows matrix, so a batch of B
queries probes all B*k coordinates with a single ``searchsorted`` + gather
instead of k separate host round-trips (the batched query engine's probe
stage).  Two re-keying schemes, chosen at build time:

* ``packed`` — when every stored key fits in 56 bits (ICWS pair keys with
  small vocabularies), re-key as ``(coord << 56) | key``; the coordinate-
  major concatenation of per-coordinate sorted segments is then globally
  sorted and one plain ``searchsorted`` finds exact slots.
* ``coord``  — when packing would overflow (61/64-bit multiset hashes),
  keep the original 64-bit keys sorted by ``(key, coord)`` with a parallel
  uint16 coordinate-tag array.  The probe is still one ``searchsorted`` on
  the key alone, followed by a tiny vectorized advance over the duplicate
  run (bounded by ``max_run``, the longest equal-key run — almost always 1
  because the k hash functions are independent).

Both schemes resolve to the same slot the lexicographic binary search in
the Pallas kernel (``repro.kernels.probe_arena``) finds, so the NumPy and
device probe backends are bit-for-bit interchangeable.
"""

from __future__ import annotations

import sys
from dataclasses import dataclass

import numpy as np

KIND_EMPTY = "empty"
KIND_INT = "int"
KIND_PAIR = "pair"

_MISS = np.uint64(0xFFFFFFFFFFFFFFFF)  # sentinel for unpackable probe keys


def _pack_pairs(toks: np.ndarray, kints: np.ndarray, kint_min
                ) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized ``(token << 32) | (k_int - kint_min)`` pair packing with
    its uint32 range checks: -> (packed u64 with ``_MISS`` on out-of-range,
    valid mask).  ``kint_min`` may be a scalar (one table) or an array
    broadcast against the inputs (the arena's per-coordinate biases)."""
    rel = kints - kint_min
    ok = (toks >= 0) & (toks < 1 << 32) & (rel >= 0) & (rel < 1 << 32)
    packed = (np.where(ok, toks, 0).astype(np.uint64) << np.uint64(32)) | \
        np.where(ok, rel, 0).astype(np.uint64)
    return np.where(ok, packed, _MISS), ok


def pack_ident_columns(kind: str, ident: np.ndarray
                       ) -> tuple[np.ndarray, int]:
    """Pack per-window identity columns into sortable uint64 keys.

    ``ident`` is what the columnar build pipeline accumulates: uint64 (N,)
    hash values for ``kind == "int"`` tables, int64 (N, 2) (token, k_int)
    rows for ``kind == "pair"``.  Returns (packed u64 (N,), kint_min) with
    exactly the range checks (and bias) of ``FrozenTable.from_dict`` — the
    distinct values of the window column ARE the table's keys, so checking
    all windows is checking all keys.
    """
    if kind == KIND_PAIR:
        toks = ident[:, 0]
        kints = ident[:, 1]
        if len(toks) and (toks.min() < 0 or toks.max() >= 1 << 32):
            raise ValueError("token id out of uint32 range: cannot "
                             "pack (token, k_int) keys for freezing")
        kint_min = int(kints.min()) if len(kints) else 0
        if len(kints) and int(kints.max()) - kint_min >= 1 << 32:
            raise ValueError("k_int span exceeds uint32: cannot pack "
                             "(token, k_int) keys for freezing")
        packed = (toks.astype(np.uint64) << np.uint64(32)) | \
            (kints - kint_min).astype(np.uint64)
        return packed, kint_min
    return np.ascontiguousarray(ident, np.uint64), 0


def _concat_ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenate [s, s+c) ranges into one index vector, vectorized."""
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, np.int64)
    rep_starts = np.repeat(starts, counts)
    ends = np.cumsum(counts)
    seq = np.arange(total, dtype=np.int64) - np.repeat(ends - counts, counts)
    return rep_starts + seq


@dataclass
class FrozenTable:
    """One immutable CSR inverted table (one sketch coordinate)."""

    kind: str
    keys: np.ndarray        # uint64 (nkeys,), sorted
    offsets: np.ndarray     # int64 (nkeys + 1,)
    windows: np.ndarray     # int32 (nwin, 5): tid, a, b, c, d
    kint_min: int = 0       # pair-pack bias (kind == "pair" only)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_dict(cls, table: dict) -> "FrozenTable":
        if not table:
            return cls(kind=KIND_EMPTY, keys=np.empty(0, np.uint64),
                       offsets=np.zeros(1, np.int64),
                       windows=np.empty((0, 5), np.int32))
        first = next(iter(table))
        kind = KIND_PAIR if isinstance(first, tuple) else KIND_INT
        kint_min = 0
        if kind == KIND_PAIR:
            toks = np.fromiter((k[0] for k in table), np.int64, len(table))
            kints = np.fromiter((k[1] for k in table), np.int64, len(table))
            if toks.min() < 0 or toks.max() >= 1 << 32:
                raise ValueError("token id out of uint32 range: cannot "
                                 "pack (token, k_int) keys for freezing")
            kint_min = int(kints.min())
            if int(kints.max()) - kint_min >= 1 << 32:
                raise ValueError("k_int span exceeds uint32: cannot pack "
                                 "(token, k_int) keys for freezing")
            packed = (toks.astype(np.uint64) << np.uint64(32)) | \
                (kints - kint_min).astype(np.uint64)
        else:
            packed = np.fromiter((int(k) for k in table), np.uint64,
                                 len(table))
        order = np.argsort(packed, kind="stable")
        packed = packed[order]
        items = list(table.values())
        counts = np.array([len(items[i]) for i in order], np.int64)
        offsets = np.zeros(len(packed) + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        # one concatenate over the key-ordered posting lists (C fast path)
        # instead of a per-key Python copy loop — freeze time is part of the
        # paper's index-construction cost
        windows = np.concatenate(
            [np.asarray(items[i], np.int32).reshape(-1, 5) for i in order],
            axis=0) if len(order) else np.empty((0, 5), np.int32)
        return cls(kind=kind, keys=packed, offsets=offsets, windows=windows,
                   kint_min=kint_min)

    @classmethod
    def from_packed_columns(cls, kind: str, packed: np.ndarray,
                            windows: np.ndarray, kint_min: int = 0
                            ) -> "FrozenTable":
        """Columnar freeze: per-window packed keys + window rows -> CSR.

        One global stable argsort groups the windows by ascending key while
        preserving append order within each key — block-identical to
        ``from_dict`` on the equivalent dict table (whose per-key lists
        hold the same windows in the same append order), with no dict ever
        materialized.
        """
        n = len(packed)
        if n == 0:
            return cls(kind=KIND_EMPTY, keys=np.empty(0, np.uint64),
                       offsets=np.zeros(1, np.int64),
                       windows=np.empty((0, 5), np.int32))
        order = np.argsort(packed, kind="stable")
        packed = packed[order]
        windows = np.ascontiguousarray(
            np.asarray(windows, np.int32).reshape(-1, 5)[order])
        starts = np.concatenate(
            [[0], np.flatnonzero(packed[1:] != packed[:-1]) + 1])
        offsets = np.concatenate([starts, [n]]).astype(np.int64)
        return cls(kind=kind, keys=np.ascontiguousarray(packed[starts]),
                   offsets=offsets, windows=windows, kint_min=kint_min)

    @classmethod
    def from_columns(cls, kind: str, ident: np.ndarray, windows: np.ndarray
                     ) -> "FrozenTable":
        """``pack_ident_columns`` + ``from_packed_columns`` in one step."""
        if kind == KIND_EMPTY or len(windows) == 0:
            return cls.from_packed_columns(KIND_EMPTY,
                                           np.empty(0, np.uint64), windows)
        packed, kint_min = pack_ident_columns(kind, ident)
        return cls.from_packed_columns(kind, packed, windows, kint_min)

    def ident_columns(self) -> tuple[np.ndarray, np.ndarray]:
        """The table's contents as per-window (identity, windows) columns —
        the inverse of the columnar freeze, for merge-compaction.

        Repeating each key over its CSR range recovers exactly the append
        columns the columnar pipeline would hold for these windows: CSR
        order is key-ascending with append order preserved inside each
        key, and ``FrozenTable.from_packed_columns``'s stable sort leaves
        such a column block-identical.  Pair keys are unpacked back to
        exact ``(token, k_int)`` rows (the pack is lossless), so absorbed
        columns re-pack against whatever ``kint_min`` the merged table
        needs.
        """
        per = np.repeat(np.asarray(self.keys), np.diff(self.offsets))
        if self.kind == KIND_PAIR:
            ident = np.empty((len(per), 2), np.int64)
            ident[:, 0] = (per >> np.uint64(32)).astype(np.int64)
            ident[:, 1] = (per & np.uint64(0xFFFFFFFF)).astype(np.int64) \
                + self.kint_min
        else:
            ident = per
        return ident, np.asarray(self.windows)

    # -- probing ------------------------------------------------------------

    def encode(self, values) -> np.ndarray:
        """Pack a list of probe keys -> uint64 (P,); unpackable -> _MISS."""
        if self.kind == KIND_PAIR:
            toks = np.array([v[0] for v in values], np.int64)
            kints = np.array([v[1] for v in values], np.int64)
            packed, _ok = _pack_pairs(toks, kints, self.kint_min)
            return packed
        if self.kind == KIND_INT:
            return np.array([int(v) for v in values], np.uint64)
        return np.full(len(values), _MISS, np.uint64)

    def probe(self, packed: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized lookup: packed (P,) u64 -> CSR (starts, ends) int64.

        Misses get an empty range (start == end == 0).
        """
        n = len(self.keys)
        if n == 0:
            z = np.zeros(len(packed), np.int64)
            return z, z
        pos = np.searchsorted(self.keys, packed)
        safe = np.where(pos < n, pos, 0)
        hit = (pos < n) & (self.keys[safe] == packed)
        starts = np.where(hit, self.offsets[safe], 0)
        ends = np.where(hit, self.offsets[safe + 1], 0)
        return starts, ends

    def get(self, v, default=None):
        """dict.get-compatible single lookup -> int32 (m, 5) rows."""
        packed = self.encode([v])
        s, e = self.probe(packed)
        if e[0] > s[0]:
            return self.windows[s[0]:e[0]]
        return default if default is not None else self.windows[:0]

    # -- introspection / persistence ----------------------------------------

    def __len__(self) -> int:
        return len(self.keys)

    @property
    def nbytes(self) -> int:
        return self.keys.nbytes + self.offsets.nbytes + self.windows.nbytes

    def state_dict(self) -> dict:
        return {"kind": self.kind, "keys": self.keys, "offsets": self.offsets,
                "windows": self.windows, "kint_min": self.kint_min}

    @classmethod
    def from_state(cls, state: dict) -> "FrozenTable":
        return cls(kind=state["kind"],
                   keys=np.asarray(state["keys"], np.uint64),
                   offsets=np.asarray(state["offsets"], np.int64),
                   windows=np.asarray(state["windows"], np.int32),
                   kint_min=int(state["kint_min"]))


# --------------------------------------------------------------------------
# fused probe arena
# --------------------------------------------------------------------------

PACK_SHIFT = 56                    # coord tag bits in "packed" mode
_PACK_LIMIT = np.uint64(1) << np.uint64(PACK_SHIFT)

MODE_PACKED = "packed"
MODE_COORD = "coord"


@dataclass
class ProbeArena:
    """All k frozen tables fused into one device-residable CSR structure.

    See the module docstring for the two re-keying schemes.  ``windows``
    rows are regrouped so each arena slot's CSR range is contiguous, which
    keeps the batch gather a single ``_concat_ranges`` + fancy index.
    """

    mode: str
    keys: np.ndarray          # uint64 (nslots,), globally sorted (see mode)
    coords: np.ndarray        # uint16 (nslots,) coordinate tags ("coord"
                              # mode; empty in "packed" mode)
    offsets: np.ndarray       # int64 (nslots + 1,) global CSR row pointers
    windows: np.ndarray       # int32 (nwin, 5): tid, a, b, c, d
    kinds: list[str]          # per-coordinate table kind
    kint_mins: np.ndarray     # int64 (k,) per-coordinate pair-pack bias
    max_run: int = 1          # longest equal-key run ("coord" mode bound)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_tables(cls, tables: list[FrozenTable],
                    mode: str | None = None) -> "ProbeArena":
        k = len(tables)
        if mode is None:
            packable = k <= (1 << (64 - PACK_SHIFT)) and all(
                t.keys.size == 0 or np.uint64(t.keys.max()) < _PACK_LIMIT
                for t in tables)
            mode = MODE_PACKED if packable else MODE_COORD
        kinds = [t.kind for t in tables]
        kint_mins = np.array([t.kint_min for t in tables], np.int64)
        key_chunks, coord_chunks, count_chunks, start_chunks, win_chunks = \
            [], [], [], [], []
        win_base = 0
        for i, t in enumerate(tables):
            key_chunks.append(t.keys)
            coord_chunks.append(np.full(len(t.keys), i, np.uint16))
            count_chunks.append(np.diff(t.offsets))
            start_chunks.append(t.offsets[:-1] + win_base)
            win_chunks.append(np.asarray(t.windows))
            win_base += len(t.windows)
        keys = np.concatenate(key_chunks) if key_chunks else \
            np.empty(0, np.uint64)
        coords = np.concatenate(coord_chunks) if coord_chunks else \
            np.empty(0, np.uint16)
        counts = np.concatenate(count_chunks) if count_chunks else \
            np.empty(0, np.int64)
        starts = np.concatenate(start_chunks) if start_chunks else \
            np.empty(0, np.int64)
        windows = np.concatenate(win_chunks) if win_chunks else \
            np.empty((0, 5), np.int32)
        max_run = 1
        if mode == MODE_PACKED:
            if keys.size and np.uint64(keys.max()) >= _PACK_LIMIT:
                raise ValueError("keys exceed 56 bits: cannot re-key as "
                                 "(coord << 56) | key; use mode='coord'")
            # per-coordinate segments are sorted, so the coordinate-major
            # concatenation is globally sorted once coord rides the top bits
            keys = (coords.astype(np.uint64) << np.uint64(PACK_SHIFT)) | keys
            coords = np.empty(0, np.uint16)
            # windows are already grouped in slot order
        else:
            order = np.lexsort((coords, keys))   # key primary, coord tie
            keys = np.ascontiguousarray(keys[order])
            coords = np.ascontiguousarray(coords[order])
            starts, counts = starts[order], counts[order]
            windows = windows[_concat_ranges(starts, counts)]
            if keys.size:
                change = np.flatnonzero(keys[1:] != keys[:-1])
                bounds = np.concatenate([[0], change + 1, [len(keys)]])
                max_run = int(np.diff(bounds).max())
        offsets = np.zeros(len(keys) + 1, np.int64)
        np.cumsum(counts, out=offsets[1:])
        return cls(mode=mode, keys=keys, coords=coords, offsets=offsets,
                   windows=windows, kinds=kinds, kint_mins=kint_mins,
                   max_run=max_run)

    @classmethod
    def from_window_columns(cls, kinds: list[str],
                            packed_cols: list[np.ndarray],
                            window_cols: list[np.ndarray],
                            kint_mins: np.ndarray,
                            mode: str | None = None) -> "ProbeArena":
        """Build the arena straight from per-coordinate window columns.

        ``packed_cols[i]``/``window_cols[i]`` are coordinate i's per-window
        packed keys (``pack_ident_columns``) and int32 (n_i, 5) rows in
        append order — the columnar build pipeline's buffers.  ONE global
        lexsort replaces the per-table sort + slot regroup of
        ``from_tables``; the result is array-identical to
        ``from_tables([FrozenTable.from_packed_columns(...)])`` because
        both orderings group windows by (coordinate, key) — resp. (key,
        coordinate) — with append order preserved inside each slot.
        """
        k = len(kinds)
        key_w = np.concatenate(packed_cols) if packed_cols else \
            np.empty(0, np.uint64)
        coord_w = np.concatenate(
            [np.full(len(p), i, np.uint16)
             for i, p in enumerate(packed_cols)]) if packed_cols else \
            np.empty(0, np.uint16)
        windows = np.concatenate(
            [np.asarray(w, np.int32).reshape(-1, 5) for w in window_cols]
        ) if window_cols else np.empty((0, 5), np.int32)
        if mode is None:
            packable = k <= (1 << (64 - PACK_SHIFT)) and (
                key_w.size == 0 or np.uint64(key_w.max()) < _PACK_LIMIT)
            mode = MODE_PACKED if packable else MODE_COORD
        n = len(key_w)
        max_run = 1
        if n == 0:
            keys = np.empty(0, np.uint64)
            coords = np.empty(0, np.uint16)
            offsets = np.zeros(1, np.int64)
        elif mode == MODE_PACKED:
            if np.uint64(key_w.max()) >= _PACK_LIMIT:
                raise ValueError("keys exceed 56 bits: cannot re-key as "
                                 "(coord << 56) | key; use mode='coord'")
            order = np.lexsort((key_w, coord_w))   # coord-major, key asc
            qk = (coord_w[order].astype(np.uint64)
                  << np.uint64(PACK_SHIFT)) | key_w[order]
            windows = np.ascontiguousarray(windows[order])
            starts = np.concatenate(
                [[0], np.flatnonzero(qk[1:] != qk[:-1]) + 1])
            keys = np.ascontiguousarray(qk[starts])
            coords = np.empty(0, np.uint16)
            offsets = np.concatenate([starts, [n]]).astype(np.int64)
        else:
            order = np.lexsort((coord_w, key_w))   # key primary, coord tie
            sk, sc = key_w[order], coord_w[order]
            windows = np.ascontiguousarray(windows[order])
            starts = np.concatenate(
                [[0], np.flatnonzero((sk[1:] != sk[:-1]) |
                                     (sc[1:] != sc[:-1])) + 1])
            keys = np.ascontiguousarray(sk[starts])
            coords = np.ascontiguousarray(sc[starts])
            offsets = np.concatenate([starts, [n]]).astype(np.int64)
            if keys.size:
                change = np.flatnonzero(keys[1:] != keys[:-1])
                bounds = np.concatenate([[0], change + 1, [len(keys)]])
                max_run = int(np.diff(bounds).max())
        return cls(mode=mode, keys=keys, coords=coords, offsets=offsets,
                   windows=windows, kinds=list(kinds),
                   kint_mins=np.asarray(kint_mins, np.int64),
                   max_run=max_run)

    # -- probing ------------------------------------------------------------

    @property
    def k(self) -> int:
        return len(self.kinds)

    def encode_batch(self, sketches) -> tuple[np.ndarray, np.ndarray,
                                              np.ndarray]:
        """Pack a batch of sketches into flat probe arrays.

        sketches: B lists of k identities (ints or (token, k_int) tuples).
        Returns (probe_keys u64, probe_coords u16, valid bool), each
        (B*k,) in (query-major, coordinate-minor) order.
        """
        B = len(sketches)
        k = self.k
        coords = np.tile(np.arange(k, dtype=np.uint16), B)
        live = np.array([kind != KIND_EMPTY for kind in self.kinds], bool)
        valid = np.tile(live, B)
        if B and isinstance(sketches[0][0], (tuple, list, np.ndarray)):
            ident = np.asarray(sketches, np.int64)          # (B, k, 2)
            pkeys, ok = _pack_pairs(ident[..., 0], ident[..., 1],
                                    self.kint_mins[None, :])
            pkeys = pkeys.ravel()
            valid &= ok.ravel()
        else:
            pkeys = np.array(sketches, np.uint64).reshape(-1)
        if self.mode == MODE_PACKED:
            # stored keys all fit in 56 bits, so wider probes cannot hit
            valid &= pkeys < _PACK_LIMIT
        return pkeys, coords, valid

    def probe(self, pkeys: np.ndarray, coords: np.ndarray,
              valid: np.ndarray, *, backend: str = "numpy",
              interpret: bool | None = None
              ) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized arena lookup -> CSR (starts, ends) int64, one
        ``searchsorted`` (or one Pallas launch) for the whole batch.
        Misses get an empty range (start == end == 0)."""
        n = len(self.keys)
        if n == 0 or len(pkeys) == 0:
            z = np.zeros(len(pkeys), np.int64)
            return z, z
        if self.mode == MODE_PACKED:
            q = (coords.astype(np.uint64) << np.uint64(PACK_SHIFT)) | \
                np.where(valid, pkeys, 0)
            if backend == "pallas":
                pos = self._pallas_search(q, np.zeros(len(q), np.uint32),
                                          interpret=interpret)
            else:
                pos = np.searchsorted(self.keys, q)
            safe = np.minimum(pos, n - 1)
            hit = valid & (pos < n) & (self.keys[safe] == q)
        else:
            if backend == "pallas":
                pos = self._pallas_search(pkeys, coords.astype(np.uint32),
                                          interpret=interpret)
            else:
                pos = np.searchsorted(self.keys, pkeys)
                # advance over the (tiny) duplicate run to the probe's
                # coordinate; bounded by the longest equal-key run
                for _ in range(self.max_run - 1):
                    safe = np.minimum(pos, n - 1)
                    adv = (pos < n) & (self.keys[safe] == pkeys) & \
                        (self.coords[safe] < coords)
                    if not adv.any():
                        break
                    pos = pos + adv
            safe = np.minimum(pos, n - 1)
            hit = valid & (pos < n) & (self.keys[safe] == pkeys) & \
                (self.coords[safe] == coords)
        starts = np.where(hit, self.offsets[safe], 0)
        ends = np.where(hit, self.offsets[safe + 1], 0)
        return starts, ends

    def _pallas_search(self, qkeys: np.ndarray, qtags: np.ndarray, *,
                       interpret: bool | None) -> np.ndarray:
        from ..kernels.probe_arena import arena_search
        if self.mode == MODE_COORD:
            tags = np.ascontiguousarray(self.coords, dtype=np.uint32)
        else:
            tags = np.zeros(len(self.keys), np.uint32)
        return np.asarray(arena_search(
            np.asarray(self.keys), tags, qkeys, qtags, interpret=interpret),
            dtype=np.int64)

    # -- introspection ------------------------------------------------------

    @property
    def nbytes(self) -> int:
        return (self.keys.nbytes + self.coords.nbytes +
                self.offsets.nbytes + self.windows.nbytes)


def dict_tables_nbytes(tables: list[dict]) -> int:
    """Resident size of dict-of-lists-of-tuples tables (recursive sizeof)."""
    total = 0
    for table in tables:
        total += sys.getsizeof(table)
        for key, wins in table.items():
            total += sys.getsizeof(key) + sys.getsizeof(wins)
            for w in wins:
                total += sys.getsizeof(w) + sum(sys.getsizeof(x) for x in w)
    return total
