"""AllAlign — the greedy recursive partitioning baseline (Feng & Deng,
SIGMOD'21), reconstructed from the description in §6 of the paper:

    "AllAlign generates compact windows in recursion.  In each iteration, it
     takes a rectangle as input and partitions all the subsequences in this
     rectangle into a few compact windows and one or more smaller rectangles
     ... recursively partitioned until no rectangles left.  At the beginning,
     the input rectangle is [1,n] × [1,n]."

Reconstruction: for a rectangle R = [rl,rh] × [cl,ch] of cells (i,j)
(start, end positions), the largest cell (rl, ch) contains the key set of
every cell in R; let (p*, q*) be the minimum-hash key inside span [rl, ch].
Every cell (i, j) ∈ R with i ≤ p* and j ≥ q* contains that key, and cannot
contain a smaller one (it is inside [rl, ch]) — so the sub-rectangle
[rl, min(rh,p*)] × [max(cl,q*), ch] is one compact window with value
h(p*,q*).  The two leftover rectangles recurse.  This is greedy (earliest
split boundaries fragment windows — the behaviour the paper measures) and
has no complexity guarantee, exactly as the paper states.

The min-key-in-span query uses a segment tree over the hash-sorted key
array with (max p, min q) per node, descending leftmost-first.
"""

from __future__ import annotations

import numpy as np

from .hashing import UniversalHash
from .icws import ICWS
from .keys import KeySet, generate_keys_icws, generate_keys_multiset
from .partition import Partition
from .weights import WeightFn


class _MinKeyInSpan:
    """First key (in hash order) with p >= lo and q <= hi."""

    def __init__(self, p: np.ndarray, q: np.ndarray):
        self.m = m = len(p)
        size = 1
        while size < max(m, 1):
            size *= 2
        self.size = size
        self.maxp = np.full(2 * size, -1, dtype=np.int64)
        self.minq = np.full(2 * size, np.iinfo(np.int64).max, dtype=np.int64)
        self.maxp[size:size + m] = p
        self.minq[size:size + m] = q
        for i in range(size - 1, 0, -1):
            self.maxp[i] = max(self.maxp[2 * i], self.maxp[2 * i + 1])
            self.minq[i] = min(self.minq[2 * i], self.minq[2 * i + 1])
        self.p = p
        self.q = q

    def first(self, lo: int, hi: int) -> int:
        """Smallest index idx with p[idx] >= lo and q[idx] <= hi, else -1."""
        if self.m == 0:
            return -1
        return self._descend(1, lo, hi)

    def _descend(self, node: int, lo: int, hi: int) -> int:
        # node conditions (max p, min q) are necessary, not sufficient —
        # descend leftmost-first with backtracking.
        if not (self.maxp[node] >= lo and self.minq[node] <= hi):
            return -1
        if node >= self.size:
            idx = node - self.size
            if idx < self.m and self.p[idx] >= lo and self.q[idx] <= hi:
                return idx
            return -1
        cand = self._descend(2 * node, lo, hi)
        if cand >= 0:
            return cand
        return self._descend(2 * node + 1, lo, hi)


def allalign_partition(keys: KeySet) -> Partition:
    """Greedy recursive partition from a hash-sorted KeySet."""
    n = keys.n
    tree = _MinKeyInSpan(keys.p, keys.q)
    kp, kq, kg = keys.p, keys.q, keys.gid

    out_gid: list[int] = []
    out_a: list[int] = []
    out_b: list[int] = []
    out_c: list[int] = []
    out_d: list[int] = []

    # stack of rectangles [rl, rh] x [cl, ch] (start-range x end-range)
    stack = [(0, n - 1, 0, n - 1)]
    while stack:
        rl, rh, cl, ch = stack.pop()
        # clip away invalid cells (i > j): need i <= j, i >= rl, j <= ch
        if rl > rh or cl > ch or rl > ch:
            continue
        idx = tree.first(rl, ch)
        if idx < 0:
            continue  # cannot happen for non-empty valid rect ((i,i) keys)
        ps, qs = int(kp[idx]), int(kq[idx])
        pe = min(rh, ps)
        qs_clip = max(cl, qs)
        if pe >= rl and qs_clip <= ch:
            out_gid.append(int(kg[idx]))
            out_a.append(rl)
            out_b.append(pe)
            out_c.append(qs_clip)
            out_d.append(ch)
        # leftovers
        stack.append((pe + 1, rh, cl, ch))       # rows below the window
        stack.append((rl, pe, cl, qs_clip - 1))  # left part of window rows
    return Partition(
        n=n,
        gid=np.array(out_gid, dtype=np.int64),
        a=np.array(out_a, dtype=np.int64),
        b=np.array(out_b, dtype=np.int64),
        c=np.array(out_c, dtype=np.int64),
        d=np.array(out_d, dtype=np.int64),
        gid_key=keys.gid_key,
    )


def allalign_multiset(tokens, hashfn: UniversalHash) -> Partition:
    """AllAlign baseline for multi-set Jaccard (its published scope)."""
    return allalign_partition(generate_keys_multiset(tokens, hashfn, active=False))


def allalign_icws(tokens, icws: ICWS, weight: WeightFn) -> Partition:
    """AllAlign extended to CWS (for like-for-like comparisons only;
    the original system does not support weighted Jaccard)."""
    return allalign_partition(generate_keys_icws(tokens, icws, weight, active=False))
