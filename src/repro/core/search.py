"""Immutable serve-side index: frozen CSR tables, vectorized probes.

``SearchIndex`` is the *serve* half of the build→serve lifecycle: a fixed
set of :class:`~repro.core.frozen.FrozenTable` CSR tables plus the metadata
the query engine needs.  It has no ``add_text`` — growing an index is the
:class:`repro.core.builder.IndexBuilder`'s job — so there is no frozen/
mutable personality switch to trip over at runtime.

Persistence goes through the versioned directory store
(:mod:`repro.core.store`): ``save(path)`` writes a JSON manifest plus one
raw ``.npy`` file per table array, and ``SearchIndex.load(path, mmap=True)``
maps those arrays back with ``np.load(mmap_mode="r")`` so a larger-than-RAM
corpus serves queries without materializing ``windows``/``keys``/
``offsets``.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .frozen import FrozenTable, ProbeArena


@dataclass
class SearchIndex:
    """k immutable CSR inverted tables over a fixed collection."""

    scheme: object
    tables: list[FrozenTable]
    method: str = "mono_active"
    num_texts: int = 0
    num_windows: int = 0
    text_lengths: list[int] = field(default_factory=list)
    _arena: ProbeArena | None = field(default=None, repr=False, compare=False)
    # (host ProbeArena, DeviceArena | None) pair cached by
    # repro.core.device_plan.device_arena — keyed on the arena's identity,
    # so residency lives and dies with this (immutable) index instance
    _device_arena: tuple | None = field(default=None, repr=False,
                                        compare=False)

    # -- query-engine surface (duck-typed with IndexBuilder) ----------------

    @property
    def is_frozen(self) -> bool:
        return True

    @property
    def frozen(self) -> list[FrozenTable]:
        """The CSR tables, under the name the batched probe path uses."""
        return self.tables

    def lookup(self, i: int, v):
        """Postings of hash identity ``v`` in table ``i``: an int32 (m, 5)
        row view (iterates as 5-sequences, like the builder's tuples)."""
        return self.tables[i].get(v)

    def arena(self) -> ProbeArena:
        """The fused probe arena over all k tables (one-searchsorted batch
        probes).  Built lazily from the tables and cached; a store load
        restores the persisted arena instead (mmap-able)."""
        if self._arena is None:
            self._arena = ProbeArena.from_tables(self.tables)
        return self._arena

    def freeze(self) -> "SearchIndex":
        """Already frozen; returns self so build/serve call sites compose."""
        return self

    def nbytes(self) -> int:
        """Exact resident array bytes (mmap-backed arrays count virtual)."""
        return sum(t.nbytes for t in self.tables)

    def is_mmap(self) -> bool:
        """True when every non-empty table array is memory-mapped."""
        import numpy as np
        arrays = [a for t in self.tables
                  for a in (t.keys, t.offsets, t.windows) if a.size]
        return bool(arrays) and all(isinstance(a, np.memmap) for a in arrays)

    # -- persistence --------------------------------------------------------

    def save(self, path) -> None:
        """Write the versioned on-disk format (manifest + ``.npy`` arrays)."""
        from .store import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path, *, mmap: bool = True) -> "SearchIndex":
        """Load a saved index; ``mmap=True`` maps the table arrays instead
        of reading them into RAM."""
        from .store import load_index
        return load_index(path, mmap=mmap)

    # legacy dict-state round-trip (kept for the sharded pickle checkpoints)

    def state_dict(self) -> dict:
        return {"method": self.method, "num_texts": self.num_texts,
                "num_windows": self.num_windows,
                "text_lengths": list(self.text_lengths), "tables": [],
                "frozen": [t.state_dict() for t in self.tables]}

    @classmethod
    def from_state(cls, scheme, state: dict) -> "SearchIndex":
        return cls(scheme=scheme, method=state["method"],
                   tables=[FrozenTable.from_state(s)
                           for s in state["frozen"]],
                   num_texts=state["num_texts"],
                   num_windows=state["num_windows"],
                   text_lengths=list(state["text_lengths"]))
