"""Engine-thread affinity guard for the serving concurrency model.

The serving stack's correctness rests on ONE invariant: every operation
that touches a served index's mutable state — delta adds, the seal and
promote phases of compaction — runs on the single
``DynamicBatcher`` engine thread (a ``ThreadPoolExecutor(max_workers=1,
thread_name_prefix="align-engine")``).  That invariant used to live only
in docstrings; this module makes it machine-checkable twice over:

* **statically** — ``@engine_only`` marks the mutating APIs, and
  ``python -m repro.analysis`` (rule RPR101) flags any call path in
  :mod:`repro.serve` that reaches a marked function without going
  through ``DynamicBatcher.submit_query``/``submit_control``;
* **at runtime** — with ``REPRO_THREAD_GUARD=1`` in the environment, a
  marked method raises :class:`EngineAffinityError` when called on an
  *engine-owned* object from any thread other than the engine.

Ownership keeps the guard precise: ``DynamicBatcher`` calls
:func:`adopt` on the index it serves (and :func:`disown` on close), so
build scripts, benchmarks and tests that mutate indexes no server owns
keep working unguarded even with the env var set.

The env var is read ONCE, at import time.  Guard off (the default) means
``engine_only`` hands back the original function — the decorated call
path carries zero overhead, not even an ``if``.
"""

from __future__ import annotations

import functools
import os
import threading

#: Thread-name prefix of the batcher's single-worker engine executor.
ENGINE_THREAD_PREFIX = "align-engine"

#: Read once at import: runtime enforcement is opt-in per process.
GUARD_ENABLED = os.environ.get("REPRO_THREAD_GUARD", "") == "1"


class EngineAffinityError(RuntimeError):
    """An engine-only method ran off the engine thread while its object
    was owned by a serving ``DynamicBatcher``."""


def on_engine_thread() -> bool:
    """True when the current thread is a batcher engine worker."""
    return threading.current_thread().name.startswith(ENGINE_THREAD_PREFIX)


def adopt(*objs) -> None:
    """Mark objects engine-owned: their ``@engine_only`` methods must now
    run on the engine thread (no-op unless the guard is enabled)."""
    for o in objs:
        if o is None:
            continue
        try:
            o._engine_owned = True
        except (AttributeError, TypeError):
            pass                      # slots/frozen objects stay unguarded


def disown(*objs) -> None:
    """Release engine ownership (the batcher shut its engine down)."""
    for o in objs:
        if o is None:
            continue
        try:
            o._engine_owned = False
        except (AttributeError, TypeError):
            pass


def engine_only(fn=None, *, reads_immutable: bool = False):
    """Declare a method part of the engine-only mutating API.

    Always attaches the static markers ``__engine_only__`` (and
    ``__engine_reads_immutable__``) that ``repro.analysis`` keys on.
    With ``REPRO_THREAD_GUARD=1`` it additionally wraps the method to
    raise :class:`EngineAffinityError` when the receiver is engine-owned
    (see :func:`adopt`) and the caller is not the engine thread.

    ``reads_immutable=True`` is for the one sanctioned exception — the
    compaction *merge*, which deliberately runs off-band and reads only
    immutable state (frozen arrays + the sealed delta).  It gets the
    static marker but never the runtime check.
    """
    def mark(f):
        f.__engine_only__ = True
        f.__engine_reads_immutable__ = reads_immutable
        return f

    def wrap(f):
        if not GUARD_ENABLED or reads_immutable:
            return mark(f)            # guard off: the original function

        @functools.wraps(f)
        def guarded(self, *args, **kwargs):
            if getattr(self, "_engine_owned", False) \
                    and not on_engine_thread():
                raise EngineAffinityError(
                    f"{type(self).__name__}.{f.__name__} is engine-only: "
                    f"this object is served by a DynamicBatcher engine, "
                    f"but the call came from thread "
                    f"{threading.current_thread().name!r}; route it "
                    "through DynamicBatcher.submit_control/submit_query")
            return f(self, *args, **kwargs)

        return mark(guarded)

    return wrap if fn is None else wrap(fn)
