"""Mutable build-side index (Algorithm 1): dict tables, incremental adds.

``IndexBuilder`` is the *build* half of the build→serve lifecycle.  It
partitions each added text under all k hash functions and appends the
compact windows to per-coordinate dict tables
``key -> list[(tid, a, b, c, d)]`` — ideal for incremental construction,
terrible for serving.  ``freeze()`` hands off to the immutable
:class:`repro.core.search.SearchIndex` (contiguous CSR arrays, vectorized
probes, mmap-able persistence); the builder itself never changes
personality and stays usable afterwards.

``query``/``batch_query`` accept a builder directly (dict-table probes), so
admit-as-you-go workloads like :class:`repro.data.dedup.DedupFilter` never
need to freeze.

For whole-corpus (batch) construction, the columnar pipeline
(:class:`repro.core.columnar.ColumnarBuilder`) produces block-identical
frozen tables without ever materializing these dict tables, several times
faster — this builder remains the incremental path.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

from .allalign import allalign_partition
from .frozen import FrozenTable, dict_tables_nbytes
from .guard import engine_only
from .keys import occurrence_lists
from .partition import monotonic_partition

_METHODS = {
    "mono_all": (monotonic_partition, False),
    "mono_active": (monotonic_partition, True),
    "allalign": (allalign_partition, False),
}


@dataclass
class IndexBuilder:
    """k inverted dict-tables of compact windows over a growing collection."""

    scheme: object
    method: str = "mono_active"
    tables: list[dict] = field(default_factory=list)
    num_texts: int = 0
    num_windows: int = 0
    text_lengths: list[int] = field(default_factory=list)

    def __post_init__(self):
        if not self.tables:
            self.tables = [dict() for _ in range(self.scheme.k)]

    # the query engine duck-types on this flag to pick its probe path
    @property
    def is_frozen(self) -> bool:
        return False

    @engine_only
    def add_text(self, tokens) -> int:
        """Partition one text under all k hash functions and index it."""
        tid = self.num_texts
        self.num_texts += 1
        self.text_lengths.append(len(tokens))
        partition_fn, active = _METHODS[self.method]
        occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
        for i in range(self.scheme.k):
            keys = self.scheme.keys(tokens, i, active, occ=occ)
            part = partition_fn(keys)
            self.num_windows += len(part)
            table = self.tables[i]
            for w in range(len(part)):
                v = part.gid_key[int(part.gid[w])]
                table.setdefault(v, []).append(
                    (tid, int(part.a[w]), int(part.b[w]),
                     int(part.c[w]), int(part.d[w])))
        return tid

    def build(self, texts: Iterable) -> "IndexBuilder":
        for tokens in texts:
            self.add_text(tokens)
        return self

    def lookup(self, i: int, v):
        """Postings of hash identity ``v`` in table ``i``."""
        return self.tables[i].get(v, [])

    def table_columns(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """Table ``i``'s contents as per-window (identity, windows) columns
        for merge-compaction (:meth:`ColumnarBuilder.absorb_builder`).

        Windows come out grouped by key (dict insertion order) with append
        order preserved inside each key — the only order a stable
        key-sort cares about, so the columnar freeze of these columns is
        block-identical to ``freeze()`` of this table.
        """
        table = self.tables[i]
        if not table:
            return np.empty(0, np.uint64), np.empty((0, 5), np.int32)
        counts = np.fromiter((len(v) for v in table.values()),
                             np.int64, len(table))
        windows = np.concatenate(
            [np.asarray(v, np.int32).reshape(-1, 5) for v in table.values()])
        if isinstance(next(iter(table)), tuple):
            ident = np.empty((len(windows), 2), np.int64)
            ident[:, 0] = np.repeat(np.fromiter(
                (k[0] for k in table), np.int64, len(table)), counts)
            ident[:, 1] = np.repeat(np.fromiter(
                (k[1] for k in table), np.int64, len(table)), counts)
        else:
            ident = np.repeat(np.fromiter(
                (int(k) for k in table), np.uint64, len(table)), counts)
        return ident, windows

    def nbytes(self) -> int:
        """Resident size estimate (recursive ``sys.getsizeof``)."""
        return dict_tables_nbytes(self.tables)

    def freeze(self):
        """Compact into an immutable :class:`SearchIndex` (build→serve
        handoff).  The builder is left untouched; callers that are done
        building simply drop it."""
        from .search import SearchIndex
        return SearchIndex(
            scheme=self.scheme, method=self.method,
            tables=[FrozenTable.from_dict(t) for t in self.tables],
            num_texts=self.num_texts, num_windows=self.num_windows,
            text_lengths=list(self.text_lengths))

    # -- persistence (build-time checkpoints; serve-side uses the store) ----

    def state_dict(self) -> dict:
        return {"method": self.method, "num_texts": self.num_texts,
                "num_windows": self.num_windows,
                "text_lengths": list(self.text_lengths),
                "tables": self.tables}

    def load_state_dict(self, state: dict) -> None:
        self.method = state["method"]
        self.num_texts = state["num_texts"]
        self.num_windows = state["num_windows"]
        self.text_lengths = list(state["text_lengths"])
        self.tables = state["tables"]
