"""Brute-force O(n²) oracles and partition validators.

These enumerate every subsequence T[i..j] and compute its min-hash by
definition (Eq. 1 / Eq. 4).  Used only by tests and benchmark verification.
"""

from __future__ import annotations

import numpy as np

from .icws import ICWS
from .keys import occurrence_lists
from .partition import Partition
from .weights import WeightFn

_NOVAL = -1


def minhash_gid_grid_multiset(tokens, hashfn) -> tuple[np.ndarray, list]:
    """(n, n) grid of *dense group ids* of the min-hash of T[i..j] (upper
    triangle; lower triangle = -1), plus gid -> hash-value table.

    Group ids here are keyed identically to keys.generate_keys_multiset:
    the integer hash value itself (deduped into a local table).
    """
    tokens = np.asarray(tokens, dtype=np.int64)
    n = len(tokens)
    occ = occurrence_lists(tokens)
    # hash lookup per (token, freq)
    hgrid = {t: hashfn(np.full(len(pos), t, dtype=np.int64),
                       np.arange(1, len(pos) + 1)) for t, pos in occ.items()}
    key_of: dict[int, int] = {}
    table: list = []
    grid = np.full((n, n), _NOVAL, dtype=np.int64)
    for i in range(n):
        counts: dict[int, int] = {}
        cur = None  # uint64 running min
        for j in range(i, n):
            t = int(tokens[j])
            x = counts.get(t, 0) + 1
            counts[t] = x
            hv = int(hgrid[t][x - 1])
            if cur is None or hv < cur:
                cur = hv
            if cur not in key_of:
                key_of[cur] = len(table)
                table.append(cur)
            grid[i, j] = key_of[cur]
    return grid, table


def minhash_gid_grid_icws(tokens, icws: ICWS, weight: WeightFn
                          ) -> tuple[np.ndarray, list]:
    """Same as above under CWS: identity = (token, k_int), order = a."""
    tokens = np.asarray(tokens, dtype=np.int64)
    n = len(tokens)
    occ = occurrence_lists(tokens)
    agrid = {}
    kgrid = {}
    for t, pos in occ.items():
        m = len(pos)
        w = weight.grid(t, m)
        k_int, _y, a = icws.hash_parts(np.full(m, t, dtype=np.int64), w)
        agrid[t] = a
        kgrid[t] = k_int
    key_of: dict[tuple, int] = {}
    table: list = []
    grid = np.full((n, n), _NOVAL, dtype=np.int64)
    for i in range(n):
        counts: dict[int, int] = {}
        cur_a = np.inf
        cur_key = None
        for j in range(i, n):
            t = int(tokens[j])
            x = counts.get(t, 0) + 1
            counts[t] = x
            av = float(agrid[t][x - 1])
            if av < cur_a:
                cur_a = av
                cur_key = (t, int(kgrid[t][x - 1]))
            if cur_key not in key_of:
                key_of[cur_key] = len(table)
                table.append(cur_key)
            grid[i, j] = key_of[cur_key]
    return grid, table


def validate_partition(part: Partition, grid: np.ndarray, table: list
                       ) -> None:
    """Assert Definition 3 (disjointness + coverage) and value correctness
    of every compact window against the oracle grid.  Raises AssertionError.
    """
    n = part.n
    cover = np.zeros((n, n), dtype=np.int64)
    # map part gids -> oracle gids through the hash-value identity
    oracle_gid_of = {v: i for i, v in enumerate(table)}
    for w in range(len(part)):
        a, b, c, d = int(part.a[w]), int(part.b[w]), int(part.c[w]), int(part.d[w])
        assert 0 <= a <= b <= c <= d < n, f"window {w} coords invalid: {(a,b,c,d)}"
        cover[a:b + 1, c:d + 1] += 1
        want = oracle_gid_of[part.gid_key[int(part.gid[w])]]
        cells = grid[a:b + 1, c:d + 1]
        assert np.all(cells == want), (
            f"window {w}=({a},{b},{c},{d}) value mismatch: "
            f"oracle gids {np.unique(cells)} vs {want}")
    iu = np.triu_indices(n)
    assert np.all(cover[iu] == 1), (
        f"coverage violated: {np.sum(cover[iu] == 0)} uncovered, "
        f"{np.sum(cover[iu] > 1)} overlapping cells")
    il = np.tril_indices(n, k=-1)
    assert np.all(cover[il] == 0), "windows cover invalid cells (i > j)"


def jaccard_multiset(tokens_a, tokens_b) -> float:
    """Exact multi-set Jaccard similarity (§2.1)."""
    from collections import Counter
    ca, cb = Counter(np.asarray(tokens_a).tolist()), Counter(np.asarray(tokens_b).tolist())
    tokens = set(ca) | set(cb)
    num = sum(min(ca.get(t, 0), cb.get(t, 0)) for t in tokens)
    den = sum(max(ca.get(t, 0), cb.get(t, 0)) for t in tokens)
    return num / den if den else 1.0


def jaccard_weighted(tokens_a, tokens_b, weight: WeightFn) -> float:
    """Exact weighted Jaccard similarity (§5)."""
    from collections import Counter
    ca, cb = Counter(np.asarray(tokens_a).tolist()), Counter(np.asarray(tokens_b).tolist())
    tokens = set(ca) | set(cb)
    num = den = 0.0
    for t in tokens:
        wa = float(weight(t, ca[t])) if ca.get(t) else 0.0
        wb = float(weight(t, cb[t])) if cb.get(t) else 0.0
        num += min(wa, wb)
        den += max(wa, wb)
    return num / den if den else 1.0
