"""Distributed (multi-host) index build & query fan-out.

The corpus is sharded across data-parallel workers; each worker builds an
independent AlignmentIndex over its shard (the skyline partitioner is
host-side; device kernels produce sketches -- DESIGN.md §2.2).  Queries
broadcast the k sketch coordinates (O(k) bytes) and union per-shard results.
Each shard checkpoints independently: a lost worker rebuilds only its shard
(fault tolerance), and shards can be re-split when the worker count changes
(elasticity).
"""

from __future__ import annotations

import json
import pickle
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from .index import AlignmentIndex
from .query import Alignment, batch_query, query


def shard_of(doc_id: int, n_shards: int) -> int:
    return doc_id % n_shards


@dataclass
class ShardedAlignmentIndex:
    """n_shards independent AlignmentIndexes with a global doc-id space."""

    scheme: object
    n_shards: int = 4
    method: str = "mono_active"
    shards: list[AlignmentIndex] = field(init=False)
    doc_map: list[tuple[int, int]] = field(default_factory=list)
    # doc_map[global_id] = (shard, local_id)

    def __post_init__(self):
        self.shards = [AlignmentIndex(scheme=self.scheme, method=self.method)
                       for _ in range(self.n_shards)]

    def add_text(self, tokens) -> int:
        gid = len(self.doc_map)
        s = shard_of(gid, self.n_shards)
        lid = self.shards[s].add_text(np.asarray(tokens, np.int64))
        self.doc_map.append((s, lid))
        return gid

    def build(self, texts) -> "ShardedAlignmentIndex":
        for t in texts:
            self.add_text(t)
        return self

    def query(self, tokens, theta: float) -> list[Alignment]:
        """Fan-out / union; local ids remapped into the global space."""
        out: list[Alignment] = []
        inverse = self._inverse_doc_map()
        for s, shard in enumerate(self.shards):
            for al in query(shard, tokens, theta):
                out.append(Alignment(text_id=inverse[(s, al.text_id)],
                                     blocks=al.blocks))
        return sorted(out, key=lambda a: a.text_id)

    def batch_query(self, texts, theta: float) -> list[list[Alignment]]:
        """Batched fan-out: sketch the batch once (shards share the hash
        family), probe every shard's tables with the same sketches, union
        per query in the global id space."""
        if not texts:
            return []
        sketches = self.scheme.sketch_batch(texts)
        inverse = self._inverse_doc_map()
        per_q: list[list[Alignment]] = [[] for _ in texts]
        for s, shard in enumerate(self.shards):
            res = batch_query(shard, texts, theta, sketches=sketches)
            for qi, als in enumerate(res):
                per_q[qi].extend(
                    Alignment(text_id=inverse[(s, al.text_id)],
                              blocks=al.blocks) for al in als)
        return [sorted(r, key=lambda a: a.text_id) for r in per_q]

    def freeze(self) -> "ShardedAlignmentIndex":
        """Freeze every shard into the CSR serving layout (idempotent)."""
        for shard in self.shards:
            shard.freeze()
        return self

    @property
    def is_frozen(self) -> bool:
        return all(s.is_frozen for s in self.shards)

    def nbytes(self) -> int:
        return sum(s.nbytes() for s in self.shards)

    def _inverse_doc_map(self) -> dict[tuple[int, int], int]:
        return {(s, lid): gid
                for gid, (s, lid) in enumerate(self.doc_map)}

    @property
    def num_windows(self) -> int:
        return sum(s.num_windows for s in self.shards)

    # -- per-shard persistence (fault tolerance / elasticity) ---------------

    def save(self, root: str | Path):
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        meta = {"n_shards": self.n_shards, "method": self.method,
                "doc_map": self.doc_map}
        for s, shard in enumerate(self.shards):
            tmp = root / f"shard_{s}.pkl.tmp"
            with open(tmp, "wb") as f:
                pickle.dump(shard.state_dict(), f)
            tmp.rename(root / f"shard_{s}.pkl")        # atomic commit
        (root / "meta.json").write_text(json.dumps(meta))

    def restore(self, root: str | Path, *, missing_ok: bool = True
                ) -> list[int]:
        """Load shards from disk; returns the list of shard ids that were
        missing/corrupt and have been rebuilt empty (the caller re-adds only
        those shards' documents -- partial recovery)."""
        root = Path(root)
        meta = json.loads((root / "meta.json").read_text())
        assert meta["n_shards"] == self.n_shards, "elastic re-shard: rebuild"
        self.doc_map = [tuple(x) for x in meta["doc_map"]]
        lost = []
        for s in range(self.n_shards):
            p = root / f"shard_{s}.pkl"
            try:
                with open(p, "rb") as f:
                    self.shards[s].load_state_dict(pickle.load(f))
            except Exception:
                if not missing_ok:
                    raise
                lost.append(s)
        return lost

    def docs_of_shard(self, s: int) -> list[int]:
        return [gid for gid, (sh, _l) in enumerate(self.doc_map) if sh == s]
