"""Distributed (multi-host) index build & query fan-out.

The corpus is sharded across data-parallel workers; each worker builds an
independent :class:`~repro.core.builder.IndexBuilder` over its shard (the
skyline partitioner is host-side; device kernels produce sketches --
DESIGN.md §2.2), or — on the batch path — a columnar
:class:`~repro.core.columnar.ColumnarBuilder` per shard, optionally in a
process pool with finished shards streamed straight into store
directories (``build(pipeline="columnar", fanout=..., store=...)``).
Queries broadcast the k sketch coordinates (O(k) bytes)
and union per-shard results.  Each shard checkpoints independently: a lost
worker rebuilds only its shard (fault tolerance), and shards can be
re-split when the worker count changes (elasticity).

Persistence is two-format by lifecycle stage:

* **frozen** shards (post ``freeze()``, :class:`SearchIndex`) are saved as
  versioned ``shard_{s}/`` store directories (:mod:`repro.core.store`) —
  JSON manifest + raw ``.npy`` arrays, restorable with ``mmap=True`` so a
  larger-than-RAM corpus serves without materializing the tables.
* **mutable** shards (mid-build ``IndexBuilder``) are pickled as
  ``shard_{s}.pkl`` build-time checkpoints, as before.

Live serving (``restore(..., live=True)``) wraps every store-backed shard
in a :class:`~repro.core.live.LiveIndex` — frozen mmap arrays plus a
small per-shard mutable delta — so the restored index takes ``add_text``
writes while serving, and :meth:`ShardedAlignmentIndex.compact` folds all
the deltas into new per-shard store generations (optionally fanned out
across a spawn process pool) with atomic per-shard promotion.
"""

from __future__ import annotations

import json
import math
import pickle
import time
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from ..fault import checkpoint as fault_checkpoint
from ..fault import fsio
from . import store as index_store
from .builder import IndexBuilder
from .plan import resolve_plan
from .query import Alignment, _sweep_gathered, batch_probe, query
from .results import UNSET, QueryOptions, coerce_query_options
from .search import SearchIndex

META_VERSION = 1


def shard_of(doc_id: int, n_shards: int) -> int:
    return doc_id % n_shards


@dataclass
class ShardedAlignmentIndex:
    """n_shards independent indexes with a global doc-id space."""

    scheme: object
    n_shards: int = 4
    method: str = "mono_active"
    shards: list = field(init=False)
    doc_map: list[tuple[int, int]] = field(default_factory=list)
    # doc_map[global_id] = (shard, local_id)
    _inverse: dict | None = field(default=None, init=False, repr=False)
    _pool: object = field(default=None, init=False, repr=False)
    _root: Path | None = field(default=None, init=False, repr=False)

    def __post_init__(self):
        self.shards = [IndexBuilder(scheme=self.scheme, method=self.method)
                       for _ in range(self.n_shards)]

    def add_text(self, tokens) -> int:
        gid = len(self.doc_map)
        s = shard_of(gid, self.n_shards)
        shard = self.shards[s]
        if getattr(shard, "is_live", False):
            # live shard: the delta takes the write; pin the global id so
            # the shard's own doc_map (persisted at compaction) stays in
            # step with ours
            lid = shard.add_text(np.asarray(tokens, np.int64), gid=gid)
        elif shard.is_frozen:
            raise RuntimeError(
                f"shard {s} is frozen (SearchIndex); adds belong to the "
                "build stage — restore(live=True) for incremental serving, "
                "or rebuild the shard with an IndexBuilder")
        else:
            lid = shard.add_text(np.asarray(tokens, np.int64))
        self.doc_map.append((s, lid))
        self._inverse = None              # invalidate the cached inverse map
        return gid

    def build(self, texts, *, pipeline: str = "dict",
              fanout: str = "serial", store: str | Path | None = None,
              mmap: bool = True) -> "ShardedAlignmentIndex":
        """Index a corpus across the shards.

        ``pipeline="dict"`` (default) is the incremental path: every text
        goes through ``add_text`` into its shard's mutable dict builder.

        ``pipeline="columnar"`` is the batch path: documents are
        partitioned across shards up front and each shard is built by a
        :class:`~repro.core.columnar.ColumnarBuilder` and frozen — the
        shards come out as serving-ready ``SearchIndex`` objects
        (block-identical to dict-build + ``freeze()``).  ``fanout`` picks
        the shard-level parallelism:

        * ``"serial"``   — one shard after another, in-process.
        * ``"threaded"`` — a thread pool; the vectorized sort/pack stages
          release the GIL, the Python partition loop does not, so gains
          are workload-dependent.
        * ``"process"``  — a spawn-based process pool; the columnar build
          is no longer dict-mutation-bound, so shards scale across cores.
          The scheme travels as its JSON ``scheme_spec``.

        ``store=`` streams every finished shard straight into
        ``store/shard_{s}`` store directories (plus the root ``meta.json``)
        and restores the shards from there (``mmap=True`` maps them) —
        corpus to saved sharded store in one pass, without ever holding
        all shards' tables in RAM.  With ``fanout="process"`` the shard
        arrays then never cross the process boundary at all.
        """
        if pipeline == "dict":
            if fanout != "serial" or store is not None:
                raise ValueError(
                    "fanout/store are columnar-pipeline options; the dict "
                    'pipeline is incremental — use pipeline="columnar"')
            for t in texts:
                self.add_text(t)
            return self
        if pipeline != "columnar":
            raise ValueError(f"unknown pipeline {pipeline!r}; "
                             "expected 'dict' or 'columnar'")
        if fanout not in ("serial", "threaded", "process"):
            # validate BEFORE touching doc_map / store dirs: a failed call
            # must leave the index untouched and retryable
            raise ValueError(f"unknown fanout {fanout!r}; expected "
                             "'serial', 'threaded' or 'process'")
        if self.doc_map:
            raise RuntimeError(
                "columnar build requires an empty index (it assigns the "
                "whole corpus to shards up front); use add_text / the dict "
                "pipeline to grow an existing one")
        docs = [np.asarray(t, np.int64) for t in texts]
        per_shard: list[list] = [[] for _ in range(self.n_shards)]
        for gid, d in enumerate(docs):
            s = shard_of(gid, self.n_shards)
            self.doc_map.append((s, len(per_shard[s])))
            per_shard[s].append(d)
        self._inverse = None
        root = None
        if store is not None:
            root = Path(store)
            root.mkdir(parents=True, exist_ok=True)
            self._root = root
        dirs = [root / f"shard_{s}" if root is not None else None
                for s in range(self.n_shards)]
        if fanout == "process":
            self._build_shards_process(per_shard, dirs, mmap)
        else:
            from .columnar import ColumnarBuilder

            def build_one(s: int):
                builder = ColumnarBuilder(
                    scheme=self.scheme,
                    method=self.method).build(per_shard[s])
                if dirs[s] is not None:
                    return builder.freeze_to_store(
                        dirs[s], mmap=mmap, include_scheme=False,
                        doc_map=self.docs_of_shard(s))
                return builder.freeze()

            if fanout == "threaded" and self.n_shards > 1:
                shards = list(self._fanout_pool().map(
                    build_one, range(self.n_shards)))
            else:
                shards = [build_one(s) for s in range(self.n_shards)]
            self.shards = shards
        if root is not None:
            self._write_meta(root)
        return self

    def _build_shards_process(self, per_shard, dirs, mmap: bool) -> None:
        """Columnar-build every shard in a spawn process pool."""
        import os
        from concurrent.futures import ProcessPoolExecutor
        from multiprocessing import get_context

        from .columnar import _shard_build_payload
        from .schemes import scheme_spec
        spec = scheme_spec(self.scheme)      # workers rebuild the scheme
        workers = min(self.n_shards, os.cpu_count() or 1)
        with ProcessPoolExecutor(max_workers=workers,
                                 mp_context=get_context("spawn")) as pool:
            futures = [
                pool.submit(_shard_build_payload, spec, self.method,
                            per_shard[s],
                            str(dirs[s]) if dirs[s] is not None else None,
                            self.docs_of_shard(s))
                for s in range(self.n_shards)]
            for s, fut in enumerate(futures):
                payload = fut.result()
                if dirs[s] is not None:
                    # just written by the worker: skip checksum verification
                    self.shards[s] = index_store.load_index(
                        dirs[s], mmap=mmap, scheme=self.scheme, verify=False)
                else:
                    self.shards[s] = SearchIndex.from_state(
                        self.scheme, payload)

    def query(self, tokens, theta: float) -> list[Alignment]:
        """Fan-out / union; local ids remapped into the global space."""
        out: list[Alignment] = []
        inverse = self._inverse_doc_map()
        for s, shard in enumerate(self.shards):
            for al in query(shard, tokens, theta):
                out.append(Alignment(text_id=inverse[(s, al.text_id)],
                                     blocks=al.blocks, ncoords=al.ncoords))
        return sorted(out, key=lambda a: a.text_id)

    def batch_query(self, texts, theta: float, *,
                    options: QueryOptions | None = None,
                    sketches=UNSET, backend=UNSET, probe_backend=UNSET,
                    fanout=UNSET,
                    stage_times: dict | None = None,
                    failures: list | None = None,
                    shard_retries: int = 1,
                    retry_backoff_s: float = 0.005) -> list[list[Alignment]]:
        """Batched fan-out: sketch the batch once (shards share the hash
        family), probe every shard's tables with the same sketches, union
        per query in the global id space.

        Execution comes in as ``options=QueryOptions(...)`` whose ``plan``
        is resolved once for the whole fan-out (every shard runs the same
        resolved stages; ``plan="device"`` probes each frozen shard's
        resident arena).  The pre-redesign ``sketches``/``backend``/
        ``probe_backend``/``fanout`` keywords still work behind a
        ``DeprecationWarning``.

        ``QueryOptions.fanout="threaded"`` (default) overlaps the
        per-shard *probe* stage (:func:`repro.core.query.batch_probe`)
        with a thread pool — NumPy releases the GIL inside
        searchsorted/gather and mmap-backed shards overlap page-ins — and
        then runs the GIL-bound plane-sweep stage serially (threading it
        just convoys on the GIL); ``"serial"`` keeps the fully sequential
        loop.  Results are merged in shard order either way, so the two
        are block-identical.  ``probe_backend`` picks each shard's probe
        path, and ``sketches`` short-circuits sketching when the caller
        already holds the batch's sketch coordinates (shards share the
        hash family, so they are computed once regardless).
        ``stage_times`` accumulates per-stage wall seconds under
        ``"sketch"``/``"probe"``/``"sweep"`` when given.

        **Degraded mode**: with ``failures`` set to a caller-owned list,
        a shard whose probe keeps raising after ``shard_retries`` bounded
        exponential-backoff retries is *skipped* — its shard id is
        appended to ``failures`` and the union simply misses its docs —
        instead of failing the whole fan-out.  With ``failures=None``
        (default) the first shard exception propagates, preserving the
        strict all-or-nothing semantics oracles rely on.
        """
        opts = coerce_query_options(
            options, "ShardedAlignmentIndex.batch_query", sketches=sketches,
            backend=backend, probe_backend=probe_backend, fanout=fanout)
        xp = resolve_plan(opts)
        if not texts:
            return []
        t0 = time.perf_counter()
        sk = opts.sketches
        if sk is None:
            sk = self.scheme.sketch_batch(texts, backend=xp.sketch_backend)
        inverse = self._inverse_doc_map()
        B = len(texts)
        m = max(1, math.ceil(self.scheme.k * theta))

        def probe_shard(s_shard):
            s, shard = s_shard
            attempts = 1 + (shard_retries if failures is not None else 0)
            delay = retry_backoff_s
            for attempt in range(attempts):
                try:
                    fault_checkpoint(f"sharded.probe.s{s}")
                    return batch_probe(shard, sk,
                                       probe_backend=xp.probe_backend)
                except Exception:
                    if attempt + 1 >= attempts:
                        if failures is None:
                            raise
                        failures.append(s)
                        return None
                    time.sleep(delay)
                    delay *= 2

        t1 = time.perf_counter()
        if xp.fanout == "threaded" and self.n_shards > 1:
            gathered = list(self._fanout_pool().map(probe_shard,
                                                    enumerate(self.shards)))
        else:
            gathered = [probe_shard(s) for s in enumerate(self.shards)]
        t2 = time.perf_counter()
        # a failed (skipped) shard contributes an empty result per query
        shard_results = [_sweep_gathered(g, B, m, xp.sweep)
                         if g is not None else [[] for _ in texts]
                         for g in gathered]

        per_q: list[list[Alignment]] = [[] for _ in texts]
        for s, res in enumerate(shard_results):
            for qi, als in enumerate(res):
                per_q[qi].extend(
                    Alignment(text_id=inverse[(s, al.text_id)],
                              blocks=al.blocks, ncoords=al.ncoords)
                    for al in als)
        out = [sorted(r, key=lambda a: a.text_id) for r in per_q]
        if stage_times is not None:
            t3 = time.perf_counter()
            stage_times["sketch"] = stage_times.get("sketch", 0.) + (t1 - t0)
            stage_times["probe"] = stage_times.get("probe", 0.) + (t2 - t1)
            stage_times["sweep"] = stage_times.get("sweep", 0.) + (t3 - t2)
        return out

    def freeze(self) -> "ShardedAlignmentIndex":
        """Freeze every shard into the CSR serving layout (idempotent).
        Live shards merge their delta in memory (their store generations
        are untouched; use :meth:`compact` to persist in place)."""
        self.shards = [shard.freeze() for shard in self.shards]
        return self

    def compact(self, *, fanout: str = "serial") -> "ShardedAlignmentIndex":
        """Fold every live shard's delta into a new store generation and
        promote it (see :meth:`repro.core.live.LiveIndex.compact`).

        ``fanout="process"`` runs the per-shard merge-compactions in a
        spawn process pool — deltas travel as pickled state dicts, arrays
        never cross the boundary (workers write the generation dirs, the
        parent mmap-reloads) — and promotion always happens in the
        parent, one atomic pointer flip per shard, after that shard's
        manifest is committed.  The root ``meta.json`` is rewritten last
        with the grown doc map; per-shard manifests keep ``restore``
        correct even if a crash lands between the flips and that rewrite.
        """
        from .live import LiveIndex, _shard_compact_payload
        if fanout not in ("serial", "process"):
            raise ValueError(f"unknown fanout {fanout!r}; expected "
                             "'serial' or 'process'")
        live = [s for s in range(self.n_shards)
                if getattr(self.shards[s], "is_live", False)]
        if not live:
            raise RuntimeError(
                "no live shards to compact; restore the index with "
                "live=True (Aligner.load(path, live=True)) to serve writes")
        # shards whose delta levels are empty have nothing to fold in —
        # don't rewrite them into duplicate generations
        live = [s for s in live if self.shards[s].delta.num_texts
                or self.shards[s].sealed is not None]
        if not live:
            return self
        if fanout == "process" and len(live) > 1:
            import os
            from concurrent.futures import ProcessPoolExecutor
            from multiprocessing import get_context

            from .schemes import scheme_spec
            spec = scheme_spec(self.scheme)
            workers = min(len(live), os.cpu_count() or 1)
            with ProcessPoolExecutor(max_workers=workers,
                                     mp_context=get_context("spawn")) as pool:
                futures = {
                    s: pool.submit(_shard_compact_payload, spec,
                                   str(self.shards[s].root),
                                   self.shards[s].delta.state_dict(),
                                   self.shards[s].doc_map)
                    for s in live}
                gens = {s: fut.result() for s, fut in futures.items()}
            for s in live:
                shard = self.shards[s]
                index_store.promote_generation(shard.root, gens[s])
                self.shards[s] = LiveIndex.open(shard.root, mmap=shard.mmap,
                                                scheme=self.scheme)
        else:
            for s in live:
                self.shards[s].compact()
        if self._root is not None:
            self._write_meta(self._root)
        return self

    @property
    def is_frozen(self) -> bool:
        return all(s.is_frozen for s in self.shards)

    def nbytes(self) -> int:
        return sum(s.nbytes() for s in self.shards)

    def _fanout_pool(self):
        """Reused fan-out thread pool (spawning one per batch_query would
        pay n_shards thread start/joins on every serving call).  Lifetime
        is tied to the index: when it is dropped, CPython's executor
        weakref callback wakes the idle workers and they exit — no
        explicit shutdown needed."""
        if self._pool is None:
            import os
            from concurrent.futures import ThreadPoolExecutor
            self._pool = ThreadPoolExecutor(
                max_workers=min(self.n_shards, os.cpu_count() or 1),
                thread_name_prefix="shard-fanout")
        return self._pool

    def _inverse_doc_map(self) -> dict[tuple[int, int], int]:
        """(shard, local_id) -> global_id, cached between queries (rebuilt
        lazily after ``add_text``/``restore`` invalidate it)."""
        if self._inverse is None or len(self._inverse) != len(self.doc_map):
            self._inverse = {(s, lid): gid
                             for gid, (s, lid) in enumerate(self.doc_map)}
        return self._inverse

    @property
    def num_windows(self) -> int:
        return sum(s.num_windows for s in self.shards)

    # -- per-shard persistence (fault tolerance / elasticity) ---------------

    def _write_meta(self, root: Path) -> None:
        from .schemes import scheme_spec
        meta = {"meta_version": META_VERSION, "n_shards": self.n_shards,
                "method": self.method, "doc_map": self.doc_map,
                "scheme": scheme_spec(self.scheme)}
        fsio.commit_text(root / "meta.json", json.dumps(meta),
                         site="sharded.meta")

    def save(self, root: str | Path):
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        if self._root is None:
            self._root = root          # snapshot saves don't retarget compact
        for s, shard in enumerate(self.shards):
            store_dir = root / f"shard_{s}"
            pkl = root / f"shard_{s}.pkl"
            if getattr(shard, "is_live", False):
                # snapshot a live shard as one flat merged store at the
                # target (its own store generations are untouched)
                shard = shard.freeze()
            if shard.is_frozen:
                # scheme spec lives once in meta.json (a tfidf spec carries
                # the corpus-wide doc-frequency table; don't write n copies)
                index_store.save_index(shard, store_dir,
                                       doc_map=self.docs_of_shard(s),
                                       include_scheme=False)
                # the snapshot is the flat layout; retire any generation
                # pointer AFTER its manifest commit so readers flip from a
                # complete old generation to the complete new snapshot
                fsio.unlink(store_dir / index_store.CURRENT_POINTER,
                            site="sharded.retire_pointer", missing_ok=True)
                fsio.unlink(pkl, site="sharded.retire_checkpoint",
                            missing_ok=True)      # drop stale checkpoint
            else:
                # atomic commit (tmp + rename inside commit_bytes)
                fsio.commit_bytes(pkl, pickle.dumps(shard.state_dict()),
                                  site="sharded.checkpoint")
                if store_dir.exists():
                    fsio.rmtree(store_dir,
                                site="sharded.reset")  # drop stale store
        self._write_meta(root)

    def restore(self, root: str | Path, *, missing_ok: bool = True,
                mmap: bool = False, live: bool = False) -> list[int]:
        """Load shards from disk; returns the list of shard ids that were
        missing/corrupt and have been rebuilt empty (the caller re-adds only
        those shards' documents -- partial recovery).

        ``mmap=True`` maps frozen shards' table arrays instead of reading
        them into RAM (versioned store directories only; pickled build
        checkpoints always materialize).  ``live=True`` wraps every
        store-backed shard in a :class:`~repro.core.live.LiveIndex` so the
        restored index accepts ``add_text`` and ``compact()`` without
        thawing (mutable pickled shards already accept adds and load as
        usual).

        The global id mapping is taken from the per-shard store manifests
        where available (they are rewritten on every compaction promote),
        with ``meta.json`` covering mutable/lost shards — so a shard
        compacted after the root meta was last written still restores with
        correct global ids.
        """
        root = Path(root)
        meta = json.loads((root / "meta.json").read_text())
        if meta["n_shards"] != self.n_shards:
            raise ValueError(
                f"shard-count mismatch: checkpoint at {root} has "
                f"{meta['n_shards']} shards but this index was built with "
                f"n_shards={self.n_shards}; construct the index with the "
                "checkpoint's shard count, or re-shard the corpus and "
                "rebuild (elastic re-shard)")
        self.doc_map = [tuple(x) for x in meta["doc_map"]]
        self._inverse = None
        self._root = root
        lost = []
        for s in range(self.n_shards):
            try:
                self.shards[s] = self._load_shard(root, s, mmap=mmap,
                                                  live=live)
            except Exception:
                if not missing_ok:
                    raise
                self.shards[s] = IndexBuilder(scheme=self.scheme,
                                              method=self.method)
                lost.append(s)
        self._remap_doc_ids_from_stores(root, lost)
        return lost

    def _remap_doc_ids_from_stores(self, root: Path, lost: list[int]) -> None:
        """Overlay the per-shard store manifests' ``doc_map`` onto the
        global map: local id ``lid`` of shard ``s`` serves global doc
        ``manifest.doc_map[lid]``.  The manifests are authoritative for
        frozen shards (promotion rewrites them atomically with the
        arrays); ``meta.json`` keeps covering pickled shards and lost
        shards' documents, and contiguous shard-local ids are no longer
        assumed anywhere."""
        for s in range(self.n_shards):
            store_dir = root / f"shard_{s}"
            if s in lost or not index_store.is_index_store(store_dir):
                continue
            shard_map = index_store.read_manifest(store_dir).get("doc_map")
            if shard_map is None:
                continue
            for lid, gid in enumerate(shard_map):
                gid = int(gid)
                if gid >= len(self.doc_map):
                    self.doc_map.extend(
                        [None] * (gid + 1 - len(self.doc_map)))
                self.doc_map[gid] = (s, lid)
        holes = [g for g, e in enumerate(self.doc_map) if e is None]
        if holes:
            raise ValueError(
                f"global doc ids {holes[:8]}{'...' if len(holes) > 8 else ''}"
                f" appear in no shard manifest and predate {root}/meta.json;"
                " the store is torn — re-save the index or restore the "
                "missing shard stores")
        self._inverse = None

    def _load_shard(self, root: Path, s: int, *, mmap: bool,
                    live: bool = False):
        store_dir = root / f"shard_{s}"
        if index_store.is_index_store(store_dir):
            if live:
                from .live import LiveIndex
                return LiveIndex.open(store_dir, mmap=mmap,
                                      scheme=self.scheme)
            return index_store.load_index(store_dir, mmap=mmap,
                                          scheme=self.scheme)
        with open(root / f"shard_{s}.pkl", "rb") as f:
            state = pickle.load(f)
        if state.get("frozen") is not None:
            return SearchIndex.from_state(self.scheme, state)
        builder = IndexBuilder(scheme=self.scheme, method=self.method)
        builder.load_state_dict(state)
        return builder

    def docs_of_shard(self, s: int) -> list[int]:
        return [gid for gid, (sh, _l) in enumerate(self.doc_map) if sh == s]
