"""Query processing (Algorithm 2): sketch the query, probe the k inverted
lists, plane-sweep the collided compact windows for cells covered >= ⌈kθ⌉
times (those subsequences have estimated Jaccard >= θ, Eq. 2/Eq. 5).
"""

from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass

import numpy as np

from .index import AlignmentIndex


@dataclass
class Alignment:
    """All result subsequences of one data text, as maximal blocks.

    blocks: list of (i_lo, i_hi, j_lo, j_hi) — every T[i..j] with
    i ∈ [i_lo, i_hi], j ∈ [j_lo, j_hi] is a result (0-indexed inclusive).
    """

    text_id: int
    blocks: list[tuple[int, int, int, int]]

    def cells(self) -> set[tuple[int, int]]:
        out = set()
        for il, ih, jl, jh in self.blocks:
            for i in range(il, ih + 1):
                for j in range(jl, jh + 1):
                    out.add((i, j))
        return out

    @property
    def num_cells(self) -> int:
        return sum((ih - il + 1) * (jh - jl + 1) for il, ih, jl, jh in self.blocks)


def _sweep_text(windows: list[tuple[int, int, int, int]], m: int
                ) -> list[tuple[int, int, int, int]]:
    """Cells covered by >= m of the given rectangles, as disjoint blocks.

    Coordinate-compressed 2-D difference array + cumulative sums; output
    blocks are maximal runs within each compressed stripe.
    """
    if len(windows) < m:
        return []
    arr = np.asarray(windows, dtype=np.int64)
    a, b, c, d = arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3]
    xs = np.unique(np.concatenate([a, b + 1]))
    ys = np.unique(np.concatenate([c, d + 1]))
    nx, ny = len(xs), len(ys)
    diff = np.zeros((nx + 1, ny + 1), dtype=np.int32)
    xi_a = np.searchsorted(xs, a)
    xi_b = np.searchsorted(xs, b + 1)
    yi_c = np.searchsorted(ys, c)
    yi_d = np.searchsorted(ys, d + 1)
    np.add.at(diff, (xi_a, yi_c), 1)
    np.add.at(diff, (xi_a, yi_d), -1)
    np.add.at(diff, (xi_b, yi_c), -1)
    np.add.at(diff, (xi_b, yi_d), 1)
    count = np.cumsum(np.cumsum(diff, axis=0), axis=1)[:nx, :ny]
    hot = count >= m
    blocks: list[tuple[int, int, int, int]] = []
    # xs[i]..xs[i+1]-1 stripes; the last compressed coord is always an
    # exclusive upper bound (b+1 / d+1), so hot cannot extend past it.
    for xi in range(nx - 1):
        row = hot[xi]
        if not row.any():
            continue
        j = 0
        while j < ny - 1:
            if row[j]:
                j2 = j
                while j2 + 1 < ny - 1 and row[j2 + 1]:
                    j2 += 1
                blocks.append((int(xs[xi]), int(xs[xi + 1] - 1),
                               int(ys[j]), int(ys[j2 + 1] - 1)))
                j = j2 + 1
            else:
                j += 1
    return blocks


def query(index: AlignmentIndex, query_tokens, theta: float
          ) -> list[Alignment]:
    """Near-duplicate text alignment (Definition 1) for one query."""
    k = index.scheme.k
    m = max(1, math.ceil(k * theta))
    sketch = index.scheme.sketch(query_tokens)
    per_text: dict[int, list] = defaultdict(list)
    for i in range(k):
        for (tid, a, b, c, d) in index.lookup(i, sketch[i]):
            per_text[tid].append((a, b, c, d))
    results = []
    for tid, wins in sorted(per_text.items()):
        blocks = _sweep_text(wins, m)
        if blocks:
            results.append(Alignment(text_id=tid, blocks=blocks))
    return results


def estimate_similarity(index: AlignmentIndex, query_tokens, data_tokens
                        ) -> float:
    """Sketch-estimated Jaccard between two full texts (Eq. 2 / Eq. 5)."""
    sq = index.scheme.sketch(query_tokens)
    sd = index.scheme.sketch(data_tokens)
    return float(np.mean([1.0 if x == y else 0.0 for x, y in zip(sq, sd)]))
