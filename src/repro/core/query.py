"""Query processing (Algorithm 2): sketch the query, probe the k inverted
lists, plane-sweep the collided compact windows for cells covered >= ⌈kθ⌉
times (those subsequences have estimated Jaccard >= θ, Eq. 2/Eq. 5).

Two execution paths over the same algorithm:

* ``query``       — one query at a time; works on mutable (dict) and frozen
  indexes alike.
* ``batch_query`` — the serving path: sketches the whole batch at once,
  probes ALL B*k (query, coordinate) pairs against the fused probe arena
  (``repro.core.frozen.ProbeArena``) in ONE ``searchsorted`` + gather
  (``probe_backend="numpy"``; ``"pallas"`` routes the binary search through
  the device kernel, ``"percoord"`` keeps the legacy per-coordinate probe
  loop, which is also what mutable dict indexes use), and groups the
  collided windows by (query, text) with one lexsort.  The per-group plane
  sweep goes through a grouped dispatcher: the many tiny groups of Zipf
  traffic are batched through one vectorized small-group sweep
  (``sweep="grouped"``, the default) and only large groups fall back to
  the per-group ``_sweep_text``.  Every combination returns block-for-block
  the same results as looping ``query``.
"""

from __future__ import annotations

import math
import time
from collections import defaultdict
from dataclasses import dataclass

import numpy as np

from .frozen import _concat_ranges
from .plan import resolve_plan
from .results import UNSET, QueryOptions, coerce_query_options


@dataclass
class Alignment:
    """All result subsequences of one data text, as maximal blocks.

    blocks: list of (i_lo, i_hi, j_lo, j_hi) — every T[i..j] with
    i ∈ [i_lo, i_hi], j ∈ [j_lo, j_hi] is a result (0-indexed inclusive).
    """

    text_id: int
    blocks: list[tuple[int, int, int, int]]
    # distinct colliding sketch coordinates (>= ceil(k*theta) whenever
    # blocks is non-empty); ncoords/k estimates the query<->text Jaccard
    ncoords: int | None = None

    def cells(self) -> set[tuple[int, int]]:
        out = set()
        for il, ih, jl, jh in self.blocks:
            for i in range(il, ih + 1):
                for j in range(jl, jh + 1):
                    out.add((i, j))
        return out

    @property
    def num_cells(self) -> int:
        return sum((ih - il + 1) * (jh - jl + 1) for il, ih, jl, jh in self.blocks)


def _sweep_text(windows: list[tuple[int, int, int, int]], m: int
                ) -> list[tuple[int, int, int, int]]:
    """Cells covered by >= m of the given rectangles, as disjoint blocks.

    Coordinate-compressed 2-D difference array + cumulative sums; output
    blocks are maximal runs within each compressed stripe.
    """
    if len(windows) < m:
        return []
    arr = np.asarray(windows, dtype=np.int64)
    a, b, c, d = arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3]
    xs = np.unique(np.concatenate([a, b + 1]))
    ys = np.unique(np.concatenate([c, d + 1]))
    nx, ny = len(xs), len(ys)
    xi_a = np.searchsorted(xs, a)
    xi_b = np.searchsorted(xs, b + 1)
    yi_c = np.searchsorted(ys, c)
    yi_d = np.searchsorted(ys, d + 1)
    # one bincount scatter of the four +-1 corner pulses (C fast path)
    stride = ny + 1
    pos = np.concatenate([xi_a * stride + yi_c, xi_b * stride + yi_d])
    neg = np.concatenate([xi_a * stride + yi_d, xi_b * stride + yi_c])
    diff = (np.bincount(pos, minlength=(nx + 1) * stride)
            - np.bincount(neg, minlength=(nx + 1) * stride)
            ).reshape(nx + 1, stride).astype(np.int32)
    count = np.cumsum(np.cumsum(diff, axis=0), axis=1)
    # xs[i]..xs[i+1]-1 stripes; the last compressed coord is always an
    # exclusive upper bound (b+1 / d+1), so hot cannot extend past it.
    hot = count[:nx - 1, :ny - 1] >= m
    if not hot.any():
        return []
    # maximal horizontal runs per stripe, vectorized: +1/-1 edges of the
    # zero-padded hot mask mark run starts / one-past-run ends
    hpad = np.zeros((nx - 1, ny + 1), dtype=np.int8)
    hpad[:, 1:ny] = hot
    edges = np.diff(hpad, axis=1)
    rs, cs = np.nonzero(edges == 1)       # run starts (row-major)
    _, ce = np.nonzero(edges == -1)       # aligned exclusive run ends
    return [(int(xs[r]), int(xs[r + 1] - 1), int(ys[c0]), int(ys[c1] - 1))
            for r, c0, c1 in zip(rs, cs, ce)]


def query(index, query_tokens, theta: float
          ) -> list[Alignment]:
    """Near-duplicate text alignment (Definition 1) for one query."""
    k = index.scheme.k
    m = max(1, math.ceil(k * theta))
    sketch = index.scheme.sketch(query_tokens)
    per_text: dict[int, list] = defaultdict(list)
    ncoords: dict[int, int] = defaultdict(int)
    for i in range(k):
        prev = None
        for (tid, a, b, c, d) in index.lookup(i, sketch[i]):
            per_text[tid].append((a, b, c, d))
            if tid != prev:                 # postings are grouped by tid
                ncoords[tid] += 1
                prev = tid
    results = []
    for tid, wins in sorted(per_text.items()):
        # windows from one coordinate are disjoint (a cell's min-hash is
        # unique), so coverage >= m needs >= m distinct coordinates — skip
        # the sweep when that is impossible
        if ncoords[tid] < m:
            continue
        blocks = _sweep_text(wins, m)
        if blocks:
            results.append(Alignment(text_id=int(tid), blocks=blocks,
                                     ncoords=int(ncoords[tid])))
    return results


_SMALL_GROUP_MAX = 32    # windows; larger groups use the per-group sweep
_SMALL_CHUNK_CELLS = 1 << 22   # bound the batched difference-array footprint


def _sweep_small_batch(arr: np.ndarray, sizes: np.ndarray, m: int
                       ) -> list[list[tuple[int, int, int, int]]]:
    """Vectorized ``_sweep_text`` over G small groups at once.

    arr: int64 (G, S, 4) rectangle rows, padded past ``sizes[g]`` with
    anything; returns per-group block lists identical to running
    ``_sweep_text(arr[g, :sizes[g]], m)`` group by group.

    Padding is normalized to zero-width rectangles at each group's max
    boundary and given bincount weight 0, so padded entries contribute no
    coverage and only duplicate existing compressed coordinates.  Duplicate
    boundary values are harmless: searchsorted-left drops every pulse on
    the first duplicate, making later duplicates exact pass-throughs, so
    run starts/ends land on the same coordinate values as the
    ``np.unique``-compressed per-group sweep; zero-width *stripes* are
    masked cold because each stripe emits its own block.
    """
    G, S, _ = arr.shape
    # chunk so the per-chunk difference array stays cache/RAM friendly even
    # when a batch produces tens of thousands of small groups
    per = max(1, _SMALL_CHUNK_CELLS // ((2 * S + 1) * (2 * S + 1)))
    if G > per:
        out = []
        for lo in range(0, G, per):
            out.extend(_sweep_small_batch(arr[lo:lo + per],
                                          sizes[lo:lo + per], m))
        return out
    arr = arr.astype(np.int64, copy=True)
    pad = np.arange(S)[None, :] >= sizes[:, None]            # (G, S)
    a, b, c, d = arr[..., 0], arr[..., 1], arr[..., 2], arr[..., 3]
    bmax = np.where(pad, np.iinfo(np.int64).min, b + 1).max(axis=1)
    dmax = np.where(pad, np.iinfo(np.int64).min, d + 1).max(axis=1)
    a[pad], c[pad] = 0, 0
    b[pad], d[pad] = -1, -1
    a += np.where(pad, bmax[:, None], 0)
    b += np.where(pad, bmax[:, None], 0)
    c += np.where(pad, dmax[:, None], 0)
    d += np.where(pad, dmax[:, None], 0)

    NX = 2 * S
    xs = np.sort(np.concatenate([a, b + 1], axis=1), axis=1)  # (G, NX)
    ys = np.sort(np.concatenate([c, d + 1], axis=1), axis=1)
    # (the device sweep kernel, repro.kernels.sweep_grid, reproduces
    # everything from here to the hot mask on-device; _extract_runs is the
    # shared tail both paths finish through)
    # row-wise searchsorted in one call: bias each group's (small, < 2**31)
    # coordinates into a disjoint int64 band
    bias = np.arange(G, dtype=np.int64)[:, None] << 33
    xs_f, ys_f = (xs + bias).ravel(), (ys + bias).ravel()
    row0 = np.arange(G, dtype=np.int64)[:, None] * NX

    def rs(flat_sorted, probes):
        return np.searchsorted(flat_sorted,
                               (probes + bias).ravel()).reshape(G, S) - row0

    xi_a, xi_b = rs(xs_f, a), rs(xs_f, b + 1)
    yi_c, yi_d = rs(ys_f, c), rs(ys_f, d + 1)

    # one global bincount of the +-1 corner pulses (weight 0 on padding)
    STR = NX + 1
    cell0 = np.arange(G, dtype=np.int64)[:, None] * ((NX + 1) * STR)
    w = np.where(pad, 0.0, 1.0).ravel()
    ww = np.concatenate([w, w])
    flat = lambda xi, yi: (cell0 + xi * STR + yi).ravel()
    L = G * (NX + 1) * STR
    pos = np.concatenate([flat(xi_a, yi_c), flat(xi_b, yi_d)])
    neg = np.concatenate([flat(xi_a, yi_d), flat(xi_b, yi_c)])
    diff = (np.bincount(pos, weights=ww, minlength=L)
            - np.bincount(neg, weights=ww, minlength=L)
            ).reshape(G, NX + 1, STR).astype(np.int32)
    count = np.cumsum(np.cumsum(diff, axis=1), axis=2)
    hot = count[:, :NX - 1, :NX - 1] >= m
    hot &= (xs[:, 1:] > xs[:, :-1])[:, :, None]              # zero-width
    return _extract_runs(hot, xs, ys)


def _extract_runs(hot: np.ndarray, xs: np.ndarray, ys: np.ndarray
                  ) -> list[list[tuple[int, int, int, int]]]:
    """Maximal horizontal runs of the hot stripe mask, as per-group block
    lists — the shared tail of the host (``_sweep_small_batch``) and
    device (``repro.kernels.sweep_grid``) grouped sweeps.

    hot bool (G, NX-1, NX-1); xs/ys int (G, NX) sorted stripe boundaries
    (stripe i spans ``xs[i]..xs[i+1]-1``).  Vectorized: +1/-1 edges of the
    zero-padded hot mask mark run starts / one-past-run ends.
    """
    G, _, ny = hot.shape
    NX = ny + 1
    out: list[list[tuple[int, int, int, int]]] = [[] for _ in range(G)]
    if not hot.any():
        return out
    hpad = np.zeros((G, NX - 1, NX + 1), np.int8)
    hpad[:, :, 1:NX] = hot
    edges = np.diff(hpad, axis=2)
    gs, rows, cs = np.nonzero(edges == 1)     # run starts, row-major
    _, _, ce = np.nonzero(edges == -1)        # aligned exclusive run ends
    flat_blocks = np.stack([xs[gs, rows], xs[gs, rows + 1] - 1,
                            ys[gs, cs], ys[gs, ce] - 1], axis=1).tolist()
    grp = np.searchsorted(gs, np.arange(G + 1))   # gs ascending (row-major)
    for g in range(G):
        lo, hi = grp[g], grp[g + 1]
        if hi > lo:
            out[g] = [tuple(int(x) for x in r) for r in flat_blocks[lo:hi]]
    return out


def _gather_coord(index, i: int, probe_keys: list
                  ) -> tuple[np.ndarray, np.ndarray]:
    """All windows colliding with the B probe keys on coordinate ``i``:
    (query ids (M,), windows (M, 5) int64)."""
    if index.is_frozen:
        table = index.frozen[i]
        packed = table.encode(probe_keys)
        starts, ends = table.probe(packed)
        counts = ends - starts
        qids = np.repeat(np.arange(len(probe_keys), dtype=np.int64), counts)
        rows = table.windows[_concat_ranges(starts, counts)]
        return qids, rows.astype(np.int64)
    qid_chunks, win_chunks = [], []
    for b, key in enumerate(probe_keys):
        wins = index.tables[i].get(key)
        if wins:
            qid_chunks.append(np.full(len(wins), b, np.int64))
            win_chunks.append(np.asarray(wins, np.int64))
    if not qid_chunks:
        return np.empty(0, np.int64), np.empty((0, 5), np.int64)
    return np.concatenate(qid_chunks), np.concatenate(win_chunks)


def _gather_arena(index, sketches, probe_backend: str
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One-shot probe of ALL B*k coordinates against the fused arena:
    (query ids (M,), windows (M, 5) int64, coordinate ids (M,))."""
    arena = index.arena()
    k = arena.k
    pkeys, coords, valid = arena.encode_batch(sketches)
    if probe_backend == "device":
        from .device_plan import resident_probe
        starts, ends = resident_probe(index, pkeys, coords, valid)
    else:
        starts, ends = arena.probe(
            pkeys, coords, valid,
            backend="pallas" if probe_backend == "pallas" else "numpy")
    counts = ends - starts
    rows = arena.windows[_concat_ranges(starts, counts)]
    probe_ids = np.repeat(np.arange(len(pkeys), dtype=np.int64), counts)
    return probe_ids // k, rows.astype(np.int64), probe_ids % k


def batch_query(index, queries, theta: float, *,
                options: QueryOptions | None = None,
                sketches=UNSET,
                sketch_backend=UNSET,
                probe_backend=UNSET,
                sweep=UNSET,
                stage_times: dict | None = None) -> list[list[Alignment]]:
    """Definition-1 alignment for a batch of queries (the serving path).

    Execution comes in as ``options=QueryOptions(...)``: the ``plan``
    field picks the pipeline (``"cpu"`` — exact host sketch, one host
    ``searchsorted`` over the fused arena, vectorized grouped sweep;
    ``"device"`` — arena resident on the accelerator, probe binary search
    and small-group sweep as Pallas kernels, fused so only probe inputs go
    up and final block extents come down; ``"auto"`` — device when a real
    accelerator backs jax, else cpu), resolved ONCE per batch by
    :func:`repro.core.plan.resolve_plan`.  Stage fields on the options
    object pin individual stages for debugging.  All plans and pins are
    block-identical.

    ``QueryOptions.sketches`` short-circuits sketching when the caller
    already holds the batch's sketch coordinates (the sharded fan-out
    computes them once and reuses them on every shard).

    The bare ``sketches=``/``sketch_backend=``/``probe_backend=``/
    ``sweep=`` keywords are deprecated (one release behind a
    ``DeprecationWarning``); they coerce to pins on the cpu plan.

    ``stage_times``, when given, accumulates per-stage wall seconds under
    the keys ``"sketch"``, ``"probe"`` and ``"sweep"`` (the serve-path
    metrics hook; += so one dict can span many batches).
    """
    opts = coerce_query_options(options, "batch_query", sketches=sketches,
                                sketch_backend=sketch_backend,
                                probe_backend=probe_backend, sweep=sweep)
    xp = resolve_plan(opts)
    B = len(queries)
    if B == 0:
        return []
    m = max(1, math.ceil(index.scheme.k * theta))
    t0 = time.perf_counter()
    sk = opts.sketches
    if sk is None:
        sk = index.scheme.sketch_batch(queries, backend=xp.sketch_backend)
    t1 = time.perf_counter()
    if xp.fused and getattr(index, "is_frozen", False):
        from .device_plan import fused_batch_query
        out = fused_batch_query(index, sk, B, m, stage_times=stage_times)
        if stage_times is not None:
            stage_times["sketch"] = stage_times.get("sketch", 0.0) + (t1 - t0)
        return out
    gathered = batch_probe(index, sk, probe_backend=xp.probe_backend)
    t2 = time.perf_counter()
    out = _sweep_gathered(gathered, B, m, xp.sweep)
    if stage_times is not None:
        t3 = time.perf_counter()
        stage_times["sketch"] = stage_times.get("sketch", 0.0) + (t1 - t0)
        stage_times["probe"] = stage_times.get("probe", 0.0) + (t2 - t1)
        stage_times["sweep"] = stage_times.get("sweep", 0.0) + (t3 - t2)
    return out


def batch_probe(index, sketches, *, probe_backend: str = "numpy"
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The probe stage of ``batch_query``: all windows colliding with the
    batch's sketches, as (query ids (M,), windows (M, 5) int64, coordinate
    ids (M,)).

    Pure NumPy/mmap work that releases the GIL in searchsorted/gather —
    the sharded fan-out overlaps THIS stage across shards with a thread
    pool and keeps the (GIL-bound) sweep stage serial.
    """
    if getattr(index, "is_live", False):
        # live index: merge the frozen-arena and delta-dict probes (delta
        # tids re-based after the frozen corpus) into one gathered triple
        return index.batch_probe(sketches, probe_backend=probe_backend)
    B = len(sketches)
    k = index.scheme.k
    if index.is_frozen and probe_backend != "percoord":
        return _gather_arena(index, sketches, probe_backend)
    qid_chunks, win_chunks, cid_chunks = [], [], []
    for i in range(k):
        qids, wins = _gather_coord(index, i, [sketches[b][i]
                                              for b in range(B)])
        if len(qids):
            qid_chunks.append(qids)
            win_chunks.append(wins)
            cid_chunks.append(np.full(len(qids), i, np.int64))
    if not qid_chunks:
        return (np.empty(0, np.int64), np.empty((0, 5), np.int64),
                np.empty(0, np.int64))
    return (np.concatenate(qid_chunks), np.concatenate(win_chunks),
            np.concatenate(cid_chunks))


def _group_bounds(qid_all: np.ndarray, tid_all: np.ndarray,
                  cid_all: np.ndarray
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """(query, text) grouping of a gathered probe.

    Returns ``(order, starts, ends, distinct)``: ``order`` stably sorts the
    gathered rows by (query id, text id) — both gather orders
    (coordinate-major and query-major) are coordinate-ascending within a
    (query, text) group, which the stable sort preserves — ``starts``/
    ``ends`` bound each group in the sorted order, and ``distinct`` counts
    each group's distinct colliding sketch coordinates (the >= m
    prefilter, one reduceat).  Shared by the host dispatcher and the fused
    device pipeline (:mod:`repro.core.device_plan`).
    """
    order = np.lexsort((tid_all, qid_all))
    qid_s, tid_s, cid_s = qid_all[order], tid_all[order], cid_all[order]
    n = len(qid_s)
    change = (qid_s[1:] != qid_s[:-1]) | (tid_s[1:] != tid_s[:-1])
    bounds = np.flatnonzero(change) + 1
    starts = np.concatenate([[0], bounds])
    ends = np.concatenate([bounds, [n]])
    cid_step = np.empty(n, bool)
    cid_step[0] = True
    cid_step[1:] = cid_s[1:] != cid_s[:-1]
    cid_step[starts] = True
    distinct = np.add.reduceat(cid_step, starts)
    return order, starts, ends, distinct


#: small-group size buckets: padded width S stays tight for the (dominant)
#: tiny groups instead of paying the largest small group everywhere
_SIZE_BUCKETS = ((0, 8), (8, 16), (16, _SMALL_GROUP_MAX))


def _sweep_gathered(gathered, B: int, m: int, sweep: str
                    ) -> list[list[Alignment]]:
    """Group the gathered windows by (query, text) and plane-sweep each
    group (the second stage of ``batch_query``)."""
    qid_all, win_all, cid_all = gathered
    results: list[list[Alignment]] = [[] for _ in range(B)]
    if not len(qid_all):
        return results

    order, starts, ends, distinct = _group_bounds(
        qid_all, win_all[:, 0], cid_all)
    qid_all, win_all = qid_all[order], win_all[order]
    keep = distinct >= m
    sizes = ends - starts

    small_results: dict[int, list] = {}
    if sweep in ("grouped", "device"):
        sm_ids = np.flatnonzero(keep & (sizes <= _SMALL_GROUP_MAX))
        for b_lo, b_hi in _SIZE_BUCKETS:
            ids = sm_ids[(sizes[sm_ids] > b_lo) & (sizes[sm_ids] <= b_hi)]
            if not len(ids):
                continue
            s_starts, s_sizes = starts[ids], sizes[ids]
            G, S = len(ids), int(s_sizes.max())
            arr = np.zeros((G, S, 4), np.int64)
            rows = win_all[_concat_ranges(s_starts, s_sizes), 1:5]
            slot = np.arange(len(rows)) - np.repeat(
                np.cumsum(s_sizes) - s_sizes, s_sizes)
            arr[np.repeat(np.arange(G), s_sizes), slot] = rows
            if sweep == "device":
                from ..kernels.sweep_grid import sweep_small_batch_device
                batched = _extract_runs(
                    *sweep_small_batch_device(arr, s_sizes, m))
            else:
                batched = _sweep_small_batch(arr, s_sizes, m)
            for g, blocks in zip(ids, batched):
                small_results[int(g)] = blocks

    for g in np.flatnonzero(keep):
        g = int(g)
        lo = starts[g]
        blocks = small_results[g] if g in small_results else \
            _sweep_text(win_all[lo:ends[g], 1:5], m)
        if blocks:
            results[int(qid_all[lo])].append(
                Alignment(text_id=int(win_all[lo, 0]), blocks=blocks,
                          ncoords=int(distinct[g])))
    return results


def estimate_similarity(index, query_tokens, data_tokens
                        ) -> float:
    """Sketch-estimated Jaccard between two full texts (Eq. 2 / Eq. 5):
    one vectorized equality over the k sketch coordinates."""
    sq = index.scheme.sketch(query_tokens)
    sd = index.scheme.sketch(data_tokens)
    if sq and isinstance(sq[0], (tuple, list)):
        # ICWS identities: exact (token, k_int) pairs -> (k, 2) int64
        eq = np.asarray(sq, np.int64) == np.asarray(sd, np.int64)
        return float(np.mean(eq.all(axis=1)))
    # multiset identities: 61/64-bit hashes -> uint64 (the frozen tables'
    # key packing)
    return float(np.mean(np.array(sq, np.uint64) == np.array(sd, np.uint64)))
