"""Query processing (Algorithm 2): sketch the query, probe the k inverted
lists, plane-sweep the collided compact windows for cells covered >= ⌈kθ⌉
times (those subsequences have estimated Jaccard >= θ, Eq. 2/Eq. 5).

Two execution paths over the same algorithm:

* ``query``       — one query at a time; works on mutable (dict) and frozen
  indexes alike.
* ``batch_query`` — the serving path: sketches the whole batch at once,
  probes each of the k coordinates for all queries in a single vectorized
  ``searchsorted`` (frozen CSR tables), and groups the collided windows by
  (query, text) with one lexsort before the per-pair plane sweep.  Returns
  block-for-block the same results as looping ``query``.
"""

from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass

import numpy as np

from .frozen import _concat_ranges


@dataclass
class Alignment:
    """All result subsequences of one data text, as maximal blocks.

    blocks: list of (i_lo, i_hi, j_lo, j_hi) — every T[i..j] with
    i ∈ [i_lo, i_hi], j ∈ [j_lo, j_hi] is a result (0-indexed inclusive).
    """

    text_id: int
    blocks: list[tuple[int, int, int, int]]

    def cells(self) -> set[tuple[int, int]]:
        out = set()
        for il, ih, jl, jh in self.blocks:
            for i in range(il, ih + 1):
                for j in range(jl, jh + 1):
                    out.add((i, j))
        return out

    @property
    def num_cells(self) -> int:
        return sum((ih - il + 1) * (jh - jl + 1) for il, ih, jl, jh in self.blocks)


def _sweep_text(windows: list[tuple[int, int, int, int]], m: int
                ) -> list[tuple[int, int, int, int]]:
    """Cells covered by >= m of the given rectangles, as disjoint blocks.

    Coordinate-compressed 2-D difference array + cumulative sums; output
    blocks are maximal runs within each compressed stripe.
    """
    if len(windows) < m:
        return []
    arr = np.asarray(windows, dtype=np.int64)
    a, b, c, d = arr[:, 0], arr[:, 1], arr[:, 2], arr[:, 3]
    xs = np.unique(np.concatenate([a, b + 1]))
    ys = np.unique(np.concatenate([c, d + 1]))
    nx, ny = len(xs), len(ys)
    xi_a = np.searchsorted(xs, a)
    xi_b = np.searchsorted(xs, b + 1)
    yi_c = np.searchsorted(ys, c)
    yi_d = np.searchsorted(ys, d + 1)
    # one bincount scatter of the four +-1 corner pulses (C fast path)
    stride = ny + 1
    pos = np.concatenate([xi_a * stride + yi_c, xi_b * stride + yi_d])
    neg = np.concatenate([xi_a * stride + yi_d, xi_b * stride + yi_c])
    diff = (np.bincount(pos, minlength=(nx + 1) * stride)
            - np.bincount(neg, minlength=(nx + 1) * stride)
            ).reshape(nx + 1, stride).astype(np.int32)
    count = np.cumsum(np.cumsum(diff, axis=0), axis=1)
    # xs[i]..xs[i+1]-1 stripes; the last compressed coord is always an
    # exclusive upper bound (b+1 / d+1), so hot cannot extend past it.
    hot = count[:nx - 1, :ny - 1] >= m
    if not hot.any():
        return []
    # maximal horizontal runs per stripe, vectorized: +1/-1 edges of the
    # zero-padded hot mask mark run starts / one-past-run ends
    hpad = np.zeros((nx - 1, ny + 1), dtype=np.int8)
    hpad[:, 1:ny] = hot
    edges = np.diff(hpad, axis=1)
    rs, cs = np.nonzero(edges == 1)       # run starts (row-major)
    _, ce = np.nonzero(edges == -1)       # aligned exclusive run ends
    return [(int(xs[r]), int(xs[r + 1] - 1), int(ys[c0]), int(ys[c1] - 1))
            for r, c0, c1 in zip(rs, cs, ce)]


def query(index, query_tokens, theta: float
          ) -> list[Alignment]:
    """Near-duplicate text alignment (Definition 1) for one query."""
    k = index.scheme.k
    m = max(1, math.ceil(k * theta))
    sketch = index.scheme.sketch(query_tokens)
    per_text: dict[int, list] = defaultdict(list)
    ncoords: dict[int, int] = defaultdict(int)
    for i in range(k):
        prev = None
        for (tid, a, b, c, d) in index.lookup(i, sketch[i]):
            per_text[tid].append((a, b, c, d))
            if tid != prev:                 # postings are grouped by tid
                ncoords[tid] += 1
                prev = tid
    results = []
    for tid, wins in sorted(per_text.items()):
        # windows from one coordinate are disjoint (a cell's min-hash is
        # unique), so coverage >= m needs >= m distinct coordinates — skip
        # the sweep when that is impossible
        if ncoords[tid] < m:
            continue
        blocks = _sweep_text(wins, m)
        if blocks:
            results.append(Alignment(text_id=int(tid), blocks=blocks))
    return results


def _gather_coord(index, i: int, probe_keys: list
                  ) -> tuple[np.ndarray, np.ndarray]:
    """All windows colliding with the B probe keys on coordinate ``i``:
    (query ids (M,), windows (M, 5) int64)."""
    if index.is_frozen:
        table = index.frozen[i]
        packed = table.encode(probe_keys)
        starts, ends = table.probe(packed)
        counts = ends - starts
        qids = np.repeat(np.arange(len(probe_keys), dtype=np.int64), counts)
        rows = table.windows[_concat_ranges(starts, counts)]
        return qids, rows.astype(np.int64)
    qid_chunks, win_chunks = [], []
    for b, key in enumerate(probe_keys):
        wins = index.tables[i].get(key)
        if wins:
            qid_chunks.append(np.full(len(wins), b, np.int64))
            win_chunks.append(np.asarray(wins, np.int64))
    if not qid_chunks:
        return np.empty(0, np.int64), np.empty((0, 5), np.int64)
    return np.concatenate(qid_chunks), np.concatenate(win_chunks)


def batch_query(index, queries, theta: float, *,
                sketches: list[list] | None = None,
                sketch_backend: str = "exact") -> list[list[Alignment]]:
    """Definition-1 alignment for a batch of queries (the serving path).

    ``sketches`` short-circuits sketching when the caller already holds the
    batch's sketch coordinates (the sharded fan-out computes them once and
    reuses them on every shard).  ``sketch_backend="pallas"`` routes a
    weighted scheme's sketching through the fused device kernel in one
    launch (f32; see ``WeightedScheme.sketch_batch``).
    """
    B = len(queries)
    if B == 0:
        return []
    k = index.scheme.k
    m = max(1, math.ceil(k * theta))
    if sketches is None:
        sketches = index.scheme.sketch_batch(queries, backend=sketch_backend)

    qid_chunks, win_chunks, cid_chunks = [], [], []
    for i in range(k):
        qids, wins = _gather_coord(index, i, [sketches[b][i]
                                              for b in range(B)])
        if len(qids):
            qid_chunks.append(qids)
            win_chunks.append(wins)
            cid_chunks.append(np.full(len(qids), i, np.int64))
    results: list[list[Alignment]] = [[] for _ in range(B)]
    if not qid_chunks:
        return results
    qid_all = np.concatenate(qid_chunks)
    win_all = np.concatenate(win_chunks)
    cid_all = np.concatenate(cid_chunks)

    # one lexsort groups the collided windows by (query, text); each group
    # is a contiguous slice handed to the plane sweep
    order = np.lexsort((win_all[:, 0], qid_all))
    qid_all, win_all, cid_all = qid_all[order], win_all[order], cid_all[order]
    change = (qid_all[1:] != qid_all[:-1]) | \
        (win_all[1:, 0] != win_all[:-1, 0])
    bounds = np.flatnonzero(change) + 1
    for lo, hi in zip(np.concatenate([[0], bounds]),
                      np.concatenate([bounds, [len(qid_all)]])):
        # same distinct-coordinate prefilter as ``query`` (the stable sort
        # keeps each group's coordinate ids ascending)
        cids = cid_all[lo:hi]
        if 1 + np.count_nonzero(cids[1:] != cids[:-1]) < m:
            continue
        blocks = _sweep_text(win_all[lo:hi, 1:5], m)
        if blocks:
            results[int(qid_all[lo])].append(
                Alignment(text_id=int(win_all[lo, 0]), blocks=blocks))
    return results


def estimate_similarity(index, query_tokens, data_tokens
                        ) -> float:
    """Sketch-estimated Jaccard between two full texts (Eq. 2 / Eq. 5)."""
    sq = index.scheme.sketch(query_tokens)
    sd = index.scheme.sketch(data_tokens)
    return float(np.mean([1.0 if x == y else 0.0 for x, y in zip(sq, sd)]))
