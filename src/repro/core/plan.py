"""Execution plans: where each stage of a batched query runs.

PR 3-9 grew the query path one knob at a time — ``sketch_backend=``,
``probe_backend=``, ``sweep=``, ``fanout=`` — until picking "run on the
accelerator" meant knowing four stage-level spellings.  An
:class:`ExecutionPlan` names the whole pipeline instead:

* ``"cpu"``    — the NumPy reference path (exact host sketching, one host
  ``searchsorted`` over the fused arena, vectorized grouped sweep).  This
  is the bit-parity oracle every other plan is gated against.
* ``"device"`` — the device-resident path (:mod:`repro.core.device_plan`):
  the arena stays resident on the accelerator across batches, the probe
  binary search and the small-group sweep's difference-array run as Pallas
  kernels, and only final block extents return to host.  Sketching stays
  on the exact host path by default so the plan is bit-identical to
  ``"cpu"`` by construction; pin ``sketch_backend="pallas"`` to move the
  (f32) ICWS sketch onto the device too.
* ``"auto"``   — resolve once per batch: ``"device"`` when a real
  accelerator backs jax, else silently ``"cpu"``.

A plan is resolved from :class:`repro.core.results.QueryOptions` via
:func:`resolve_plan` — once per batch, never per query.  Stage fields left
``None`` take the plan's defaults; a non-``None`` stage field *pins* that
stage (the debugging escape hatch), and pinning a stage to a value the
plan cannot execute is a ``TypeError`` rather than a silent fallback.
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = ["ExecutionPlan", "resolve_plan", "register_plan",
           "plan_names", "device_preferred"]

#: the QueryOptions stage fields a plan resolves (in pin order)
STAGE_FIELDS = ("sketch_backend", "probe_backend", "sweep", "fanout")


@dataclass(frozen=True)
class ExecutionPlan:
    """A fully resolved pipeline: concrete backend per stage.

    ``name`` is the resolved plan ("auto" never survives resolution), the
    stage fields are the concrete values the query engine dispatches on.
    """

    name: str
    sketch_backend: str
    probe_backend: str
    sweep: str
    fanout: str

    @property
    def fused(self) -> bool:
        """True when probe and sweep both run device-side, enabling the
        fused pipeline (device gather, no per-stage host round-trip)."""
        return self.probe_backend == "device" and self.sweep == "device"


@dataclass(frozen=True)
class _PlanSpec:
    defaults: dict           # stage field -> default backend
    choices: dict            # stage field -> the values this plan can run
    requires_device: bool    # "auto" only picks it on a real accelerator


_PLANS: dict[str, _PlanSpec] = {}


def register_plan(name: str, *, defaults: dict, choices: dict,
                  requires_device: bool = False) -> None:
    """Register an execution plan.  ``defaults`` must name every stage
    field; ``choices`` lists the stage values the plan can execute."""
    missing = [f for f in STAGE_FIELDS if f not in defaults]
    if missing:
        raise ValueError(f"plan {name!r} defaults missing stages {missing}")
    _PLANS[name] = _PlanSpec(defaults=dict(defaults),
                             choices={f: frozenset(choices.get(f, ()))
                                      for f in STAGE_FIELDS},
                             requires_device=requires_device)


def plan_names() -> list[str]:
    return sorted(_PLANS) + ["auto"]


register_plan("cpu", defaults={
    "sketch_backend": "exact", "probe_backend": "numpy",
    "sweep": "grouped", "fanout": "threaded",
}, choices={
    "sketch_backend": ("exact", "pallas"),
    "probe_backend": ("numpy", "pallas", "percoord"),
    "sweep": ("grouped", "loop"),
    "fanout": ("threaded", "serial"),
})

register_plan("device", defaults={
    # exact host sketching keeps plan="device" bit-identical to plan="cpu";
    # sketch_backend="pallas" pins the f32 on-device ICWS sketch instead
    "sketch_backend": "exact", "probe_backend": "device",
    "sweep": "device", "fanout": "threaded",
}, choices={
    "sketch_backend": ("exact", "pallas"),
    "probe_backend": ("device", "numpy", "pallas", "percoord"),
    "sweep": ("device", "grouped", "loop"),
    "fanout": ("threaded", "serial"),
}, requires_device=True)


def device_preferred() -> bool:
    """Capability check for ``plan="auto"``: is a real accelerator backing
    jax?  Interpret-mode Pallas on CPU is correct but slower than NumPy,
    so auto only picks the device plan when the hardware pays for it."""
    try:
        import jax
        return jax.default_backend() in ("tpu", "gpu")
    except Exception:
        return False


def _capable(name: str, capabilities: dict | None) -> bool:
    if capabilities is not None and name in capabilities:
        return bool(capabilities[name])
    spec = _PLANS.get(name)
    if spec is None:
        return False
    return device_preferred() if spec.requires_device else True


def resolve_plan(options=None, *, capabilities: dict | None = None
                 ) -> ExecutionPlan:
    """Resolve options (or a bare plan name) into an :class:`ExecutionPlan`.

    Called once per batch by every query entry point.  ``capabilities``
    overrides the availability checks per plan name (``{"device": False}``
    forces the auto downgrade; tests and the batcher's capability cache
    use it).  ``"auto"`` silently resolves to ``"device"`` only when that
    plan's capability check passes, else to ``"cpu"``; an *explicitly*
    requested plan is honored regardless (on CPU it runs the kernels in
    interpret mode — the parity-gating configuration CI exercises).
    """
    if options is None:
        name, pins = "cpu", {}
    elif isinstance(options, str):
        name, pins = options, {}
    else:
        name = getattr(options, "plan", "cpu") or "cpu"
        pins = {f: getattr(options, f) for f in STAGE_FIELDS
                if getattr(options, f, None) is not None}
    if name == "auto":
        name = "device" if _capable("device", capabilities) else "cpu"
    spec = _PLANS.get(name)
    if spec is None:
        raise ValueError(f"unknown execution plan {name!r}; "
                         f"registered plans: {plan_names()}")
    stages = dict(spec.defaults)
    for f, v in pins.items():
        if v not in spec.choices[f]:
            raise TypeError(
                f"plan {name!r} cannot execute {f}={v!r} (valid pins: "
                f"{sorted(spec.choices[f])}); pinning a stage beyond what "
                "the plan supports is an error, not a fallback")
        stages[f] = v
    return ExecutionPlan(name=name, **stages)
