"""Typed query results and options — the public result surface and the
serving wire protocol.

The engine's native result is :class:`repro.core.query.Alignment` (one per
(query, data-text) pair, carrying the Definition-1 maximal blocks).  The
facade and the network server speak in terms of:

* :class:`Match` — one aligned data text, as a frozen record with the
  global ``doc_id``, the outer ``span`` of all result subsequences in the
  data text, the ``query_span`` it aligned against (Definition 1 aligns
  the *whole* query, so this is the full query extent), the
  ``estimated_similarity`` (the fraction of the query's k sketch
  coordinates that collided with the text — ``>= theta`` for every
  returned match, Eq. 2/Eq. 5), and the full ``blocks`` family.
* :class:`QueryResult` — the per-query container; iterates its matches
  (so ``for hit in aligner.find(...)`` keeps working) and round-trips
  through ``to_dict``/``from_dict``/JSON, which is exactly the payload
  the :mod:`repro.serve` server puts on the wire.
* :class:`QueryOptions` — one dataclass for the query-execution knobs
  that used to sprawl across ``backend``/``probe_backend``/``sweep``/
  ``fanout``/``sketches`` keyword arguments.  ``Aligner.find/find_batch``,
  ``LiveIndex.batch_query`` and ``ShardedAlignmentIndex.batch_query`` all
  accept ``options=QueryOptions(...)``; the old kwargs still work for one
  release behind a ``DeprecationWarning`` (:func:`coerce_query_options`).

None of these affect result *content*: every options combination remains
block-identical, and a ``Match`` is a re-labelling of an ``Alignment``.
"""

from __future__ import annotations

import json
import warnings
from dataclasses import dataclass, field, replace

__all__ = ["Match", "QueryResult", "QueryOptions", "UNSET",
           "coerce_query_options"]


@dataclass(frozen=True)
class Match:
    """One aligned data text (all its result subsequences, as blocks).

    span: (lo, hi) outer extent of the result subsequences in the data
        text: every reported ``T[i..j]`` has ``lo <= i`` and ``j <= hi``.
    query_span: (0, len(query) - 1) — the query extent the text aligned
        against (the paper aligns the full query).
    estimated_similarity: colliding-coordinate fraction ``ncoords / k``
        (>= theta by construction: a reported cell is covered by
        >= ceil(k * theta) coordinates); ``None`` when the producing path
        did not count collisions.
    blocks: the Definition-1 maximal blocks, ``(i_lo, i_hi, j_lo, j_hi)``
        tuples exactly as :class:`~repro.core.query.Alignment` carries
        them (every ``T[i..j]`` with ``i in [i_lo, i_hi]``,
        ``j in [j_lo, j_hi]`` is a result).
    """

    doc_id: int
    span: tuple[int, int]
    query_span: tuple[int, int]
    estimated_similarity: float | None
    blocks: list[tuple[int, int, int, int]] = field(default_factory=list)

    @property
    def text_id(self) -> int:
        """Legacy alias (``Alignment.text_id``) so pre-typed callers keep
        reading ``hit.text_id``."""
        return self.doc_id

    def __iter__(self):
        # tuple-style unpacking: doc_id, span, query_span, similarity
        yield self.doc_id
        yield self.span
        yield self.query_span
        yield self.estimated_similarity

    def to_dict(self) -> dict:
        return {"doc_id": self.doc_id,
                "span": list(self.span),
                "query_span": list(self.query_span),
                "estimated_similarity": self.estimated_similarity,
                "blocks": [list(b) for b in self.blocks]}

    @classmethod
    def from_dict(cls, d: dict) -> "Match":
        return cls(doc_id=int(d["doc_id"]),
                   span=tuple(int(x) for x in d["span"]),
                   query_span=tuple(int(x) for x in d["query_span"]),
                   estimated_similarity=(
                       None if d.get("estimated_similarity") is None
                       else float(d["estimated_similarity"])),
                   blocks=[tuple(int(x) for x in b) for b in d["blocks"]])

    @classmethod
    def from_alignment(cls, al, *, k: int, query_len: int) -> "Match":
        """Re-label one engine :class:`Alignment` (``k`` is the sketch
        width, for the similarity estimate)."""
        blocks = list(al.blocks)
        span = (min(b[0] for b in blocks), max(b[3] for b in blocks))
        sim = None if al.ncoords is None else al.ncoords / k
        return cls(doc_id=int(al.text_id), span=span,
                   query_span=(0, max(0, query_len - 1)),
                   estimated_similarity=sim, blocks=blocks)


@dataclass(frozen=True)
class QueryResult:
    """All matches of one query, plus the query's own context.

    Iterates (and indexes, and bool-tests) as the list of matches, so the
    pre-typed ``for hit in aligner.find(q, theta)`` loop is unchanged.

    ``degraded=True`` marks a *partial* result: one or more sharded
    fan-out probes failed (after bounded retries) and were skipped, so
    matches from the shards in ``failed_shards`` may be missing.  Healthy
    results keep the defaults, so pre-degraded consumers are unaffected.
    """

    matches: list[Match]
    theta: float
    query_len: int | None = None
    degraded: bool = False
    failed_shards: tuple = ()

    def __iter__(self):
        return iter(self.matches)

    def __len__(self) -> int:
        return len(self.matches)

    def __getitem__(self, i):
        return self.matches[i]

    def __bool__(self) -> bool:
        return bool(self.matches)

    def to_dict(self) -> dict:
        return {"matches": [m.to_dict() for m in self.matches],
                "theta": self.theta, "query_len": self.query_len,
                "degraded": self.degraded,
                "failed_shards": list(self.failed_shards)}

    @classmethod
    def from_dict(cls, d: dict) -> "QueryResult":
        return cls(matches=[Match.from_dict(m) for m in d["matches"]],
                   theta=float(d["theta"]),
                   query_len=(None if d.get("query_len") is None
                              else int(d["query_len"])),
                   degraded=bool(d.get("degraded", False)),
                   failed_shards=tuple(d.get("failed_shards", ())))

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s: str) -> "QueryResult":
        return cls.from_dict(json.loads(s))

    @classmethod
    def from_alignments(cls, alignments, *, theta: float, k: int,
                        query_len: int) -> "QueryResult":
        return cls(matches=[Match.from_alignment(al, k=k,
                                                 query_len=query_len)
                            for al in alignments],
                   theta=theta, query_len=query_len)


# sentinel distinguishing "kwarg not passed" from an explicit None
UNSET = object()

#: release in which the deprecated per-stage kwargs are removed — named in
#: every DeprecationWarning so callers know how long the shim lives
_REMOVAL_RELEASE = "0.3"

# Legacy kwargs that RENAME to a QueryOptions field.  Kwargs whose spelling
# already matches the field (probe_backend=, sweep=, ...) live only in
# _LEGACY_PASSTHROUGH — a name is either current or legacy, never both
# (the old table mapped probe_backend to itself, double-listing it).
_LEGACY_RENAMES = {"backend": "sketch_backend"}

# legacy kwargs whose QueryOptions field keeps the same name
_LEGACY_PASSTHROUGH = ("sketch_backend", "probe_backend", "sweep", "fanout",
                      "sketches")

#: the stage fields a plan resolves (mirrors repro.core.plan.STAGE_FIELDS,
#: duplicated here so the wire/result layer stays import-light)
_STAGE_FIELDS = ("sketch_backend", "probe_backend", "sweep", "fanout")

_WIRE_FIELDS = ("plan",) + _STAGE_FIELDS


@dataclass(frozen=True)
class QueryOptions:
    """Execution knobs for the batched query path (content-neutral: every
    combination returns block-identical results).

    plan: which :class:`repro.core.plan.ExecutionPlan` runs the batch —
        ``"cpu"`` (NumPy reference path), ``"device"`` (arena resident on
        the accelerator, probe + sweep as Pallas kernels) or ``"auto"``
        (device when a real accelerator backs jax, else silently cpu).
        Resolved once per batch by ``repro.core.plan.resolve_plan``.
    sketch_backend / probe_backend / sweep / fanout: per-stage *pins*.
        ``None`` (the default) lets the plan pick; a concrete value pins
        that one stage for debugging (``probe_backend="percoord"`` forces
        the legacy k-probe loop regardless of plan).  Pinning a value the
        plan cannot execute raises ``TypeError`` at resolution.
    sketches: precomputed batch sketch coordinates, short-circuiting the
        sketch stage (the caller guarantees they match the queries).
        Excluded from the wire form.
    """

    plan: str = "cpu"
    sketch_backend: str | None = None
    probe_backend: str | None = None
    sweep: str | None = None
    fanout: str | None = None
    sketches: object = None

    def batch_key(self) -> tuple:
        """Coalescing key: requests whose options agree on these knobs may
        be served by one fused probe.  The plan name is part of the key,
        so mixed-plan traffic (cpu and device requests interleaved on one
        server) never coalesces into a single dispatch; unresolved pins
        (``None``) key differently from their resolved values — a
        conservative split that can only under-coalesce, never mix."""
        return (self.plan, self.sketch_backend, self.probe_backend,
                self.sweep, self.fanout)

    def to_dict(self) -> dict:
        d = {"plan": self.plan}
        d.update({f: getattr(self, f) for f in _STAGE_FIELDS
                  if getattr(self, f) is not None})
        return d

    @classmethod
    def from_dict(cls, d: dict | None) -> "QueryOptions":
        d = d or {}
        unknown = set(d) - set(_WIRE_FIELDS)
        if unknown:
            if "sketches" in d:
                raise ValueError("sketches are an in-process short-circuit "
                                 "and cannot travel over the wire")
            raise ValueError(f"unknown query options: {sorted(unknown)}")
        return cls(**{k: d[k] for k in d})


def coerce_query_options(options: QueryOptions | None, where: str,
                         **legacy) -> QueryOptions:
    """Resolve the (new options object, old kwargs) call surface into one
    :class:`QueryOptions`.

    ``legacy`` maps old kwarg names to the values the caller received
    (``UNSET`` when not passed).  Passing any old kwarg emits a
    ``DeprecationWarning`` naming the replacement and the release the
    kwarg dies in; mixing both surfaces in one call is an error (silently
    preferring one would hide a bug).  Coerced stage kwargs become *pins*
    on the default ``"cpu"`` plan, which reproduces their pre-plan
    behavior exactly.
    """
    given = {k: v for k, v in legacy.items() if v is not UNSET}
    if not given:
        return options if options is not None else QueryOptions()
    if options is not None:
        raise TypeError(
            f"{where}: pass options=QueryOptions(...) or the legacy "
            f"keyword arguments {sorted(given)}, not both")
    renames = {}
    for k in given:
        if k in _LEGACY_RENAMES:
            renames[k] = _LEGACY_RENAMES[k]
        elif k in _LEGACY_PASSTHROUGH:
            renames[k] = k
        else:
            raise TypeError(f"{where}: unknown legacy keyword argument {k!r}")
    warnings.warn(
        f"{where}: keyword arguments {sorted(given)} are deprecated and "
        f"will be removed in release {_REMOVAL_RELEASE}; pass "
        "options=QueryOptions(" +
        ", ".join(f"{renames[k]}=..." for k in sorted(given)) + ") instead",
        DeprecationWarning, stacklevel=3)
    return replace(QueryOptions(), **{renames[k]: v for k, v in given.items()})
