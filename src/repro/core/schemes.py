"""Sketch schemes and the ``make_scheme`` registry.

A *scheme* bundles the k hash functions of one similarity notion and knows
how to (a) generate compact-window index keys for a text and (b) sketch a
query.  Two families implement the paper:

  * ``MultisetScheme``  — integer universal min-hash (§2) for multi-set
    Jaccard; index key ``int(h)``.
  * ``WeightedScheme``  — ICWS (§5) for weighted Jaccard; index key
    ``(token, k_int)``.

``make_scheme(similarity, ...)`` is the single construction point used by
the :class:`repro.api.Aligner` facade and the data-plane filters:

  * ``"multiset"`` — unweighted multi-set Jaccard.
  * ``"weighted"`` — weighted Jaccard with a corpus-free weight function
    (TF only; ``idf="unary"`` unless corpus stats are passed explicitly).
  * ``"tfidf"``    — weighted Jaccard with a corpus-fitted TF-IDF weight
    (requires ``corpus=`` so ``WeightFn.fit`` can count doc frequencies).

Schemes round-trip through JSON (``scheme_spec`` / ``scheme_from_spec``) so
the versioned index store (:mod:`repro.core.store`) can reconstruct the
exact hash family when an index is loaded in a fresh process.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .hashing import UniversalHash
from .icws import ICWS
from .keys import (generate_key_columns_icws, generate_key_columns_multiset,
                   generate_keys_icws, generate_keys_multiset)
from .weights import WeightFn


@dataclass
class MultisetScheme:
    """Sketch scheme for multi-set Jaccard (standard min-hash over (t, x)).

    family="universal" is the paper's linear family (§2.2).  family="mix"
    (splitmix64) is our beyond-paper variant: the linear family is an
    arithmetic progression in x, which empirically inflates the number of
    active hash values (≈1.7× at f=256) over the idealized i.i.d. analysis
    of Lemma 11 — splitmix removes that structure, shrinking keys, windows,
    and thus the index (see EXPERIMENTS.md §Beyond-paper).
    """

    seed: int = 0
    k: int = 16
    family: str = "universal"
    hashers: list = field(init=False)

    def __post_init__(self):
        from .hashing import MixHash
        cls = {"universal": UniversalHash, "mix": MixHash}[self.family]
        self.hashers = cls.from_seed(self.seed, self.k)

    def keys(self, tokens, i: int, active: bool, occ=None):
        return generate_keys_multiset(tokens, self.hashers[i], active=active,
                                      occ=occ)

    def key_columns(self, tokens, i: int, active: bool, occ=None):
        """Columnar ``keys``: same KeySet, per-gid identities as a uint64
        array (``gid_ident``) instead of boxed Python ints (the columnar
        build pipeline's keygen path)."""
        return generate_key_columns_multiset(tokens, self.hashers[i],
                                             active=active, occ=occ)

    def sketch(self, tokens) -> list:
        """k min-hash identities of a whole text (Eq. 1)."""
        from .keys import occurrence_lists
        occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
        out = []
        for h in self.hashers:
            best = None
            for t, pos in occ.items():
                hv = h(np.full(len(pos), t, dtype=np.int64),
                       np.arange(1, len(pos) + 1))
                m = int(hv.min())
                if best is None or m < best:
                    best = m
            out.append(best)
        return out

    def sketch_batch(self, texts, *, backend: str = "exact") -> list[list]:
        """Sketches of many texts; bit-identical to per-text ``sketch``
        (integer hashes are exact on every backend, so ``backend`` is
        accepted for signature parity and ignored).

        One vectorized hash call per (text, hasher) over the flat (t, x)
        grid instead of a Python loop per token — the batched query
        engine's sketching path.
        """
        from .keys import _flat_grid, occurrence_lists
        out = []
        for tokens in texts:
            occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
            _toks, _fs, t_rep, x_rep, _bounds = _flat_grid(occ)
            out.append([int(h(t_rep, x_rep).min()) for h in self.hashers])
        return out


@dataclass
class WeightedScheme:
    """Sketch scheme for weighted Jaccard (ICWS over (t, w(t, f)))."""

    weight: WeightFn
    seed: int = 0
    k: int = 16
    hashers: list[ICWS] = field(init=False)

    def __post_init__(self):
        self.hashers = ICWS.from_seed(self.seed, self.k)

    def keys(self, tokens, i: int, active: bool, occ=None):
        return generate_keys_icws(tokens, self.hashers[i], self.weight,
                                  active=active, occ=occ)

    def key_columns(self, tokens, i: int, active: bool, occ=None):
        """Columnar ``keys``: same KeySet, per-gid identities as an int64
        (G, 2) array (``gid_ident``) instead of boxed (token, k_int)
        tuples (the columnar build pipeline's keygen path)."""
        return generate_key_columns_icws(tokens, self.hashers[i], self.weight,
                                         active=active, occ=occ)

    def sketch(self, tokens) -> list:
        from .keys import occurrence_lists
        occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
        toks = np.array(sorted(occ), dtype=np.int64)
        freqs = np.array([len(occ[int(t)]) for t in toks], dtype=np.int64)
        w = self.weight(toks, freqs)
        out = []
        for h in self.hashers:
            t_star, k_star, _a = h.min_hash(toks, w)
            out.append((t_star, k_star))
        return out

    def sketch_batch(self, texts, *, backend: str = "exact") -> list[list]:
        """Sketches of many texts.

        backend="exact"  — float64 host math, bit-identical to per-text
        ``sketch`` (the default; what result-parity guarantees assume).
        The whole batch is sketched in ONE flat (k, N) hash evaluation
        over the concatenated unique tokens of every text plus a padded
        segmented argmin, instead of B * k small per-text numpy calls —
        the cost of sketching B short queries is then dominated by the
        flat array math, not per-call overhead, which is what makes the
        serving path's dynamic batching pay off.
        backend="pallas" — all texts through the fused ``icws_sketch_batch``
        kernel in one launch (f32 device math; identities can differ from
        the exact path only on argmin near-ties).
        """
        if backend == "pallas":
            from ..kernels.ops import cws_sketch_batch
            from .keys import occurrence_lists
            token_lists, weight_lists = [], []
            for tokens in texts:
                occ = occurrence_lists(np.asarray(tokens, dtype=np.int64))
                toks = np.array(sorted(occ), dtype=np.int64)
                freqs = np.array([len(occ[int(t)]) for t in toks],
                                 dtype=np.int64)
                token_lists.append(toks)
                weight_lists.append(self.weight(toks, freqs))
            return cws_sketch_batch(self.seed, self.k, token_lists,
                                    weight_lists)
        uniq = [np.unique(np.asarray(t, dtype=np.int64), return_counts=True)
                for t in texts]
        if not uniq or min(len(u) for u, _ in uniq) == 0:
            return [self.sketch(t) for t in texts]
        out: list[list] = []
        # chunk so the (k, B_chunk, Umax) argmin pad stays cache-sized even
        # for batches of long texts
        budget = (1 << 22) // max(1, self.k)
        lo = 0
        while lo < len(uniq):
            hi, umax = lo, 0
            while hi < len(uniq):
                umax = max(umax, len(uniq[hi][0]))
                if hi > lo and (hi - lo + 1) * umax > budget:
                    break
                hi += 1
            out.extend(self._sketch_chunk(uniq[lo:hi]))
            lo = hi
        return out

    def _sketch_chunk(self, uniq: list) -> list[list]:
        """Vectorized exact sketches of one chunk of (unique tokens,
        counts) pairs; bit-identical to looping ``sketch``."""
        from .icws import _token_params
        B = len(uniq)
        sizes = np.array([len(u) for u, _ in uniq], dtype=np.int64)
        toks = np.concatenate([u for u, _ in uniq])
        freqs = np.concatenate([c for _, c in uniq])
        w = self.weight(toks, freqs)
        seeds = np.array([h.seed for h in self.hashers], dtype=np.uint64)
        # (k, N): same float64 formulas as ICWS.hash_parts, elementwise,
        # so every (hasher, token) value matches the per-text path bit
        # for bit
        r, c, beta = _token_params(seeds[:, None], toks[None, :])
        logw = np.log(w)[None, :]
        k_int = np.floor(logw / r + beta)
        y = np.exp(r * (k_int - beta))
        a = c / (y * np.exp(r))
        # segmented argmin via an inf-padded (k, B, Umax) view; tokens are
        # ascending within each text exactly as in ``sketch``, and inf
        # padding sits after them, so first-min indices agree
        starts = np.cumsum(sizes) - sizes
        slot = np.arange(len(toks), dtype=np.int64) - np.repeat(starts, sizes)
        row = np.repeat(np.arange(B, dtype=np.int64), sizes)
        pad = np.full((self.k, B, int(sizes.max())), np.inf)
        pad[:, row, slot] = a
        amin = pad.argmin(axis=2)                     # (k, B)
        flat = starts[None, :] + amin
        t_star = toks[flat]
        k_star = np.take_along_axis(k_int.astype(np.int64), flat, axis=1)
        return [[(int(t_star[i, b]), int(k_star[i, b]))
                 for i in range(self.k)] for b in range(B)]


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, Callable] = {}


def register_scheme(name: str):
    """Register a scheme factory under ``name`` (used by ``make_scheme``)."""
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


@register_scheme("multiset")
def _make_multiset(*, seed=0, k=16, family="universal", **_ignored):
    return MultisetScheme(seed=seed, k=k, family=family)


@register_scheme("weighted")
def _make_weighted(*, seed=0, k=16, tf="raw", idf="unary", weight=None,
                   n_docs=None, doc_freq=None, **_ignored):
    if weight is None:
        weight = WeightFn(tf=tf, idf=idf, n_docs=n_docs, doc_freq=doc_freq)
    return WeightedScheme(weight=weight, seed=seed, k=k)


@register_scheme("tfidf")
def _make_tfidf(*, seed=0, k=16, tf="raw", idf="smooth", weight=None,
                corpus=None, **_ignored):
    if weight is None:
        if corpus is None:
            raise ValueError(
                'similarity="tfidf" fits IDF from document frequencies: '
                "pass corpus= (token docs) or a pre-fitted weight=")
        weight = WeightFn.fit(corpus, tf=tf, idf=idf)
    return WeightedScheme(weight=weight, seed=seed, k=k)


def make_scheme(similarity: str = "weighted", **kw):
    """Construct a sketch scheme by similarity name.

    See the module docstring for the registered names; extra keyword
    arguments are forwarded to the factory (``seed``, ``k``, ``tf``,
    ``idf``, ``family``, ``weight``, ``corpus``).
    """
    try:
        factory = _REGISTRY[similarity]
    except KeyError:
        raise ValueError(f"unknown similarity {similarity!r}; registered: "
                         f"{sorted(_REGISTRY)}") from None
    return factory(**kw)


# --------------------------------------------------------------------------
# JSON round-trip (the versioned store's manifest entry)
# --------------------------------------------------------------------------

def scheme_spec(scheme) -> dict:
    """JSON-serializable description sufficient to rebuild ``scheme``."""
    if isinstance(scheme, MultisetScheme):
        return {"kind": "multiset", "seed": scheme.seed, "k": scheme.k,
                "family": scheme.family}
    if isinstance(scheme, WeightedScheme):
        w = scheme.weight
        return {"kind": "weighted", "seed": scheme.seed, "k": scheme.k,
                "weight": {"tf": w.tf, "idf": w.idf, "n_docs": w.n_docs,
                           "doc_freq": ({str(t): c
                                         for t, c in w.doc_freq.items()}
                                        if w.doc_freq is not None else None)}}
    raise TypeError(f"cannot serialize scheme of type {type(scheme)!r}")


def scheme_from_spec(spec: dict):
    """Inverse of ``scheme_spec``: rebuild the exact hash family."""
    kind = spec["kind"]
    if kind == "multiset":
        return MultisetScheme(seed=spec["seed"], k=spec["k"],
                              family=spec.get("family", "universal"))
    if kind == "weighted":
        w = spec["weight"]
        doc_freq = ({int(t): int(c) for t, c in w["doc_freq"].items()}
                    if w.get("doc_freq") is not None else None)
        weight = WeightFn(tf=w["tf"], idf=w["idf"], n_docs=w.get("n_docs"),
                          doc_freq=doc_freq)
        return WeightedScheme(weight=weight, seed=spec["seed"], k=spec["k"])
    raise ValueError(f"unknown scheme kind {kind!r} in manifest")
