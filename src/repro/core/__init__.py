# The paper's primary contribution: near-duplicate text alignment under
# weighted Jaccard similarity via MonoActive compact-window partitioning.
#
# Build→serve lifecycle (PR 2): IndexBuilder (mutable dict tables) freezes
# into SearchIndex (immutable CSR tables + versioned mmap-able store);
# repro.api.Aligner is the one-object facade.  The long-deprecated
# AlignmentIndex shim is no longer re-exported here — import it from
# repro.core.index if you still need the pre-split object.
from .allalign import allalign_icws, allalign_multiset, allalign_partition
from .builder import IndexBuilder
from .columnar import ColumnarBuilder
from .frozen import FrozenTable, ProbeArena
from .hashing import MixHash, UniversalHash
from .icws import ICWS
from .keys import (KeySet, count_active_hashes, generate_keys_icws,
                   generate_keys_multiset, occurrence_lists)
from .live import LiveIndex
from .oracle import (jaccard_multiset, jaccard_weighted,
                     minhash_gid_grid_icws, minhash_gid_grid_multiset,
                     validate_partition)
from .partition import (Partition, mono_active_icws, mono_active_multiset,
                        mono_all_icws, mono_all_multiset, monotonic_partition)
from .plan import ExecutionPlan, plan_names, resolve_plan
from .query import Alignment, batch_query, estimate_similarity, query
from .results import Match, QueryOptions, QueryResult
from .schemes import (MultisetScheme, WeightedScheme, make_scheme,
                      scheme_from_spec, scheme_spec)
from .search import SearchIndex
from .sharded_index import ShardedAlignmentIndex
from .store import load_index, read_manifest, save_index
from .weights import WeightFn

__all__ = [
    "ICWS", "UniversalHash", "MixHash", "WeightFn", "KeySet", "Partition",
    "IndexBuilder", "ColumnarBuilder", "SearchIndex",
    "LiveIndex", "MultisetScheme",
    "WeightedScheme", "make_scheme", "scheme_spec", "scheme_from_spec",
    "Alignment", "Match", "QueryResult", "QueryOptions",
    "ExecutionPlan", "resolve_plan", "plan_names",
    "generate_keys_multiset", "generate_keys_icws", "occurrence_lists",
    "count_active_hashes", "monotonic_partition", "mono_all_multiset",
    "mono_active_multiset", "mono_all_icws", "mono_active_icws",
    "allalign_partition", "allalign_multiset", "allalign_icws",
    "minhash_gid_grid_multiset", "minhash_gid_grid_icws", "validate_partition",
    "jaccard_multiset", "jaccard_weighted", "query", "estimate_similarity",
    "FrozenTable", "ProbeArena", "batch_query", "ShardedAlignmentIndex",
    "save_index", "load_index", "read_manifest",
]
