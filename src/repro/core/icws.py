"""Improved Consistent Weighted Sampling (Ioffe 2010; Algorithm 6 of paper).

A hash function h ∈ H maps (token t, weight w) -> HashValue (t, y, a):

    r_t, c_t ~ Gamma(2,1),  β_t ~ Uniform(0,1)      (per token, per function)
    k_int = ⌊ ln(w)/r_t + β_t ⌋                      (the "quantized log-weight")
    y     = exp(r_t · (k_int − β_t))
    a     = c_t / (y · exp(r_t))

Ordering: v1 < v2  iff  a1 < a2.   Equality: same t and same y — and since y
is determined by the *integer* k_int (given t), we use (t, k_int) as the
exact identity of a hash value.  This gives the host partitioner an integer
grouping key with no float-equality fragility (recorded in DESIGN.md §6).

Per-token randomness is derived *statelessly* from (seed, token) via
splitmix64 — Gamma(2,1) = −ln(u1·u2) — so no vocabulary-sized tables exist
and every distributed worker reproduces identical hash functions.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .hashing import mix2, uniform01


def _token_params(seed, t: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """r_t, c_t, beta_t for token array t (float64).  ``seed`` may be a
    scalar or an array broadcastable against ``t`` (the batched sketch
    evaluates all k hashers in one (k, N) call)."""
    t = np.asarray(t, dtype=np.uint64)
    base = mix2(np.asarray(seed, dtype=np.uint64), t)
    u1 = uniform01(mix2(base, np.uint64(1)))
    u2 = uniform01(mix2(base, np.uint64(2)))
    u3 = uniform01(mix2(base, np.uint64(3)))
    u4 = uniform01(mix2(base, np.uint64(4)))
    u5 = uniform01(mix2(base, np.uint64(5)))
    r = -np.log(u1 * u2)   # Gamma(2, 1)
    c = -np.log(u3 * u4)   # Gamma(2, 1)
    beta = u5              # Uniform(0, 1)
    return r, c, beta


@dataclass(frozen=True)
class ICWS:
    """One member of the ICWS hash family (≙ one sketch coordinate)."""

    seed: int

    @classmethod
    def from_seed(cls, seed: int, k: int) -> list["ICWS"]:
        base = mix2(np.uint64(seed), np.arange(k, dtype=np.uint64))
        return [cls(int(base[i])) for i in range(k)]

    def hash_parts(self, t, w) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return (k_int, y, a) for tokens t with weights w (broadcastable).

        k_int is the integer identity component; a is the sort component.
        """
        t = np.atleast_1d(np.asarray(t, dtype=np.int64))
        w = np.atleast_1d(np.asarray(w, dtype=np.float64))
        t, w = np.broadcast_arrays(t, w)
        r, c, beta = _token_params(self.seed, t)
        k_int = np.floor(np.log(w) / r + beta)
        y = np.exp(r * (k_int - beta))
        a = c / (y * np.exp(r))
        return k_int.astype(np.int64), y, a

    def a_value(self, t, w) -> np.ndarray:
        """Just the comparable part a (float64)."""
        return self.hash_parts(t, w)[2]

    def min_hash(self, tokens: np.ndarray, weights: np.ndarray
                 ) -> tuple[int, int, float]:
        """Weighted min-hash of a text given (distinct tokens, weights).

        Returns the identity/order triple (t*, k_int*, a*).
        """
        k_int, _y, a = self.hash_parts(tokens, weights)
        i = int(np.argmin(a))
        return int(tokens[i]), int(k_int[i]), float(a[i])
