"""Key generation (Algorithms 3 & 5 of the paper), multiset and ICWS variants.

A *key* is a pair (p, q), 0-indexed here, with T[p] == T[q]; its hash value is
h(T[q], f(T[q], T[p,q])).  ``generate_keys`` enumerates all keys (Alg. 3);
``generate_active_keys`` only keys whose hash value is a strict running
minimum over the frequency axis (Alg. 5) — the paper's active-hash
optimization, which cuts the expected key count to O(n + n·log f).

Keys are returned pre-sorted in visiting order: ascending hash, ties broken
by frequency ASCENDING, then (p, q).

Erratum note (recorded in DESIGN.md §4): the §5 caveat of the paper as
printed says to visit the *higher*-frequency key first on hash ties.  That
ordering makes MonoAll emit extra windows for non-active keys (they are
visited before the equal-hash lower-frequency keys that dominate them),
contradicting the paper's own §6.1 statement that "the optimization in
MonoActive does not change the generated compact windows".  Visiting the
LOWER frequency first restores Lemma 8's skipping argument for equal hash
values (the short key dominates the long one and is visited first), making
MonoAll ≡ MonoActive exactly — which we assert in tests.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .icws import ICWS
from .weights import WeightFn


@dataclass
class KeySet:
    """Keys in visiting order plus the hash-identity table for the index.

    gid is a *local* dense group id per distinct hash value; ``gid_key``
    maps gid -> hashable identity used as the inverted-index key:
      multiset:  int(h)           (uint64 universal hash value)
      ICWS:      (token, k_int)   (exact integer identity, DESIGN.md §6)
    ``order`` is the sortable hash magnitude (uint64 h, or float64 a).

    The columnar generators (``generate_key_columns_*``) skip the
    ``gid_key`` list (building per-gid Python objects is exactly the boxing
    cost the columnar build pipeline removes) and fill ``gid_ident``
    instead: uint64 (G,) hash values for multiset, int64 (G, 2)
    (token, k_int) rows for ICWS.
    """

    n: int
    p: np.ndarray
    q: np.ndarray
    gid: np.ndarray
    order: np.ndarray
    freq: np.ndarray
    gid_key: list = field(default_factory=list)
    gid_order: np.ndarray | None = None  # order value per gid (for sketches)
    gid_ident: np.ndarray | None = None  # columnar identity per gid

    def __len__(self) -> int:
        return len(self.p)


def occurrence_lists(tokens: np.ndarray) -> dict[int, np.ndarray]:
    """token -> sorted positions (0-indexed)."""
    tokens = np.asarray(tokens, dtype=np.int64)
    order = np.argsort(tokens, kind="stable")
    sorted_tok = tokens[order]
    bounds = np.flatnonzero(np.diff(sorted_tok)) + 1
    groups = np.split(order, bounds)
    return {int(tokens[g[0]]): np.sort(g) for g in groups}


def _sort_keys(n, ps, qs, gids, orders, freqs, gid_key, gid_order) -> KeySet:
    p = np.concatenate(ps) if ps else np.empty(0, np.int64)
    q = np.concatenate(qs) if qs else np.empty(0, np.int64)
    g = np.concatenate(gids) if gids else np.empty(0, np.int64)
    o = np.concatenate(orders) if orders else np.empty(0, np.float64)
    f = np.concatenate(freqs) if freqs else np.empty(0, np.int64)
    # visiting order: hash asc, freq ASC (see erratum note), then (p, q)
    idx = np.lexsort((q, p, f, o))
    return KeySet(n=n, p=p[idx], q=q[idx], gid=g[idx], order=o[idx],
                  freq=f[idx], gid_key=gid_key, gid_order=gid_order)


# ---------------------------------------------------------------------------
# Multiset (integer universal hash) key generation
# ---------------------------------------------------------------------------


def _flat_grid(occ: dict[int, np.ndarray]):
    """One flat (t, x) enumeration of the whole hash grid.

    §Perf cell D iteration 1: hashing token-by-token spent 46% of index
    build time in numpy small-call overhead (253k mod_m61 invocations for a
    20k-token text); one vectorized call is ~30 invocations total."""
    toks = np.fromiter(occ.keys(), np.int64, len(occ))
    fs = np.fromiter((len(v) for v in occ.values()), np.int64, len(occ))
    total = int(fs.sum())
    t_rep = np.repeat(toks, fs)
    starts = np.concatenate([[0], np.cumsum(fs)[:-1]])
    x_rep = np.arange(total, dtype=np.int64) - np.repeat(starts, fs) + 1
    return toks, fs, t_rep, x_rep, np.cumsum(fs)[:-1]


def _multiset_hash_per_token(occ: dict[int, np.ndarray], hashfn):
    """token -> uint64 array h(t, 1..f_t) (single vectorized hash call)."""
    toks, _fs, t_rep, x_rep, bounds = _flat_grid(occ)
    h_all = hashfn(t_rep, x_rep)
    return dict(zip(toks.tolist(), np.split(h_all, bounds)))


def generate_keys_multiset(tokens: np.ndarray, hashfn, active: bool = False,
                           occ: dict | None = None) -> KeySet:
    """Algorithm 3 (active=False) / Algorithm 5 (active=True) for the
    multi-set min-hash."""
    tokens = np.asarray(tokens, dtype=np.int64)
    n = len(tokens)
    if occ is None:
        occ = occurrence_lists(tokens)
    hpt = _multiset_hash_per_token(occ, hashfn)

    ps, qs, gids, orders, freqs = [], [], [], [], []
    gid_key: list = []
    gid_order: list = []
    for t, pos in occ.items():
        m = len(pos)
        hv_u = hpt[t]                   # uint64, exact hash values h(t, 1..m)
        if active:
            run_min = np.minimum.accumulate(hv_u)
            is_act = np.empty(m, dtype=bool)
            is_act[0] = True
            is_act[1:] = hv_u[1:] < run_min[:-1]
            xs = np.flatnonzero(is_act) + 1   # active frequencies (1-based)
        else:
            xs = np.arange(1, m + 1)
        for x in xs:
            cnt = m - x + 1
            ps.append(pos[:cnt])
            qs.append(pos[x - 1:])
            g = len(gid_key)
            gid_key.append(int(hv_u[x - 1]))
            gid_order.append(int(hv_u[x - 1]))
            gids.append(np.full(cnt, g, dtype=np.int64))
            # exact uint64 ordering — no float rounding of 61-bit values
            orders.append(np.full(cnt, hv_u[x - 1], dtype=np.uint64))
            freqs.append(np.full(cnt, x, dtype=np.int64))
    return _sort_keys(n, ps, qs, gids, orders, freqs, gid_key,
                      np.array(gid_order, dtype=np.uint64))


# ---------------------------------------------------------------------------
# ICWS (weighted) key generation
# ---------------------------------------------------------------------------


def generate_keys_icws(tokens: np.ndarray, icws: ICWS, weight: WeightFn,
                       active: bool = False, occ: dict | None = None) -> KeySet:
    """Key generation under consistent weighted sampling (§5).

    Hash values h(t, x) := icws(t, w(t, x)) are non-increasing in x
    (Lemma 12), so a value is active iff it strictly decreases — iff its
    integer component k_int strictly exceeds the previous one.
    """
    tokens = np.asarray(tokens, dtype=np.int64)
    n = len(tokens)
    if occ is None:
        occ = occurrence_lists(tokens)

    # vectorized ICWS over the whole (t, x) grid (§Perf cell D iteration 1)
    toks_u, _fs, t_rep, x_rep, bounds = _flat_grid(occ)
    w_all = weight(t_rep, x_rep)
    k_all, _y_all, a_all = icws.hash_parts(t_rep, w_all)
    k_split = dict(zip(toks_u.tolist(), np.split(k_all, bounds)))
    a_split = dict(zip(toks_u.tolist(), np.split(a_all, bounds)))

    ps, qs, gids, orders, freqs = [], [], [], [], []
    gid_key: list = []
    gid_order: list = []
    for t, pos in occ.items():
        m = len(pos)
        k_int, a = k_split[t], a_split[t]
        if active:
            # a is non-increasing; active iff strict decrease vs running min
            run_min = np.minimum.accumulate(a)
            is_act = np.empty(m, dtype=bool)
            is_act[0] = True
            is_act[1:] = a[1:] < run_min[:-1]
            xs = np.flatnonzero(is_act) + 1
        else:
            xs = np.arange(1, m + 1)
        for x in xs:
            cnt = m - x + 1
            ps.append(pos[:cnt])
            qs.append(pos[x - 1:])
            g = len(gid_key)
            gid_key.append((t, int(k_int[x - 1])))
            gid_order.append(float(a[x - 1]))
            gids.append(np.full(cnt, g, dtype=np.int64))
            orders.append(np.full(cnt, a[x - 1], dtype=np.float64))
            freqs.append(np.full(cnt, x, dtype=np.int64))
    return _sort_keys(n, ps, qs, gids, orders, freqs, gid_key,
                      np.array(gid_order, dtype=np.float64))


# ---------------------------------------------------------------------------
# Columnar key generation (the build pipeline's vectorized fast path)
# ---------------------------------------------------------------------------
#
# The per-gid loops above materialize one Python object per hash identity
# (an int or a (token, k_int) tuple) because the dict IndexBuilder needs
# hashable keys.  The columnar build pipeline never touches a dict, so its
# generators below produce the same KeySet — provably the same (p, q, order,
# freq) rows in the same visiting order, since the lexsort comparators are
# value-based and (p, q) pairs are globally unique — from a handful of
# whole-grid NumPy ops, with per-gid identities left as arrays
# (``KeySet.gid_ident``).


def _occ_columns(occ: dict[int, np.ndarray]):
    """Flatten an occurrence dict into parallel columns (token-major):
    (tokens (T,), freqs (T,), segment starts (T,), positions flat (N,),
    token-index per grid cell (N,), frequency 1..f per grid cell (N,)).

    Thin wrapper over ``_flat_grid`` (the ONE enumeration of the (t, x)
    grid — the dict and columnar pipelines must share it or their key
    orders silently diverge) that adds the per-cell token index and the
    flat position array the columnar expansion needs."""
    toks, fs, _t_rep, x_flat, bounds = _flat_grid(occ)
    starts = np.concatenate([[0], bounds]).astype(np.int64)[:len(fs)]
    pos_flat = (np.concatenate(list(occ.values()))
                if occ else np.empty(0, np.int64))
    ti_flat = np.repeat(np.arange(len(fs), dtype=np.int64), fs)
    return toks, fs, starts, pos_flat, ti_flat, x_flat


def _segmented_active(vals: np.ndarray, fs: np.ndarray, starts: np.ndarray
                      ) -> np.ndarray:
    """Strict-running-minimum mask within each token segment, vectorized.

    ``act[j]`` iff ``vals[j] < min(vals[seg_start:j])`` (segment starts are
    always active).  Segments are batched by frequency so each distinct f
    runs ONE ``minimum.accumulate`` over a (tokens_with_f, f) matrix —
    O(sum f) total work instead of a Python loop per token."""
    act = np.zeros(len(vals), bool)
    act[starts] = True
    for f in np.unique(fs):
        f = int(f)
        if f <= 1:
            continue
        sel = np.flatnonzero(fs == f)
        idx = starts[sel][:, None] + np.arange(f)
        m = vals[idx]
        run = np.minimum.accumulate(m[:, :-1], axis=1)
        act[idx[:, 1:].ravel()] = (m[:, 1:] < run).ravel()
    return act


def _expand_key_columns(n, fs, starts, pos_flat, ti_flat, x_flat,
                        order_flat, gid_ident, active: bool) -> KeySet:
    """Expand (token, frequency) grid cells into key-instance columns.

    Each selected cell g = (t, x) contributes cnt = f_t - x + 1 keys
    (p, q) = (pos[j], pos[x-1+j]); everything is repeat/arange arithmetic
    over the flat position array, then one lexsort into visiting order."""
    if active:
        act = _segmented_active(order_flat, fs, starts)
        sel = np.flatnonzero(act)
    else:
        sel = np.arange(len(order_flat), dtype=np.int64)
    g_ti = ti_flat[sel]
    g_x = x_flat[sel]
    cnt = fs[g_ti] - g_x + 1
    total = int(cnt.sum())
    gid = np.repeat(np.arange(len(sel), dtype=np.int64), cnt)
    seq = np.arange(total, dtype=np.int64) - \
        np.repeat(np.cumsum(cnt) - cnt, cnt)
    base = starts[g_ti][gid]
    p = pos_flat[base + seq]
    q = pos_flat[base + g_x[gid] - 1 + seq]
    order = order_flat[sel][gid]
    freq = g_x[gid]
    # visiting order: hash asc, freq ASC (see erratum note), then (p, q) —
    # identical to _sort_keys, and total (no stability dependence) because
    # (p, q) pairs are globally unique
    idx = np.lexsort((q, p, freq, order))
    return KeySet(n=n, p=p[idx], q=q[idx], gid=gid[idx], order=order[idx],
                  freq=freq[idx], gid_key=[], gid_order=order_flat[sel],
                  gid_ident=gid_ident[sel])


def generate_key_columns_multiset(tokens: np.ndarray, hashfn,
                                  active: bool = False,
                                  occ: dict | None = None) -> KeySet:
    """Columnar Algorithm 3/5 for the multi-set min-hash: same KeySet as
    :func:`generate_keys_multiset` with ``gid_ident`` uint64 hash ids in
    place of the boxed ``gid_key`` list."""
    tokens = np.asarray(tokens, dtype=np.int64)
    n = len(tokens)
    if occ is None:
        occ = occurrence_lists(tokens)
    toks, fs, starts, pos_flat, ti_flat, x_flat = _occ_columns(occ)
    h_flat = (hashfn(toks[ti_flat], x_flat) if len(ti_flat)
              else np.empty(0, np.uint64))
    return _expand_key_columns(n, fs, starts, pos_flat, ti_flat, x_flat,
                               h_flat, h_flat, active)


def generate_key_columns_icws(tokens: np.ndarray, icws: ICWS,
                              weight: WeightFn, active: bool = False,
                              occ: dict | None = None) -> KeySet:
    """Columnar §5 key generation (ICWS): same KeySet as
    :func:`generate_keys_icws` with ``gid_ident`` int64 (G, 2)
    (token, k_int) rows in place of the boxed tuple list."""
    tokens = np.asarray(tokens, dtype=np.int64)
    n = len(tokens)
    if occ is None:
        occ = occurrence_lists(tokens)
    toks, fs, starts, pos_flat, ti_flat, x_flat = _occ_columns(occ)
    t_rep = toks[ti_flat]
    if len(t_rep):
        w_flat = weight(t_rep, x_flat)
        k_flat, _y, a_flat = icws.hash_parts(t_rep, w_flat)
    else:
        k_flat = np.empty(0, np.int64)
        a_flat = np.empty(0, np.float64)
    ident = np.stack([t_rep, k_flat], axis=1) if len(t_rep) else \
        np.empty((0, 2), np.int64)
    return _expand_key_columns(n, fs, starts, pos_flat, ti_flat, x_flat,
                               a_flat, ident, active)


def count_active_hashes(tokens: np.ndarray, icws: ICWS | None, weight: WeightFn | None,
                        hashfn=None) -> int:
    """|{active hash values}| — used by complexity tests (Lemma 13)."""
    tokens = np.asarray(tokens, dtype=np.int64)
    occ = occurrence_lists(tokens)
    total = 0
    for t, pos in occ.items():
        m = len(pos)
        if hashfn is not None:
            hv = hashfn(np.full(m, t, dtype=np.int64), np.arange(1, m + 1))
            vals = hv.astype(np.float64)
            run = np.minimum.accumulate(hv)
            total += 1 + int(np.sum(hv[1:] < run[:-1]))
        else:
            w = weight.grid(t, m)
            _ki, _y, a = icws.hash_parts(np.full(m, t, dtype=np.int64), w)
            run = np.minimum.accumulate(a)
            total += 1 + int(np.sum(a[1:] < run[:-1]))
    return total
