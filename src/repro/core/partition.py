"""Monotonic Partitioning (Algorithm 4) — the paper's core contribution.

Visits keys in ascending hash order while maintaining the skyline of visited
keys (a totally ordered staircase, Lemmas 5–7); each visit emits one compact
window per staircase step it consumes (Lemma 14 C2) and updates the skyline.

The skyline is kept in two parallel coordinate-ordered Python lists with
guard keys (−1,−1) and (n,n) (0-indexed variant of the paper's (0,0) and
(n+1,n+1)).  Every key is inserted at most once and removed at most once;
removals are contiguous slices, so the list operations are O(len) memmoves
at C speed and binary searches are O(log n) — matching the paper's
O(|X(T)|·log n) bound up to the memmove constant.

Windows use 0-indexed inclusive coordinates: ⟨gid, a, b, c, d⟩ represents
all subsequences T[i..j] with i ∈ [a,b], j ∈ [c,d].
"""

from __future__ import annotations

from bisect import bisect_left, bisect_right
from dataclasses import dataclass

import numpy as np

from .hashing import UniversalHash
from .icws import ICWS
from .keys import KeySet, generate_keys_icws, generate_keys_multiset
from .weights import WeightFn


@dataclass
class Partition:
    """A partition P(T, h): compact windows + the gid identity table."""

    n: int
    gid: np.ndarray   # int64 local group id per window
    a: np.ndarray     # int64 window coords (0-indexed, inclusive)
    b: np.ndarray
    c: np.ndarray
    d: np.ndarray
    gid_key: list     # gid -> hashable inverted-index key

    def __len__(self) -> int:
        return len(self.gid)

    @property
    def num_windows(self) -> int:
        return len(self.gid)

    def covered_cells(self) -> int:
        return int(np.sum((self.b - self.a + 1) * (self.d - self.c + 1)))


def monotonic_partition(keys: KeySet) -> Partition:
    """Algorithm 4 over a pre-sorted KeySet (MonoAll or MonoActive depending
    on how ``keys`` was generated).

    This loop is the sequential heart of the build pipeline (everything
    around it is vectorized), so it is written for CPython constant
    factors: one binary search replaces the Lines 4+6 pair (``ys`` is
    strictly increasing, so the largest ``y <= c`` is ``il`` exactly when
    ``ys[il] == c``, else ``il - 1``), the splice+insert of Lines 14-15 is
    a single slice assignment (one memmove), and the common emit case
    (one staircase step, no dominated keys) skips the general loop.
    """
    n = keys.n
    kp = keys.p.tolist()
    kq = keys.q.tolist()
    kg = keys.gid.tolist()

    # skyline with guards; xs/ys are both sorted (Lemma 6)
    xs = [-1, n]
    ys = [-1, n]

    out_gid: list[int] = []
    out_a: list[int] = []
    out_b: list[int] = []
    out_c: list[int] = []
    out_d: list[int] = []
    emit_gid = out_gid.append
    emit_a = out_a.append
    emit_b = out_b.append
    emit_c = out_c.append
    emit_d = out_d.append

    for b, c, g in zip(kp, kq, kg):
        # Lines 4+6 fused: il = first index with ys >= c, so the largest
        # index with y < c (Line 6's i) is il - 1 and the largest with
        # y <= c (Line 4's j') is il iff ys[il] == c, else il - 1
        il = bisect_left(ys, c)
        i = il - 1
        jp = il if ys[il] == c else i
        xjp = xs[jp]
        # Line 5: S[j'] dominates (b,c) iff [xjp, ys[jp]] ⊂ [b, c]
        if xjp >= b and not (xjp == b and ys[jp] == c):
            continue
        # Line 7: smallest j with S[j].x > b
        j = bisect_right(xs, b)
        # Lines 8-13: emit staircase windows (Lemma 14 C2)
        if j == il:
            # one staircase step, nothing dominated: pure insert
            a = xs[i] + 1
            d = ys[il] - 1
            if a <= b and c <= d:
                emit_gid(g)
                emit_a(a)
                emit_b(b)
                emit_c(c)
                emit_d(d)
            xs.insert(il, b)
            ys.insert(il, c)
            continue
        cprime = c
        for kk in range(i, j):
            a = xs[kk] + 1
            d = ys[kk + 1] - 1
            if a <= b and cprime <= d:
                emit_gid(g)
                emit_a(a)
                emit_b(b)
                emit_c(cprime)
                emit_d(d)
            cprime = ys[kk + 1]
        # Lines 14-15: splice dominated keys out, insert (b, c) — one
        # slice assignment instead of del + insert
        xs[il:j] = (b,)
        ys[il:j] = (c,)

    return Partition(
        n=n,
        gid=np.array(out_gid, dtype=np.int64),
        a=np.array(out_a, dtype=np.int64),
        b=np.array(out_b, dtype=np.int64),
        c=np.array(out_c, dtype=np.int64),
        d=np.array(out_d, dtype=np.int64),
        gid_key=keys.gid_key,
    )


# --- user-facing wrappers ---------------------------------------------------


def mono_all_multiset(tokens, hashfn: UniversalHash) -> Partition:
    """MonoAll: vanilla Algorithm 4 over ALL keys (multi-set Jaccard)."""
    return monotonic_partition(generate_keys_multiset(tokens, hashfn, active=False))


def mono_active_multiset(tokens, hashfn: UniversalHash) -> Partition:
    """MonoActive: Algorithm 4 + active-hash optimization (multi-set)."""
    return monotonic_partition(generate_keys_multiset(tokens, hashfn, active=True))


def mono_all_icws(tokens, icws: ICWS, weight: WeightFn) -> Partition:
    """MonoAll under weighted Jaccard (CWS hash values, §5)."""
    return monotonic_partition(generate_keys_icws(tokens, icws, weight, active=False))


def mono_active_icws(tokens, icws: ICWS, weight: WeightFn) -> Partition:
    """MonoActive under weighted Jaccard (CWS hash values, §5)."""
    return monotonic_partition(generate_keys_icws(tokens, icws, weight, active=True))
