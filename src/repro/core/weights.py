"""TF / IDF weight functions (Table 1 of the paper).

A weight function w(t, x) maps (token, frequency-in-text) -> positive real,
under the paper's AoW assumption: monotonically increasing in x and
independent of any other property of the text.  w(t, x) = tf(x) · idf(t).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

# --- TF weight functions (x is an integer frequency >= 1) -----------------

TF_FUNCS: dict[str, Callable[[np.ndarray], np.ndarray]] = {
    "binary": lambda x: (np.asarray(x, dtype=np.float64) >= 1).astype(np.float64),
    "raw": lambda x: np.asarray(x, dtype=np.float64),
    "log": lambda x: np.log(np.asarray(x, dtype=np.float64) + 1.0),
    "squared": lambda x: np.asarray(x, dtype=np.float64) ** 2,
}


def make_idf(kind: str, n_docs: int | None = None,
             doc_freq: dict[int, int] | None = None) -> Callable[[np.ndarray], np.ndarray]:
    """IDF weight per Table 1.  ``unary`` needs no corpus stats; the others
    need N = |D| and N_t (doc frequency per token)."""
    if kind == "unary":
        return lambda t: np.ones_like(np.asarray(t, dtype=np.float64))
    if n_docs is None or doc_freq is None:
        raise ValueError(f"idf kind {kind!r} needs corpus stats (n_docs, doc_freq)")
    n = float(n_docs)

    def _nt(t: np.ndarray) -> np.ndarray:
        t = np.atleast_1d(np.asarray(t, dtype=np.int64))
        return np.array([max(doc_freq.get(int(ti), 1), 1) for ti in t], dtype=np.float64)

    if kind == "standard":
        return lambda t: np.log(np.maximum(n / _nt(t), 1.0 + 1e-12))
    if kind == "smooth":
        return lambda t: np.log((n + _nt(t)) / _nt(t)) + 1.0
    if kind == "probabilistic":
        return lambda t: np.log(np.maximum((n - _nt(t)), 1.0) / _nt(t) + 1e-12) + 1e-9
    raise ValueError(f"unknown idf kind {kind!r}")


@dataclass
class WeightFn:
    """w(t, x) = tf(x) * idf(t), AoW-compliant."""

    tf: str = "raw"
    idf: str = "unary"
    n_docs: int | None = None
    doc_freq: dict[int, int] | None = None
    _idf_fn: Callable = field(init=False, repr=False)

    def __post_init__(self):
        if self.tf not in TF_FUNCS:
            raise ValueError(f"unknown tf kind {self.tf!r}")
        self._idf_fn = make_idf(self.idf, self.n_docs, self.doc_freq)

    @classmethod
    def fit(cls, docs, *, tf: str = "raw", idf: str = "smooth") -> "WeightFn":
        """Fit corpus statistics (N, per-token doc frequency) from token
        docs and return the corresponding TF-IDF weight function.

        ``idf="unary"`` needs no statistics but is accepted for a uniform
        construction path (``Aligner.build`` calls this for every weighted
        similarity).
        """
        doc_freq: dict[int, int] = {}
        n_docs = 0
        for d in docs:
            n_docs += 1
            for t in np.unique(np.asarray(d, dtype=np.int64)):
                t = int(t)
                doc_freq[t] = doc_freq.get(t, 0) + 1
        return cls(tf=tf, idf=idf, n_docs=n_docs, doc_freq=doc_freq)

    def __call__(self, t, x) -> np.ndarray:
        """Weight of token(s) t at frequency(ies) x (broadcastable)."""
        tfv = TF_FUNCS[self.tf](x)
        idfv = self._idf_fn(t)
        return np.maximum(tfv * idfv, 1e-300)  # keep strictly positive

    def grid(self, t: int, max_x: int) -> np.ndarray:
        """w(t, 1..max_x) as float64 array of length max_x."""
        xs = np.arange(1, max_x + 1)
        return self(np.full(max_x, t, dtype=np.int64), xs)
