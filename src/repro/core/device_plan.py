"""The device-resident query pipeline behind ``plan="device"``.

The cpu plan's batch flow round-trips the host between every stage: the
arena binary search (even with ``probe_backend="pallas"``) re-uploads the
key arena each launch, the collided window rows are gathered on the host,
and the grouped sweep is NumPy.  This module keeps the heavy state — the
fused :class:`~repro.core.frozen.ProbeArena` key/offset/window arrays —
*resident* on the accelerator and runs the probe binary search and the
grouped small-group sweep as Pallas kernels, so per batch only

* up:   the packed probe keys (B*k few-byte words) and the small-group
  gather index grids,
* down: the CSR probe extents and the compressed coverage grids + stripe
  boundaries the final blocks are read from

cross the bus — never the arena, never the window rows.

Residency
---------
:func:`device_arena` caches a :class:`DeviceArena` on the index instance,
keyed by the *identity* of its host ``ProbeArena``: a ``SearchIndex`` is
immutable, and every path that changes the store generation
(``LiveIndex.compact``/``promote_sealed``) swaps in a NEW ``SearchIndex``,
so the upload happens at most once per store generation and invalidation
is automatic.  The mutable live delta level never comes through here — it
keeps the host dict probe (``repro.core.query.batch_probe`` routes
non-frozen levels to the per-coordinate loop), which is what keeps live
serving correct between compactions.

Bit parity
----------
Every device stage has exact integer semantics (the binary search and hit
detect are u32 lexicographic compares, the sweep kernel is integer-exact
by construction — see :mod:`repro.kernels.sweep_grid`), and the plan's
default sketch stage is the exact host path, so ``plan="device"`` is
bit-identical to ``plan="cpu"`` — gated in ``tests/test_device_plan.py``.

``transfer_stats()`` exposes logical host<->device byte counters (what
crosses the bus on a real accelerator; in interpret mode the same arrays
flow, uncounted copies aside) for the residency tests and the roofline
benchmark's fused-pipeline row.
"""

from __future__ import annotations

import functools
import time
from dataclasses import dataclass

import numpy as np

from .frozen import MODE_PACKED, PACK_SHIFT, _concat_ranges

__all__ = ["DeviceArena", "device_arena", "resident_probe",
           "fused_batch_query", "transfer_stats", "reset_transfer_stats"]

_I32_MAX = np.iinfo(np.int32).max

# logical host<->device transfer accounting (bytes that cross the bus on
# a real accelerator).  arena_* count the once-per-generation residency
# upload; h2d/d2h count the per-batch steady-state traffic.
_STATS = {"arena_uploads": 0, "arena_bytes": 0,
          "h2d_bytes": 0, "d2h_bytes": 0, "batches": 0}


def transfer_stats() -> dict:
    """A snapshot of the module's transfer counters."""
    return dict(_STATS)


def reset_transfer_stats() -> None:
    for key in _STATS:
        _STATS[key] = 0


def _interpret() -> bool:
    import jax
    return jax.default_backend() != "tpu"


@dataclass
class DeviceArena:
    """One store generation's ProbeArena, resident on the accelerator.

    Keys are split into u32 (hi, lo) halves plus the coordinate tag word
    (the probe kernel's comparison format); offsets are narrowed to int32
    (guarded at build — an arena too large falls back to the host probe);
    ``win_rect`` holds only the (a, b, c, d) rectangle columns, because
    the text-id column is read host-side (an mmap column read) for
    grouping and never needs the bus.
    """

    mode: str
    n: int                    # arena slots
    khi: object               # jnp u32 (n,)
    klo: object               # jnp u32 (n,)
    ktag: object              # jnp u32 (n,)
    offsets: object           # jnp i32 (n + 1,)
    win_rect: object          # jnp i32 (nwin, 4)
    nbytes: int


def _build_device_arena(arena) -> DeviceArena | None:
    """Upload one ProbeArena; ``None`` when it cannot go resident (empty,
    or its CSR extent overflows the kernel's int32 offsets)."""
    n = len(arena.keys)
    if n == 0 or int(arena.offsets[-1]) > _I32_MAX:
        return None
    import jax.numpy as jnp

    from ..kernels.probe_arena import _split_u64
    khi, klo = _split_u64(np.asarray(arena.keys))
    if arena.mode == MODE_PACKED:
        ktag = np.zeros(n, np.uint32)
    else:
        ktag = np.ascontiguousarray(arena.coords, np.uint32)
    offsets = np.asarray(arena.offsets, np.int32)
    rect = np.ascontiguousarray(np.asarray(arena.windows)[:, 1:5], np.int32)
    dev = DeviceArena(
        mode=arena.mode, n=n,
        khi=jnp.asarray(khi), klo=jnp.asarray(klo), ktag=jnp.asarray(ktag),
        offsets=jnp.asarray(offsets), win_rect=jnp.asarray(rect),
        nbytes=(khi.nbytes + klo.nbytes + ktag.nbytes + offsets.nbytes +
                rect.nbytes))
    _STATS["arena_uploads"] += 1
    _STATS["arena_bytes"] += dev.nbytes
    return dev


def device_arena(index) -> DeviceArena | None:
    """The index's resident arena, uploading on first use and caching on
    the index instance (``SearchIndex._device_arena``).  The cache is
    keyed by the host ``ProbeArena``'s identity, so a promotion/compaction
    (which swaps in a new ``SearchIndex`` and so a new arena) re-uploads
    exactly once and stale residency can never serve a new generation."""
    arena = index.arena()
    cached = getattr(index, "_device_arena", None)
    if cached is not None and cached[0] is arena:
        return cached[1]
    dev = _build_device_arena(arena)
    try:
        index._device_arena = (arena, dev)   # also caches the None fallback
    except (AttributeError, TypeError):
        pass                                 # slotted/frozen duck: no cache
    return dev


# --------------------------------------------------------------------------
# resident probe (the probe stage of both the pinned and the fused paths)
# --------------------------------------------------------------------------


def _probe_jit_factory():
    """Build the jitted device probe lazily so importing this module never
    pays a jax import."""
    import jax
    import jax.numpy as jnp

    from ..kernels.probe_arena import _arena_search

    @functools.partial(jax.jit, static_argnames=("interpret",))
    def probe(khi, klo, ktag, offsets, qhi, qlo, qtag, valid, *, interpret):
        n = khi.shape[0]
        pos = _arena_search(khi, klo, ktag, qhi, qlo, qtag,
                            interpret=interpret)
        safe = jnp.minimum(pos, n - 1)
        # generic (hi, lo, tag) equality covers both arena modes: packed
        # arenas carry all-zero tags (and all-zero probe tags), coord
        # arenas compare the coordinate word — exactly the host hit detect
        hit = valid & (pos < n) & \
            (jnp.take(khi, safe) == qhi) & (jnp.take(klo, safe) == qlo) & \
            (jnp.take(ktag, safe) == qtag)
        starts = jnp.where(hit, jnp.take(offsets, safe), 0)
        ends = jnp.where(hit, jnp.take(offsets, safe + 1), 0)
        return starts, ends

    return probe


_PROBE_JIT = None


def _encode_queries(mode: str, pkeys: np.ndarray, coords: np.ndarray,
                    valid: np.ndarray
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host-side probe re-keying, identical to ``ProbeArena.probe``: packed
    arenas fold the coordinate into the key's top bits, coord arenas carry
    it as the tag word."""
    from ..kernels.probe_arena import _split_u64
    if mode == MODE_PACKED:
        q = (coords.astype(np.uint64) << np.uint64(PACK_SHIFT)) | \
            np.where(valid, pkeys, 0)
        qhi, qlo = _split_u64(q)
        qtag = np.zeros(len(q), np.uint32)
    else:
        qhi, qlo = _split_u64(pkeys)
        qtag = coords.astype(np.uint32)
    return qhi, qlo, qtag


def _device_probe(da: DeviceArena, pkeys, coords, valid
                  ) -> tuple[np.ndarray, np.ndarray]:
    global _PROBE_JIT
    if _PROBE_JIT is None:
        _PROBE_JIT = _probe_jit_factory()
    import jax.numpy as jnp
    if len(pkeys) == 0:
        z = np.zeros(0, np.int64)
        return z, z
    qhi, qlo, qtag = _encode_queries(da.mode, pkeys, coords, valid)
    valid = np.ascontiguousarray(valid, bool)
    starts, ends = _PROBE_JIT(
        da.khi, da.klo, da.ktag, da.offsets,
        jnp.asarray(qhi), jnp.asarray(qlo), jnp.asarray(qtag),
        jnp.asarray(valid), interpret=_interpret())
    _STATS["h2d_bytes"] += (qhi.nbytes + qlo.nbytes + qtag.nbytes +
                            valid.nbytes)
    starts = np.asarray(starts, np.int64)
    ends = np.asarray(ends, np.int64)
    _STATS["d2h_bytes"] += 2 * len(pkeys) * 4        # i32 starts + ends
    return starts, ends


def resident_probe(index, pkeys, coords, valid
                   ) -> tuple[np.ndarray, np.ndarray]:
    """``ProbeArena.probe``-identical (starts, ends), probing the resident
    device arena.  Falls back to the host searchsorted when the arena
    cannot go resident."""
    da = device_arena(index)
    if da is None:
        return index.arena().probe(pkeys, coords, valid, backend="numpy")
    return _device_probe(da, pkeys, coords, valid)


# --------------------------------------------------------------------------
# fused pipeline (probe="device" AND sweep="device": no host window gather)
# --------------------------------------------------------------------------


def fused_batch_query(index, sketches, B: int, m: int, *,
                      stage_times: dict | None = None) -> list:
    """The fused frozen-index batch path: device probe over the resident
    arena, host grouping on the windows' text-id column alone (an mmap
    column read — no transfer), device gather of the rectangle rows from
    the resident ``win_rect``, device sweep, and block extraction from
    the compressed coverage grids.  Block-identical to the cpu plan.
    """
    from .query import (_SIZE_BUCKETS, _SMALL_GROUP_MAX, Alignment,
                        _extract_runs, _group_bounds, _sweep_text)
    t1 = time.perf_counter()
    arena = index.arena()
    k = arena.k
    pkeys, coords, valid = arena.encode_batch(sketches)
    da = device_arena(index)
    _STATS["batches"] += 1
    if da is None:
        # arena too large for the kernel's i32 offsets: whole batch on host
        from .query import _gather_arena, _sweep_gathered
        return _sweep_gathered(_gather_arena(index, sketches, "numpy"),
                               B, m, "grouped")
    starts, ends = _device_probe(da, pkeys, coords, valid)
    counts = ends - starts
    row_ids = _concat_ranges(starts, counts)
    probe_ids = np.repeat(np.arange(len(pkeys), dtype=np.int64), counts)
    qid_all, cid_all = probe_ids // k, probe_ids % k
    # the ONE window column the host touches: text ids, for grouping and
    # result labelling (mmap page-ins, not bus traffic)
    tid_all = np.asarray(arena.windows[row_ids, 0], np.int64)
    t2 = time.perf_counter()

    results: list[list[Alignment]] = [[] for _ in range(B)]
    if len(qid_all):
        import jax.numpy as jnp

        from ..kernels.sweep_grid import sweep_grid
        order, g_starts, g_ends, distinct = _group_bounds(
            qid_all, tid_all, cid_all)
        qid_s, tid_s, row_s = qid_all[order], tid_all[order], row_ids[order]
        keep = distinct >= m
        sizes = g_ends - g_starts
        interpret = _interpret()

        small_results: dict[int, list] = {}
        sm_ids = np.flatnonzero(keep & (sizes <= _SMALL_GROUP_MAX))
        for b_lo, b_hi in _SIZE_BUCKETS:
            ids = sm_ids[(sizes[sm_ids] > b_lo) & (sizes[sm_ids] <= b_hi)]
            if not len(ids):
                continue
            s_starts, s_sizes = g_starts[ids], sizes[ids]
            G, S = len(ids), int(s_sizes.max())
            idx = np.zeros((G, S), np.int32)
            rows = row_s[_concat_ranges(s_starts, s_sizes)]
            slot = np.arange(len(rows)) - np.repeat(
                np.cumsum(s_sizes) - s_sizes, s_sizes)
            idx[np.repeat(np.arange(G), s_sizes), slot] = rows
            sz32 = s_sizes.astype(np.int32)
            # device-side row gather from the resident rectangle columns:
            # only the (G, S) index grid goes up, never the window rows
            rects = jnp.take(da.win_rect, jnp.asarray(idx), axis=0)
            hot, xs, ys = sweep_grid(rects, jnp.asarray(sz32), m=m,
                                     interpret=interpret)
            _STATS["h2d_bytes"] += idx.nbytes + sz32.nbytes
            NX = int(xs.shape[1])
            # bool-cast on device: the grid crosses at 1 byte per cell
            hot_np = np.asarray(hot[:, :NX - 1, :NX - 1].astype(jnp.bool_))
            xs_np = np.asarray(xs, np.int64)
            ys_np = np.asarray(ys, np.int64)
            _STATS["d2h_bytes"] += hot_np.size + 2 * xs_np.size * 4  # b8/i32
            for g, blocks in zip(ids, _extract_runs(hot_np, xs_np, ys_np)):
                small_results[int(g)] = blocks

        for g in np.flatnonzero(keep):
            g = int(g)
            lo = g_starts[g]
            if g in small_results:
                blocks = small_results[g]
            else:
                # rare large group: host sweep straight off the mmap rows
                blocks = _sweep_text(
                    np.asarray(arena.windows[row_s[lo:g_ends[g]], 1:5],
                               np.int64), m)
            if blocks:
                results[int(qid_s[lo])].append(
                    Alignment(text_id=int(tid_s[lo]), blocks=blocks,
                              ncoords=int(distinct[g])))
    if stage_times is not None:
        t3 = time.perf_counter()
        stage_times["probe"] = stage_times.get("probe", 0.0) + (t2 - t1)
        stage_times["sweep"] = stage_times.get("sweep", 0.0) + (t3 - t2)
    return results
