"""Hash families for multi-set min-hash (§2.2 of the paper).

Two interchangeable families:

* :class:`UniversalHash` — the paper's h(t, x) = (a1·t + a2·x + b) mod p
  with p = 2^61 − 1 (Mersenne prime).  Exact 61-bit arithmetic is done in
  numpy uint64 via Mersenne folding (no Python-int fallback), so hash grids
  for a whole text vectorize.
* :class:`MixHash` — a stateless splitmix64 counter-based mix.  Slightly
  faster, used by the distributed pipeline where every worker must derive
  identical hash functions from (seed, k) without broadcasting tables.

Both are deterministic functions of an integer ``seed``.
"""

from __future__ import annotations

import numpy as np

MERSENNE61 = np.uint64((1 << 61) - 1)
_LOW31 = np.uint64((1 << 31) - 1)

# ---------------------------------------------------------------------------
# splitmix64 — the stateless mixing primitive everything derives from.
# ---------------------------------------------------------------------------

_SM_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM_M1 = np.uint64(0xBF58476D1CE4E5B9)
_SM_M2 = np.uint64(0x94D049BB133111EB)


def splitmix64(x: np.ndarray | int) -> np.ndarray:
    """Vectorized splitmix64 finalizer. uint64 -> uint64."""
    z = np.asarray(x, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = (z + _SM_GAMMA).astype(np.uint64)
        z = ((z ^ (z >> np.uint64(30))) * _SM_M1).astype(np.uint64)
        z = ((z ^ (z >> np.uint64(27))) * _SM_M2).astype(np.uint64)
        z = z ^ (z >> np.uint64(31))
    return z


def mix2(a, b) -> np.ndarray:
    """Combine two uint64 streams into one mixed stream."""
    a = np.asarray(a, dtype=np.uint64)
    b = np.asarray(b, dtype=np.uint64)
    with np.errstate(over="ignore"):
        return splitmix64(splitmix64(a) ^ (b * _SM_GAMMA).astype(np.uint64))


def uniform01(bits: np.ndarray) -> np.ndarray:
    """uint64 -> float64 uniform in (0, 1), never exactly 0 or 1."""
    # keep the top 53 bits, add 0.5 ulp offset so u in (0,1) strictly
    return ((bits >> np.uint64(11)).astype(np.float64) + 0.5) * (2.0 ** -53)


# ---------------------------------------------------------------------------
# Mersenne-61 modular arithmetic (vectorized, overflow-free in uint64)
# ---------------------------------------------------------------------------


def mod_m61(x: np.ndarray) -> np.ndarray:
    """x mod (2^61-1) for x < 2^64 (one or two folds)."""
    x = np.asarray(x, dtype=np.uint64)
    x = (x & MERSENNE61) + (x >> np.uint64(61))
    x = (x & MERSENNE61) + (x >> np.uint64(61))
    # x may now equal p exactly
    return np.where(x == MERSENNE61, np.uint64(0), x).astype(np.uint64)


def mulmod_m61(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """(a * b) mod (2^61-1) with a, b < 2^61, without 128-bit ints.

    Split a = ah·2^31 + al (ah < 2^30, al < 2^31).  Then
       a·b = ah·b·2^31 + al·b.
    ah·b < 2^30·2^61 overflows, so reduce b first: all products are taken
    with operands < 2^31 after splitting both sides (schoolbook, 4 partials).
    """
    a = np.asarray(a, dtype=np.uint64)
    b = np.asarray(b, dtype=np.uint64)
    ah = a >> np.uint64(31)
    al = a & _LOW31
    bh = b >> np.uint64(31)
    bl = b & _LOW31
    with np.errstate(over="ignore"):
        # a*b = ah*bh*2^62 + (ah*bl + al*bh)*2^31 + al*bl
        # 2^62 ≡ 2 (mod p); 2^31 fold below.
        hh = mod_m61(ah * bh)              # < p
        mid = mod_m61(ah * bl + al * bh)   # each partial < 2^61, sum < 2^62 fits
        ll = mod_m61(al * bl)
        # hh * 2^62 mod p = hh * 2
        term_hh = mod_m61(hh << np.uint64(1))
        # mid * 2^31 mod p: split mid = mh*2^30 + ml; mid*2^31 = mh*2^61 + ml*2^31
        mh = mid >> np.uint64(30)
        ml = mid & np.uint64((1 << 30) - 1)
        term_mid = mod_m61(mh + (ml << np.uint64(31)))
        return mod_m61(term_hh + term_mid + ll)


class UniversalHash:
    """The paper's universal family h(t,x) = (a1 t + a2 x + b) mod p.

    One instance = one hash function.  ``from_seed(seed, k)`` derives k
    independent members deterministically.
    """

    __slots__ = ("a1", "a2", "b")

    def __init__(self, a1: int, a2: int, b: int):
        p = int(MERSENNE61)
        self.a1 = np.uint64(a1 % p or 1)
        self.a2 = np.uint64(a2 % p or 1)
        self.b = np.uint64(b % p)

    @classmethod
    def from_seed(cls, seed: int, k: int) -> list["UniversalHash"]:
        idx = np.arange(k, dtype=np.uint64)
        base = mix2(np.uint64(seed), idx)
        a1 = mod_m61(splitmix64(base ^ np.uint64(0xA1)))
        a2 = mod_m61(splitmix64(base ^ np.uint64(0xA2)))
        b = mod_m61(splitmix64(base ^ np.uint64(0xB0)))
        return [cls(int(a1[i]), int(a2[i]), int(b[i])) for i in range(k)]

    def __call__(self, t, x) -> np.ndarray:
        """h(t, x); t and x broadcastable integer arrays. Returns uint64 < p."""
        t = mod_m61(np.asarray(t, dtype=np.uint64))
        x = mod_m61(np.asarray(x, dtype=np.uint64))
        with np.errstate(over="ignore"):
            return mod_m61(mulmod_m61(self.a1, t) + mulmod_m61(self.a2, x) + self.b)


class MixHash:
    """Stateless counter-based family: h(t,x) = splitmix-mix(seed, t, x)."""

    __slots__ = ("seed",)

    def __init__(self, seed: int):
        self.seed = np.uint64(seed)

    @classmethod
    def from_seed(cls, seed: int, k: int) -> list["MixHash"]:
        base = mix2(np.uint64(seed), np.arange(k, dtype=np.uint64))
        return [cls(int(base[i])) for i in range(k)]

    def __call__(self, t, x) -> np.ndarray:
        t = np.asarray(t, dtype=np.uint64)
        x = np.asarray(x, dtype=np.uint64)
        return mix2(mix2(self.seed, t), x)
