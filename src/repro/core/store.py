"""Versioned on-disk format for frozen indexes (mmap-backed serving).

One directory per index::

    index_dir/
      manifest.json           format/version, scheme spec, method, doc map,
                              text lengths, per-table kinds, arena meta
      table_00.keys.npy       uint64 sorted packed hash identities
      table_00.offsets.npy    int64 CSR row pointers
      table_00.windows.npy    int32 (nwin, 5) compact-window rows
      ...                     one triple per sketch coordinate
      arena.keys.npy          fused probe arena: one sorted key array,
      arena.coords.npy        coordinate tags ("coord" mode; empty in
      arena.offsets.npy       "packed" mode), global CSR offsets, and the
      arena.windows.npy       slot-regrouped windows matrix

The arena quadruple is the serving-side fast path (one searchsorted per
batch); it roughly doubles the windows bytes on disk but restores mmap'd
like the tables, so the batched probe never materializes a rebuild.
Stores written before the arena existed (or with the files deleted) still
load — the arena is then rebuilt lazily from the tables on first batched
query.

The arrays are raw ``.npy`` files (not a zipped ``.npz``) precisely so
``np.load(mmap_mode="r")`` can map them: a larger-than-RAM corpus then
serves queries through the OS page cache without ever materializing
``windows``/``keys``/``offsets``.  ``searchsorted`` probes touch O(log n)
pages per key and the plane sweep reads only the collided rows.

Writes are crash-safe by ordering: the arrays are written first and the
manifest last, so a directory without a readable manifest is an aborted
write, never a torn index.  ``python -m repro.analysis`` enforces this
ordering statically — RPR201 flags any function that commits a
manifest/pointer before its array payload, RPR202 flags manifest/CURRENT
writes outside this module that skip the tmp + rename staging below.  ``FORMAT_VERSION`` is checked on load and
unknown versions are rejected with ``ValueError`` (forward compatibility
is an explicit migration, not a silent misread).

Generations (live serving)
--------------------------
A live store grows *versions*: merge-compaction
(:class:`repro.core.live.LiveIndex`) writes the folded index into a fresh
``v{N:06d}/`` subdirectory and then flips the plain-text ``CURRENT``
pointer file at the root (tmp + rename, after the new manifest exists)::

    index_dir/
      CURRENT                 "v000002" — the serving generation
      manifest.json + *.npy   generation 0: the original flat layout
      v000001/                older compacted generation (rollback target)
      v000002/                serving generation (manifest + arrays)

Readers resolve through :func:`resolve_store`: no ``CURRENT`` means the
flat layout (every pre-generation store keeps loading unchanged).
Promotion is atomic and ordered — arrays, then the generation's manifest,
then the pointer — so a crash at any point leaves ``CURRENT`` naming a
complete older generation; rolling back is rewriting ``CURRENT`` to a
retained version's name (or deleting it to serve the flat root).

Integrity and recovery
----------------------
``IndexWriter`` records a CRC32 per array file in the manifest
(``"checksums"``); :func:`verify_generation` / :func:`verify_store`
re-hash the files against it (``python -m repro.fsck`` is the CLI).
Loaders resolve through :func:`resolve_verified`: a serving generation
that fails verification is *quarantined* — renamed into
``quarantine/v{N:06d}``, never deleted — and the pointer falls back to
the newest retained generation that verifies (or the flat root), so a
corrupted promotion degrades to serving older data instead of crashing
the reader.  ``quarantine/`` numbers stay reserved
(:func:`next_generation` never renumbers over them) and
:func:`prune_generations` reclaims superseded/aborted version dirs
without ever touching quarantine by default.

All durable mutations in this module route through
:mod:`repro.fault.fsio` (enforced by RPR203), so the seeded
fault-injection harness can crash, tear, or fail any write.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field
from pathlib import Path
from zlib import crc32

import numpy as np

from ..fault import fsio
from .frozen import FrozenTable, ProbeArena
from .schemes import scheme_from_spec, scheme_spec

FORMAT = "mono-index"
FORMAT_VERSION = 1
CURRENT_POINTER = "CURRENT"
QUARANTINE_DIR = "quarantine"

# recovery counters (per process; surfaced by serve /metrics and fsck)
_COUNTERS = {"verify_failures": 0, "quarantined_generations": 0,
             "recovered_fallbacks": 0}


def store_counters() -> dict:
    """Snapshot of this process's store-recovery counters."""
    return dict(_COUNTERS)

_ARRAYS = ("keys", "offsets", "windows")
_DTYPES = {"keys": np.uint64, "offsets": np.int64, "windows": np.int32}
_ARENA_ARRAYS = ("keys", "coords", "offsets", "windows")
_ARENA_DTYPES = {"keys": np.uint64, "coords": np.uint16,
                 "offsets": np.int64, "windows": np.int32}


def _table_path(root: Path, i: int, name: str) -> Path:
    return root / f"table_{i:02d}.{name}.npy"


# --------------------------------------------------------------------------
# store generations (live serving: compaction writes a new version dir and
# atomically flips the CURRENT pointer; see the module docstring)
# --------------------------------------------------------------------------

def _read_pointer(root: Path) -> str | None:
    try:
        return (Path(root) / CURRENT_POINTER).read_text().strip() or None
    except FileNotFoundError:
        return None


def generation_dir(root, gen: int) -> Path:
    """Directory of generation ``gen``; 0 is the flat layout root itself."""
    root = Path(root)
    return root if gen == 0 else root / f"v{gen:06d}"


def current_generation(root) -> int:
    """The serving generation number: 0 (flat root) when no ``CURRENT``
    pointer exists, else the ``N`` of the ``v{N:06d}`` dir it names."""
    name = _read_pointer(Path(root))
    return int(name.lstrip("v")) if name else 0


def next_generation(root) -> int:
    """The next free generation number: one past both the serving
    generation and the largest COMMITTED one (manifest present).

    Promoted generations are immutable — after a rollback the next
    compaction must not renumber over a retained version directory (its
    arrays may be mmap'd by running readers).  An aborted, manifest-less
    directory is not committed and is reused by the retry.  Quarantined
    generations keep their numbers reserved too: a future promotion must
    never reuse the number of an index that was once served.
    """
    root = Path(root)
    committed = [0]
    for p in root.glob("v[0-9][0-9][0-9][0-9][0-9][0-9]"):
        if (p / "manifest.json").exists():
            committed.append(int(p.name[1:]))
    for p in (root / QUARANTINE_DIR).glob("v*"):
        digits = p.name[1:7]
        if digits.isdigit():
            committed.append(int(digits))
    return max(max(committed), current_generation(root)) + 1


def resolve_store(root) -> Path:
    """Follow the generation pointer to the serving directory.

    Flat stores (no ``CURRENT``) resolve to themselves, so every loader
    can resolve unconditionally.  A pointer naming a version without a
    readable manifest is a corrupt promotion (the pointer is only ever
    flipped *after* the manifest commit) and is rejected loudly rather
    than silently serving a stale flat root.
    """
    root = Path(root)
    name = _read_pointer(root)
    if name is None:
        return root
    target = root / name
    if not (target / "manifest.json").exists():
        raise ValueError(
            f"{root}: {CURRENT_POINTER} names generation {name!r} but "
            "that version has no manifest; the pointer file was edited or "
            "the version directory was deleted — rewrite CURRENT to a "
            "retained version (or delete it to serve the flat root)")
    return target


def promote_generation(root, gen: int) -> None:
    """Atomically flip the serving pointer to generation ``gen``.

    Refuses to point at a version without a committed manifest (an aborted
    compaction must never become the serving generation).  The pointer is
    written tmp + rename, so readers always see either the old or the new
    generation, never a torn pointer.  This helper (and ``IndexWriter``)
    is the only sanctioned CURRENT writer — RPR202 lints any other.
    """
    root = Path(root)
    if gen < 1:
        raise ValueError("generation 0 is the flat root; delete the "
                         f"{CURRENT_POINTER} file to serve it")
    gdir = generation_dir(root, gen)
    if not (gdir / "manifest.json").exists():
        raise ValueError(f"{gdir} has no manifest (aborted compaction?); "
                         "refusing to promote it to the serving generation")
    # atomic reader flip (tmp + rename inside commit_text)
    fsio.commit_text(root / CURRENT_POINTER, gdir.name, site="store.promote")


def _arena_path(root: Path, name: str) -> Path:
    return root / f"arena.{name}.npy"


def _checksum_record(arr) -> dict:
    """CRC32 + shape/dtype fingerprint of one array (stdlib ``zlib`` —
    cheap enough to hash every file at write and load-verify time)."""
    a = np.ascontiguousarray(arr)
    return {"algo": "crc32",
            "crc": int(crc32(a.reshape(-1).view(np.uint8)) & 0xFFFFFFFF),
            "dtype": str(a.dtype), "shape": list(a.shape)}


class IndexWriter:
    """Streaming store writer: tables land on disk as they are finalized.

    The batch build pipeline produces one frozen table at a time; holding
    all k of them just to call ``save_index`` at the end doubles the peak
    footprint.  ``IndexWriter`` inverts the flow: ``add_table(i, table)``
    writes coordinate i's three ``.npy`` files immediately (the caller
    drops the table and moves on), ``add_arena`` does the same for the
    fused probe arena, and ``finalize`` commits the manifest.  Crash
    safety is the same ordering contract as before: any previous manifest
    is unlinked up front and the new one is written last (tmp + rename),
    so a directory without a readable manifest is an aborted write, never
    a torn index.
    """

    def __init__(self, path, *, scheme=None, method: str = "mono_active"):
        self.root = Path(path)
        self.root.mkdir(parents=True, exist_ok=True)
        # invalidate any previous commit before touching its arrays: a
        # crash mid-rewrite must leave "no manifest" (aborted write),
        # never a stale manifest validating torn arrays
        fsio.unlink(self.root / "manifest.json", site="store.writer.reset",
                    missing_ok=True)
        self._scheme = scheme
        self._method = method
        self._tables: list[dict] = []
        self._arena: dict | None = None
        self._checksums: dict[str, dict] = {}

    def _save_array(self, path: Path, arr, *, site: str) -> None:
        fsio.np_save(path, arr, site=site)
        self._checksums[path.name] = _checksum_record(arr)

    def add_table(self, i: int, table) -> None:
        if i != len(self._tables):
            raise ValueError(f"tables must be added in coordinate order: "
                             f"got table {i}, expected {len(self._tables)}")
        for name in _ARRAYS:
            self._save_array(_table_path(self.root, i, name),
                             getattr(table, name), site="store.writer.table")
        self._tables.append({"kind": table.kind,
                             "kint_min": int(table.kint_min)})

    def add_arena(self, arena) -> None:
        for name in _ARENA_ARRAYS:
            self._save_array(_arena_path(self.root, name),
                             getattr(arena, name), site="store.writer.arena")
        self._arena = {"mode": arena.mode, "max_run": int(arena.max_run)}

    def finalize(self, *, num_texts: int, num_windows: int,
                 text_lengths, doc_map=None, wal_watermark=None) -> None:
        manifest = {
            "format": FORMAT,
            "format_version": FORMAT_VERSION,
            "scheme": (scheme_spec(self._scheme)
                       if self._scheme is not None else None),
            "method": self._method,
            "num_texts": int(num_texts),
            "num_windows": int(num_windows),
            "text_lengths": [int(n) for n in text_lengths],
            "doc_map": ([int(g) for g in doc_map]
                        if doc_map is not None else None),
            "tables": self._tables,
            "arena": self._arena,
            "checksums": self._checksums,
        }
        if wal_watermark is not None:
            # every WAL record below this LSN is folded into these arrays;
            # replay skips them and truncation may drop their segments
            manifest["wal_watermark"] = int(wal_watermark)
        # last write in the RPR201 ordering: arrays, then this commit
        # (atomic tmp + rename inside commit_text)
        fsio.commit_text(self.root / "manifest.json", json.dumps(manifest),
                         site="store.writer.manifest")


def save_index(index, path, *, doc_map=None,
               include_scheme: bool = True) -> None:
    """Write ``index`` (a SearchIndex) as a versioned store directory.

    ``doc_map`` optionally records the global doc id of each local text id
    (used by the sharded store); ``None`` means the identity mapping.
    ``include_scheme=False`` omits the scheme spec from the manifest (the
    sharded store writes it once at the root instead of per shard — a
    tfidf spec carries the corpus-wide doc-frequency table); such a store
    can only be loaded with an explicit ``scheme=``.
    """
    writer = IndexWriter(path,
                         scheme=index.scheme if include_scheme else None,
                         method=index.method)
    for i, t in enumerate(index.tables):
        writer.add_table(i, t)
    # fused probe arena: built once at save time (reuses the index's cache)
    # so serving loads map it instead of rebuilding from the tables
    writer.add_arena(index.arena())
    writer.finalize(num_texts=index.num_texts,
                    num_windows=index.num_windows,
                    text_lengths=index.text_lengths, doc_map=doc_map)


def read_manifest(path) -> dict:
    """Read and validate a store directory's manifest (the serving
    generation's, when ``path`` is a versioned live-store root)."""
    root = resolve_store(path)
    mpath = root / "manifest.json"
    if not mpath.exists():
        raise FileNotFoundError(f"{root} is not an index store "
                                "(no manifest.json)")
    manifest = json.loads(mpath.read_text())
    if manifest.get("format") != FORMAT:
        raise ValueError(f"{root}: not a {FORMAT} store "
                         f"(format={manifest.get('format')!r})")
    version = manifest.get("format_version")
    if version != FORMAT_VERSION:
        raise ValueError(
            f"{root}: unsupported index format version {version!r} "
            f"(this build reads version {FORMAT_VERSION}); re-save the "
            "index with a matching build or migrate it explicitly")
    return manifest


# --------------------------------------------------------------------------
# integrity verification + quarantine recovery (see module docstring;
# ``python -m repro.fsck`` is the CLI over these)
# --------------------------------------------------------------------------

@dataclass
class VerifyReport:
    """Outcome of verifying one generation directory."""

    path: str
    committed: bool = False         # readable, valid manifest present
    arrays: int = 0                 # array files structurally checked
    checksummed: int = 0            # of those, verified against a CRC
    problems: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return self.committed and not self.problems

    def to_dict(self) -> dict:
        return {"path": self.path, "ok": self.ok, "committed": self.committed,
                "arrays": self.arrays, "checksummed": self.checksummed,
                "problems": list(self.problems)}


def verify_generation(path) -> VerifyReport:
    """Verify one generation directory: manifest readable and valid, every
    required array file present, loadable, dtype-correct, and matching its
    recorded CRC32.  Stores written before checksums existed verify
    structurally (noted in the report, not a failure)."""
    root = Path(path)
    rep = VerifyReport(path=str(root))
    mpath = root / "manifest.json"
    if not mpath.exists():
        rep.problems.append("no manifest.json (aborted or foreign directory)")
        return rep
    try:
        manifest = json.loads(mpath.read_text())
    except (OSError, ValueError) as e:
        rep.problems.append(f"manifest unreadable: {e}")
        return rep
    if manifest.get("format") != FORMAT:
        rep.problems.append(f"not a {FORMAT} store "
                            f"(format={manifest.get('format')!r})")
        return rep
    if manifest.get("format_version") != FORMAT_VERSION:
        rep.problems.append(
            "unsupported index format version "
            f"{manifest.get('format_version')!r} (this build reads version "
            f"{FORMAT_VERSION})")
        return rep
    rep.committed = True
    # legacy (pre-checksum) manifests verify structurally only
    checksums = manifest.get("checksums") or {}
    expected_dtypes = {}
    for i in range(len(manifest.get("tables", []))):
        for name in _ARRAYS:
            expected_dtypes[_table_path(root, i, name).name] = _DTYPES[name]
    if manifest.get("arena"):
        for name in _ARENA_ARRAYS:
            expected_dtypes[_arena_path(root, name).name] = _ARENA_DTYPES[name]
    # every table file is required by the loader; arena files are optional
    # (lazy rebuild) unless a checksum was recorded for them
    required = [f for f in expected_dtypes
                if f.startswith("table_") or f in checksums]
    for fname in required:
        fpath = root / fname
        if not fpath.exists():
            rep.problems.append(f"{fname}: missing")
            continue
        try:
            a = np.load(fpath, mmap_mode="r")
        except (OSError, ValueError) as e:
            rep.problems.append(f"{fname}: unreadable ({e})")
            continue
        rep.arrays += 1
        want = expected_dtypes.get(fname)
        if want is not None and a.dtype != want:
            rep.problems.append(f"{fname}: dtype {a.dtype}, expected "
                                f"{np.dtype(want)}")
            continue
        rec = checksums.get(fname)
        if rec is None:
            continue
        got = _checksum_record(a)
        if list(a.shape) != list(rec.get("shape", [])) or \
                got["crc"] != rec.get("crc"):
            rep.problems.append(
                f"{fname}: checksum mismatch (crc {got['crc']} != "
                f"recorded {rec.get('crc')})")
        else:
            rep.checksummed += 1
    # a checksummed file the manifest knows but we didn't require above
    # (e.g. stray entry) — verify it too so tampering can't hide there
    for fname in checksums:
        if fname not in required and not (root / fname).exists():
            rep.problems.append(f"{fname}: checksummed file missing")
    return rep


def _generation_entries(root: Path) -> list:
    """(gen, dir, committed) for the flat root and every version dir."""
    out = []
    if (root / "manifest.json").exists():
        out.append((0, root, True))
    for p in sorted(root.glob("v[0-9][0-9][0-9][0-9][0-9][0-9]")):
        out.append((int(p.name[1:]), p, (p / "manifest.json").exists()))
    return out


def verify_store(root) -> dict:
    """Verify a whole store tree: the serving chain, every committed
    generation, aborted dirs, and quarantine.  Returns a JSON-ready dict;
    ``ok`` means the serving chain and all committed, non-quarantined
    generations verify."""
    root = Path(root)
    pointer = _read_pointer(root)
    serving_gen = current_generation(root)
    out = {"root": str(root), "pointer": pointer,
           "serving_generation": serving_gen, "generations": [],
           "quarantined": [], "ok": True}
    seen_serving = False
    for gen, gdir, committed in _generation_entries(root):
        role = "serving" if gen == serving_gen else "retained"
        if not committed:
            out["generations"].append(
                {"path": str(gdir), "generation": gen, "role": "aborted",
                 "ok": False, "committed": False, "arrays": 0,
                 "checksummed": 0, "problems": ["no manifest (aborted)"]})
            continue
        rep = verify_generation(gdir).to_dict()
        rep.update(generation=gen, role=role)
        out["generations"].append(rep)
        if not rep["ok"]:
            out["ok"] = False
        if gen == serving_gen:
            seen_serving = True
    if not seen_serving:
        out["ok"] = False
        out["generations"].append(
            {"path": str(root / (pointer or "")), "generation": serving_gen,
             "role": "serving", "ok": False, "committed": False, "arrays": 0,
             "checksummed": 0,
             "problems": [f"{CURRENT_POINTER} names {pointer!r} but no such "
                          "committed generation exists"]})
    qdir = root / QUARANTINE_DIR
    if qdir.is_dir():
        for p in sorted(qdir.iterdir()):
            rep = verify_generation(p).to_dict()
            rep["role"] = "quarantined"
            out["quarantined"].append(rep)
    # write-ahead log: segment CRCs/chain + watermark <-> serving-
    # generation consistency (absent wal/ dir verifies vacuously)
    from ..wal import verify_wal
    watermark = None
    sdir = generation_dir(root, serving_gen)
    if (sdir / "manifest.json").exists():
        try:
            watermark = json.loads(
                (sdir / "manifest.json").read_text()).get("wal_watermark")
        except (OSError, ValueError):
            pass                      # already reported by the gen check
    out["wal"] = verify_wal(root, serving_watermark=watermark)
    if not out["wal"]["ok"]:
        out["ok"] = False
    return out


def quarantine_generation(root, name: str) -> Path:
    """Move version dir ``name`` into ``quarantine/`` (rename, never
    delete) and return its new path.  Name collisions get a ``.k`` suffix."""
    root = Path(root)
    qdir = root / QUARANTINE_DIR
    qdir.mkdir(exist_ok=True)
    dst = qdir / name
    k = 0
    while dst.exists():
        k += 1
        dst = qdir / f"{name}.{k}"
    fsio.replace(root / name, dst, site="store.quarantine")
    _COUNTERS["quarantined_generations"] += 1
    return dst


def resolve_verified(root) -> Path:
    """:func:`resolve_store` plus integrity checking and recovery.

    Verifies the directory the pointer names.  On failure the corrupt
    generation is quarantined and the pointer falls back to the newest
    retained generation that verifies (or deleted to serve a verifying
    flat root).  Raises ``ValueError`` only when *nothing* verifies —
    a corrupted promotion otherwise degrades to serving older data.
    """
    root = Path(root)
    name = _read_pointer(root)
    target = root if name is None else root / name
    rep = verify_generation(target)
    if rep.ok:
        return target
    _COUNTERS["verify_failures"] += 1
    if name is None:
        raise ValueError(f"{root}: store fails verification and no older "
                         f"generation remains: {rep.problems}")
    if target.exists():
        quarantine_generation(root, name)
    # fall back: newest committed generation that verifies, else flat root
    for gen, gdir, committed in sorted(_generation_entries(root),
                                       reverse=True):
        if not committed or gdir == target:
            continue
        if gen == 0:
            if verify_generation(root).ok:
                fsio.unlink(root / CURRENT_POINTER,
                            site="store.recover.pointer", missing_ok=True)
                _COUNTERS["recovered_fallbacks"] += 1
                return root
            continue
        if verify_generation(gdir).ok:
            promote_generation(root, gen)
            _COUNTERS["recovered_fallbacks"] += 1
            return gdir
    raise ValueError(
        f"{root}: serving generation {name!r} failed verification "
        f"({rep.problems}) and no retained generation verifies; the "
        f"corrupt index was moved to {QUARANTINE_DIR}/")


def prune_generations(root, keep: int = 2, *,
                      keep_quarantined: bool = True) -> list:
    """Reclaim superseded version directories; returns the removed paths.

    Keeps the serving generation, the newest ``keep`` committed
    generations (rollback targets), and the flat root (generation 0 is
    never removed).  Aborted manifest-less dirs numbered at or below the
    serving generation are stale retries and are removed too.  Removal is
    crash-safe: the manifest is unlinked first (demoting the dir to
    "aborted"), so a crash mid-``rmtree`` leaves debris a later prune
    reclaims, never a half-valid generation.  Quarantined generations are
    untouched unless ``keep_quarantined=False`` discards the whole
    quarantine.  Callers must size ``keep`` so no running reader still
    maps a pruned generation.
    """
    root = Path(root)
    serving = current_generation(root)
    committed = [g for g, _, c in _generation_entries(root) if c and g > 0]
    keep_set = set(sorted(committed, reverse=True)[:max(0, keep)]) | {serving}
    removed = []
    for gen, gdir, is_committed in _generation_entries(root):
        if gen == 0 or gen in keep_set:
            continue
        if not is_committed and gen > serving:
            continue        # in-flight compaction target: leave it alone
        if is_committed:
            fsio.unlink(gdir / "manifest.json", site="store.prune.retire")
        fsio.rmtree(gdir, site="store.prune")
        removed.append(gdir)
    qdir = root / QUARANTINE_DIR
    if not keep_quarantined and qdir.is_dir():
        fsio.rmtree(qdir, site="store.prune.quarantine")
        removed.append(qdir)
    return removed


def load_index(path, *, mmap: bool = True, scheme=None, verify: bool = True):
    """Load a store directory back into a ``SearchIndex``.

    ``mmap=True`` maps every table array with ``np.load(mmap_mode="r")``
    (read-only ``np.memmap`` views); ``mmap=False`` reads them into RAM.
    ``scheme`` overrides manifest reconstruction when the caller already
    holds the (identical) hash family — the sharded fan-out shares one
    scheme object across shards so sketches are computed once.
    ``verify=True`` resolves through :func:`resolve_verified` (checksum
    check + quarantine fallback — load-time only, the query hot path is
    untouched); builders re-loading a store they just wrote pass
    ``verify=False``.
    """
    from .search import SearchIndex
    root = resolve_verified(path) if verify else resolve_store(path)
    manifest = read_manifest(root)
    if scheme is None:
        if manifest["scheme"] is None:
            raise ValueError(
                f"{root}: manifest carries no scheme spec (saved with "
                "include_scheme=False, e.g. a sharded-store shard); pass "
                "scheme= explicitly")
        scheme = scheme_from_spec(manifest["scheme"])
    mode = "r" if mmap else None
    tables = []
    for i, tmeta in enumerate(manifest["tables"]):
        arrays = {}
        for name in _ARRAYS:
            a = np.load(_table_path(root, i, name), mmap_mode=mode)
            if a.dtype != _DTYPES[name]:
                raise ValueError(f"{root}: table {i} {name} has dtype "
                                 f"{a.dtype}, expected {_DTYPES[name]}")
            arrays[name] = a
        tables.append(FrozenTable(kind=tmeta["kind"],
                                  kint_min=int(tmeta["kint_min"]), **arrays))
    arena = _load_arena(root, manifest, tables, mode)
    return SearchIndex(scheme=scheme, method=manifest["method"],
                       tables=tables, num_texts=manifest["num_texts"],
                       num_windows=manifest["num_windows"],
                       text_lengths=list(manifest["text_lengths"]),
                       _arena=arena)


def _load_arena(root: Path, manifest: dict, tables: list[FrozenTable],
                mmap_mode):
    """Map the persisted probe arena back; ``None`` (lazy rebuild from the
    tables) for pre-arena stores or missing/mismatched files."""
    ameta = manifest.get("arena")
    if not ameta:
        return None
    arrays = {}
    for name in _ARENA_ARRAYS:
        path = _arena_path(root, name)
        if not path.exists():
            return None
        a = np.load(path, mmap_mode=mmap_mode)
        if a.dtype != _ARENA_DTYPES[name]:
            raise ValueError(f"{root}: arena {name} has dtype {a.dtype}, "
                             f"expected {_ARENA_DTYPES[name]}")
        arrays[name] = a
    return ProbeArena(mode=ameta["mode"], max_run=int(ameta["max_run"]),
                      kinds=[t.kind for t in tables],
                      kint_mins=np.array([t.kint_min for t in tables],
                                         np.int64),
                      **arrays)


def is_index_store(path) -> bool:
    root = Path(path)
    if (root / "manifest.json").exists():
        return True
    name = _read_pointer(root)
    return name is not None and (root / name / "manifest.json").exists()
