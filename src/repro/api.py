"""`repro.api` — the one-object service facade over the paper's pipeline.

The workload is index-once/query-many: build k inverted indexes of compact
windows over a corpus, then serve threshold-θ alignment queries.  The
:class:`Aligner` makes that lifecycle explicit::

    from repro.api import Aligner

    aligner = Aligner.build(corpus, similarity="tfidf", k=32)   # build
    hits = aligner.find(query, theta=0.8)                       # query
    aligner.save("idx_dir")                                     # freeze+persist

    server = Aligner.load("idx_dir", mmap=True)                 # serve (mmap)
    results = server.find_batch(queries, theta=0.8)

    live = Aligner.load("idx_dir", live=True)                   # live serve
    live.add(new_doc)                  # served immediately (delta index)
    live.compact()                     # fold into a new store generation

``build`` fits the weight function from the corpus (``WeightFn.fit``),
constructs the sketch scheme through the :func:`repro.core.make_scheme`
registry, and indexes every document — sharded across
:class:`~repro.core.sharded_index.ShardedAlignmentIndex` when
``shards > 1``.  ``save`` freezes the dict build tables into immutable CSR
``SearchIndex`` arrays and writes the versioned directory store;
``load(mmap=True)`` maps those arrays back with ``np.load(mmap_mode="r")``
so a larger-than-RAM corpus serves queries through the OS page cache.

Documents and queries may be token arrays or plain strings — strings are
encoded with the (deterministic, stateless) tokenizer, which round-trips
through the store manifest.
"""

from __future__ import annotations

import dataclasses
import json
import warnings
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from .fault import fsio

from .core import batch_query as _batch_query, make_scheme
from .core.builder import IndexBuilder
from .core.live import LiveIndex
from .core.query import Alignment
from .core.results import (UNSET, Match, QueryOptions, QueryResult,
                           coerce_query_options)
from .core.search import SearchIndex
from .core.sharded_index import ShardedAlignmentIndex
from .core.store import (CURRENT_POINTER, load_index, read_manifest,
                         save_index)
from .core.weights import WeightFn

_ALIGNER_META = "aligner.json"


@dataclass(frozen=True)
class AlignerConfig:
    """Everything ``Aligner.build`` needs besides the corpus.

    similarity: "tfidf" (corpus-fitted TF-IDF weighted Jaccard, the
        default), "weighted" (TF-only weighted Jaccard, corpus-free), or
        "multiset" (unweighted multi-set Jaccard).
    k: sketch width (number of hash functions / inverted tables).
    shards: >1 builds a sharded index (per-shard checkpoints, fan-out).
    method: compact-window partitioner ("mono_active", "mono_all",
        "allalign").
    tf / idf: weight-function kinds (Table 1); ``idf=None`` picks the
        similarity's default ("smooth" for tfidf, "unary" for weighted).
    family: multiset hash family ("universal" or "mix").
    """

    similarity: str = "tfidf"
    k: int = 16
    shards: int = 1
    method: str = "mono_active"
    seed: int = 0
    tf: str = "raw"
    idf: str | None = None
    family: str = "universal"

    def make_scheme(self, corpus=None):
        idf = self.idf or {"tfidf": "smooth"}.get(self.similarity, "unary")
        return make_scheme(self.similarity, seed=self.seed, k=self.k,
                           tf=self.tf, idf=idf, family=self.family,
                           corpus=corpus)


class Aligner:
    """Build→serve facade: index a corpus once, serve alignment queries.

    Construct via :meth:`build` (fresh index) or :meth:`load` (saved
    store); the raw constructor wires pre-built parts together and is
    mostly internal.
    """

    def __init__(self, index, *, config: AlignerConfig | None = None,
                 tokenizer=None):
        self._index = index
        self.config = config or AlignerConfig()
        self.tokenizer = tokenizer

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, corpus, *, similarity: str = "tfidf", k: int = 16,
              shards: int = 1, method: str = "mono_active", seed: int = 0,
              tf: str = "raw", idf: str | None = None,
              family: str = "universal", tokenizer=None,
              pipeline: str = "dict", fanout: str = "serial",
              store=None, mmap: bool = True,
              config: AlignerConfig | None = None) -> "Aligner":
        """Fit weights from ``corpus``, construct the scheme, and index
        every document.  ``corpus`` is an iterable of token arrays or
        strings (strings are tokenized; pass ``tokenizer=`` to control
        how, else a default ``HashWordTokenizer`` is used).

        ``pipeline`` picks the construction path: ``"dict"`` (default)
        builds mutable dict tables that stay open for :meth:`add`;
        ``"columnar"`` runs the batch columnar pipeline — the index comes
        back already frozen (block-identical tables, several times faster
        to build).  With ``pipeline="columnar"``: ``fanout``
        ("serial"/"threaded"/"process") parallelizes a sharded build
        across shards, and ``store=`` streams the finished index straight
        into a versioned store directory (``mmap=True`` serves from the
        mapped arrays) — corpus to saved, serving-ready store in one
        pass, no separate :meth:`save` needed."""
        if config is None:
            config = AlignerConfig(similarity=similarity, k=k, shards=shards,
                                   method=method, seed=seed, tf=tf, idf=idf,
                                   family=family)
        if pipeline not in ("dict", "columnar"):
            raise ValueError(f"unknown pipeline {pipeline!r}; "
                             "expected 'dict' or 'columnar'")
        if fanout not in ("serial", "threaded", "process"):
            raise ValueError(f"unknown fanout {fanout!r}; expected "
                             "'serial', 'threaded' or 'process'")
        if pipeline == "dict" and (store is not None or fanout != "serial"):
            raise ValueError(
                "store/fanout are columnar-pipeline options; pass "
                'pipeline="columnar"')
        docs = list(corpus)
        if docs and isinstance(docs[0], str) and tokenizer is None:
            from .data.tokenizer import HashWordTokenizer
            tokenizer = HashWordTokenizer()
        self = cls(None, config=config, tokenizer=tokenizer)
        token_docs = [self._tokens(d) for d in docs]
        scheme = config.make_scheme(corpus=token_docs)
        if config.shards > 1:
            self._index = ShardedAlignmentIndex(
                scheme=scheme, n_shards=config.shards, method=config.method)
            self._index.build(token_docs, pipeline=pipeline, fanout=fanout,
                              store=store, mmap=mmap)
        elif pipeline == "columnar":
            from .core.columnar import ColumnarBuilder
            builder = ColumnarBuilder(
                scheme=scheme, method=config.method).build(token_docs)
            if store is not None:
                self._index = builder.freeze_to_store(store, mmap=mmap)
            else:
                self._index = builder.freeze(arena=True)
        else:
            self._index = IndexBuilder(
                scheme=scheme, method=config.method).build(token_docs)
        if store is not None:
            self._write_meta(Path(store))
        return self

    # -- lifecycle ----------------------------------------------------------

    @property
    def is_frozen(self) -> bool:
        return self._index.is_frozen

    def add(self, text, *, request_id: str | None = None) -> int:
        """Index one more document; returns its (global) doc id.

        Valid in the build stage and on a live-loaded Aligner
        (``Aligner.load(path, live=True)``), where the write lands in the
        mutable delta and is served immediately alongside the frozen
        store.

        ``request_id`` (live indexes only) makes the call idempotent
        within the un-compacted window: a replayed id returns the
        original doc id without indexing a duplicate.  With a WAL open
        (``Aligner.load(..., wal=...)``) the id is logged into the WAL
        record so the window survives crash replay."""
        if isinstance(self._index, LiveIndex):
            lid = self._index.add_text(self._tokens(text),
                                       request_id=request_id)
            return self._index.doc_map[lid]
        if self.is_frozen:
            raise RuntimeError(
                "this Aligner serves a frozen index; reload it with "
                "Aligner.load(path, live=True) to accept writes, or build "
                "a new index (Aligner.build) to grow the corpus")
        return self._index.add_text(self._tokens(text))

    def freeze(self) -> "Aligner":
        """Finalize the build: compact every table into the immutable CSR
        serving layout (idempotent).  A live index merges its delta in
        memory (the on-disk store is untouched; use :meth:`compact` to
        persist in place)."""
        self._index = self._index.freeze()
        return self

    def compact(self, *, fanout: str = "serial") -> "Aligner":
        """Fold a live Aligner's delta into a new store generation and
        atomically promote it (old generation retained for rollback).
        Sharded live indexes compact every shard — ``fanout="process"``
        spreads the per-shard merges across a spawn process pool."""
        if isinstance(self._index, LiveIndex):
            self._index.compact()
        elif isinstance(self._index, ShardedAlignmentIndex):
            self._index.compact(fanout=fanout)
        else:
            raise RuntimeError(
                "compact() folds a live delta into its store; load the "
                "index with Aligner.load(path, live=True) first")
        return self

    # -- queries ------------------------------------------------------------

    def find(self, text, theta: float, *,
             options: QueryOptions | None = None,
             legacy_tuples: bool = False,
             stage_times: dict | None = None) -> QueryResult:
        """All indexed subsequences aligned with ``text`` at estimated
        (weighted) Jaccard >= theta (paper Definition 1), as a
        :class:`~repro.core.results.QueryResult` of typed
        :class:`~repro.core.results.Match` records (iterating it yields
        the matches, so ``for hit in aligner.find(...)`` is unchanged).

        ``legacy_tuples=True`` returns the pre-typed ``list[Alignment]``
        shape behind a ``DeprecationWarning``."""
        # repro: allow[RPR402] (the shim forwards its own legacy flag)
        return self.find_batch([text], theta, options=options,
                               legacy_tuples=legacy_tuples,
                               stage_times=stage_times)[0]

    def find_batch(self, texts, theta: float, *,
                   options: QueryOptions | None = None,
                   backend=UNSET, sketch_backend=UNSET, probe_backend=UNSET,
                   sweep=UNSET,
                   legacy_tuples: bool = False,
                   stage_times: dict | None = None) -> list[QueryResult]:
        """Batched :meth:`find` (the serving path — one fused arena probe
        for the whole batch); one :class:`QueryResult` per input text.

        Execution comes in as ``options=QueryOptions(...)``, whose
        ``plan`` names the pipeline: ``"cpu"`` (NumPy reference path, the
        default), ``"device"`` (the arena stays resident on the
        accelerator; probe and sweep run as Pallas kernels, block-identical
        to cpu), or ``"auto"`` (device when a real accelerator backs jax,
        else silently cpu).  Stage fields on the options object pin
        individual stages for debugging — e.g.
        ``QueryOptions(sketch_backend="pallas")`` moves weighted-scheme
        sketching into the fused device kernel, and
        ``probe_backend="percoord"`` forces the legacy k-probe loop.
        Sharded indexes fan the probes out across a thread pool
        (``QueryOptions.fanout``).

        The pre-redesign ``backend``/``sketch_backend``/``probe_backend``/
        ``sweep`` keywords still work for one release behind a
        ``DeprecationWarning`` (they coerce to pins on the cpu plan), as
        does ``legacy_tuples=True`` for the old ``list[list[Alignment]]``
        return shape.  ``stage_times`` accumulates per-stage wall seconds
        under ``"sketch"``/``"probe"``/``"sweep"`` (the serve-path metrics
        hook)."""
        opts = coerce_query_options(options, "Aligner.find_batch",
                                    backend=backend,
                                    sketch_backend=sketch_backend,
                                    probe_backend=probe_backend, sweep=sweep)
        tokens = [self._tokens(t) for t in texts]
        failed: list[int] = []
        if isinstance(self._index, ShardedAlignmentIndex):
            # degraded fan-out: a shard that keeps failing is skipped
            # (retried with backoff) and reported on the results instead
            # of failing the whole batch
            res = self._index.batch_query(tokens, theta, options=opts,
                                          stage_times=stage_times,
                                          failures=failed)
        elif isinstance(self._index, LiveIndex):
            res = self._index.batch_query(tokens, theta, options=opts,
                                          stage_times=stage_times)
        else:
            res = _batch_query(self._index, tokens, theta, options=opts,
                               stage_times=stage_times)
        if legacy_tuples:
            warnings.warn(
                "legacy_tuples=True is deprecated; Aligner.find/find_batch "
                "return typed QueryResult containers of Match records "
                "(iteration, len() and truthiness are unchanged)",
                DeprecationWarning, stacklevel=2)
            return res
        k = self.scheme.k
        results = [QueryResult.from_alignments(r, theta=theta, k=k,
                                               query_len=len(t))
                   for r, t in zip(res, tokens)]
        if failed:
            fs = tuple(sorted(set(failed)))
            results = [dataclasses.replace(r, degraded=True,
                                           failed_shards=fs)
                       for r in results]
        return results

    # -- persistence --------------------------------------------------------

    def _write_meta(self, root: Path) -> None:
        meta = {"similarity": self.config.similarity,
                "tokenizer": _tokenizer_spec(self.tokenizer)}
        fsio.write_text(root / _ALIGNER_META, json.dumps(meta),
                        site="aligner.meta")

    def save(self, path) -> "Aligner":
        """Freeze (if still building) and write the versioned store: JSON
        manifests + raw ``.npy`` arrays per frozen table, one directory per
        index (per shard when sharded).

        A live Aligner snapshots frozen + delta as one flat merged store
        at ``path`` without disturbing its own serving state (its store
        generations persist via :meth:`compact`, not here).  Snapshotting
        over the store this Aligner is *serving from* is refused — that
        would rewrite the mmap'd arrays in place under the reader; use
        :meth:`compact` to persist the delta there."""
        root = Path(path)
        if isinstance(self._index, LiveIndex):
            live = self._index
            self._refuse_live_overwrite(root, [live.root])
            identity = live.doc_map == list(range(len(live.doc_map)))
            save_index(live.freeze(), root,
                       doc_map=None if identity else live.doc_map)
            # the snapshot is flat: retire any stale generation pointer at
            # the target AFTER the manifest commit, so readers flip from a
            # complete old generation to the complete snapshot
            fsio.unlink(root / CURRENT_POINTER,
                        site="aligner.retire_pointer", missing_ok=True)
            self._write_meta(root)
            return self
        if isinstance(self._index, ShardedAlignmentIndex):
            live_shards = [s for s in self._index.shards
                           if getattr(s, "is_live", False)]
            if live_shards:
                self._refuse_live_overwrite(
                    root, [s.root.parent for s in live_shards
                           if s.root is not None])
            else:
                self.freeze()
            # live shards are snapshot-merged inside save() without
            # disturbing this aligner's serving state
            self._index.save(root)
        else:
            self.freeze()
            save_index(self._index, root)
        self._write_meta(root)
        return self

    @staticmethod
    def _refuse_live_overwrite(root: Path, serving_roots) -> None:
        for served in serving_roots:
            if served is not None and root.resolve() == served.resolve():
                raise RuntimeError(
                    "refusing to snapshot a live Aligner over the store it "
                    f"is serving from ({root}): np.save would truncate the "
                    "mmap'd arrays under the reader; use compact() to "
                    "persist the delta there, or save to a new directory")

    @classmethod
    def load(cls, path, *, mmap: bool = True, live: bool = False,
             wal=False) -> "Aligner":
        """Load a saved store and serve from it.  ``mmap=True`` (default)
        maps the table arrays read-only instead of materializing them —
        the serving mode for larger-than-RAM indexes.

        ``live=True`` opens the store for *incremental* serving: the
        returned Aligner accepts :meth:`add` without thawing (writes land
        in a small mutable delta, queried alongside the frozen arrays)
        and :meth:`compact` folds the delta into a new, atomically
        promoted store generation.  Sharded stores get one delta per
        shard.

        ``wal`` (flat live stores only) opens a write-ahead log under
        the store dir: every :meth:`add` is logged before it is indexed,
        un-compacted writes are replayed on the next open, and
        :meth:`compact` truncates the covered log suffix.  Pass ``True``
        for the default per-record fsync policy or a
        :class:`repro.wal.WalConfig` to choose group-commit batching."""
        root = Path(path)
        meta = {}
        if (root / _ALIGNER_META).exists():
            meta = json.loads((root / _ALIGNER_META).read_text())
        if (root / "meta.json").exists():               # sharded layout
            if wal:
                raise ValueError(
                    "wal is supported for flat live stores only "
                    "(per-shard WALs are future work)")
            smeta = json.loads((root / "meta.json").read_text())
            from .core import scheme_from_spec
            manifest_scheme = smeta["scheme"]
            index = ShardedAlignmentIndex(
                scheme=scheme_from_spec(manifest_scheme),
                n_shards=smeta["n_shards"], method=smeta["method"])
            index.restore(root, missing_ok=False, mmap=mmap, live=live)
        else:                                           # flat layout
            if wal and not live:
                raise ValueError("wal requires live=True")
            index = (LiveIndex.open(root, mmap=mmap, wal=wal) if live
                     else load_index(root, mmap=mmap))
            manifest_scheme = read_manifest(root)["scheme"]
        weight = manifest_scheme.get("weight") or {}
        config = AlignerConfig(
            similarity=meta.get("similarity", manifest_scheme["kind"]),
            k=manifest_scheme["k"], seed=manifest_scheme["seed"],
            method=index.method,
            tf=weight.get("tf", "raw"), idf=weight.get("idf"),
            family=manifest_scheme.get("family", "universal"),
            shards=(index.n_shards
                    if isinstance(index, ShardedAlignmentIndex) else 1))
        return cls(index, config=config,
                   tokenizer=_tokenizer_from_spec(meta.get("tokenizer")))

    # -- introspection ------------------------------------------------------

    @property
    def scheme(self):
        return self._index.scheme

    @property
    def num_docs(self) -> int:
        if isinstance(self._index, (ShardedAlignmentIndex, LiveIndex)):
            return len(self._index.doc_map)
        return self._index.num_texts

    @property
    def num_windows(self) -> int:
        return self._index.num_windows

    def nbytes(self) -> int:
        return self._index.nbytes()

    def __repr__(self) -> str:
        live = isinstance(self._index, LiveIndex) or (
            isinstance(self._index, ShardedAlignmentIndex) and
            any(getattr(s, "is_live", False) for s in self._index.shards))
        stage = "live" if live else "serve" if self.is_frozen else "build"
        return (f"Aligner(similarity={self.config.similarity!r}, "
                f"k={self.config.k}, shards={self.config.shards}, "
                f"docs={self.num_docs}, windows={self.num_windows}, "
                f"stage={stage!r})")

    # -- helpers ------------------------------------------------------------

    def _tokens(self, text) -> np.ndarray:
        if isinstance(text, str):
            if self.tokenizer is None:
                # inventing a tokenizer here would encode the query with a
                # vocabulary the index was never built with (silent garbage)
                raise ValueError(
                    "this Aligner has no tokenizer (the corpus was token "
                    "arrays, or the build tokenizer did not round-trip "
                    "through the store); pass token arrays, or set "
                    ".tokenizer to the one used at build time")
            return np.asarray(self.tokenizer.encode(text), np.int64)
        return np.asarray(text, np.int64)


def _tokenizer_spec(tok) -> dict | None:
    from .data.tokenizer import ByteTokenizer, HashWordTokenizer
    if tok is None:
        return None
    if isinstance(tok, HashWordTokenizer):
        return {"kind": "hash_word", "vocab": tok.vocab,
                "lowercase": tok.lowercase}
    if isinstance(tok, ByteTokenizer):
        return {"kind": "byte"}
    return None          # custom tokenizers don't round-trip; pass anew


def _tokenizer_from_spec(spec: dict | None):
    if not spec:
        return None
    from .data.tokenizer import ByteTokenizer, HashWordTokenizer
    if spec["kind"] == "hash_word":
        return HashWordTokenizer(vocab=spec["vocab"],
                                 lowercase=spec["lowercase"])
    if spec["kind"] == "byte":
        return ByteTokenizer()
    return None


__all__ = ["Aligner", "AlignerConfig", "WeightFn", "Alignment",
           "Match", "QueryResult", "QueryOptions",
           "SearchIndex", "IndexBuilder", "LiveIndex"]
