"""The server's wire protocol: JSON request/response schemas over HTTP
POST bodies and WebSocket text frames.

Query request::

    {"text": "a string" | [int tokens],
     "theta": 0.8,
     "options": {"plan": "device", ...},           # QueryOptions.to_dict()
     "deadline_ms": 50,                            # optional, relative
     "id": "any-client-token"}                     # optional, echoed back

Query response (200)::

    {"ok": true, "id": ..., "result": QueryResult.to_dict()}

where ``result.matches[*]`` is a :class:`repro.core.results.Match` record::

    {"doc_id": 5, "span": [3, 41], "query_span": [0, 44],
     "estimated_similarity": 0.8125, "blocks": [[3, 7, 30, 41], ...]}

Errors carry ``{"ok": false, "error": "...", "status": 503|504|400}`` —
503 when admission control rejects at queue capacity, 504 when the
deadline expired before the probe ran.

``/add`` takes ``{"text": ..., "request_id": "client-token"}`` (the id is
optional) and returns ``{"ok": true, "doc_id": n, "deduped": false}``.
The ``request_id`` is logged into the WAL record and makes retries safe:
a replayed id within the un-compacted window returns the original
``doc_id`` with ``"deduped": true`` instead of indexing a duplicate.
``/compact`` takes ``{}`` and returns ``{"ok": true, "generation": g}``.
"""

from __future__ import annotations

import json

import numpy as np

from ..core.results import QueryOptions


class ProtocolError(ValueError):
    """Malformed request body → HTTP 400."""


class QueryRequest:
    __slots__ = ("text", "theta", "options", "deadline_s", "id")

    def __init__(self, text, theta: float, options: QueryOptions,
                 deadline_s: float | None, id=None):
        self.text = text
        self.theta = theta
        self.options = options
        self.deadline_s = deadline_s
        self.id = id


def parse_query_request(body: bytes | str | dict) -> QueryRequest:
    d = _as_dict(body)
    if "text" not in d:
        raise ProtocolError("query request needs a 'text' field")
    text = d["text"]
    if not isinstance(text, str):
        try:
            text = np.asarray(text, np.int64)
        except (TypeError, ValueError) as e:
            raise ProtocolError(
                f"'text' must be a string or a list of ints: {e}") from None
        if text.ndim != 1:
            raise ProtocolError("'text' token array must be 1-D")
    theta = d.get("theta", 0.5)
    if not isinstance(theta, (int, float)) or not 0.0 < theta <= 1.0:
        raise ProtocolError("'theta' must be a number in (0, 1]")
    try:
        options = QueryOptions.from_dict(d.get("options"))
    except ValueError as e:
        raise ProtocolError(str(e)) from None
    deadline_ms = d.get("deadline_ms")
    if deadline_ms is not None and (
            not isinstance(deadline_ms, (int, float)) or deadline_ms <= 0):
        raise ProtocolError("'deadline_ms' must be a positive number")
    return QueryRequest(text=text, theta=float(theta), options=options,
                        deadline_s=(None if deadline_ms is None
                                    else float(deadline_ms) / 1e3),
                        id=d.get("id"))


def parse_add_request(body: bytes | str | dict):
    """Returns ``(text, request_id)`` — the id ``None`` when the client
    sent none (no retry-dedup window for this add)."""
    d = _as_dict(body)
    if "text" not in d:
        raise ProtocolError("add request needs a 'text' field")
    rid = d.get("request_id")
    if rid is not None and (not isinstance(rid, str) or not rid
                            or len(rid) > 200):
        raise ProtocolError("'request_id' must be a non-empty string "
                            "(at most 200 chars)")
    text = d["text"]
    if isinstance(text, str):
        return text, rid
    try:
        arr = np.asarray(text, np.int64)
    except (TypeError, ValueError) as e:
        raise ProtocolError(
            f"'text' must be a string or a list of ints: {e}") from None
    if arr.ndim != 1:
        raise ProtocolError("'text' token array must be 1-D")
    return arr, rid


def ok_response(payload: dict) -> bytes:
    return json.dumps({"ok": True, **payload}).encode()


def error_response(message: str, status: int) -> bytes:
    return json.dumps({"ok": False, "error": message,
                       "status": status}).encode()


def _as_dict(body) -> dict:
    if isinstance(body, dict):
        return body
    try:
        d = json.loads(body)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ProtocolError(f"request body is not JSON: {e}") from None
    if not isinstance(d, dict):
        raise ProtocolError("request body must be a JSON object")
    return d
