"""Background compaction policy: the robustness half of "make compaction
automatic" (ROADMAP item 3).

A :class:`CompactionSupervisor` watches the served index's
``delta_fraction`` / delta age — plus, with a WAL open, the log's size
and the age of its oldest un-compacted record (compaction is what
truncates the log, so these bound crash-replay time and WAL disk) — and,
when a threshold trips, runs the same
graceful seal → off-band merge → promote sequence as ``POST /compact``
(:meth:`AlignServer.compact`) — traffic never pauses.  After each
successful compaction it prunes superseded store generations
(:func:`repro.core.store.prune_generations`; quarantine is never touched).

Failure is expected, not exceptional: a failed attempt (e.g. an injected
or real ``OSError`` mid-merge) is retried with exponential backoff; after
``max_retries`` consecutive failures the supervisor rolls the seal back
(:meth:`LiveIndex.unseal_delta` — queries were never wrong either way,
the sealed level keeps serving) and reports itself failing, which flips
``/healthz`` to ``degraded`` until an attempt succeeds again.  Counters
(``supervisor_compactions_total`` / ``supervisor_retries_total`` /
``supervisor_failures_total`` / ``pruned_generations_total``) land in the
``/metrics`` snapshot.
"""

from __future__ import annotations

import asyncio

from ..core.live import LiveIndex
from ..core.sharded_index import ShardedAlignmentIndex
from ..core.store import prune_generations


class CompactionSupervisor:
    """Threshold-driven background compaction with retry and rollback.

    Construct it, pass it to ``AlignServer(supervisor=...)``, and the
    server starts/stops it with its own lifecycle.  All index state is
    read through the server's batcher dispatchers, so the engine-affinity
    contract (RPR101 / ``REPRO_THREAD_GUARD``) holds.
    """

    def __init__(self, *, max_delta_fraction: float = 0.25,
                 max_delta_age_s: float = 30.0, interval_s: float = 1.0,
                 max_retries: int = 5, backoff_base_s: float = 0.5,
                 backoff_max_s: float = 30.0, prune_keep: int = 2,
                 max_wal_bytes: int = 32_000_000,
                 max_wal_age_s: float = 60.0):
        self.max_delta_fraction = max_delta_fraction
        self.max_delta_age_s = max_delta_age_s
        self.interval_s = interval_s
        self.max_wal_bytes = max_wal_bytes
        self.max_wal_age_s = max_wal_age_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_max_s = backoff_max_s
        self.prune_keep = prune_keep
        self.failing = False            # surfaces in /healthz as degraded
        self.failures = 0               # consecutive failed attempts
        self._server = None
        self._task: asyncio.Task | None = None

    # -- lifecycle (driven by AlignServer) -----------------------------------

    def bind(self, server) -> None:
        self._server = server

    def start(self) -> None:
        if self._server is None:
            raise RuntimeError("bind(server) before start()")
        self._task = asyncio.get_running_loop().create_task(
            # engine work inside _run goes through AlignServer.compact,
            # which routes every index touch via the batcher
            self._run(), name="compaction-supervisor")  # repro: allow[RPR101]

    async def close(self) -> None:
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None

    # -- policy --------------------------------------------------------------

    def _live_shards(self) -> list:
        idx = self._server.aligner._index
        if isinstance(idx, LiveIndex):
            return [idx]
        if isinstance(idx, ShardedAlignmentIndex):
            return [s for s in idx.shards if getattr(s, "is_live", False)]
        return []

    def _due(self) -> bool:
        """Reads only counters/timestamps (no index mutation), safe off
        the engine thread like the other monitoring reads."""
        for live in self._live_shards():
            if live.sealed is not None:
                return True             # unfinished merge: retry it
            if live.delta.num_texts == 0:
                continue
            if live.delta_fraction >= self.max_delta_fraction:
                return True
            if live.delta_age_s >= self.max_delta_age_s:
                return True
            # WAL pressure: compacting truncates the covered log suffix,
            # bounding both replay time after a crash and disk held by
            # segments.  Gated on lag_records so covered tail debris
            # (the one un-removable active segment) can't trip a busy
            # no-op loop.
            wal = (live.wal_status()
                   if isinstance(live, LiveIndex) else None)
            if wal is not None and wal["lag_records"] > 0:
                if wal["bytes"] >= self.max_wal_bytes:
                    return True
                if wal["age_s"] >= self.max_wal_age_s:
                    return True
        return False

    async def _run(self) -> None:
        delay = self.interval_s
        while True:
            await asyncio.sleep(delay)
            delay = self.interval_s
            if self._server._compacting or not self._due():
                continue
            try:
                # AlignServer.compact (not the engine-only index method):
                # it seals/promotes via submit_control and merges off-band
                await self._server.compact()  # repro: allow[RPR101]
                await self._prune()
            except asyncio.CancelledError:
                raise
            except Exception:                       # noqa: BLE001
                self.failures += 1
                self._server.metrics.inc("supervisor_retries_total")
                if self.failures > self.max_retries:
                    # give up on this delta for now: roll the seal back
                    # (it keeps serving correctly either way) and report
                    # unhealthy until an attempt succeeds
                    if not self.failing:
                        self.failing = True
                        self._server.metrics.inc("supervisor_failures_total")
                    await self._rollback()
                delay = min(self.backoff_max_s,
                            self.backoff_base_s * 2 ** (self.failures - 1))
                continue
            self.failures = 0
            self.failing = False
            self._server.metrics.inc("supervisor_compactions_total")

    async def _rollback(self) -> None:
        batcher = self._server.batcher
        for live in self._live_shards():
            if live.sealed is not None:
                await batcher.submit_control(live.unseal_delta, "unseal")

    async def _prune(self) -> None:
        roots = [live.root for live in self._live_shards()
                 if live.root is not None]
        if not roots:
            return
        removed = await self._server.batcher.run_offband(
            lambda: [p for r in roots
                     for p in prune_generations(r, keep=self.prune_keep)])
        if removed:
            self._server.metrics.inc("pruned_generations_total",
                                     by=len(removed))
