"""Serve CLI: run an align server over a saved index store.

    PYTHONPATH=src python -m repro.serve --store idx_dir --live \
        --port 8080 --max-batch 32 --linger-us 2000

``--live`` opens the store for incremental serving (POST /add and
POST /compact work); without it the server is query-only.
"""

from __future__ import annotations

import argparse
import asyncio


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        "python -m repro.serve",
        description="asyncio alignment server with dynamic batching")
    ap.add_argument("--store", required=True,
                    help="index store directory (Aligner.save / build store=)")
    ap.add_argument("--live", action="store_true",
                    help="open live: accept /add writes and /compact")
    ap.add_argument("--no-mmap", action="store_true",
                    help="materialize the index instead of mmap-serving it")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--max-batch", type=int, default=32,
                    help="dynamic batch size cap (default 32)")
    ap.add_argument("--linger-us", type=float, default=2000.0,
                    help="max micro-batch linger in microseconds")
    ap.add_argument("--queue-cap", type=int, default=256,
                    help="in-flight request cap; beyond it requests get 503")
    ap.add_argument("--auto-compact", action="store_true",
                    help="run a CompactionSupervisor: background "
                         "seal/merge/promote when the delta grows or ages "
                         "past the thresholds below (live stores only)")
    ap.add_argument("--compact-fraction", type=float, default=0.25,
                    help="compact when delta docs exceed this fraction of "
                         "the total (default 0.25)")
    ap.add_argument("--compact-age-s", type=float, default=30.0,
                    help="compact when the oldest delta doc is this old "
                         "(default 30s)")
    ap.add_argument("--prune-keep", type=int, default=2,
                    help="superseded store generations to retain after each "
                         "background compaction (default 2)")
    args = ap.parse_args(argv)

    from repro.api import Aligner
    from repro.serve import AlignServer, CompactionSupervisor

    aligner = Aligner.load(args.store, mmap=not args.no_mmap, live=args.live)
    print(f"serving {aligner!r}")

    supervisor = None
    if args.auto_compact:
        supervisor = CompactionSupervisor(
            max_delta_fraction=args.compact_fraction,
            max_delta_age_s=args.compact_age_s,
            prune_keep=args.prune_keep)

    async def run():
        server = AlignServer(aligner, host=args.host, port=args.port,
                             max_batch=args.max_batch,
                             max_linger_us=args.linger_us,
                             queue_cap=args.queue_cap,
                             supervisor=supervisor)
        await server.start()
        print(f"listening on http://{server.host}:{server.port} "
              f"(endpoints: /query /add /compact /metrics /healthz /ws)")
        try:
            await server._server.serve_forever()
        finally:
            await server.close()

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
