"""Serve CLI: run an align server over a saved index store.

    PYTHONPATH=src python -m repro.serve --store idx_dir --live \
        --port 8080 --max-batch 32 --linger-us 2000

``--live`` opens the store for incremental serving (POST /add and
POST /compact work); without it the server is query-only.

``--wal`` (requires ``--live``) makes ingest durable: every /add is
logged to a write-ahead log before it is indexed and acknowledged only
after its record is fsynced.  The default policy is group commit — the
batcher runs one fsync per write micro-batch, so its linger window is
the commit window; ``--wal-fsync-every-n 1`` forces an fsync per record
instead.
"""

from __future__ import annotations

import argparse
import asyncio


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        "python -m repro.serve",
        description="asyncio alignment server with dynamic batching")
    ap.add_argument("--store", required=True,
                    help="index store directory (Aligner.save / build store=)")
    ap.add_argument("--live", action="store_true",
                    help="open live: accept /add writes and /compact")
    ap.add_argument("--no-mmap", action="store_true",
                    help="materialize the index instead of mmap-serving it")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--max-batch", type=int, default=32,
                    help="dynamic batch size cap (default 32)")
    ap.add_argument("--linger-us", type=float, default=2000.0,
                    help="max micro-batch linger in microseconds")
    ap.add_argument("--queue-cap", type=int, default=256,
                    help="in-flight request cap; beyond it requests get 503")
    ap.add_argument("--auto-compact", action="store_true",
                    help="run a CompactionSupervisor: background "
                         "seal/merge/promote when the delta grows or ages "
                         "past the thresholds below (live stores only)")
    ap.add_argument("--compact-fraction", type=float, default=0.25,
                    help="compact when delta docs exceed this fraction of "
                         "the total (default 0.25)")
    ap.add_argument("--compact-age-s", type=float, default=30.0,
                    help="compact when the oldest delta doc is this old "
                         "(default 30s)")
    ap.add_argument("--prune-keep", type=int, default=2,
                    help="superseded store generations to retain after each "
                         "background compaction (default 2)")
    ap.add_argument("--wal", action="store_true",
                    help="durable ingest (flat live stores only): log every "
                         "/add to a write-ahead log and ack only after its "
                         "record is fsynced; crash replay restores every "
                         "acknowledged write")
    ap.add_argument("--wal-fsync-every-n", type=int, default=0,
                    help="WAL fsync policy: 0 (default) = group commit, one "
                         "fsync per batcher write micro-batch; 1 = fsync "
                         "every record; N>1 = fsync every N records")
    ap.add_argument("--wal-segment-bytes", type=int, default=4 << 20,
                    help="WAL segment rotation size (default 4 MiB)")
    ap.add_argument("--wal-max-bytes", type=int, default=32_000_000,
                    help="with --auto-compact: compact when un-truncated WAL "
                         "segments exceed this many bytes (default 32e6)")
    ap.add_argument("--wal-max-age-s", type=float, default=60.0,
                    help="with --auto-compact: compact when the oldest "
                         "un-compacted WAL record is this old (default 60s)")
    args = ap.parse_args(argv)

    from repro.api import Aligner
    from repro.serve import AlignServer, CompactionSupervisor

    wal = False
    if args.wal:
        if not args.live:
            ap.error("--wal requires --live")
        from repro.wal import WalConfig
        wal = WalConfig(fsync_every_n=args.wal_fsync_every_n,
                        segment_bytes=args.wal_segment_bytes)
    # WAL replay inside load() indexes into the delta, but this runs at
    # startup before the server (and its engine thread) exists
    aligner = Aligner.load(args.store, mmap=not args.no_mmap,  # repro: allow[RPR101]
                           live=args.live, wal=wal)
    print(f"serving {aligner!r}")

    supervisor = None
    if args.auto_compact:
        supervisor = CompactionSupervisor(
            max_delta_fraction=args.compact_fraction,
            max_delta_age_s=args.compact_age_s,
            prune_keep=args.prune_keep,
            max_wal_bytes=args.wal_max_bytes,
            max_wal_age_s=args.wal_max_age_s)

    async def run():
        server = AlignServer(aligner, host=args.host, port=args.port,
                             max_batch=args.max_batch,
                             max_linger_us=args.linger_us,
                             queue_cap=args.queue_cap,
                             supervisor=supervisor)
        await server.start()
        print(f"listening on http://{server.host}:{server.port} "
              f"(endpoints: /query /add /compact /metrics /healthz /ws)")
        try:
            await server._server.serve_forever()
        finally:
            await server.close()

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
