"""The dynamic micro-batcher: coalesce concurrent single-query requests
into ``find_batch`` calls, run them off the event loop, fan results back
to per-request futures.

Concurrency model — ONE engine thread for ALL index access:

Every operation that touches the index — query batches, ``add`` writes,
the seal and promote phases of compaction — runs as a job on a single
``ThreadPoolExecutor(max_workers=1)``.  That serialization is the whole
correctness story: the mutable delta's dict tables are never read while
being written, generation swaps land *between* batches (a batch holds
its references for the duration of one ``find_batch`` call and the swap
only rebinds attributes for later batches), and FIFO job order gives
read-your-writes (a query enqueued after an ``add`` sees its document).
The only index work OFF this thread is the compaction *merge*, which
reads exclusively immutable state (frozen arrays + the sealed delta) —
see :meth:`repro.serve.app.AlignServer.compact`.

The drain loop implements the batching policy:

* pop a request, then keep coalescing requests with the same
  ``(theta, QueryOptions.batch_key())`` until ``max_batch`` is reached or
  ``max_linger_us`` expires — under load the linger never sleeps because
  the queue already holds a backlog;
* a control job (seal/promote) or an incompatible query stops the
  current batch (preserving FIFO order: it is stashed and handled next);
* write jobs (``/add``) coalesce the same way queries do: consecutive
  writes form a group that runs on the engine and is covered by ONE
  ``write_flush`` durability barrier (the WAL fsync) before any ack —
  group commit, with the linger window as the commit window;
* requests whose deadline passed while queued are completed with
  :class:`DeadlineExceeded` *before* the probe runs — expired work never
  costs engine time;
* admission control caps the number of in-flight requests
  (:class:`QueueFull` → HTTP 503).  Control jobs are always admitted:
  backpressure must shed query load without wedging writes or
  compaction.
"""

from __future__ import annotations

import asyncio
from concurrent.futures import ThreadPoolExecutor

from ..core import guard
from ..core.guard import engine_only
from ..core.results import QueryOptions
from ..fault import checkpoint as fault_checkpoint
from .metrics import ServeMetrics


class QueueFull(Exception):
    """Admission control rejected the request (queue at capacity)."""


class DeadlineExceeded(Exception):
    """The request's deadline passed before its batch was probed."""


class _QueryItem:
    __slots__ = ("tokens", "theta", "options", "deadline", "enqueued",
                 "future")

    def __init__(self, tokens, theta, options, deadline, enqueued, future):
        self.tokens = tokens
        self.theta = theta
        self.options = options
        self.deadline = deadline        # absolute loop.time(), or None
        self.enqueued = enqueued
        self.future = future

    def batch_key(self):
        return (self.theta, self.options.batch_key())


class _ControlItem:
    __slots__ = ("fn", "future", "label")

    def __init__(self, fn, future, label):
        self.fn = fn
        self.future = future
        self.label = label


class _WriteItem:
    """A durable write (an ``/add``): runs on the engine like a control
    job, but consecutive writes coalesce into one group that shares a
    single ``write_flush`` durability barrier before any ack."""

    __slots__ = ("fn", "future")

    def __init__(self, fn, future):
        self.fn = fn
        self.future = future


class DynamicBatcher:
    """Coalescing queue + single-threaded engine around an ``Aligner``."""

    def __init__(self, aligner, *, max_batch: int = 32,
                 max_linger_us: float = 2000.0, queue_cap: int = 256,
                 metrics: ServeMetrics | None = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.aligner = aligner
        self.max_batch = max_batch
        self.linger_s = max_linger_us / 1e6
        self.queue_cap = queue_cap
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self._queue: asyncio.Queue = asyncio.Queue()
        self._stash = None              # item popped but not yet batchable
        # durable-ack hook (set by the server when the index has a WAL):
        # called ONCE per write group, on the engine thread, after every
        # member ran — group commit with the batcher's linger window
        self.write_flush = None
        self._inflight = 0
        self._engine = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=guard.ENGINE_THREAD_PREFIX)
        self._task: asyncio.Task | None = None
        self._closed = False
        # engine-affinity guard (REPRO_THREAD_GUARD=1): while this engine
        # serves them, the index, its shards, and the batcher itself only
        # accept @engine_only calls from the engine thread
        idx = getattr(aligner, "_index", None)
        self._owned = (self, idx, *getattr(idx, "shards", ()))
        guard.adopt(*self._owned)

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(
                self._drain(), name="batcher-drain")

    async def close(self) -> None:
        self._closed = True
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:
                pass
            self._task = None
        while not self._queue.empty():
            item = self._queue.get_nowait()
            if not item.future.done():
                item.future.cancel()
        if self._stash is not None and not self._stash.future.done():
            self._stash.future.cancel()
        self._engine.shutdown(wait=True)
        guard.disown(*self._owned)

    # -- submission ----------------------------------------------------------

    def submit_query(self, tokens, theta: float,
                     options: QueryOptions | None = None,
                     deadline_s: float | None = None) -> asyncio.Future:
        """Enqueue one query; the returned future resolves to its
        ``QueryResult`` (or ``DeadlineExceeded``).  Raises
        :class:`QueueFull` when admission control is at capacity."""
        if self._closed:
            raise QueueFull("server is shutting down")
        if self._inflight >= self.queue_cap:
            self.metrics.inc("rejected_total")
            raise QueueFull(
                f"{self._inflight} requests in flight (cap "
                f"{self.queue_cap})")
        loop = asyncio.get_running_loop()
        now = loop.time()
        fut = loop.create_future()
        item = _QueryItem(
            tokens=tokens, theta=float(theta),
            options=options if options is not None else QueryOptions(),
            deadline=None if deadline_s is None else now + deadline_s,
            enqueued=now, future=fut)
        self._inflight += 1
        fut.add_done_callback(self._on_done(item, loop))
        self.metrics.inc("requests_total")
        self._queue.put_nowait(item)
        self.start()
        return fut

    def submit_control(self, fn, label: str = "control") -> asyncio.Future:
        """Run ``fn()`` alone on the engine thread, in FIFO order with the
        query stream.  Always admitted (never sheds writes/compaction)."""
        loop = asyncio.get_running_loop()
        fut = loop.create_future()
        self._queue.put_nowait(_ControlItem(fn=fn, future=fut, label=label))
        self.start()
        return fut

    def submit_write(self, fn) -> asyncio.Future:
        """Enqueue a durable write: ``fn()`` runs on the engine thread in
        FIFO order, consecutive writes coalesce (up to ``max_batch`` /
        the linger window) and the whole group is covered by ONE
        ``write_flush`` before any of their futures resolve — the ack is
        durable, the fsync amortized.  Always admitted, like control
        jobs.  If the flush fails the whole group fails un-acked (the
        documents may still be indexed; an at-least-once client retries
        with the same ``request_id`` and dedups server-side)."""
        loop = asyncio.get_running_loop()
        fut = loop.create_future()
        self._queue.put_nowait(_WriteItem(fn=fn, future=fut))
        self.start()
        return fut

    def run_offband(self, fn) -> asyncio.Future:
        """Run ``fn()`` on a throwaway thread OUTSIDE the engine — for
        work that must overlap serving and only reads immutable state
        (the compaction merge)."""
        loop = asyncio.get_running_loop()
        return loop.run_in_executor(None, fn)

    def _on_done(self, item: _QueryItem, loop):
        def cb(fut):
            self._inflight -= 1
            if not fut.cancelled() and fut.exception() is None:
                self.metrics.observe_latency(loop.time() - item.enqueued)
        return cb

    # -- drain loop ----------------------------------------------------------

    async def _drain(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            item = self._stash
            self._stash = None
            if item is None:
                item = await self._queue.get()
            if isinstance(item, _ControlItem):
                await self._run_control(item)
                continue
            if isinstance(item, _WriteItem):
                group = [item]
                end = loop.time() + self.linger_s
                while len(group) < self.max_batch:
                    wait = end - loop.time()
                    try:
                        if wait > 0:
                            nxt = await asyncio.wait_for(self._queue.get(),
                                                         wait)
                        else:
                            nxt = self._queue.get_nowait()
                    except (asyncio.TimeoutError, asyncio.QueueEmpty):
                        break
                    if not isinstance(nxt, _WriteItem):
                        self._stash = nxt   # FIFO: handled right after us
                        break
                    group.append(nxt)
                await self._commit_group(group)
                continue
            batch = [item]
            key = item.batch_key()
            end = loop.time() + self.linger_s
            while len(batch) < self.max_batch:
                wait = end - loop.time()
                try:
                    if wait > 0:
                        nxt = await asyncio.wait_for(self._queue.get(),
                                                     wait)
                    else:
                        # linger spent: sweep only what is already queued
                        nxt = self._queue.get_nowait()
                except (asyncio.TimeoutError, asyncio.QueueEmpty):
                    break
                if isinstance(nxt, _ControlItem) or nxt.batch_key() != key:
                    self._stash = nxt       # FIFO: handled right after us
                    break
                batch.append(nxt)
            await self._dispatch(batch, loop)

    async def _run_control(self, item: _ControlItem) -> None:
        loop = asyncio.get_running_loop()
        try:
            out = await loop.run_in_executor(self._engine, item.fn)
        except Exception as e:                      # noqa: BLE001
            if not item.future.done():
                item.future.set_exception(e)
        else:
            if not item.future.done():
                item.future.set_result(out)

    async def _commit_group(self, group: list) -> None:
        loop = asyncio.get_running_loop()
        try:
            results = await loop.run_in_executor(
                self._engine, self._apply_writes, [w.fn for w in group])
        except Exception as e:                      # noqa: BLE001
            # the durable barrier (or the engine itself) failed: nothing
            # in the group is acknowledged — at-least-once clients retry
            # with their request_id and the index dedups the replay
            self.metrics.inc("errors_total", by=len(group))
            for w in group:
                if not w.future.done():
                    w.future.set_exception(e)
            return
        self.metrics.observe_group_commit(len(group))
        for w, (ok, val) in zip(group, results):
            if w.future.done():
                continue
            if ok:
                w.future.set_result(val)
            else:
                w.future.set_exception(val)

    @engine_only
    def _apply_writes(self, fns: list):
        """Engine-thread body of one write group: run every member
        (collecting per-item success/failure), then ONE ``write_flush``
        durability barrier covering them all."""
        out = []
        for fn in fns:
            try:
                out.append((True, fn()))
            except Exception as e:                  # noqa: BLE001
                out.append((False, e))
        if self.write_flush is not None:
            self.write_flush()
        return out

    async def _dispatch(self, batch: list, loop) -> None:
        now = loop.time()
        live, expired = [], []
        for q in batch:
            if q.future.done():
                continue                 # client went away / cancelled
            if q.deadline is not None and now > q.deadline:
                expired.append(q)
            else:
                live.append(q)
        for q in expired:
            self.metrics.inc("expired_total")
            q.future.set_exception(DeadlineExceeded(
                f"deadline passed {1e3 * (now - q.deadline):.1f} ms before "
                "the batch was probed"))
        if not live:
            return                       # nothing left: skip the probe
        stage: dict = {}
        try:
            results = await loop.run_in_executor(
                self._engine, self._probe, live, stage)
        except Exception as e:                      # noqa: BLE001
            self.metrics.inc("errors_total", by=len(live))
            for q in live:
                if not q.future.done():
                    q.future.set_exception(e)
            return
        self.metrics.observe_batch(
            len(live), [now - q.enqueued for q in live], stage)
        for q, res in zip(live, results):
            if not q.future.done():
                q.future.set_result(res)

    @engine_only
    def _probe(self, live: list, stage: dict):
        """Engine-thread body: ONE ``find_batch`` over the coalesced
        queries (all share theta and an options batch key)."""
        # serve-path injection hook: an armed FaultPlan can slow this
        # batch (latency testing) or raise (exercising the 500 path); a
        # no-op two-checks guard when nothing is armed
        fault_checkpoint("serve.batcher.probe")
        return self.aligner.find_batch(
            [q.tokens for q in live], live[0].theta,
            options=live[0].options, stage_times=stage)
