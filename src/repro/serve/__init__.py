"""Async serving front end: an asyncio HTTP + WebSocket server around
:class:`repro.api.Aligner` with a dynamic micro-batcher.

The paper's headline number is query latency, and the repo's batched
engine (`find_batch` over the fused ``ProbeArena``) is several times the
throughput of looped ``find`` — but only for callers who hand-assemble
batches.  This package turns that batched throughput into tail-latency
wins for *concurrent single-query* clients:

* :class:`~repro.serve.batcher.DynamicBatcher` — concurrent requests
  enqueue into a coalescing queue; a drain loop forms ``find_batch``
  batches under a max-batch-size / max-linger policy and runs the
  GIL-releasing probe off the event loop on a single engine thread.
  Admission control (bounded in-flight count → 503) and per-request
  deadlines (expired work dropped before probing → 504) included.
* :class:`~repro.serve.app.AlignServer` — pure-stdlib asyncio HTTP/1.1 +
  RFC 6455 WebSocket front end speaking the typed
  :class:`~repro.core.results.Match`/``QueryResult`` JSON protocol
  (:mod:`repro.serve.protocol`), with ``/metrics`` observability
  (:mod:`repro.serve.metrics`) and graceful generation-swap compaction:
  ``/compact`` seals the live delta on the engine thread, merges it into
  a new store generation on a background thread while traffic keeps
  flowing, and promotes the ``CURRENT`` pointer between batches — no
  request is ever dropped or served torn state.

Start one with::

    PYTHONPATH=src python -m repro.serve --store idx_dir --live

and query it with :mod:`repro.serve.client` or plain ``curl``.
"""

from .app import AlignServer
from .batcher import DeadlineExceeded, DynamicBatcher, QueueFull
from .client import AlignClient, AsyncAlignClient
from .metrics import ServeMetrics
from .supervisor import CompactionSupervisor

__all__ = ["AlignServer", "DynamicBatcher", "ServeMetrics",
           "AlignClient", "AsyncAlignClient", "QueueFull",
           "DeadlineExceeded", "CompactionSupervisor"]
