"""The asyncio front end: HTTP/1.1 + WebSocket endpoints over the
dynamic batcher, plus the graceful-compaction orchestration.

Pure stdlib on purpose — the repo's dependency surface is numpy+jax, and
an alignment query server needs exactly six endpoints:

====== ========== ===================================================
POST   /query     one alignment query (dynamic-batched); body/response
                  per :mod:`repro.serve.protocol`
POST   /add       index one document into the live delta (FIFO with
                  queries: later queries see it); with a WAL open the
                  200 is sent only after the record is fsync-durable,
                  and a client ``request_id`` makes retries idempotent
POST   /compact   fold the delta into a new store generation without
                  pausing traffic (see :meth:`AlignServer.compact`)
GET    /metrics   :class:`~repro.serve.metrics.ServeMetrics` snapshot
GET    /healthz   liveness + serving generation
GET    /ws        WebSocket upgrade; each text frame is one /query
                  body, responses fan back per-message (pipelined)
====== ========== ===================================================

Graceful generation swap: ``/compact`` never stops the world.  The
engine thread seals the delta (one pointer swap between batches), a
background thread merges frozen + sealed into a new ``v{N:06d}``
generation — reading only immutable state while queries keep batching
against (frozen, sealed, fresh delta) — and the engine thread promotes
the ``CURRENT`` pointer between two batches.  A query in flight when the
promotion lands was dispatched against the old references and completes
against them; the next batch sees the new generation.  Local text ids
are stable across the swap, so the two views are bit-identical.
"""

from __future__ import annotations

import asyncio
import base64
import hashlib
import json
import struct

from .. import fault
from ..core.live import LiveIndex
from ..core.sharded_index import ShardedAlignmentIndex
from ..core.store import store_counters
from .batcher import DeadlineExceeded, DynamicBatcher, QueueFull
from .metrics import ServeMetrics
from .protocol import (ProtocolError, error_response, ok_response,
                       parse_add_request, parse_query_request)

_WS_GUID = "258EAFA5-E914-47DA-95CA-C5AB0DC85B11"
_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 409: "Conflict",
            500: "Internal Server Error", 503: "Service Unavailable",
            504: "Gateway Timeout"}


class AlignServer:
    """One Aligner behind an asyncio TCP server with dynamic batching."""

    def __init__(self, aligner, *, host: str = "127.0.0.1", port: int = 0,
                 max_batch: int = 32, max_linger_us: float = 2000.0,
                 queue_cap: int = 256, retry_after_s: float = 1.0,
                 supervisor=None):
        self.aligner = aligner
        self.host = host
        self.port = port
        self.metrics = ServeMetrics()
        self.batcher = DynamicBatcher(aligner, max_batch=max_batch,
                                      max_linger_us=max_linger_us,
                                      queue_cap=queue_cap,
                                      metrics=self.metrics)
        idx = aligner._index
        if isinstance(idx, LiveIndex) and idx.wal is not None:
            # durable-ack hook: adds coalesce into write groups and the
            # batcher runs ONE wal fsync per group before resolving any
            # of their futures — the linger window IS the commit window
            self.batcher.write_flush = idx.wal_commit
        # advisory Retry-After on admission-control 503s (seconds)
        self.retry_after_s = retry_after_s
        # optional CompactionSupervisor (serve.supervisor); started and
        # stopped with the server's own lifecycle
        self.supervisor = supervisor
        self._server: asyncio.AbstractServer | None = None
        self._compacting = False
        # shard ids the most recent degraded fan-out skipped (empty while
        # healthy); drives the /healthz healthy|degraded status
        self._last_failed_shards: tuple = ()

    # -- lifecycle -----------------------------------------------------------

    async def start(self) -> "AlignServer":
        self.batcher.start()
        self._server = await asyncio.start_server(self._handle_conn,
                                                  self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        if self.supervisor is not None:
            self.supervisor.bind(self)
            self.supervisor.start()
        return self

    async def close(self) -> None:
        if self.supervisor is not None:
            await self.supervisor.close()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.batcher.close()

    async def __aenter__(self) -> "AlignServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def serve_forever(self) -> None:
        await self.start()
        try:
            await self._server.serve_forever()
        finally:
            await self.close()

    # -- endpoint bodies (shared by HTTP and WebSocket) ----------------------

    async def handle_query(self, body) -> tuple[int, bytes]:
        try:
            req = parse_query_request(body)
            tokens = self.aligner._tokens(req.text)
        except (ProtocolError, ValueError) as e:
            return 400, error_response(str(e), 400)

        def err(message: str, status: int) -> tuple[int, bytes]:
            # errors echo the client's id too, so pipelined WebSocket
            # clients can correlate every outcome
            d = json.loads(error_response(message, status))
            if req.id is not None:
                d["id"] = req.id
            return status, json.dumps(d).encode()

        try:
            fut = self.batcher.submit_query(tokens, req.theta, req.options,
                                            deadline_s=req.deadline_s)
        except QueueFull as e:
            return err(str(e), 503)
        try:
            result = await fut
        except DeadlineExceeded as e:
            return err(str(e), 504)
        except asyncio.CancelledError:
            raise
        except Exception as e:                      # noqa: BLE001
            return err(f"{type(e).__name__}: {e}", 500)
        if result.degraded:
            self.metrics.inc("degraded_total")
            self._last_failed_shards = tuple(result.failed_shards)
        else:
            self._last_failed_shards = ()
        payload = {"result": result.to_dict()}
        if req.id is not None:
            payload["id"] = req.id
        return 200, ok_response(payload)

    async def handle_add(self, body) -> tuple[int, bytes]:
        try:
            text, request_id = parse_add_request(body)
            tokens = self.aligner._tokens(text)
        except (ProtocolError, ValueError) as e:
            return 400, error_response(str(e), 400)

        def _do_add():
            # the dedup window answers replayed request_ids without
            # growing the corpus — detect that by the doc count
            before = self.aligner.num_docs
            gid = self.aligner.add(tokens, request_id=request_id)
            return gid, self.aligner.num_docs == before

        try:
            # add mutates the delta, so it is @engine_only: calling
            # aligner.add() here directly would race the batch in flight
            # (RPR101 flags it).  With a WAL wired, submit_write groups
            # consecutive adds and acks only after the group's single
            # wal fsync (write_flush); without one, submit_control keeps
            # the plain FIFO path.
            if self.batcher.write_flush is not None:
                doc_id, deduped = await self.batcher.submit_write(_do_add)
            else:
                doc_id, deduped = await self.batcher.submit_control(
                    _do_add, "add")
        except RuntimeError as e:       # frozen (non-live) index
            return 409, error_response(str(e), 409)
        self.metrics.inc("adds_deduped_total" if deduped else "adds_total")
        return 200, ok_response({"doc_id": int(doc_id),
                                 "deduped": bool(deduped)})

    async def handle_compact(self) -> tuple[int, bytes]:
        try:
            gen = await self.compact()
        except RuntimeError as e:
            return 409, error_response(str(e), 409)
        return 200, ok_response({"generation": int(gen)})

    async def compact(self) -> int:
        """Fold the live delta into a new promoted store generation
        WITHOUT pausing traffic (seal on engine → merge off-band →
        promote on engine); returns the serving generation.

        Every index touch below rides a dispatcher: ``seal_delta`` and
        ``promote_sealed`` are ``@engine_only`` (RPR101) and go through
        ``submit_control``; ``merge_sealed`` reads only immutable state
        and runs via ``run_offband`` so serving never pauses."""
        idx = self.aligner._index
        if isinstance(idx, ShardedAlignmentIndex):
            # per-shard deltas: run the whole fold as one engine op (it
            # blocks batches for its duration; the overlapped path below
            # is the flat live store's)
            await self.batcher.submit_control(idx.compact, "compact")
            self.metrics.inc("compactions_total")
            return max((s.generation for s in idx.shards
                        if getattr(s, "is_live", False)), default=0)
        if not isinstance(idx, LiveIndex):
            raise RuntimeError(
                "this server holds a frozen index; load the store with "
                "live=True to take writes and compactions")
        if self._compacting:
            raise RuntimeError("a compaction is already in progress")
        self._compacting = True
        try:
            def _seal():
                if idx.sealed is None and idx.delta.num_texts == 0:
                    return False         # nothing to fold in
                if idx.sealed is None:
                    idx.seal_delta()
                return True

            if not await self.batcher.submit_control(_seal, "seal"):
                return idx.generation
            gen, new_idx = await self.batcher.run_offband(idx.merge_sealed)
            await self.batcher.submit_control(
                lambda: idx.promote_sealed(gen, new_idx), "promote")
            self.metrics.inc("compactions_total")
            return gen
        finally:
            self._compacting = False

    def _healthz(self) -> bytes:
        idx = self.aligner._index
        gen = getattr(idx, "generation", None)
        degraded = bool(self._last_failed_shards) or \
            (self.supervisor is not None and self.supervisor.failing)
        payload = {"status": "degraded" if degraded else "healthy",
                   "docs": self.aligner.num_docs,
                   "generation": gen,
                   "live": isinstance(idx, LiveIndex),
                   "compacting": self._compacting,
                   "failed_shards": list(self._last_failed_shards)}
        if isinstance(idx, LiveIndex):
            # compaction-pressure gauges plus the ingest-durability view:
            # wal.lag_records is what a crash right now would replay
            payload["delta_fraction"] = idx.delta_fraction
            payload["delta_age_s"] = idx.delta_age_s
            wal = idx.wal_status()
            if wal is not None:
                payload["wal"] = {"replayed": wal["replayed"],
                                  "lag_records": wal["lag_records"],
                                  "pending_records": wal["pending"],
                                  "bytes": wal["bytes"],
                                  "age_s": wal["age_s"]}
        return ok_response(payload)

    # -- HTTP plumbing -------------------------------------------------------

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        try:
            while True:
                parsed = await self._read_request(reader)
                if parsed is None:
                    break
                method, path, headers, body = parsed
                if path == "/ws" and \
                        "websocket" in headers.get("upgrade", "").lower():
                    await self._ws_session(reader, writer, headers)
                    break
                status, payload = await self._route(method, path, body)
                close = headers.get("connection", "").lower() == "close"
                retry_after = self.retry_after_s if status == 503 else None
                writer.write(_http_response(status, payload, close=close,
                                            retry_after_s=retry_after))
                await writer.drain()
                if close:
                    break
        except (asyncio.IncompleteReadError, ConnectionResetError,
                BrokenPipeError):
            pass
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError):
                pass

    async def _route(self, method: str, path: str, body: bytes
                     ) -> tuple[int, bytes]:
        if path == "/query" and method == "POST":
            return await self.handle_query(body)
        if path == "/add" and method == "POST":
            return await self.handle_add(body)
        if path == "/compact" and method == "POST":
            return await self.handle_compact()
        if path == "/metrics" and method == "GET":
            snap = self.metrics.snapshot()
            snap["fault"] = fault.stats()
            snap["store"] = store_counters()
            idx = self.aligner._index
            if isinstance(idx, LiveIndex):
                snap["wal"] = idx.wal_status()
            return 200, json.dumps(snap).encode()
        if path == "/healthz" and method == "GET":
            return 200, self._healthz()
        if path in ("/query", "/add", "/compact", "/metrics", "/healthz"):
            return 405, error_response(f"{method} not allowed on {path}",
                                       405)
        return 404, error_response(f"no such endpoint: {path}", 404)

    @staticmethod
    async def _read_request(reader):
        line = await reader.readline()
        if not line:
            return None
        try:
            method, path, _version = line.decode("latin-1").split()
        except ValueError:
            return None
        headers = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            key, _, val = h.decode("latin-1").partition(":")
            headers[key.strip().lower()] = val.strip()
        n = int(headers.get("content-length", 0) or 0)
        body = await reader.readexactly(n) if n else b""
        return method, path.split("?", 1)[0], headers, body

    # -- WebSocket (RFC 6455, text frames) -----------------------------------

    async def _ws_session(self, reader, writer, headers) -> None:
        key = headers.get("sec-websocket-key")
        if not key:
            writer.write(_http_response(
                400, error_response("missing Sec-WebSocket-Key", 400),
                close=True))
            await writer.drain()
            return
        accept = base64.b64encode(hashlib.sha1(
            (key + _WS_GUID).encode()).digest()).decode()
        writer.write(("HTTP/1.1 101 Switching Protocols\r\n"
                      "Upgrade: websocket\r\n"
                      "Connection: Upgrade\r\n"
                      f"Sec-WebSocket-Accept: {accept}\r\n\r\n").encode())
        await writer.drain()
        send_lock = asyncio.Lock()
        tasks: set[asyncio.Task] = set()

        async def answer(payload: bytes):
            try:
                _status, resp = await self.handle_query(payload)
                async with send_lock:
                    writer.write(_ws_frame(0x1, resp))
                    await writer.drain()
            except (ConnectionResetError, BrokenPipeError):
                pass

        try:
            while True:
                frame = await _ws_read_frame(reader)
                if frame is None:
                    break
                opcode, payload = frame
                if opcode == 0x8:                        # close
                    async with send_lock:
                        writer.write(_ws_frame(0x8, payload[:2]))
                        await writer.drain()
                    break
                if opcode == 0x9:                        # ping -> pong
                    async with send_lock:
                        writer.write(_ws_frame(0xA, payload))
                        await writer.drain()
                    continue
                if opcode == 0xA:                        # stray pong
                    continue
                # text (or binary) frame: one query; answer out-of-band so
                # the socket pipelines many in-flight queries
                t = asyncio.get_running_loop().create_task(answer(payload))
                tasks.add(t)
                t.add_done_callback(tasks.discard)
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
        finally:
            for t in tasks:
                t.cancel()


def _http_response(status: int, body: bytes, *, close: bool = False,
                   retry_after_s: float | None = None) -> bytes:
    head = (f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}\r\n"
            "Content-Type: application/json\r\n"
            f"Content-Length: {len(body)}\r\n")
    if retry_after_s is not None:
        # advisory backoff for admission-control 503s (RFC 9110 §10.2.3;
        # delta-seconds form, fractional values are tolerated by our client)
        head += f"Retry-After: {retry_after_s:g}\r\n"
    head += f"Connection: {'close' if close else 'keep-alive'}\r\n\r\n"
    return head.encode("latin-1") + body


def _ws_frame(opcode: int, payload: bytes) -> bytes:
    """One server->client frame (fin=1, unmasked)."""
    head = bytes([0x80 | opcode])
    n = len(payload)
    if n < 126:
        head += bytes([n])
    elif n < (1 << 16):
        head += bytes([126]) + struct.pack("!H", n)
    else:
        head += bytes([127]) + struct.pack("!Q", n)
    return head + payload


async def _ws_read_frame(reader) -> tuple[int, bytes] | None:
    """One client->server frame; unmasks, rejects fragmentation (each
    protocol message fits one frame)."""
    try:
        b0, b1 = await reader.readexactly(2)
    except asyncio.IncompleteReadError:
        return None
    fin, opcode = b0 & 0x80, b0 & 0x0F
    if not fin or opcode == 0x0:
        raise ConnectionResetError("fragmented WebSocket frames are not "
                                   "supported by this server")
    masked, n = b1 & 0x80, b1 & 0x7F
    if n == 126:
        n = struct.unpack("!H", await reader.readexactly(2))[0]
    elif n == 127:
        n = struct.unpack("!Q", await reader.readexactly(8))[0]
    mask = await reader.readexactly(4) if masked else None
    payload = await reader.readexactly(n) if n else b""
    if mask:
        payload = bytes(c ^ mask[i % 4] for i, c in enumerate(payload))
    return opcode, payload
