"""Minimal clients for the align server — test/bench plumbing, not an
SDK.

* :class:`AlignClient` — blocking, one keep-alive HTTP connection
  (stdlib ``http.client``).
* :class:`AsyncAlignClient` — asyncio, one keep-alive HTTP connection,
  requests serialized per connection (a closed-loop virtual client).
* :class:`AsyncWSClient` — asyncio WebSocket connection with pipelining:
  many queries in flight at once, correlated by the protocol's ``id``
  field (the open-loop bench driver).
"""

from __future__ import annotations

import asyncio
import base64
import hashlib
import http.client
import itertools
import json
import os
import random
import struct
import time
import uuid

from .app import _WS_GUID, _ws_read_frame


class ServerError(RuntimeError):
    def __init__(self, status: int, message: str):
        super().__init__(f"HTTP {status}: {message}")
        self.status = status


def _raise_for(status: int, payload: dict):
    if status != 200:
        raise ServerError(status, payload.get("error", "unknown error"))


def _query_body(text, theta, options=None, deadline_ms=None, id=None
                ) -> dict:
    body = {"text": text if isinstance(text, str) else
            [int(t) for t in text], "theta": theta}
    if options is not None:
        body["options"] = options if isinstance(options, dict) \
            else options.to_dict()
    if deadline_ms is not None:
        body["deadline_ms"] = deadline_ms
    if id is not None:
        body["id"] = id
    return body


class AlignClient:
    """Blocking client over one keep-alive HTTP connection.

    ``retries`` (default 0 — off) arms bounded retry with exponential
    backoff + jitter: a 503 (admission control shedding load, honoring
    its ``Retry-After`` hint) or a dropped connection (server restart)
    is retried up to ``retries`` times.  Queries are always safe to
    retry; ``add`` is retried only under a ``request_id`` (one is
    auto-generated when retries are armed), which the server echoes into
    the WAL record and dedups within the un-compacted window — a
    connection lost mid-request no longer leaves the add's effect
    unknown.  ``compact`` is never retried (a replay would fold the next
    delta too).
    """

    def __init__(self, host: str = "127.0.0.1", port: int = 0,
                 timeout: float = 30.0, retries: int = 0,
                 backoff_s: float = 0.1, backoff_max_s: float = 2.0):
        self._conn = http.client.HTTPConnection(host, port, timeout=timeout)
        self.retries = retries
        self.backoff_s = backoff_s
        self.backoff_max_s = backoff_max_s

    def close(self) -> None:
        self._conn.close()

    def __enter__(self) -> "AlignClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _request_full(self, method: str, path: str,
                      body: dict | None = None
                      ) -> tuple[int, dict, dict]:
        payload = json.dumps(body).encode() if body is not None else b""
        self._conn.request(method, path, body=payload,
                           headers={"Content-Type": "application/json"})
        resp = self._conn.getresponse()
        headers = {k.lower(): v for k, v in resp.getheaders()}
        return resp.status, json.loads(resp.read()), headers

    def _request(self, method: str, path: str, body: dict | None = None
                 ) -> tuple[int, dict]:
        status, payload, _ = self._request_full(method, path, body)
        return status, payload

    def _request_retrying(self, method: str, path: str, body: dict,
                          *, can_retry: bool) -> tuple[int, dict]:
        """Bounded-retry request: 503s back off (honoring Retry-After),
        dropped connections reconnect clean.  ``can_retry=False``
        degrades to a single attempt (non-idempotent request)."""
        retries = self.retries if can_retry else 0
        for attempt in range(retries + 1):
            retry_after = None
            try:
                status, payload, headers = self._request_full(
                    method, path, body)
            except ConnectionError:
                # reset/refused/broken-pipe, including http.client's
                # RemoteDisconnected (a ConnectionResetError): reset the
                # keep-alive connection so the retry reconnects clean
                if attempt >= retries:
                    raise
                self._conn.close()
            else:
                if status != 503 or attempt >= retries:
                    return status, payload
                ra = headers.get("retry-after")
                if ra is not None:
                    try:
                        retry_after = float(ra)
                    except ValueError:
                        retry_after = None
            delay = min(self.backoff_max_s, self.backoff_s * 2 ** attempt)
            delay *= 0.5 + 0.5 * random.random()    # full-jitter half-band
            if retry_after is not None:
                delay = max(delay, min(retry_after, self.backoff_max_s))
            time.sleep(delay)
        raise AssertionError("unreachable")  # loop returns or raises

    def query(self, text, theta: float, *, options=None, deadline_ms=None
              ) -> dict:
        """Returns the response's ``result`` dict
        (``QueryResult.to_dict()`` shape — rebuild with
        ``QueryResult.from_dict`` if you want the typed object)."""
        body = _query_body(text, theta, options=options,
                           deadline_ms=deadline_ms)
        status, payload = self._request_retrying("POST", "/query", body,
                                                 can_retry=True)
        _raise_for(status, payload)
        return payload["result"]

    def add(self, text, *, request_id: str | None = None) -> int:
        """Index one document; returns its doc id.  A ``request_id``
        makes the call idempotent server-side (replays within the
        un-compacted window return the original id), so when retries are
        armed and none was given one is auto-generated — without an id
        the request falls back to a single attempt."""
        if request_id is None and self.retries > 0:
            request_id = uuid.uuid4().hex
        body = {"text": text if isinstance(text, str) else
                [int(t) for t in text]}
        if request_id is not None:
            body["request_id"] = request_id
        status, payload = self._request_retrying(
            "POST", "/add", body, can_retry=request_id is not None)
        _raise_for(status, payload)
        return payload["doc_id"]

    def compact(self) -> int:
        status, payload = self._request("POST", "/compact", {})
        _raise_for(status, payload)
        return payload["generation"]

    def metrics(self) -> dict:
        return self._request("GET", "/metrics")[1]

    def healthz(self) -> dict:
        return self._request("GET", "/healthz")[1]


class AsyncAlignClient:
    """One keep-alive HTTP connection; requests serialized on it (a
    closed-loop virtual client issues one request at a time anyway)."""

    def __init__(self, reader, writer):
        self._reader = reader
        self._writer = writer
        self._lock = asyncio.Lock()

    @classmethod
    async def connect(cls, host: str, port: int) -> "AsyncAlignClient":
        reader, writer = await asyncio.open_connection(host, port)
        return cls(reader, writer)

    async def close(self) -> None:
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass

    async def request(self, method: str, path: str,
                      body: dict | None = None) -> tuple[int, dict]:
        payload = json.dumps(body).encode() if body is not None else b""
        head = (f"{method} {path} HTTP/1.1\r\n"
                "Host: align\r\n"
                "Content-Type: application/json\r\n"
                f"Content-Length: {len(payload)}\r\n\r\n").encode()
        async with self._lock:
            self._writer.write(head + payload)
            await self._writer.drain()
            status_line = await self._reader.readline()
            status = int(status_line.split()[1])
            n = 0
            while True:
                h = await self._reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                if h.lower().startswith(b"content-length:"):
                    n = int(h.split(b":", 1)[1])
            body_bytes = await self._reader.readexactly(n) if n else b"{}"
        return status, json.loads(body_bytes)

    async def query(self, text, theta: float, *, options=None,
                    deadline_ms=None) -> tuple[int, dict]:
        """Returns (status, payload) — the bench wants non-200s as data,
        not exceptions."""
        return await self.request(
            "POST", "/query", _query_body(text, theta, options=options,
                                          deadline_ms=deadline_ms))

    async def add(self, text) -> int:
        status, payload = await self.request(
            "POST", "/add", {"text": text if isinstance(text, str) else
                             [int(t) for t in text]})
        _raise_for(status, payload)
        return payload["doc_id"]

    async def compact(self) -> int:
        status, payload = await self.request("POST", "/compact", {})
        _raise_for(status, payload)
        return payload["generation"]

    async def metrics(self) -> dict:
        return (await self.request("GET", "/metrics"))[1]


class AsyncWSClient:
    """WebSocket client with pipelining: ``submit`` returns a future, a
    reader task correlates responses by the echoed ``id``."""

    def __init__(self, reader, writer):
        self._reader = reader
        self._writer = writer
        self._pending: dict[str, asyncio.Future] = {}
        self._ids = itertools.count()
        self._reader_task: asyncio.Task | None = None

    @classmethod
    async def connect(cls, host: str, port: int) -> "AsyncWSClient":
        reader, writer = await asyncio.open_connection(host, port)
        key = base64.b64encode(os.urandom(16)).decode()
        writer.write((f"GET /ws HTTP/1.1\r\nHost: {host}\r\n"
                      "Upgrade: websocket\r\nConnection: Upgrade\r\n"
                      f"Sec-WebSocket-Key: {key}\r\n"
                      "Sec-WebSocket-Version: 13\r\n\r\n").encode())
        await writer.drain()
        status = await reader.readline()
        if b"101" not in status:
            raise ConnectionError(f"WebSocket upgrade refused: {status!r}")
        expect = base64.b64encode(hashlib.sha1(
            (key + _WS_GUID).encode()).digest()).decode()
        accepted = False
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            if h.lower().startswith(b"sec-websocket-accept:"):
                accepted = h.split(b":", 1)[1].strip().decode() == expect
        if not accepted:
            raise ConnectionError("bad Sec-WebSocket-Accept")
        self = cls(reader, writer)
        self._reader_task = asyncio.get_running_loop().create_task(
            self._read_loop())
        return self

    async def close(self) -> None:
        if self._reader_task is not None:
            self._reader_task.cancel()
            try:
                await self._reader_task
            except asyncio.CancelledError:
                pass
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError):
            pass
        for fut in self._pending.values():
            if not fut.done():
                fut.cancel()
        self._pending.clear()

    def submit(self, text, theta: float, *, options=None,
               deadline_ms=None) -> asyncio.Future:
        """Fire one query; the future resolves to the response payload
        dict (``ok``/``result`` or ``ok: false``/``status``)."""
        rid = f"q{next(self._ids)}"
        fut = asyncio.get_running_loop().create_future()
        self._pending[rid] = fut
        payload = json.dumps(_query_body(
            text, theta, options=options, deadline_ms=deadline_ms,
            id=rid)).encode()
        self._writer.write(_masked_frame(0x1, payload))
        return fut

    async def _read_loop(self) -> None:
        try:
            while True:
                frame = await _ws_read_frame(self._reader)
                if frame is None:
                    break
                opcode, payload = frame
                if opcode != 0x1:
                    continue
                msg = json.loads(payload)
                fut = self._pending.pop(msg.get("id"), None)
                if fut is None and not msg.get("ok", False):
                    # errors lose the id (the server echoes it only on
                    # success); resolve the oldest pending query
                    if self._pending:
                        fut = self._pending.pop(next(iter(self._pending)))
                if fut is not None and not fut.done():
                    fut.set_result(msg)
        except (asyncio.IncompleteReadError, ConnectionResetError):
            pass


def _masked_frame(opcode: int, payload: bytes) -> bytes:
    """One client->server frame (fin=1, masked, as RFC 6455 requires)."""
    head = bytes([0x80 | opcode])
    n = len(payload)
    if n < 126:
        head += bytes([0x80 | n])
    elif n < (1 << 16):
        head += bytes([0x80 | 126]) + struct.pack("!H", n)
    else:
        head += bytes([0x80 | 127]) + struct.pack("!Q", n)
    mask = os.urandom(4)
    return head + mask + bytes(c ^ mask[i % 4]
                               for i, c in enumerate(payload))
