"""Serve-path observability: counters, log-bucket histograms, per-stage
timing — everything ``/metrics`` reports and ``bench_serve`` asserts on.

All updates take one small lock (they happen on the event loop and the
engine thread); ``snapshot()`` returns a plain JSON-able dict.
"""

from __future__ import annotations

import threading

_NBUCKETS = 64
_FIRST_EDGE_S = 1e-5        # 10 µs; edges double per bucket → ~58 s cap


class Histogram:
    """Fixed log2-bucket histogram of positive values (seconds, counts).

    Bucket ``i`` holds values in ``(edge * 2**(i-1), edge * 2**i]`` with
    bucket 0 catching everything ``<= edge``; quantiles are read as the
    upper edge of the bucket where the cumulative count crosses — a <=2x
    overestimate by construction, which is exactly the conservative side
    a latency SLO wants.
    """

    def __init__(self, first_edge: float = _FIRST_EDGE_S):
        self.first_edge = first_edge
        self.counts = [0] * _NBUCKETS
        self.total = 0
        self.sum = 0.0
        self.max = 0.0

    def add(self, v: float) -> None:
        b = 0
        edge = self.first_edge
        while v > edge and b < _NBUCKETS - 1:
            edge *= 2.0
            b += 1
        self.counts[b] += 1
        self.total += 1
        self.sum += v
        if v > self.max:
            self.max = v

    def quantile(self, q: float) -> float:
        if not self.total:
            return 0.0
        target = q * self.total
        seen = 0
        edge = self.first_edge
        for c in self.counts:
            seen += c
            if seen >= target:
                return min(edge, self.max)
            edge *= 2.0
        return self.max

    def summary(self) -> dict:
        return {"count": self.total,
                "mean": self.sum / self.total if self.total else 0.0,
                "p50": self.quantile(0.50),
                "p99": self.quantile(0.99),
                "max": self.max}


class ServeMetrics:
    """All serve-path counters and histograms, behind one lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters = {
            "requests_total": 0,       # queries admitted
            "responses_total": 0,      # queries answered with results
            "rejected_total": 0,       # 503: queue at capacity
            "expired_total": 0,        # 504: deadline passed before probe
            "errors_total": 0,         # engine-side exceptions
            "adds_total": 0,
            "adds_deduped_total": 0,   # retried request_ids answered from
            #                            the dedup window, nothing indexed
            "wal_group_commits_total": 0,   # durable-ack flush barriers
            "compactions_total": 0,
            "batches_total": 0,        # find_batch calls issued
            "degraded_total": 0,       # partial (shard-skipping) responses
            "supervisor_compactions_total": 0,
            "supervisor_retries_total": 0,   # failed background attempts
            "supervisor_failures_total": 0,  # gave up past max_retries
            "pruned_generations_total": 0,   # store dirs reclaimed
        }
        self.latency = Histogram()         # enqueue -> response, seconds
        self.queue_wait = Histogram()      # enqueue -> batch dispatch
        self.batch_size = Histogram(first_edge=1.0)
        # adds acknowledged per durable flush — how well group commit is
        # amortizing fsyncs (mean ~1 means per-record fsync cost)
        self.wal_group_commit = Histogram(first_edge=1.0)
        self.stage_seconds = {"sketch": 0.0, "probe": 0.0, "sweep": 0.0,
                              "queue_wait": 0.0}

    def inc(self, name: str, by: int = 1) -> None:
        with self._lock:
            self.counters[name] += by

    def observe_batch(self, size: int, queue_waits, stage: dict) -> None:
        """One dispatched batch: its occupancy, each member's queue wait,
        and the engine's per-stage seconds for the ``find_batch`` call."""
        with self._lock:
            self.counters["batches_total"] += 1
            self.batch_size.add(float(size))
            for w in queue_waits:
                self.queue_wait.add(w)
                self.stage_seconds["queue_wait"] += w
            for key in ("sketch", "probe", "sweep"):
                self.stage_seconds[key] += stage.get(key, 0.0)

    def observe_latency(self, seconds: float) -> None:
        with self._lock:
            self.counters["responses_total"] += 1
            self.latency.add(seconds)

    def observe_group_commit(self, size: int) -> None:
        """One write group made durable: ``size`` adds shared the flush."""
        with self._lock:
            self.counters["wal_group_commits_total"] += 1
            self.wal_group_commit.add(float(size))

    def snapshot(self) -> dict:
        with self._lock:
            return {"counters": dict(self.counters),
                    "latency_s": self.latency.summary(),
                    "queue_wait_s": self.queue_wait.summary(),
                    "batch_size": self.batch_size.summary(),
                    "wal_group_commit": self.wal_group_commit.summary(),
                    "stage_seconds": dict(self.stage_seconds)}
