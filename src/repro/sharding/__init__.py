from .rules import (RULES, constrain, resolve_spec, tree_shardings,
                    tree_specs)

__all__ = ["RULES", "resolve_spec", "tree_specs", "tree_shardings",
           "constrain"]
