"""Logical-axis -> physical-mesh sharding rules.

Every parameter / activation dimension carries a *logical* axis name
("embed", "q_feat", "experts", ...).  A rule table maps each logical name to
an ordered list of candidate mesh-axis tuples; the resolver picks the first
candidate whose mesh axes (i) exist in the mesh, (ii) are not already used by
another dimension of the same tensor, and (iii) evenly divide the dimension.
This gives one declarative place where DP/FSDP/TP/EP decisions live and makes
every (arch x mesh) combination well-defined even when head/expert counts do
not divide the mesh axis (e.g. mixtral's 8 experts on a 16-wide model axis
fall back to ffn sharding; qwen1.5's 20 heads fall back to head_dim).
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Ordered candidates per logical axis.  () = explicit "replicate".
# "fsdp" below expands to the data axis (and optionally the pod axis for
# optimizer state -- see expand_fsdp).
RULES: dict[str, list[tuple[str, ...]]] = {
    # -- batch / tokens ----------------------------------------------------
    "batch":     [("pod", "data"), ("data",)],
    "seq":       [()],            # sequence replicated by default (SP is opt-in)
    "seq_sp":    [("model",), ()],  # sequence-parallel saved activations
    "seq_kv":    [("model",), ()],  # decode KV cache: split-KV (flash-decode)
    # -- embedding / vocab -------------------------------------------------
    "vocab":     [("model",), ()],
    "embed":     [("fsdp",), ()],            # FSDP shard of the model dim
    "embed_act": [()],                        # activation model-dim: replicated
    # -- attention ---------------------------------------------------------
    "q_feat":    [("model",), ()],            # flattened n_heads*head_dim
    "kv_feat":   [("model",), ()],            # flattened n_kv*head_dim
    "heads":     [("model",), ()],
    "kv_heads":  [("model",), ()],
    "head_dim":  [("model",), ()],
    # -- mlp / moe ----------------------------------------------------------
    "ffn":       [("model",), ()],
    "experts":   [("model",), ()],
    "moe_ff":    [("model",), ()],            # claimed only if experts failed
    # -- ssm ----------------------------------------------------------------
    "ssm_inner": [("model",), ()],
    "ssm_feat":  [("model",), ()],            # fused in_proj output segments
    "ssm_heads": [("model",), ()],
    "ssm_state": [()],
    "conv":      [()],
    "dt_rank":   [()],
    # -- misc ---------------------------------------------------------------
    "layers":    [()],
    None:        [()],
}

# Dims claimed earlier win mesh axes; tensor-parallel feature dims go first
# so e.g. (embed, ffn) gives ffn the model axis and embed the fsdp axis.
PRIORITY: dict[str, int] = {
    "vocab": 0, "q_feat": 0, "kv_feat": 0, "heads": 0, "ffn": 0,
    "experts": 0, "ssm_inner": 0, "ssm_feat": 0, "ssm_heads": 0,
    "batch": 0, "seq_sp": 0, "seq_kv": 0,
    "moe_ff": 1, "kv_heads": 1, "head_dim": 1,
    "embed": 2, "embed_act": 2, "seq": 2,
}


def expand_fsdp(axes: tuple[str, ...], mesh: Mesh,
                fsdp_axes: tuple[str, ...]) -> tuple[str, ...]:
    out: list[str] = []
    for a in axes:
        if a == "fsdp":
            out.extend(ax for ax in fsdp_axes if ax in mesh.shape)
        else:
            out.append(a)
    return tuple(out)


def resolve_spec(shape: Sequence[int], logical: Sequence[str | None],
                 mesh: Mesh, *, fsdp_axes: tuple[str, ...] = ("data",),
                 overrides: dict[str, list[tuple[str, ...]]] | None = None,
                 ) -> P:
    """Resolve one tensor's logical axes to a PartitionSpec on `mesh`."""
    assert len(shape) == len(logical), (shape, logical)
    rules = dict(RULES)
    if overrides:
        rules.update(overrides)
    order = sorted(range(len(shape)),
                   key=lambda i: (PRIORITY.get(logical[i], 3), i))
    assignment: list[tuple[str, ...] | None] = [None] * len(shape)
    taken: set[str] = set()
    for i in order:
        name = logical[i]
        for cand in rules.get(name, [()]):
            axes = expand_fsdp(cand, mesh, fsdp_axes)
            if not axes:
                assignment[i] = ()
                break
            if any(a not in mesh.shape or a in taken for a in axes):
                continue
            div = math.prod(mesh.shape[a] for a in axes)
            if shape[i] % div == 0:
                assignment[i] = axes
                taken.update(axes)
                break
        if assignment[i] is None:
            assignment[i] = ()
    return P(*[a if len(a or ()) != 1 else a[0]
               for a in [tuple(x) if x else None for x in assignment]])


def tree_specs(abstract: dict, mesh: Mesh, *,
               fsdp_axes: tuple[str, ...] = ("data",),
               overrides=None):
    """Map a pytree of ParamDesc -> pytree of PartitionSpec."""
    return jax.tree.map(
        lambda d: resolve_spec(d.shape, d.axes, mesh,
                               fsdp_axes=fsdp_axes, overrides=overrides),
        abstract, is_leaf=lambda x: hasattr(x, "axes"))


def tree_shardings(abstract: dict, mesh: Mesh, **kw):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(abstract, mesh, **kw))


def constrain(x, mesh: Mesh, *logical: str | None, **kw):
    """with_sharding_constraint by logical axis names (inside jit)."""
    spec = resolve_spec(x.shape, logical, mesh, **kw)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
