"""Serving launcher: batched KV-cache decode + alignment-checked outputs.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-1.2b --smoke

Serves batched greedy decoding against a prefill cache and, when
--memcheck is set, aligns every generated sequence against a training-corpus
index (the paper's memorization-analysis serving mode).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-1.2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--memcheck", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import RunFlags, decode_step, init_params, prefill

    if jax.default_backend() != "tpu" and not args.smoke:
        raise SystemExit("no TPU runtime: pass --smoke")
    cfg = get_config(args.arch).reduced(vocab=2048) if args.smoke \
        else get_config(args.arch)
    cfg = dataclasses.replace(cfg, compute_dtype="float32") if args.smoke \
        else cfg
    flags = RunFlags(moe_mode="dense" if args.smoke else "scatter",
                     remat_policy="none", q_chunk=0, scan_chunk=64)
    params = init_params(cfg, jax.random.PRNGKey(0))

    B, P, G = args.batch, args.prompt_len, args.gen
    max_seq = P + G
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 4, cfg.vocab)
    t0 = time.time()
    logits, cache = prefill(params, cfg, tokens=prompts, max_seq=max_seq,
                            flags=flags)
    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg,
                                                    flags=flags),
                   donate_argnums=(1,))
    nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [nxt]
    for t in range(G - 1):
        logits, cache = step(params, cache, nxt, jnp.int32(P + t))
        nxt = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(nxt)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"served {B} requests x {G} tokens in {dt:.2f}s "
          f"({B * G / dt:.1f} tok/s, batch decode)")

    if args.memcheck:
        import tempfile

        from repro.api import Aligner
        from repro.data import synthetic_corpus, HashWordTokenizer
        tok = HashWordTokenizer(vocab=cfg.vocab)
        corpus = tok.encode_batch(synthetic_corpus(100, seed=0))
        aligner = Aligner.build(corpus, similarity="multiset", seed=2,
                                k=16).freeze()   # CSR serving layout
        t1 = time.time()
        queries = [np.asarray(gen[b], np.int64) for b in range(B)]
        results = aligner.find_batch(queries, 0.5)
        flagged = sum(1 for r in results if r)
        print(f"memorization scan: {flagged}/{B} generations align with the "
              f"training corpus at theta=0.5 "
              f"(batched frozen-index scan, {time.time() - t1:.3f}s)")

        # live serving: ingest the generations online (delta index, no
        # rebuild), then fold them into a promoted store generation and
        # check the answers ride through the compaction unchanged
        with tempfile.TemporaryDirectory() as store:
            aligner.save(store)
            live = Aligner.load(store, live=True)
            t2 = time.time()
            for q in queries:
                live.add(q)
            pre = live.find_batch(queries, 0.5)
            live.compact()
            post = live.find_batch(queries, 0.5)
            assert [[h.text_id for h in r] for r in pre] == \
                [[h.text_id for h in r] for r in post], \
                "compaction changed live serving results"
            gen_no = live._index.generation
            self_hits = sum(1 for r in post if r)
            print(f"live serve: ingested {B} generations online, compacted "
                  f"to v{gen_no:06d} in {time.time() - t2:.3f}s; "
                  f"{self_hits}/{B} generations now self-align ({live!r})")


if __name__ == "__main__":
    main()
