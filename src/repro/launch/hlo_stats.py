"""Parse collective traffic + roofline terms out of a compiled HLO module.

`compiled.cost_analysis()` has no collective-bytes entry, so we regex the
post-SPMD optimized HLO: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute result shape (already *per-device* after
partitioning) is converted to wire bytes with the standard ring-algorithm
factors:

    all-gather          out_bytes * (G-1)/G        (out is the gathered size)
    reduce-scatter      out_bytes * (G-1)           (out is the scattered size)
    all-reduce          out_bytes * 2(G-1)/G
    all-to-all          out_bytes * (G-1)/G
    collective-permute  out_bytes

with G the replica-group size parsed from `replica_groups`.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^\s]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0          # per-device bytes on ICI links
    op_bytes: dict = field(default_factory=dict)
    op_count: dict = field(default_factory=dict)

    def add(self, op: str, b: float):
        self.wire_bytes += b
        self.op_bytes[op] = self.op_bytes.get(op, 0.0) + b
        self.op_count[op] = self.op_count.get(op, 0) + 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":          # counted at -start
            continue
        out_bytes = _shape_bytes(type_str)
        g = 1
        mg = _GROUPS_RE.search(line)
        if mg:
            g = mg.group(1).count(",") + 1
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))
        if op == "all-gather":
            wire = out_bytes * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = out_bytes * (g - 1)
        elif op == "all-reduce":
            wire = out_bytes * 2 * (g - 1) / max(g, 1)
        elif op == "all-to-all":
            wire = out_bytes * (g - 1) / max(g, 1)
        else:                          # collective-permute
            wire = out_bytes
        stats.add(op, wire)
    return stats


# TPU v5e-class hardware model (per assignment).
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~per-chip injection)


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   wire_bytes_per_dev: float) -> dict:
    t_c = flops_per_dev / PEAK_FLOPS
    t_m = hbm_bytes_per_dev / HBM_BW
    t_n = wire_bytes_per_dev / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_n),
              key=lambda kv: kv[1])[0]
    total = max(t_c, t_m, t_n)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "bottleneck": dom,
        "roofline_fraction": (t_c / total) if total else 0.0,
    }
