import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first
# init.  512 placeholder host devices back the 2x16x16 production mesh.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs import (ARCH_IDS, SHAPES,  # noqa: E402
                           TRAIN_MICROBATCHES, arch_cells, get_config)
from repro.launch.hlo_stats import roofline_terms  # noqa: E402
from repro.launch.hlo_walk import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import cell_arguments  # noqa: E402
from repro.models import RunFlags  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.train import (OptConfig, make_prefill_step,  # noqa: E402
                         make_serve_step, make_train_step)


def flags_for(cfg: ModelConfig, shape_name: str,
              overrides: dict | None = None) -> RunFlags:
    # shardmap EP when E and S divide the TP width (34x on qwen3-moe
    # train_4k's dominant term, §Perf cell A); falls back to GSPMD scatter.
    kw: dict = {"moe_mode": "shardmap"}
    if shape_name == "train_4k":
        kw["remat_policy"] = "full"
        # Megatron-SP: shard the scanned layer carry over `model` so saved
        # activations are 1/16th per device (big dense archs need it).
        kw["seq_shard_carry"] = cfg.d_model >= 4096
    if shape_name in ("prefill_32k",):
        kw["remat_policy"] = "none"
        kw["q_chunk"] = 2048
    if shape_name in ("decode_32k", "long_500k"):
        kw["remat_policy"] = "none"
    kw.update(overrides or {})
    return RunFlags(**kw)


def build_step(cfg, shape, mesh, flags, microbatches):
    """Returns (jitted_fn, example_args as shapedtypes)."""
    args = cell_arguments(cfg, shape, mesh)
    p_sds, p_sh = args["params"]
    b_sds, b_sh = args["batch"]
    if shape.phase == "train":
        o_sds, o_sh = args["opt"]
        fn = make_train_step(cfg, OptConfig(), mesh, flags, microbatches)
        jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                      out_shardings=(p_sh, o_sh, None),
                      donate_argnums=(0, 1))
        return jfn, (p_sds, o_sds, b_sds)
    if shape.phase == "prefill":
        c_sds, c_sh = args["cache"]
        fn = make_prefill_step(cfg, mesh, flags, max_seq=shape.seq_len)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh),
                      out_shardings=(None, c_sh))
        return jfn, (p_sds, b_sds)
    # decode
    c_sds, c_sh = args["cache"]
    fn = make_serve_step(cfg, mesh, flags)
    from jax.sharding import NamedSharding, PartitionSpec as P
    tok_sh = b_sh["tokens"]
    pos_sh = NamedSharding(mesh, P())
    jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                  out_shardings=(None, c_sh), donate_argnums=(1,))
    return jfn, (p_sds, c_sds, b_sds["tokens"], b_sds["pos"])


def model_flops(cfg: ModelConfig, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode: D = batch tokens * 1."""
    n = cfg.active_param_count()
    if shape.phase == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.phase == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             flag_overrides: dict | None = None,
             microbatches: int | None = None,
             serve_dtype: str = "bfloat16",
             train_dtype: str = "bfloat16") -> dict:
    """train_dtype bf16 = bf16-at-rest params + f32 master in the optimizer
    (§Perf cell C); pass train_dtype='float32' to measure the f32 baseline."""
    import dataclasses
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.phase != "train" and serve_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=serve_dtype)
    if shape.phase == "train" and train_dtype:
        cfg = dataclasses.replace(cfg, param_dtype=train_dtype)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    flags = flags_for(cfg, shape_name, flag_overrides)
    mb = microbatches if microbatches is not None else (
        TRAIN_MICROBATCHES.get(arch, 1) if shape.phase == "train" else 1)

    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "phase": shape.phase,
        "microbatches": mb, "flags": dataclasses.asdict(flags),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    n_dev = mesh.size
    t0 = time.time()
    with mesh:
        jfn, sds = build_step(cfg, shape, mesh, flags, mb)
        lowered = jfn.lower(*sds)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    ca = compiled.cost_analysis() or {}
    flops_dev = float(ca.get("flops", 0.0))
    hbm_dev = float(ca.get("bytes accessed", 0.0))
    rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                            if isinstance(v, (int, float))
                            and k in ("flops", "bytes accessed",
                                      "transcendentals", "utilization")}
    ma = compiled.memory_analysis()
    if ma is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                rec[k] = int(v)
        args_b = rec.get("argument_size_in_bytes", 0)
        alias_b = rec.get("alias_size_in_bytes", 0)
        out_b = rec.get("output_size_in_bytes", 0)
        rec["live_bytes_per_device"] = int(
            args_b + rec.get("temp_size_in_bytes", 0) + out_b - alias_b)

    hlo = compiled.as_text()
    rec["hlo_chars"] = len(hlo)
    # Call-graph walk with while-loop trip-count multiplication: XLA's own
    # cost_analysis counts scan bodies exactly once (recorded above under
    # cost_analysis for comparison).
    tot = analyze_hlo(hlo)
    rec["collective"] = {
        "wire_bytes_per_device": tot.wire_bytes,
        "op_bytes": tot.coll_bytes,
        "op_count": tot.coll_count,
        "dynamic_whiles": tot.dynamic_whiles,
    }
    flops_dev = tot.flops
    hbm_dev = tot.bytes
    rec["flops_per_device"] = flops_dev
    rec["hbm_bytes_per_device"] = hbm_dev
    rec["transcendentals_per_device"] = tot.transcendentals
    rec["roofline"] = roofline_terms(flops_dev, hbm_dev, tot.wire_bytes)
    mf = model_flops(cfg, shape)
    rec["model_flops_global"] = mf
    hw_flops_global = flops_dev * n_dev
    rec["hlo_flops_global"] = hw_flops_global
    rec["model_vs_hlo_flops"] = (mf / hw_flops_global) if hw_flops_global else 0.0
    # MFU-at-roofline: model-useful flops / (chips * peak * bottleneck time)
    tot = max(rec["roofline"]["compute_s"], rec["roofline"]["memory_s"],
              rec["roofline"]["collective_s"])
    rec["model_flops_util"] = (
        mf / (n_dev * 197e12 * tot) if tot else 0.0)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--train-dtype", default="bfloat16",
                    help="float32 = paper-faithful f32-params baseline; "
                         "bfloat16 = bf16-at-rest + f32 master (optimized)")
    ap.add_argument("--flag", action="append", default=[],
                    help="RunFlags override key=value (e.g. remat_policy=dots)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.flag:
        k, v = kv.split("=", 1)
        overrides[k] = (v if not v.replace("-", "").isdigit() else int(v)) \
            if v not in ("True", "False") else v == "True"

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in arch_cells(a)]
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    n_ok = n_fail = n_skip = 0
    for arch, shape_name in cells:
        skip = shape_name.endswith(":skip")
        shape_name = shape_name.split(":")[0]
        for mesh_kind in meshes:
            name = f"{arch}__{shape_name}__{mesh_kind}"
            path = outdir / f"{name}.json"
            if skip:
                path.write_text(json.dumps({
                    "arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "status": "skipped",
                    "reason": "pure full-attention arch: 500k context is "
                              "quadratic in prefill; decode-only cell not "
                              "assigned (DESIGN.md §Arch-applicability)"},
                    indent=1))
                print(f"[skip] {name}")
                n_skip += 1
                continue
            if path.exists() and not args.force:
                try:
                    old = json.loads(path.read_text())
                    if old.get("status") == "ok":
                        print(f"[cached] {name}")
                        n_ok += 1
                        continue
                except Exception:
                    pass
            t0 = time.time()
            try:
                rec = run_cell(arch, shape_name, mesh_kind,
                               overrides or None, args.microbatches,
                               train_dtype=args.train_dtype)
                rec["status"] = "ok"
                n_ok += 1
                r = rec["roofline"]
                print(f"[ok]   {name}  lower={rec['lower_s']}s "
                      f"compile={rec['compile_s']}s "
                      f"Tc={r['compute_s']:.3f}s Tm={r['memory_s']:.3f}s "
                      f"Tn={r['collective_s']:.3f}s -> {r['bottleneck']}",
                      flush=True)
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                       "status": "error", "error": str(e)[:2000],
                       "traceback": traceback.format_exc()[-4000:],
                       "seconds": round(time.time() - t0, 1)}
                n_fail += 1
                print(f"[FAIL] {name}: {str(e)[:300]}", flush=True)
            path.write_text(json.dumps(rec, indent=1, default=str))
    print(f"done: ok={n_ok} fail={n_fail} skip={n_skip}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
