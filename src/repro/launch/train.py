"""Production training launcher.

    # real pod (per-host; JAX distributed init from the env):
    python -m repro.launch.train --arch llama3-405b --shape train_4k \
        --mesh single --steps 1000 --ckpt gs://.../ckpt

    # local CPU smoke (reduced config, host mesh):
    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke

The launcher builds the production mesh, resolves shardings from the rules
table, places/initializes state, and drives jit-compiled train steps with
checkpoint/auto-resume.  On CPU (no TPU runtime) --smoke is required.
"""

from __future__ import annotations

import argparse

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the local host mesh")
    ap.add_argument("--dedup", action="store_true")
    args = ap.parse_args()

    from repro.configs import TRAIN_MICROBATCHES, get_config
    from repro.launch.mesh import TPU_XLA_FLAGS, make_production_mesh
    from repro.train import OptConfig
    from repro.train.loop import Trainer, TrainerConfig

    if jax.default_backend() == "tpu":
        jax.distributed.initialize()
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        cfg = get_config(args.arch)
        print(f"pod mesh {dict(mesh.shape)}; XLA flags: {TPU_XLA_FLAGS}")
    else:
        if not args.smoke:
            raise SystemExit("no TPU runtime detected: pass --smoke for a "
                             "reduced local run, or use launch/dryrun.py to "
                             "validate the pod configuration")
        mesh = None
        cfg = get_config(args.arch).reduced(vocab=2048)

    tc = TrainerConfig(
        steps=args.steps, batch_size=8 if args.smoke else 256,
        seq_len=128 if args.smoke else 4096,
        ckpt_dir=args.ckpt, ckpt_every=50 if args.ckpt else 0,
        microbatches=TRAIN_MICROBATCHES.get(args.arch, 1)
        if not args.smoke else 1,
        dedup_theta=0.55 if args.dedup else 0.0)
    out = Trainer(cfg, tc, ocfg=OptConfig(), mesh=mesh).run()
    print(f"done: loss {out['first_loss']:.4f} -> {out['final_loss']:.4f} "
          f"in {out['wall_s']:.1f}s; dedup={out['dedup']}")


if __name__ == "__main__":
    main()
