"""Call-graph walker over optimized (post-SPMD) HLO text.

Why: `compiled.cost_analysis()` counts each while-loop *body once*, so any
scan-based program (layers, microbatches, query chunks) is undercounted by
the trip count.  This walker parses the module into computations, resolves
while-loop trip counts from their condition computations (scan lowers to
`compare(i, constant(N)), direction=LT`), and rolls up:

  * flops        -- dot_general exactly (2*M*N*K*batch), elementwise +
                    transcendentals at 1/elem
  * hbm bytes    -- per executed instruction: operand + result bytes
                    (fusions opaque: their operands/results only), the same
                    convention XLA's own cost model uses
  * collectives  -- wire bytes with ring-model factors and replica-group
                    sizes, correctly multiplied inside loop bodies

All shapes in the partitioned module are already per-device, so every total
is per-device.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->\s*.+\{\s*$")
_INSTR_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TF_RE = re.compile(r"(?:true|false)_computation=%?([\w\.\-]+)")
_CONST_INT_RE = re.compile(r"=\s*s(?:32|64)\[\]\s+constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_TRANSCENDENTAL = {
    "exponential", "exp", "log", "log-plus-one", "exponential-minus-one",
    "tanh", "rsqrt", "sqrt", "power", "sine", "cosine", "logistic", "erf",
    "cbrt", "atan2", "tan",
}
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "remainder", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "sign", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "convert", "is-finite",
}
_NO_TRAFFIC = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "opt-barrier", "partition-id",
    "replica-id", "iota",
}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list[str]
    attrs: str
    line: str


@dataclass
class Totals:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    coll_count: dict = field(default_factory=dict)
    dynamic_whiles: int = 0

    def scaled(self, m: float) -> "Totals":
        return Totals(self.flops * m, self.transcendentals * m,
                      self.bytes * m, self.wire_bytes * m,
                      {k: v * m for k, v in self.coll_bytes.items()},
                      {k: v * m for k, v in self.coll_count.items()},
                      self.dynamic_whiles)

    def add(self, o: "Totals"):
        self.flops += o.flops
        self.transcendentals += o.transcendentals
        self.bytes += o.bytes
        self.wire_bytes += o.wire_bytes
        for k, v in o.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v
        for k, v in o.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v
        self.dynamic_whiles += o.dynamic_whiles


def _split_operands(rest: str) -> tuple[list[str], str]:
    """rest = text after the op's '(' -- split at the matching ')'."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                ops = re.findall(r"%([\w\.\-]+)", rest[:i])
                return ops, rest[i + 1:]
    return re.findall(r"%([\w\.\-]+)", rest), ""


class HloModule:
    def __init__(self, text: str, collect_top: bool = False):
        self.comps: dict[str, list[Instr]] = {}
        self.defs: dict[str, dict[str, str]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._memo: dict[tuple[str, bool], Totals] = {}
        self.collect_top = collect_top
        self.contrib: list[tuple[float, str, str, str]] = []  # bytes,op,meta

    def top_bytes(self, k=20):
        """Aggregate per-instruction byte contributions (x loop trips)."""
        agg: dict[tuple[str, str], float] = {}
        for b, op, type_str, comp in self.contrib:
            key = (op, type_str[:90])
            agg[key] = agg.get(key, 0.0) + b
        rows = sorted(agg.items(), key=lambda kv: -kv[1])[:k]
        return [(v, op, t) for (op, t), v in rows]

    def _parse(self, text: str):
        cur: str | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if line.endswith("{") and ("->" in line) and ("= " not in line.split("(")[0]):
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    self.defs[cur] = {}
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur
                    continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_HEAD_RE.match(line)
            if not m:
                continue
            name = m.group(1)
            rest = line[m.end():]
            # result type: balanced-paren tuple (may contain /*index=N*/
            # comments with '=') or a single token up to whitespace
            if rest.startswith("("):
                depth = 0
                end = 0
                for i, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            end = i + 1
                            break
                type_str, rest = rest[:end], rest[end:]
            else:
                sp = rest.find(" ")
                if sp < 0:
                    continue
                type_str, rest = rest[:sp], rest[sp:]
            mo = _OPCODE_RE.match(rest)
            if not mo:
                continue
            op = mo.group(1)
            operands, attrs = _split_operands(rest[mo.end():])
            self.comps[cur].append(
                Instr(name, type_str, op, operands, attrs, line))
            self.defs[cur][name] = type_str

    # -- helpers -----------------------------------------------------------

    def _trip_count(self, cond_comp: str) -> int | None:
        best = None
        for ins in self.comps.get(cond_comp, []):
            for mm in _CONST_INT_RE.finditer(ins.line):
                v = int(mm.group(1))
                best = v if best is None else max(best, v)
        # constants may live inside a fused compare computation
        if best is None:
            for ins in self.comps.get(cond_comp, []):
                mc = _CALLS_RE.search(ins.attrs)
                if mc:
                    inner = self._trip_count(mc.group(1))
                    if inner is not None:
                        best = inner if best is None else max(best, inner)
        return best

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        out_elems = _shape_elems(ins.type_str)
        lhs_type = self.defs[comp].get(ins.operands[0], "") if ins.operands \
            else ""
        lhs_dims = _first_shape_dims(lhs_type)
        mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
        k = 1
        if mcd and mcd.group(1) and lhs_dims:
            for d in mcd.group(1).split(","):
                di = int(d)
                if di < len(lhs_dims):
                    k *= lhs_dims[di]
        return 2.0 * out_elems * k

    def _collective(self, ins: Instr, t: Totals):
        op = ins.op.replace("-start", "")
        out_bytes = _shape_bytes(ins.type_str)
        if op == "all-gather" or op == "all-to-all" or op == "all-reduce":
            # for -start ops the result can be a (in, out) tuple: halve
            if ins.op.endswith("-start") and ins.type_str.startswith("("):
                out_bytes /= 2
        g = 1
        mg = _GROUPS_RE.search(ins.line)
        if mg:
            g = mg.group(1).count(",") + 1
        else:
            mi = _GROUPS_IOTA_RE.search(ins.line)
            if mi:
                g = int(mi.group(2))
        if op == "all-gather":
            wire = out_bytes * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            wire = out_bytes * (g - 1)
        elif op == "all-reduce":
            wire = out_bytes * 2 * (g - 1) / max(g, 1)
        elif op == "all-to-all":
            wire = out_bytes * (g - 1) / max(g, 1)
        else:
            wire = out_bytes
        t.wire_bytes += wire
        t.coll_bytes[op] = t.coll_bytes.get(op, 0.0) + wire
        t.coll_count[op] = t.coll_count.get(op, 0) + 1

    def analyze(self, comp: str | None = None,
                count_bytes: bool = True) -> Totals:
        comp = comp or self.entry
        key = (comp, count_bytes)
        if key in self._memo:
            return self._memo[key]
        t = Totals()
        self._memo[key] = t        # break cycles defensively
        for ins in self.comps.get(comp, []):
            op = ins.op
            if op in _NO_TRAFFIC:
                continue
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if not op.endswith("-done"):
                    self._collective(ins, t)
                continue
            if op == "while":
                cond = _COND_RE.search(ins.attrs)
                body = _BODY_RE.search(ins.attrs)
                mt = _TRIP_RE.search(ins.attrs)   # XLA's own annotation
                trips = int(mt.group(1)) if mt else (
                    self._trip_count(cond.group(1)) if cond else None)
                if trips is None:
                    trips = 1
                    t.dynamic_whiles += 1
                inner = Totals()
                if body:
                    inner.add(self.analyze(body.group(1), count_bytes))
                if cond:
                    inner.add(self.analyze(cond.group(1), False))
                t.add(inner.scaled(trips))
                continue
            if op == "conditional":
                branches = []
                mb = _BRANCH_RE.search(ins.attrs)
                if mb:
                    branches = re.findall(r"%?([\w\.\-]+)", mb.group(1))
                else:
                    branches = _TF_RE.findall(ins.attrs)
                if branches:
                    best = max((self.analyze(b, count_bytes)
                                for b in branches), key=lambda x: x.flops)
                    t.add(best)
                continue
            if op in ("fusion", "call", "custom-call", "reduce",
                      "reduce-window", "sort", "scatter", "select-and-scatter",
                      "map", "async-start"):
                target = _CALLS_RE.search(ins.attrs) or \
                    _TO_APPLY_RE.search(ins.attrs)
                if target and target.group(1) in self.comps:
                    inner = self.analyze(target.group(1), False)
                    if op == "reduce":
                        # applied once per input element
                        n = sum(_shape_elems(self.defs[comp].get(o, ""))
                                for o in ins.operands[:1])
                        inner = inner.scaled(max(n, 1))
                    t.flops += inner.flops
                    t.transcendentals += inner.transcendentals
                    t.wire_bytes += inner.wire_bytes
                    for k, v in inner.coll_bytes.items():
                        t.coll_bytes[k] = t.coll_bytes.get(k, 0.0) + v
                    for k, v in inner.coll_count.items():
                        t.coll_count[k] = t.coll_count.get(k, 0.0) + v
                if count_bytes:
                    if op == "fusion" and target:
                        t.bytes += self._fusion_bytes(comp, ins,
                                                      target.group(1))
                    else:
                        t.bytes += _shape_bytes(ins.type_str) + sum(
                            _shape_bytes(self.defs[comp].get(o, ""))
                            for o in ins.operands)
                continue
            # plain instruction
            if op == "dot":
                t.flops += self._dot_flops(comp, ins)
            elif op in _TRANSCENDENTAL:
                n = _shape_elems(ins.type_str)
                t.flops += n
                t.transcendentals += n
            elif op in _ELEMENTWISE:
                t.flops += _shape_elems(ins.type_str)
            if count_bytes:
                t.bytes += self._plain_bytes(comp, ins)
        self._memo[key] = t
        return t

    # -- slice-aware HBM byte accounting ------------------------------------
    # TPU buffer assignment updates dynamic-update-slice in place and reads
    # only the addressed window of dynamic-slice/gather; counting whole
    # operands charged a 32k-KV-cache decode step 9.8 TB/device of phantom
    # traffic (§Perf cell B analysis).

    def _plain_bytes(self, comp, ins) -> float:
        op = ins.op
        if op == "dynamic-slice":
            return 2.0 * _shape_bytes(ins.type_str)
        if op == "dynamic-update-slice":
            upd = _shape_bytes(self.defs[comp].get(ins.operands[1], "")) \
                if len(ins.operands) > 1 else 0
            return 2.0 * upd
        if op == "gather":
            return 2.0 * _shape_bytes(ins.type_str)
        return _shape_bytes(ins.type_str) + sum(
            _shape_bytes(self.defs[comp].get(o, ""))
            for o in ins.operands)

    _PASS_THROUGH = ("convert", "copy", "bitcast", "transpose", "reshape",
                     "negate", "multiply", "add")

    def _sliced_reads(self, pname, consumers) -> float | None:
        """Bytes actually read from param `pname` if every use reaches a
        dynamic-slice/gather through pass-through ops (else None = full)."""
        total = 0.0
        stack = [pname]
        seen = set()
        while stack:
            nm = stack.pop()
            if nm in seen:
                continue
            seen.add(nm)
            for c in consumers.get(nm, []):
                if c.op in ("dynamic-slice", "gather"):
                    total += _shape_bytes(c.type_str)
                elif c.op == "dynamic-update-slice" and \
                        c.operands and c.operands[0] == nm:
                    continue          # in-place destination: no read
                elif c.op in self._PASS_THROUGH and \
                        _shape_bytes(c.type_str) >= 0:
                    # only safe if the pass-through op itself is later
                    # sliced; keep following
                    stack.append(c.name)
                else:
                    return None
        return total

    def _fusion_bytes(self, comp, ins, called: str) -> float:
        """Operand traffic of a fusion with in-place/windowed semantics:
        * operand consumed only through (chains ending in) dynamic-slice /
          gather -> charged at the sliced window size;
        * operand that is the destination of a root dynamic-update-slice
          (the scan/cache accumulator) -> charged 0 (aliased in place),
          with the root charged 2x the update size;
        * everything else -> full operand + result size (XLA's own
          convention)."""
        body = self.comps.get(called, [])
        defs = self.defs.get(called, {})
        param_name: dict[int, str] = {}
        consumers: dict[str, list[Instr]] = {}
        root = body[-1] if body else None
        for bi in body:
            if bi.op == "parameter":
                m = re.search(r"parameter\((\d+)\)", bi.line)
                if m:
                    param_name[int(m.group(1))] = bi.name
            for o in bi.operands:
                consumers.setdefault(o, []).append(bi)
        has_dus = any(bi.op == "dynamic-update-slice" for bi in body)
        result_bytes = _shape_bytes(ins.type_str)
        total = 0.0
        result_accounted = False
        for i, o in enumerate(ins.operands):
            full = _shape_bytes(self.defs[comp].get(o, ""))
            pname = param_name.get(i)
            if pname is None:
                total += full
                continue
            if has_dus and full == result_bytes and full > 0:
                # the big buffer flowing through a DUS fusion: in-place
                upd = sum(2.0 * _shape_bytes(defs.get(bi.operands[1], ""))
                          for bi in body
                          if bi.op == "dynamic-update-slice"
                          and len(bi.operands) > 1)
                total += upd
                result_accounted = True
                continue
            sliced = self._sliced_reads(pname, consumers)
            total += full if sliced is None else sliced
        if not result_accounted:
            total += result_bytes
        return total


    def attribute(self, comp: str | None = None, mult: float = 1.0):
        """Debug walk: per-instruction byte contributions x loop trips."""
        comp = comp or self.entry
        for ins in self.comps.get(comp, []):
            op = ins.op
            if op in _NO_TRAFFIC:
                continue
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if not op.endswith("-done"):
                    t = Totals()
                    self._collective(ins, t)
                    self.contrib.append((t.wire_bytes * mult, "COLL:" + base,
                                         ins.type_str, comp))
                continue
            if op == "while":
                body = _BODY_RE.search(ins.attrs)
                mt = _TRIP_RE.search(ins.attrs)
                trips = int(mt.group(1)) if mt else 1
                if body:
                    self.attribute(body.group(1), mult * trips)
                continue
            if op == "conditional":
                mb = _BRANCH_RE.search(ins.attrs)
                branches = re.findall(r"%?([\w\.\-]+)", mb.group(1)) if mb \
                    else _TF_RE.findall(ins.attrs)
                for b in branches[:1]:
                    self.attribute(b, mult)
                continue
            b = _shape_bytes(ins.type_str) + sum(
                _shape_bytes(self.defs[comp].get(o, ""))
                for o in ins.operands)
            self.contrib.append((b * mult, op, ins.type_str, comp))

    def analyze_with_top(self, k=20):
        t = self.analyze()
        self.attribute()
        return t, self.top_bytes(k)


def analyze_hlo(text: str) -> Totals:
    return HloModule(text).analyze()
