"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
cross-pod data parallelism and the ZeRO shard of the optimizer state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math
    need = math.prod(shape)
    devs = jax.devices()
    if len(devs) > need:
        import numpy as np
        return jax.sharding.Mesh(
            np.array(devs[:need]).reshape(shape), axes)
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """A small CPU mesh for tests / local runs (uses all local devices)."""
    import numpy as np
    devs = jax.devices()
    data = data or (len(devs) // model)
    return jax.sharding.Mesh(
        np.array(devs[:data * model]).reshape(data, model),
        ("data", "model"))


# Launch-time XLA flags we would set on real TPU pods (latency hiding /
# async collectives); recorded here so launch scripts and docs share one
# source of truth.  Harmless on CPU.
TPU_XLA_FLAGS = [
    "--xla_tpu_enable_async_collective_permute=true",
    "--xla_tpu_enable_async_all_gather=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_tpu_data_parallel_opt_different_sized_ops=true",
    "--xla_tpu_megacore_fusion_allow_ags=true",
]
