"""ShapeDtypeStruct stand-ins + NamedShardings for every dry-run cell.

`input_specs()` mirrors the real data pipeline's output structure: token ids
for text archs; precomputed frame/patch embeddings for the audio/vlm stub
frontends (the modality frontend is a STUB per the assignment).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import cache_shapedtypes, param_shapedtypes
from ..models.config import ModelConfig, ShapeConfig
from ..models.params import abstract_params
from ..models.lm import cache_abstract
from ..sharding import resolve_spec, tree_specs
from ..train.optim import opt_shapedtypes


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for the step function's data arguments."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    emb = jnp.dtype(cfg.compute_dtype)
    if shape.phase == "train":
        if cfg.frontend == "none":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), emb),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.phase == "prefill":
        if cfg.frontend == "none":
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        return {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), emb)}
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    def spec(sds):
        logical = ("batch",) + (None,) * (sds.ndim - 1) if sds.ndim else ()
        return NamedSharding(mesh, resolve_spec(sds.shape, logical, mesh))

    return jax.tree.map(spec, input_specs(cfg, shape))


def param_shardings(cfg: ModelConfig, mesh: Mesh, *,
                    fsdp_axes=("data",)):
    specs = tree_specs(abstract_params(cfg), mesh, fsdp_axes=fsdp_axes)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def opt_shardings(cfg: ModelConfig, mesh: Mesh, master: bool = False):
    """Optimizer moments (+ optional f32 master params): FSDP over
    (pod, data) when a pod axis exists (ZeRO across pods), else data."""
    fsdp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    pshard = param_shardings(cfg, mesh, fsdp_axes=fsdp)
    out = {"step": NamedSharding(mesh, P()), "m": pshard, "v": pshard}
    if master:
        out["master"] = pshard
    return out


def cache_shardings(cfg: ModelConfig, batch: int, max_seq: int, mesh: Mesh):
    ab = cache_abstract(cfg, batch, max_seq)
    ov = {"batch": [tuple(batch_axes(mesh))]}
    specs = {k: resolve_spec(d.shape, d.axes, mesh, overrides=ov)
             for k, d in ab.items()}
    return {k: NamedSharding(mesh, s) for k, s in specs.items()}


def cell_arguments(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """(shapedtypes, shardings) pairs for one dry-run cell, keyed by role.

    Training cells run bf16-at-rest parameters with an f32 master in the
    optimizer (the §Perf cell-C configuration)."""
    master = shape.phase == "train" and \
        jnp.dtype(cfg.param_dtype) == jnp.bfloat16
    out = {
        "params": (param_shapedtypes(cfg), param_shardings(cfg, mesh)),
        "batch": (input_specs(cfg, shape), batch_shardings(cfg, shape, mesh)),
    }
    psds = out["params"][0]
    if shape.phase == "train":
        out["opt"] = (opt_shapedtypes(psds, master=master),
                      opt_shardings(cfg, mesh, master=master))
    if shape.phase in ("prefill", "decode"):
        out["cache"] = (
            cache_shapedtypes(cfg, shape.global_batch, shape.seq_len),
            cache_shardings(cfg, shape.global_batch, shape.seq_len, mesh))
    return out
