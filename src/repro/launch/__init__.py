# NOTE: dryrun is intentionally NOT imported here -- it sets XLA_FLAGS for
# 512 placeholder devices at module import and must only run as __main__.
from .mesh import TPU_XLA_FLAGS, make_host_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_host_mesh", "TPU_XLA_FLAGS"]
