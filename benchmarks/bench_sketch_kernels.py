"""Device-side sketching throughput: the Pallas fast path vs the jnp
reference (interpret mode measures correctness-path overhead on CPU; the
roofline numbers for the TPU kernels come from the dry-run HLO analysis).
Also reports the analytic HBM-traffic advantage of the fused sketch kernel
(one pass) over the two-pass grid+argmin formulation -- the kernel-level
statement of the paper's "avoid materializing the hash grid" idea.
"""

from __future__ import annotations

import numpy as np

from repro.kernels.ref import minhash_sketch_ref

from .common import print_table, save_result, timed


def run(quick: bool = True) -> dict:
    rows = []
    shapes = [(8, 2048, 16), (16, 4096, 32)] if quick else \
        [(8, 2048, 16), (16, 4096, 32), (32, 8192, 64)]
    rng = np.random.default_rng(0)
    for B, N, K in shapes:
        tokens = rng.integers(0, 50_000, (B, N)).astype(np.int32)
        occ = rng.integers(1, 50, (B, N)).astype(np.int32)
        seeds = rng.integers(1, 2**31, (K,), dtype=np.uint32)
        out_ref, t_ref = timed(
            lambda: np.asarray(minhash_sketch_ref(tokens, occ, seeds)),
            repeat=2)
        toks_per_s = B * N * K / t_ref
        # fused-kernel HBM model: grid pass reads 3*(K*T)*4B + writes K*T*4B;
        # fused reads the same inputs once and writes K*3 words.
        grid_bytes = (3 * K * N + K * N) * 4 * B
        fused_bytes = (3 * K * N + 3 * K) * 4 * B
        rows.append({"B": B, "N": N, "K": K,
                     "xla_ref_s": t_ref,
                     "hash_per_s": toks_per_s,
                     "hbm_two_pass_MB": grid_bytes / 1e6,
                     "hbm_fused_MB": fused_bytes / 1e6,
                     "traffic_saving_%": 100 * (1 - fused_bytes / grid_bytes)})
    print_table("device sketching (XLA ref path; Pallas validated via "
                "interpret-mode tests)", rows)
    rec = {"rows": rows}
    save_result("sketch_kernels", rec)
    return rec
