"""§6 query-latency study: latency vs corpus size and threshold θ, plus
end-to-end recall of planted near-duplicates (the accuracy-guarantee side:
every subsequence with estimated Jaccard >= θ must be returned).
"""

from __future__ import annotations

import numpy as np

from repro.core import AlignmentIndex, query
from repro.core.oracle import jaccard_multiset
from repro.data.dedup import default_scheme

from .common import print_table, save_result, timed, zipf_text


def run(quick: bool = True) -> dict:
    rows_sz, rows_theta = [], []
    k = 8
    sizes = [4, 16] if quick else [4, 16, 64]
    for n_docs in sizes:
        scheme = default_scheme("multiset", seed=31, k=k)
        docs = [zipf_text(1200, seed=300 + i) for i in range(n_docs)]
        idx = AlignmentIndex(scheme=scheme).build(docs)
        qtext = docs[0][100:220].copy()
        res, t = timed(lambda: query(idx, qtext, 0.6), repeat=3)
        rows_sz.append({"docs": n_docs, "windows": idx.num_windows,
                        "query_s": t, "hits": len(res)})

    scheme = default_scheme("multiset", seed=32, k=k)
    docs = [zipf_text(1500, seed=400 + i) for i in range(8)]
    idx = AlignmentIndex(scheme=scheme).build(docs)
    qtext = docs[3][200:320].copy()
    for theta in (0.3, 0.6, 0.9):
        res, t = timed(lambda: query(idx, qtext, theta), repeat=3)
        rows_theta.append({"theta": theta, "query_s": t,
                           "result_cells": sum(a.num_cells for a in res)})

    # recall of a planted exact sub-duplicate at theta=0.9
    found = any(a.text_id == 3 for a in query(idx, qtext, 0.9))

    print_table("query latency vs corpus size (theta=0.6)", rows_sz)
    print_table("query latency vs theta", rows_theta)
    claims = {
        "planted_dup_found_at_high_theta": bool(found),
        "results_monotone_in_theta": all(
            rows_theta[i]["result_cells"] >= rows_theta[i + 1]["result_cells"]
            for i in range(len(rows_theta) - 1)),
    }
    rec = {"vs_size": rows_sz, "vs_theta": rows_theta, "claims": claims}
    save_result("query", rec)
    return rec
