"""§6 query-latency study: latency vs corpus size and threshold θ, plus
end-to-end recall of planted near-duplicates (the accuracy-guarantee side:
every subsequence with estimated Jaccard >= θ must be returned).

Also benchmarks the serving-side index layouts: frozen CSR arrays vs the
mutable dict-of-lists build layout (resident bytes + single-query latency),
and the batched query engine (`batch_query`) vs a per-query loop across
batch sizes — the MONO headline claims (index size, query throughput).
"""

from __future__ import annotations

import numpy as np

import tempfile
from pathlib import Path

from repro.core import IndexBuilder, SearchIndex, batch_query, make_scheme, \
    query

from .common import print_table, save_result, timed, zipf_text


def _blocks(results):
    return [(a.text_id, a.blocks) for a in results]


def run(quick: bool = True) -> dict:
    rows_sz, rows_theta = [], []
    k = 8
    sizes = [4, 16] if quick else [4, 16, 64]
    for n_docs in sizes:
        scheme = make_scheme("multiset", seed=31, k=k)
        docs = [zipf_text(1200, seed=300 + i) for i in range(n_docs)]
        idx = IndexBuilder(scheme=scheme).build(docs)
        qtext = docs[0][100:220].copy()
        res, t = timed(lambda: query(idx, qtext, 0.6), repeat=3)
        rows_sz.append({"docs": n_docs, "windows": idx.num_windows,
                        "query_s": t, "hits": len(res)})

    scheme = make_scheme("multiset", seed=32, k=k)
    docs = [zipf_text(1500, seed=400 + i) for i in range(8)]
    idx = IndexBuilder(scheme=scheme).build(docs)
    qtext = docs[3][200:320].copy()
    for theta in (0.3, 0.6, 0.9):
        res, t = timed(lambda: query(idx, qtext, theta), repeat=3)
        rows_theta.append({"theta": theta, "query_s": t,
                           "result_cells": sum(a.num_cells for a in res)})

    # recall of a planted exact sub-duplicate at theta=0.9
    found = any(a.text_id == 3 for a in query(idx, qtext, 0.9))

    # ---- frozen CSR layout vs dict layout + batched query engine ----------
    # serving configuration: the paper's default sketch width (k = 16)
    scheme = make_scheme("multiset", seed=33, k=16)
    n_docs = 24 if quick else 64
    docs = [zipf_text(900, seed=500 + i) for i in range(n_docs)]
    dict_idx = IndexBuilder(scheme=scheme).build(docs)
    frozen_idx = dict_idx.freeze()
    dict_bytes, frozen_bytes = dict_idx.nbytes(), frozen_idx.nbytes()

    theta = 0.6
    rng = np.random.default_rng(7)

    def make_queries(n):
        offs = rng.integers(0, 700, size=n)
        return [docs[i % n_docs][int(o):int(o) + 120].copy()
                for i, o in enumerate(offs)]

    q1 = make_queries(1)[0]
    _, t_dict = timed(lambda: query(dict_idx, q1, theta), repeat=3)
    _, t_frozen = timed(lambda: query(frozen_idx, q1, theta), repeat=3)
    rows_frozen = [
        {"layout": "dict", "index_MB": dict_bytes / 1e6, "query_s": t_dict},
        {"layout": "frozen_csr", "index_MB": frozen_bytes / 1e6,
         "query_s": t_frozen},
    ]

    # save -> mmap-load -> query: the versioned-store serving path (PR 2);
    # arrays stay on disk and page in through the OS cache
    with tempfile.TemporaryDirectory() as tmp:
        store = str(Path(tmp) / "idx")
        _, t_save = timed(lambda: frozen_idx.save(store))
        mmap_idx, t_load = timed(lambda: SearchIndex.load(store, mmap=True))
        mmap_res, t_mmap = timed(lambda: query(mmap_idx, q1, theta), repeat=3)
        mmap_equal = _blocks(mmap_res) == _blocks(query(frozen_idx, q1, theta))
        rows_frozen.append({"layout": "mmap_store",
                            "index_MB": frozen_bytes / 1e6,
                            "query_s": t_mmap})
        rows_mmap = [{"save_s": t_save, "load_s": t_load, "query_s": t_mmap,
                      "mmap_backed": mmap_idx.is_mmap(),
                      "equal": mmap_equal}]

    batch_sizes = [1, 4, 16] if quick else [1, 4, 16, 64]
    rows_batch, speedup_at, equal_all = [], {}, True
    for bs in batch_sizes:
        qs = make_queries(bs)
        loop_res, t_loop = timed(
            lambda: [query(dict_idx, q, theta) for q in qs], repeat=2)
        bat_res, t_bat = timed(
            lambda: batch_query(frozen_idx, qs, theta), repeat=2)
        equal = [_blocks(r) for r in loop_res] == [_blocks(r) for r in bat_res]
        equal_all = equal_all and equal
        speedup_at[bs] = t_loop / t_bat
        rows_batch.append({"batch": bs, "looped_s": t_loop,
                           "batched_s": t_bat, "speedup": t_loop / t_bat,
                           "batched_qps": bs / t_bat, "equal": equal})

    print_table("query latency vs corpus size (theta=0.6)", rows_sz)
    print_table("query latency vs theta", rows_theta)
    print_table("index layout: dict vs frozen CSR vs mmap store", rows_frozen)
    print_table("save -> mmap-load -> query (versioned store)", rows_mmap)
    print_table("batched query engine vs per-query loop (theta=0.6)",
                rows_batch)
    claims = {
        "planted_dup_found_at_high_theta": bool(found),
        "results_monotone_in_theta": all(
            rows_theta[i]["result_cells"] >= rows_theta[i + 1]["result_cells"]
            for i in range(len(rows_theta) - 1)),
        "frozen_index_smaller_than_dict": frozen_bytes < dict_bytes,
        "batched_equals_looped": bool(equal_all),
        "batched_speedup_ge_3x_at_16": speedup_at[16] >= 3.0,
        "mmap_store_serves_identically": bool(mmap_equal)
        and bool(rows_mmap[0]["mmap_backed"]),
    }
    rec = {"vs_size": rows_sz, "vs_theta": rows_theta,
           "layouts": rows_frozen, "mmap_store": rows_mmap,
           "batched": rows_batch, "claims": claims}
    save_result("query", rec)
    return rec
