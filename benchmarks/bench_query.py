"""§6 query-latency study: latency vs corpus size and threshold θ, plus
end-to-end recall of planted near-duplicates (the accuracy-guarantee side:
every subsequence with estimated Jaccard >= θ must be returned).

Also benchmarks the serving-side index layouts: frozen CSR arrays vs the
mutable dict-of-lists build layout (resident bytes + single-query latency),
the batched query engine (`batch_query`) vs a per-query loop across batch
sizes — the MONO headline claims (index size, query throughput) — and the
fused probe arena (PR 3): a B ∈ {1, 16, 64, 256} sweep of the one-shot
arena probe + grouped sweep against the PR-2 per-coordinate probe loop, a
serial-vs-threaded sharded fan-out row, and a Zipf-distributed query
workload row (the ROADMAP warm-path study).
"""

from __future__ import annotations

import numpy as np

import tempfile
from pathlib import Path

from repro.core import IndexBuilder, QueryOptions, SearchIndex, \
    ShardedAlignmentIndex, batch_query, make_scheme, query

from .common import print_table, save_result, timed, zipf_text


def _blocks(results):
    return [(a.text_id, a.blocks) for a in results]


def _dup_corpus(rng, n_docs, doc_len, n_pass, pass_len):
    """Distinctive (large-vocab) docs, each carrying one planted duplicate
    passage — the near-duplicate serving regime: queries hit a handful of
    texts with small (query, text) window groups."""
    passages = [rng.integers(0, 1 << 20, size=pass_len).astype(np.int64)
                for _ in range(n_pass)]
    docs = []
    for i in range(n_docs):
        base = rng.integers(0, 1 << 20, size=doc_len).astype(np.int64)
        o = int(rng.integers(0, doc_len - pass_len))
        base[o:o + pass_len] = passages[i % n_pass]
        docs.append(base)
    return passages, docs


def _passage_queries(rng, passages, n, q_len=90):
    pass_len = len(passages[0])
    out = []
    for _ in range(n):
        p = passages[int(rng.integers(0, len(passages)))]
        o = int(rng.integers(0, pass_len - q_len))
        out.append(p[o:o + q_len].copy())
    return out


def run(quick: bool = True) -> dict:
    rows_sz, rows_theta = [], []
    k = 8
    sizes = [4, 16] if quick else [4, 16, 64]
    for n_docs in sizes:
        scheme = make_scheme("multiset", seed=31, k=k)
        docs = [zipf_text(1200, seed=300 + i) for i in range(n_docs)]
        idx = IndexBuilder(scheme=scheme).build(docs)
        qtext = docs[0][100:220].copy()
        res, t = timed(lambda: query(idx, qtext, 0.6), repeat=3)
        rows_sz.append({"docs": n_docs, "windows": idx.num_windows,
                        "query_s": t, "hits": len(res)})

    scheme = make_scheme("multiset", seed=32, k=k)
    docs = [zipf_text(1500, seed=400 + i) for i in range(8)]
    idx = IndexBuilder(scheme=scheme).build(docs)
    qtext = docs[3][200:320].copy()
    for theta in (0.3, 0.6, 0.9):
        res, t = timed(lambda: query(idx, qtext, theta), repeat=3)
        rows_theta.append({"theta": theta, "query_s": t,
                           "result_cells": sum(a.num_cells for a in res)})

    # recall of a planted exact sub-duplicate at theta=0.9
    found = any(a.text_id == 3 for a in query(idx, qtext, 0.9))

    # ---- frozen CSR layout vs dict layout + batched query engine ----------
    # serving configuration: the paper's default sketch width (k = 16)
    scheme = make_scheme("multiset", seed=33, k=16)
    n_docs = 24 if quick else 64
    docs = [zipf_text(900, seed=500 + i) for i in range(n_docs)]
    dict_idx = IndexBuilder(scheme=scheme).build(docs)
    frozen_idx = dict_idx.freeze()
    dict_bytes, frozen_bytes = dict_idx.nbytes(), frozen_idx.nbytes()

    theta = 0.6
    rng = np.random.default_rng(7)

    def make_queries(n):
        offs = rng.integers(0, 700, size=n)
        return [docs[i % n_docs][int(o):int(o) + 120].copy()
                for i, o in enumerate(offs)]

    q1 = make_queries(1)[0]
    _, t_dict = timed(lambda: query(dict_idx, q1, theta), repeat=3)
    _, t_frozen = timed(lambda: query(frozen_idx, q1, theta), repeat=3)
    rows_frozen = [
        {"layout": "dict", "index_MB": dict_bytes / 1e6, "query_s": t_dict},
        {"layout": "frozen_csr", "index_MB": frozen_bytes / 1e6,
         "query_s": t_frozen},
    ]

    # save -> mmap-load -> query: the versioned-store serving path (PR 2);
    # arrays stay on disk and page in through the OS cache
    with tempfile.TemporaryDirectory() as tmp:
        store = str(Path(tmp) / "idx")
        _, t_save = timed(lambda: frozen_idx.save(store))
        mmap_idx, t_load = timed(lambda: SearchIndex.load(store, mmap=True))
        mmap_res, t_mmap = timed(lambda: query(mmap_idx, q1, theta), repeat=3)
        mmap_equal = _blocks(mmap_res) == _blocks(query(frozen_idx, q1, theta))
        rows_frozen.append({"layout": "mmap_store",
                            "index_MB": frozen_bytes / 1e6,
                            "query_s": t_mmap})
        rows_mmap = [{"save_s": t_save, "load_s": t_load, "query_s": t_mmap,
                      "mmap_backed": mmap_idx.is_mmap(),
                      "equal": mmap_equal}]

    batch_sizes = [1, 4, 16] if quick else [1, 4, 16, 64]
    rows_batch, speedup_at, equal_all = [], {}, True
    for bs in batch_sizes:
        qs = make_queries(bs)
        loop_res, t_loop = timed(
            lambda: [query(dict_idx, q, theta) for q in qs], repeat=2)
        bat_res, t_bat = timed(
            lambda: batch_query(frozen_idx, qs, theta), repeat=2)
        equal = [_blocks(r) for r in loop_res] == [_blocks(r) for r in bat_res]
        equal_all = equal_all and equal
        speedup_at[bs] = t_loop / t_bat
        rows_batch.append({"batch": bs, "looped_s": t_loop,
                           "batched_s": t_bat, "speedup": t_loop / t_bat,
                           "batched_qps": bs / t_bat, "equal": equal})

    # ---- fused probe arena vs the PR-2 per-coordinate probe loop ----------
    # near-duplicate serving workload: many short distinctive docs, queries
    # hitting the planted duplicates with small window groups (the regime
    # the grouped small-sweep dispatcher and one-shot probe target)
    rng2 = np.random.default_rng(11)
    k2, theta2 = 16, 0.5
    n_docs2, doc_len2 = (96, 200) if quick else (240, 320)
    n_pass2, pass_len2 = (16, 110) if quick else (40, 160)
    passages, dup_docs = _dup_corpus(rng2, n_docs2, doc_len2, n_pass2,
                                     pass_len2)
    scheme2 = make_scheme("multiset", seed=35, k=k2)
    arena_idx = IndexBuilder(scheme=scheme2).build(dup_docs).freeze()
    rows_arena, arena_speedup_at, arena_equal = [], {}, True
    for bs in (1, 16, 64, 256):
        qs = _passage_queries(rng2, passages, bs)
        sk = scheme2.sketch_batch(qs)   # shared: isolate the probe + sweep
        pr2_res, t_pr2 = timed(
            lambda: batch_query(arena_idx, qs, theta2,
                                options=QueryOptions(
                                    sketches=sk, probe_backend="percoord",
                                    sweep="loop")),
            repeat=3)
        new_res, t_new = timed(
            lambda: batch_query(arena_idx, qs, theta2,
                                options=QueryOptions(sketches=sk)),
            repeat=3)
        equal = [_blocks(r) for r in pr2_res] == \
            [_blocks(r) for r in new_res]
        if bs == 16:   # device-probe parity datapoint (interpret mode)
            pal_res = batch_query(arena_idx, qs, theta2,
                                  options=QueryOptions(
                                      sketches=sk, probe_backend="pallas"))
            equal = equal and \
                [_blocks(r) for r in pal_res] == [_blocks(r) for r in new_res]
        arena_equal = arena_equal and equal
        arena_speedup_at[bs] = t_pr2 / t_new
        rows_arena.append({"batch": bs, "percoord_s": t_pr2,
                           "arena_s": t_new, "speedup": t_pr2 / t_new,
                           "arena_qps": bs / t_new, "equal": equal})

    # ---- execution plans: cpu pipeline vs fused device pipeline ----------
    # plan="device" keeps the arena resident, probes + sweeps on-device
    # (interpret mode off-TPU) and must stay block-for-block identical to
    # plan="cpu"; the sweep also records the residency soak (arena uploads
    # across batches must not grow)
    from repro.core.device_plan import reset_transfer_stats, transfer_stats
    rows_plan, plan_equal = [], True
    reset_transfer_stats()
    for bs in (16, 64):
        qs = _passage_queries(rng2, passages, bs)
        sk = scheme2.sketch_batch(qs)
        cpu_res, t_cpu = timed(
            lambda: batch_query(arena_idx, qs, theta2,
                                options=QueryOptions(plan="cpu",
                                                     sketches=sk)),
            repeat=3)
        dev_res, t_dev = timed(
            lambda: batch_query(arena_idx, qs, theta2,
                                options=QueryOptions(plan="device",
                                                     sketches=sk)),
            repeat=3)
        equal = [_blocks(r) for r in cpu_res] == [_blocks(r) for r in dev_res]
        plan_equal = plan_equal and equal
        rows_plan.append({"batch": bs, "cpu_s": t_cpu, "device_s": t_dev,
                          "device_qps": bs / t_dev, "equal": equal})
    plan_soak = transfer_stats()

    # ---- sharded fan-out: serial loop vs thread-pool overlap --------------
    # sketches are computed once and shared by both paths (and by every
    # shard), so the row isolates the per-shard probe + sweep fan-out
    n_shards = 4
    fanout_B = 256
    sharded = ShardedAlignmentIndex(scheme=scheme2, n_shards=n_shards)
    sharded.build(dup_docs).freeze()
    fan_qs = _passage_queries(rng2, passages, fanout_B)
    fan_sk = scheme2.sketch_batch(fan_qs)
    # warm-up: builds the per-shard arenas and the fan-out thread pool so
    # neither timed path pays one-time setup
    sharded.batch_query(fan_qs[:8], theta2,
                        options=QueryOptions(sketches=fan_sk[:8]))
    ser_res, t_serial = timed(
        lambda: sharded.batch_query(
            fan_qs, theta2,
            options=QueryOptions(sketches=fan_sk, fanout="serial")),
        repeat=5)
    thr_res, t_threaded = timed(
        lambda: sharded.batch_query(
            fan_qs, theta2,
            options=QueryOptions(sketches=fan_sk, fanout="threaded")),
        repeat=5)
    fanout_equal = [_blocks(r) for r in ser_res] == \
        [_blocks(r) for r in thr_res]
    rows_fanout = [{"fanout": "serial", "shards": n_shards,
                    "batch": fanout_B, "batch_s": t_serial,
                    "qps": fanout_B / t_serial},
                   {"fanout": "threaded", "shards": n_shards,
                    "batch": fanout_B, "batch_s": t_threaded,
                    "qps": fanout_B / t_threaded}]

    # ---- Zipf-distributed query traffic (warm-path / mmap eviction study) -
    # a small popular set dominates: repeated probes re-touch the same arena
    # pages (page-cache warm path) vs a uniform spread of the pool
    pool = _passage_queries(rng2, passages, 32)
    zipf_B = 128 if quick else 512
    ranks = np.minimum(rng2.zipf(1.2, size=zipf_B) - 1, len(pool) - 1)
    zipf_qs = [pool[int(r)] for r in ranks]
    uni_qs = [pool[i % len(pool)] for i in range(zipf_B)]
    zsk = scheme2.sketch_batch(zipf_qs)
    usk = scheme2.sketch_batch(uni_qs)
    _, t_zipf = timed(lambda: batch_query(arena_idx, zipf_qs, theta2,
                                          options=QueryOptions(sketches=zsk)),
                      repeat=3)
    _, t_uni = timed(lambda: batch_query(arena_idx, uni_qs, theta2,
                                         options=QueryOptions(sketches=usk)),
                     repeat=3)
    rows_zipf = [{"workload": "zipf(1.2)", "batch": zipf_B,
                  "distinct_queries": int(len(np.unique(ranks))),
                  "batch_s": t_zipf, "qps": zipf_B / t_zipf},
                 {"workload": "uniform", "batch": zipf_B,
                  "distinct_queries": len(pool),
                  "batch_s": t_uni, "qps": zipf_B / t_uni}]

    print_table("query latency vs corpus size (theta=0.6)", rows_sz)
    print_table("query latency vs theta", rows_theta)
    print_table("index layout: dict vs frozen CSR vs mmap store", rows_frozen)
    print_table("save -> mmap-load -> query (versioned store)", rows_mmap)
    print_table("batched query engine vs per-query loop (theta=0.6)",
                rows_batch)
    print_table("probe arena vs PR-2 per-coordinate probes (theta=0.5)",
                rows_arena)
    print_table("execution plans: cpu vs fused device (theta=0.5)",
                rows_plan)
    print_table(f"sharded fan-out: serial vs threaded (B={fanout_B})",
                rows_fanout)
    print_table("Zipf vs uniform query traffic (probe arena)", rows_zipf)
    claims = {
        "planted_dup_found_at_high_theta": bool(found),
        "results_monotone_in_theta": all(
            rows_theta[i]["result_cells"] >= rows_theta[i + 1]["result_cells"]
            for i in range(len(rows_theta) - 1)),
        "frozen_index_smaller_than_dict": frozen_bytes < dict_bytes,
        "batched_equals_looped": bool(equal_all),
        "batched_speedup_ge_3x_at_16": speedup_at[16] >= 3.0,
        "mmap_store_serves_identically": bool(mmap_equal)
        and bool(rows_mmap[0]["mmap_backed"]),
        "probe_arena_equals_percoord_and_pallas": bool(arena_equal),
        "probe_arena_speedup_ge_2x_at_64": arena_speedup_at[64] >= 2.0,
        # device pipeline parity is bit-exact by construction (host f64
        # sketch + integer-exact kernels); residency means the arena
        # crossed the bus at most once across the whole multi-batch sweep
        "device_plan_equals_cpu": bool(plan_equal),
        "device_arena_uploaded_once": plan_soak["arena_uploads"] <= 1
        and plan_soak["batches"] >= 2,
        # parity on small 2-core CI runners; the overlap win needs real
        # cores / cold mmap pages.  The gate exists to catch pathological
        # contention (a GIL-convoyed sweep measured 2.2x serial), so the
        # slack is sized for noisy shared runners, not for 5% wins
        "threaded_fanout_no_worse": bool(fanout_equal)
        and t_threaded <= t_serial * 1.25,
    }
    rec = {"vs_size": rows_sz, "vs_theta": rows_theta,
           "layouts": rows_frozen, "mmap_store": rows_mmap,
           "batched": rows_batch, "probe_arena": rows_arena,
           "probe_arena_speedup": arena_speedup_at,
           "execution_plans": rows_plan, "device_plan_soak": plan_soak,
           "sharded_fanout": rows_fanout, "zipf_traffic": rows_zipf,
           "claims": claims}
    save_result("query", rec)
    return rec
