"""Index-construction study — the paper's headline claim is BUILD speed
(MONO constructs its index up to 26x faster than AllAlign at equal serving
quality), so this suite times the two build pipelines end-to-end
(tokens -> frozen CSR tables):

* ``dict``     — the incremental ``IndexBuilder``: per-window boxed tuples
  into dict tables, then a full dict re-walk in ``freeze()``.
* ``columnar`` — the batch ``ColumnarBuilder``: vectorized columnar key
  generation, chunked per-table window columns, one global stable sort per
  table (``FrozenTable.from_packed_columns``).

Both pipelines must produce *block-identical* frozen arrays (the
``columnar_freeze_block_identical`` claim), and the columnar path must be
>= 2x faster at the default bench size (``columnar_build_speedup_ge_2x``).
A serial-vs-process sharded build row covers the fan-out path (spawn
workers pay ~1s startup, so the win only shows on corpora that dwarf it —
the row is informational, the equality of its outputs is asserted).
"""

from __future__ import annotations

import numpy as np

from repro.core import ColumnarBuilder, IndexBuilder, \
    ShardedAlignmentIndex, make_scheme

from .common import print_table, save_result, timed, zipf_text


def _tables_identical(a, b) -> bool:
    """Bit-for-bit equality of two frozen indexes' CSR arrays."""
    if len(a.tables) != len(b.tables):
        return False
    for ta, tb in zip(a.tables, b.tables):
        if ta.kind != tb.kind or ta.kint_min != tb.kint_min:
            return False
        if not (np.array_equal(ta.keys, tb.keys)
                and np.array_equal(ta.offsets, tb.offsets)
                and np.array_equal(ta.windows, tb.windows)):
            return False
    return True


def run(quick: bool = True) -> dict:
    k = 16
    sizes = [(12, 700), (24, 900)] if quick else [(24, 900), (96, 1500)]
    rows, speedup_at, identical_all = [], {}, True
    for n_docs, doc_len in sizes:
        scheme = make_scheme("multiset", seed=33, k=k)
        docs = [zipf_text(doc_len, seed=500 + i) for i in range(n_docs)]

        def build_dict():
            idx = IndexBuilder(scheme=scheme).build(docs)
            return idx, idx.freeze()

        def build_columnar():
            builder = ColumnarBuilder(scheme=scheme).build(docs)
            return builder, builder.freeze()

        # best-of-2: the dict baseline is pure-Python-bound and the
        # columnar path NumPy-bound, so they respond differently to CPU
        # contention on shared CI runners — one retry keeps the gated
        # speedup ratio from dipping on a single noisy measurement
        (dict_idx, fz_dict), t_dict = timed(build_dict, repeat=2)
        (col_idx, fz_col), t_col = timed(build_columnar, repeat=2)
        identical = _tables_identical(fz_dict, fz_col)
        identical_all = identical_all and identical
        speedup_at[n_docs] = t_dict / t_col
        rows.append({"docs": n_docs, "doc_len": doc_len,
                     "windows": dict_idx.num_windows,
                     "dict_s": t_dict, "columnar_s": t_col,
                     "speedup": t_dict / t_col,
                     "dict_MB": dict_idx.nbytes() / 1e6,
                     "columnar_MB": col_idx.nbytes() / 1e6,
                     "identical": identical})

    # weighted-Jaccard datapoint (ICWS keygen + pair-packed tables take a
    # different columnar path than the uint64 multiset keys)
    w_scheme = make_scheme("weighted", seed=34, k=k)
    w_docs = [zipf_text(500, seed=700 + i) for i in range(8 if quick else 24)]
    def build_dict_weighted():
        idx = IndexBuilder(scheme=w_scheme).build(w_docs)
        return idx, idx.freeze()

    def build_columnar_weighted():
        builder = ColumnarBuilder(scheme=w_scheme).build(w_docs)
        return builder, builder.freeze()

    (_wd_builder, w_fzd), t_wd = timed(build_dict_weighted)
    (_wc_builder, w_fzc), t_wc = timed(build_columnar_weighted)
    w_identical = _tables_identical(w_fzd, w_fzc)
    identical_all = identical_all and w_identical
    rows_weighted = [{"scheme": "weighted", "docs": len(w_docs),
                      "dict_s": t_wd, "columnar_s": t_wc,
                      "speedup": t_wd / t_wc, "identical": w_identical}]

    # ---- sharded columnar build: serial vs process-pool fan-out -----------
    n_shards = 4
    sh_docs = docs            # largest corpus from the size sweep
    serial_idx, t_serial = timed(
        lambda: ShardedAlignmentIndex(
            scheme=scheme, n_shards=n_shards).build(
                sh_docs, pipeline="columnar", fanout="serial"))
    process_idx, t_process = timed(
        lambda: ShardedAlignmentIndex(
            scheme=scheme, n_shards=n_shards).build(
                sh_docs, pipeline="columnar", fanout="process"))
    sharded_equal = all(
        _tables_identical(serial_idx.shards[s], process_idx.shards[s])
        for s in range(n_shards))
    rows_sharded = [
        {"fanout": "serial", "shards": n_shards, "build_s": t_serial,
         "equal": True},
        {"fanout": "process", "shards": n_shards, "build_s": t_process,
         "equal": sharded_equal},
    ]

    print_table("build pipeline: dict vs columnar (multiset, k=16)", rows)
    print_table("build pipeline: weighted Jaccard", rows_weighted)
    print_table(f"sharded columnar build fan-out (docs={len(sh_docs)})",
                rows_sharded)

    default_size = sizes[-1][0]
    claims = {
        # the paper's headline territory: construction speed.  Gate at 2x
        # on the default bench size; observed ~2.4x locally
        "columnar_build_speedup_ge_2x": speedup_at[default_size] >= 2.0,
        # the whole point of sharing one serving layout: both pipelines
        # freeze to np.array_equal CSR arrays on every table
        "columnar_freeze_block_identical": bool(identical_all),
        "sharded_process_equals_serial": bool(sharded_equal),
    }
    rec = {"sizes": rows, "weighted": rows_weighted,
           "sharded_fanout": rows_sharded,
           "speedup": speedup_at, "claims": claims}
    save_result("build", rec)
    return rec
