"""Figure 7 reproduction: MonoActive vs AllAlign (the SIGMOD'21 greedy
state-of-the-art) -- partition size, build time, query latency, and the
paper's ratio plots, vs n and vs f (multi-set Jaccard).
"""

from __future__ import annotations

from repro.core import (IndexBuilder, MultisetScheme, UniversalHash,
                        allalign_multiset, mono_active_multiset, query)

from .common import controlled_f_text, print_table, save_result, timed, \
    zipf_text


def run(quick: bool = True) -> dict:
    hashers = UniversalHash.from_seed(21, 2)
    rows_n, rows_f, rows_q = [], [], []

    ns = [1000, 3000, 10000] if quick else [1000, 3000, 10000, 30000, 100000]
    for n in ns:
        text = zipf_text(n, seed=7)
        pa, t_aa = timed(lambda: [allalign_multiset(text, h)
                                  for h in hashers])
        pm, t_ma = timed(lambda: [mono_active_multiset(text, h)
                                  for h in hashers])
        wa = sum(len(p) for p in pa)
        wm = sum(len(p) for p in pm)
        rows_n.append({"n": n, "allalign_win": wa, "mono_win": wm,
                       "win_reduction_%": 100 * (1 - wm / wa),
                       "allalign_s": t_aa, "mono_s": t_ma,
                       "speedup": t_aa / t_ma})

    n = 5000
    fs = [10, 100, 500] if quick else [10, 100, 500, 1500, 3000]
    for f in fs:
        text = controlled_f_text(n, f, seed=8)
        pa, t_aa = timed(lambda: [allalign_multiset(text, h)
                                  for h in hashers])
        pm, t_ma = timed(lambda: [mono_active_multiset(text, h)
                                  for h in hashers])
        wa = sum(len(p) for p in pa)
        wm = sum(len(p) for p in pm)
        rows_f.append({"f": f, "allalign_win": wa, "mono_win": wm,
                       "win_reduction_%": 100 * (1 - wm / wa),
                       "allalign_s": t_aa, "mono_s": t_ma,
                       "speedup": t_aa / t_ma})

    # query latency: same index contents, different partition methods
    k = 8
    docs = [zipf_text(2000, seed=200 + i) for i in range(5)]
    qtext = docs[1][300:420].copy()
    for method in ("mono_active", "allalign"):
        scheme = MultisetScheme(seed=9, k=k)
        idx = IndexBuilder(scheme=scheme, method=method).build(docs)
        res, t = timed(lambda: query(idx, qtext, 0.6), repeat=3)
        rows_q.append({"method": method, "windows": idx.num_windows,
                       "query_s": t, "hits": len(res)})

    print_table("Fig7(a-d,m-p): MonoActive vs AllAlign vs n", rows_n)
    print_table("Fig7 vs f (n=5000)", rows_f)
    print_table("Fig7(e,f,q,r): query latency", rows_q)

    claims = {
        "mono_fewer_windows_everywhere": all(r["win_reduction_%"] > 0
                                             for r in rows_n + rows_f),
        "reduction_grows_with_n": rows_n[-1]["win_reduction_%"]
        >= rows_n[0]["win_reduction_%"] - 1.0,
        "mono_query_not_slower": rows_q[0]["query_s"]
        <= 1.2 * rows_q[1]["query_s"],
        "same_hits": rows_q[0]["hits"] == rows_q[1]["hits"],
    }
    rec = {"vs_n": rows_n, "vs_f": rows_f, "query": rows_q, "claims": claims}
    save_result("vs_allalign", rec)
    return rec
