"""Shared benchmark utilities: controlled text generation, timing, tables."""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).resolve().parent.parent / "results" / "bench"


def zipf_text(n: int, *, alpha: float = 1.3, vocab: int = 50_257,
              seed: int = 0) -> np.ndarray:
    """OWT-like token stream: Zipf-distributed ids (BPE-ish frequencies)."""
    rng = np.random.default_rng(seed)
    t = rng.zipf(alpha, size=n)
    return np.minimum(t - 1, vocab - 1).astype(np.int64)


def controlled_f_text(n: int, f: int, *, seed: int = 0) -> np.ndarray:
    """Length-n text where every token appears ~f times (max frequency f)."""
    v = max(1, n // f)
    rng = np.random.default_rng(seed)
    t = np.repeat(np.arange(v, dtype=np.int64), f)[:n]
    if len(t) < n:
        t = np.concatenate([t, rng.integers(0, v, n - len(t))])
    rng.shuffle(t)
    return t


def timed(fn, *args, repeat: int = 1, **kw):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


def save_result(name: str, record: dict):
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{name}.json").write_text(json.dumps(record, indent=1,
                                                     default=str))


def print_table(title: str, rows: list[dict]):
    if not rows:
        print(f"== {title}: no rows ==")
        return
    cols = list(rows[0].keys())
    widths = {c: max(len(str(c)), *(len(_fmt(r[c])) for r in rows))
              for c in cols}
    print(f"\n== {title} ==")
    print("  ".join(str(c).ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r[c]).ljust(widths[c]) for c in cols))


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1000 or abs(v) < 0.001:
            return f"{v:.3g}"
        return f"{v:.4g}"
    return str(v)
